"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on
TPU v5e constants:

  compute    = HLO_FLOPs_per_chip / 197e12        (bf16 MXU peak)
  memory     = HLO_bytes_per_chip / 819e9         (HBM bandwidth)
  collective = wire_bytes_per_chip / 50e9         (ICI per link)

FLOPs and bytes come from ``compiled.cost_analysis()`` of the
post-SPMD per-device module.  Collective wire bytes are parsed from
the compiled HLO text: for each all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute we take the payload
shape and apply the ring-algorithm wire factor over the op's
replica-group size g:

  all-reduce      2 * (g-1)/g * bytes      (reduce-scatter + all-gather)
  all-gather      (g-1)/g * bytes          (bytes = full output)
  reduce-scatter  (g-1)/g * bytes          (bytes = full input)
  all-to-all      (g-1)/g * bytes
  collective-permute  bytes

Caveats, recorded once here: cost_analysis "bytes accessed" counts
operand+result of every HLO op, which over-counts HBM for fusion-
resident values — treat the memory term as an upper bound; collective
bytes assume ring scheduling on a single link (v5e has multiple ICI
links; wrap-around meshes halve hop counts), so the collective term is
also conservative.
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))        # [ngroups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


def _wire_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    return {"all-reduce": 2 * frac, "all-gather": frac,
            "reduce-scatter": frac, "all-to-all": frac,
            "collective-permute": 1.0}[op]


@dataclass
class CollectiveStats:
    ops: dict = field(default_factory=dict)      # op -> count
    payload_bytes: int = 0
    wire_bytes: float = 0.0

    def to_dict(self):
        return {"ops": self.ops, "payload_bytes": self.payload_bytes,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    """Sum payload/wire bytes of every collective in the HLO text."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "-done" in line:
            continue                   # async pair: count the start only
        b = _shape_bytes(shape_str)
        g = _group_size(line, default_group)
        st.ops[op] = st.ops.get(op, 0) + 1
        st.payload_bytes += b
        st.wire_bytes += b * _wire_factor(op, g)
    return st


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    n_chips: int
    model_flops: float = 0.0          # 6*N*D (or 2*N*D decode)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips * HLO flops): remat/redundancy waste."""
        denom = self.flops * self.n_chips
        return (self.model_flops / denom) if denom else 0.0

    @property
    def mfu_bound(self) -> float:
        """Roofline-bounded MFU: useful flops / peak at t_bound."""
        if self.t_bound == 0:
            return 0.0
        return self.model_flops / (self.n_chips * PEAK_FLOPS
                                   * self.t_bound)

    def to_dict(self):
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "wire_bytes_per_chip": self.wire_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def model_flops_for(kind: str, n_params_active: float, n_tokens: float,
                    n_embedding: float = 0.0) -> float:
    """6ND training / 2ND inference, excluding embedding lookups."""
    body = n_params_active - n_embedding
    per_tok = 6.0 * body if kind == "train" else 2.0 * body
    return per_tok * n_tokens
