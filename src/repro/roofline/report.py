"""Render the roofline table from dry-run artifacts.

    PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]

Produces the EXPERIMENTS.md §Roofline markdown: one row per
(arch x shape) with the three terms, dominant bottleneck, model-flops
ratio and the roofline-bounded MFU, plus per-cell one-line "what would
move the dominant term" guidance derived from the bottleneck class.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

GUIDANCE = {
    ("train", "compute"): "at MXU roof — gains only from removing "
        "redundant flops (remat policy, causal-block skipping)",
    ("train", "memory"): "cut activation traffic: flash-attention "
        "custom-vjp (drop T^2 score buffers), bf16 residual saves",
    ("train", "collective"): "re-balance mesh: less TP for this size "
        "(d_model/16 too thin) or overlap dp-allreduce with backward",
    ("prefill", "memory"): "fuse attention pipeline; larger q-chunks; "
        "keep KV in bf16",
    ("prefill", "collective"): "sequence-parallel attention instead of "
        "TP-only; all-gather KV once per layer",
    ("prefill", "compute"): "at roof; only layout tweaks left",
    ("decode", "memory"): "weights+KV streaming bound — expected for "
        "batch-limited decode; raise batch or quantize KV",
    ("decode", "collective"): "TP all-reduce per token dominates; "
        "wider data-parallel serving or ICI-aware layout",
    ("decode", "compute"): "unusual for decode; check batching",
}


def load_cells(d: Path):
    cells = []
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        cells.append(r)
    return cells


def shape_kind(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill",
            "decode_32k": "decode", "long_500k": "decode",
            "graph500": "graph"}.get(shape, "train")


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def render(cells, mesh: str) -> str:
    rows = []
    header = ("| arch | shape | t_compute | t_memory | t_collective | "
              "bottleneck | MODEL/HLO flops | MFU bound |\n"
              "|---|---|---|---|---|---|---|---|")
    for r in cells:
        if r.get("mesh") != mesh:
            continue
        if r["status"].startswith("skip"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"N/A (skip) | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"FAILED | — | — |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['t_compute_s'])} "
            f"| {fmt_s(ro['t_memory_s'])} | {fmt_s(ro['t_collective_s'])} "
            f"| {ro['bottleneck']} | {ro['useful_flops_ratio']:.2f} "
            f"| {ro['mfu_bound']*100:.1f}% |")
    return header + "\n" + "\n".join(rows)


def render_guidance(cells, mesh: str) -> str:
    lines = []
    for r in cells:
        if r.get("mesh") != mesh or r["status"] != "ok":
            continue
        kind = shape_kind(r["shape"])
        if kind == "graph":
            continue
        g = GUIDANCE.get((kind, r["roofline"]["bottleneck"]), "")
        lines.append(f"- **{r['arch']} x {r['shape']}**: {g}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--guidance", action="store_true")
    args = ap.parse_args()
    cells = load_cells(Path(args.dir))
    print(render(cells, args.mesh))
    if args.guidance:
        print()
        print(render_guidance(cells, args.mesh))


if __name__ == "__main__":
    main()
