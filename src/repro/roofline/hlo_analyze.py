"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` counts every while-loop body ONCE — with
``lax.scan`` over 40 layers, chunked attention, and grad-accumulation
loops, that understates flops/bytes/collective traffic by 1-2 orders
of magnitude.  This module re-derives the three roofline inputs from
the compiled (post-SPMD) HLO text with loop multiplication:

  * computations are parsed into op lists with a per-computation
    symbol table (operand refs are bare names in compiled HLO);
  * ``while`` ops multiply their body+cond cost by the trip count
    (greatest integer constant in the condition computation — the form
    XLA emits for counted loops; falls back to 1 and is recorded);
  * ``fusion``/``map``/``reduce``/``sort`` bodies contribute flops but
    not bytes (fusion-internal values are register/VMEM resident); the
    fusion op itself reads operands + writes outputs once — a tighter
    HBM model than cost_analysis's "bytes accessed";
  * flops: 2*prod(out)*K per ``dot`` (K = product of lhs contracting
    dim sizes, looked up through the symbol table);
  * collectives: payload bytes x ring wire factor x loop trips, with
    group size parsed from replica_groups (iota or explicit form).

Validated against analytic 6ND model flops in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_HDR = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_REF_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVE_BASES = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "copy-start", "copy-done"}


def shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(s: str) -> int:
    n_total = 0
    for _, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        n_total += n
    return n_total


def _shape_dims(s: str) -> list[int]:
    m = _SHAPE_RE.search(s)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    rest: str          # everything after the opening paren

    @property
    def operand_str(self) -> str:
        return self.rest.split(")")[0]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_payload: float = 0.0
    coll_ops: dict = field(default_factory=dict)
    unresolved_whiles: int = 0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire_bytes += o.wire_bytes
        self.coll_payload += o.coll_payload
        self.unresolved_whiles += o.unresolved_whiles
        for k, v in o.coll_ops.items():
            self.coll_ops[k] = self.coll_ops.get(k, 0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.wire_bytes * k,
                    self.coll_payload * k,
                    {n: v * k for n, v in self.coll_ops.items()},
                    self.unresolved_whiles)


def parse_computations(hlo: str):
    """-> {comp_name: (ops, symtab name->out_type)}"""
    comps: dict[str, tuple[list[Op], dict[str, str]]] = {}
    cur = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR.match(stripped)
                if m:
                    name = m.group(1)
                    comps[name] = ([], {})
                    cur = name
                    if stripped.startswith("ENTRY"):
                        entry = name
            continue
        if stripped == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(name=m.group(1), out_type=m.group(2),
                    opcode=m.group(3), rest=m.group(4))
            comps[cur][0].append(op)
            comps[cur][1][op.name] = op.out_type
    return comps, entry


def _wire_factor(base: str, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    return {"all-reduce": 2 * frac, "all-gather": frac,
            "reduce-scatter": frac, "all-to-all": frac,
            "collective-permute": 1.0}[base]


class Analyzer:
    def __init__(self, hlo: str, default_group: int = 1):
        self.comps, self.entry = parse_computations(hlo)
        if self.entry is None and self.comps:
            self.entry = next(reversed(self.comps))
        self.default_group = default_group
        self._memo: dict[tuple[str, bool], Cost] = {}

    # -- helpers ----------------------------------------------------------
    def _operand_bytes(self, op: Op, symtab) -> int:
        total = 0
        for ref in _REF_RE.findall(op.operand_str):
            t = symtab.get(ref)
            if t:
                total += shape_bytes(t)
        return total

    def _dot_flops(self, op: Op, symtab) -> float:
        out_elems = shape_elems(op.out_type)
        refs = _REF_RE.findall(op.operand_str)
        k = 1
        if refs:
            lhs_dims = _shape_dims(symtab.get(refs[0], ""))
            m = _LHS_CDIMS_RE.search(op.rest)
            if m and m.group(1):
                for idx in m.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
        return 2.0 * out_elems * k

    def _group_size(self, op: Op) -> int:
        m = _GROUPS_IOTA_RE.search(op.rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(op.rest)
        if m:
            return max(1, m.group(1).count(",") + 1)
        return self.default_group

    def _trip_count(self, cond_name: str | None) -> int:
        if not cond_name or cond_name not in self.comps:
            return 0
        consts = []
        for op in self.comps[cond_name][0]:
            for c in _CONST_RE.findall(op.rest + op.out_type):
                consts.append(int(c))
            if op.opcode == "constant":
                m = re.search(r"\b(\d+)\b", op.rest)
                if m:
                    consts.append(int(m.group(1)))
        return max(consts) if consts else 0

    # -- recursion --------------------------------------------------------
    def comp_cost(self, name: str, include_bytes: bool) -> Cost:
        key = (name, include_bytes)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()   # cycle guard
        total = Cost()
        ops, symtab = self.comps.get(name, ([], {}))
        for op in ops:
            total += self.op_cost(op, symtab, include_bytes)
        self._memo[key] = total
        return total

    def op_cost(self, op: Op, symtab, include_bytes: bool) -> Cost:
        c = Cost()
        oc = op.opcode
        base = oc.replace("-start", "")

        if oc == "dot":
            c.flops += self._dot_flops(op, symtab)
            if include_bytes:
                c.bytes += self._operand_bytes(op, symtab) \
                    + shape_bytes(op.out_type)
            return c

        if base in COLLECTIVE_BASES and not oc.endswith("-done"):
            payload = shape_bytes(op.out_type)
            g = self._group_size(op)
            c.coll_payload += payload
            c.wire_bytes += payload * _wire_factor(base, g)
            c.coll_ops[base] = c.coll_ops.get(base, 0) + 1
            if include_bytes:
                c.bytes += self._operand_bytes(op, symtab) \
                    + shape_bytes(op.out_type)
            return c

        if oc == "while":
            mb = _BODY_RE.search(op.rest)
            mc = _COND_RE.search(op.rest)
            body = mb.group(1) if mb else None
            cond = mc.group(1) if mc else None
            trips = self._trip_count(cond)
            if trips == 0:
                trips = 1
                c.unresolved_whiles += 1
            inner = Cost()
            if body and body in self.comps:
                inner += self.comp_cost(body, include_bytes)
            if cond and cond in self.comps:
                inner += self.comp_cost(cond, include_bytes)
            inner = inner.scaled(trips)
            inner.unresolved_whiles += c.unresolved_whiles
            return inner

        if oc == "conditional":
            m = _BRANCHES_RE.search(op.rest)
            if m:
                names = re.findall(r"%?([\w\.\-]+)", m.group(1))
                costs = [self.comp_cost(n, include_bytes)
                         for n in names if n in self.comps]
                if costs:
                    c += max(costs, key=lambda x: x.flops + x.bytes)
            return c

        if oc == "call":
            for sub in _CALLS_RE.findall(op.rest):
                if sub in self.comps:
                    c += self.comp_cost(sub, include_bytes)
            # fall through to count the call's own IO

        # fusion / map / reduce / sort bodies: flops yes, bytes no
        if oc != "call":
            for sub in _CALLS_RE.findall(op.rest):
                if sub in self.comps:
                    c += self.comp_cost(sub, False)
        if include_bytes and oc not in _SKIP_BYTES_OPS:
            c.bytes += self._io_bytes(op, symtab)
        return c

    def _io_bytes(self, op: Op, symtab) -> float:
        """HBM traffic model with in-place awareness.

        * dynamic-update-slice writes a slice in place: traffic = 2x
          the update operand, not the destination buffer (scan/map
          accumulators would otherwise be counted per iteration);
        * dynamic-slice reads only the slice it produces;
        * fusions: each operand that the fused computation consumes
          ONLY via dynamic-slice is charged the slice sizes (gathers
          of stacked layer activations by the backward pass read one
          layer, not all L); fusions whose root is a DUS on operand 0
          write the update, not the whole aliased buffer.
        """
        oc = op.opcode
        if oc == "dynamic-update-slice":
            refs = _REF_RE.findall(op.operand_str)
            upd = shape_bytes(symtab.get(refs[1], "")) if len(refs) > 1 \
                else 0
            return 2.0 * upd
        if oc in ("dynamic-slice", "slice"):
            return 2.0 * shape_bytes(op.out_type)
        if oc == "fusion":
            return self._fusion_io_bytes(op, symtab)
        return self._operand_bytes(op, symtab) + shape_bytes(op.out_type)

    def _fusion_io_bytes(self, op: Op, symtab) -> float:
        refs = _REF_RE.findall(op.operand_str)
        m = _CALLS_RE.search(op.rest)
        sub = m.group(1) if m else None
        if sub not in self.comps:
            return self._operand_bytes(op, symtab) \
                + shape_bytes(op.out_type)
        sub_ops, sub_symtab = self.comps[sub]
        # parameter index -> parameter op name
        param_name: dict[int, str] = {}
        for sop in sub_ops:
            if sop.opcode == "parameter":
                mm = re.match(r"\s*(\d+)", sop.rest)
                if mm:
                    param_name[int(mm.group(1))] = sop.name
        total = 0.0
        dus_written = None
        shape_ops = ("bitcast", "reshape", "copy", "transpose")
        for i, ref in enumerate(refs):
            full = shape_bytes(symtab.get(ref, ""))
            pname = param_name.get(i)
            if pname is None:
                total += full
                continue
            # follow the param through shape-only ops to its real
            # consumers (bitcast->slice chains are common post-SPMD)
            names = {pname}
            grew = True
            while grew:
                grew = False
                for sop in sub_ops:
                    if sop.opcode in shape_ops \
                            and sop.name not in names \
                            and names & set(_REF_RE.findall(
                                sop.operand_str)):
                        names.add(sop.name)
                        grew = True
            uses = [sop for sop in sub_ops
                    if sop.opcode not in ("parameter",) + shape_ops
                    and names & set(_REF_RE.findall(sop.operand_str))]
            if uses and all(u.opcode in ("dynamic-slice", "slice")
                            for u in uses):
                total += sum(shape_bytes(u.out_type) for u in uses)
            elif uses and all(u.opcode == "dynamic-update-slice"
                              and _REF_RE.findall(u.operand_str)[0]
                              == pname for u in uses):
                # aliased in-place destination: charge written slices
                w = sum(shape_bytes(sub_symtab.get(
                    _REF_RE.findall(u.operand_str)[1], ""))
                    for u in uses if len(_REF_RE.findall(
                        u.operand_str)) > 1)
                total += 0.0
                dus_written = (dus_written or 0.0) + w
            else:
                total += full
        out = shape_bytes(op.out_type)
        if dus_written is not None:
            out = min(out, dus_written if dus_written > 0 else out)
        return total + out

    def total(self) -> Cost:
        return self.comp_cost(self.entry, True)


def analyze(hlo: str, default_group: int = 1) -> Cost:
    return Analyzer(hlo, default_group).total()
