"""repro.bfs — the public BFS surface.

One declarative configuration object (`TraversalSpec`) and a
plan/compile/run layer (`plan` -> `CompiledTraversal`) sit behind
every entry point:

    import repro.bfs as bfs

    spec = bfs.TraversalSpec(policy="beamer", max_layers=128)
    ct = bfs.plan(graph, spec)        # autos resolved ONCE, cached jit
    res = ct.run(17)                  # or ct.run_batched([3, 7, 11])
    ct.resolved                       # the concrete spec that ran
    ct.stats(res)                     # Table 1 per-layer counters

The legacy loose-knob entry points (`repro.core.engine.traverse*`,
`bfs_parallel.run_bfs`, ...) survive as thin shims over the same plan
cache; new code should use this module.  ``__all__`` is the frozen
public surface (tests/test_api_surface.py fails CI on accidental
changes).
"""
from __future__ import annotations

from repro.api.plan import (CompiledTraversal, cache_info as
                            plan_cache_info, clear_cache as
                            clear_plan_cache, plan)
from repro.api.spec import POLICIES, TraversalSpec
from repro.core.bfs_parallel import parents_graph500
from repro.core.engine import (BeamerHybrid, BfsState, EngineResult,
                               LayerStats, PaperLiteralLayers,
                               ThresholdSimd, TopDown, direction_log,
                               layer_stats, traverse)
from repro.obs.trace import SpanTracer, TraceRun, trace_run

__all__ = [
    "BeamerHybrid",
    "BfsState",
    "CompiledTraversal",
    "EngineResult",
    "LayerStats",
    "POLICIES",
    "PaperLiteralLayers",
    "SpanTracer",
    "ThresholdSimd",
    "TopDown",
    "TraceRun",
    "TraversalSpec",
    "clear_plan_cache",
    "direction_log",
    "layer_stats",
    "parents_graph500",
    "plan",
    "plan_cache_info",
    "trace_run",
    "traverse",
]
