"""Substrate: checkpoint."""
