"""Sharded checkpointing with atomic commits and elastic restore.

Layout:
    <dir>/step_000123/
        manifest.json        tree structure + leaf index + metadata
        leaf_00000.npy ...   one file per pytree leaf
    <dir>/LATEST             committed step pointer (atomic rename)

Properties the 1000-node story needs:
  * atomic: a checkpoint becomes visible only when LATEST is renamed
    over — a killed job never sees a torn checkpoint;
  * elastic: arrays are saved mesh-independently (gathered logical
    values), so a checkpoint from mesh M1 restores onto any M2 —
    ``restore(..., shardings=...)`` re-shards on load (tested across
    mesh shapes in tests/test_checkpoint.py);
  * keep_n garbage collection;
  * step-indexed, so the data pipeline (pure function of step) resumes
    bit-exactly.

On a real multi-host pod each host writes its address-able shards and
manifest writing is rank-0-only; the single-process container exercises
the same code path with host_count=1 (the multihost hooks are the
``host_id``/``n_hosts`` fields).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str | Path, step: int, tree, *, host_id: int = 0,
         keep_n: int = 3, metadata: dict | None = None) -> Path:
    """Write a checkpoint; atomic LATEST commit; GC old steps."""
    directory = Path(directory)
    tmp = directory / f".tmp_step_{step:09d}"
    final = directory / f"step_{step:09d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    index = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        index.append({"file": f"leaf_{i:05d}.npy",
                      "shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(treedef, "serialize_using_proto") else None,
        "n_leaves": len(leaves),
        "index": index,
        "time": time.time(),
        "host_id": host_id,
        "metadata": metadata or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    latest_tmp = directory / ".LATEST.tmp"
    latest_tmp.write_text(str(step))
    os.rename(latest_tmp, directory / "LATEST")
    _gc(directory, keep_n)
    return final


def _gc(directory: Path, keep_n: int):
    steps = sorted(p for p in directory.glob("step_*") if p.is_dir())
    for p in steps[:-keep_n]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str | Path) -> int | None:
    latest = Path(directory) / "LATEST"
    if not latest.exists():
        return None
    return int(latest.read_text().strip())


def restore(directory: str | Path, tree_like, *, step: int | None = None,
            shardings=None):
    """Load into the structure of ``tree_like``.

    ``shardings``: optional pytree of Shardings — the ELASTIC path:
    leaves are device_put with the new mesh's sharding regardless of
    the mesh that saved them.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in "
                                    f"{directory}")
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"checkpoint has {manifest['n_leaves']} leaves, " \
        f"model expects {len(leaves_like)}"
    arrs = []
    for i, (entry, like) in enumerate(zip(manifest["index"],
                                          leaves_like)):
        arr = np.load(d / entry["file"])
        assert tuple(arr.shape) == tuple(like.shape), \
            f"leaf {i}: ckpt {arr.shape} vs model {like.shape}"
        arrs.append(arr)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_indices_map")
            or hasattr(x, "memory_kind"))
        arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
    else:
        arrs = [jax.numpy.asarray(a) for a in arrs]
    return jax.tree_util.tree_unflatten(treedef, arrs), \
        manifest["metadata"], step


class CheckpointManager:
    """Every-N-steps saving with keep_n retention."""

    def __init__(self, directory: str | Path, every: int = 100,
                 keep_n: int = 3):
        self.directory = Path(directory)
        self.every = every
        self.keep_n = keep_n

    def maybe_save(self, step: int, tree, metadata=None) -> bool:
        if step % self.every != 0:
            return False
        save(self.directory, step, tree, keep_n=self.keep_n,
             metadata=metadata)
        return True

    def restore_latest(self, tree_like, shardings=None):
        return restore(self.directory, tree_like, shardings=shardings)
