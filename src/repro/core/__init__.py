"""The paper's primary contribution: race-tolerant, vectorized BFS.

Layers:
  bitmap         bit-array frontier/visited sets (§3.3.1)
  rmat           Graph500 Kronecker generator (§5.2)
  csr            padded CSR + alignment policy (§3.3.1, §4.2)
  bfs_serial     Algorithm 1 oracle
  engine         unified fused traversal engine + direction policies
  bfs_parallel   Algorithms 2/3 wrapper (scalar expanders)
  bfs_vectorized §4 SIMD pipeline wrapper (ThresholdSimd/PaperLiteral)
  bfs_hybrid     direction-optimizing wrapper (BeamerHybrid policy)
  bfs_distributed shard_map multi-chip BFS (engine step pieces)
  validate       Graph500 soft validator (§5.3)
  stats          64-root TEPS harness (§5.3)
"""
from repro.core import bitmap, csr, rmat  # noqa: F401
