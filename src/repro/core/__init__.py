"""The paper's primary contribution: race-tolerant, vectorized BFS.

Layers:
  bitmap         bit-array frontier/visited sets (§3.3.1)
  rmat           Graph500 Kronecker generator (§5.2)
  csr            padded CSR + alignment policy (§3.3.1, §4.2)
  bfs_serial     Algorithm 1 oracle
  bfs_parallel   Algorithms 2/3 (restoration process) in jnp
  bfs_vectorized §4 SIMD pipeline backed by Pallas kernels
  bfs_hybrid     beyond-paper direction-optimizing BFS
  bfs_distributed shard_map multi-chip BFS
  validate       Graph500 soft validator (§5.3)
  stats          64-root TEPS harness (§5.3)
"""
from repro.core import bitmap, csr, rmat  # noqa: F401
