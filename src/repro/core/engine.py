"""Unified on-device BFS traversal engine with pluggable direction policies.

DESIGN
======
Every BFS variant in this repo — Algorithms 2/3 of the paper, the §4
vectorized pipeline, the Beamer-style hybrid, and the distributed
per-chip program — is the same per-layer pipeline:

    measure workload  ->  decide direction  ->  expand  ->  restore

This module is the single home of that pipeline.  The paper sections
map onto engine phases as follows:

* **measure** (`Workload`): §4.1's layer-adaptive decision input — the
  frontier vertex/edge counts of Table 1, computed *on device* from the
  bitmap (§3.3.1) and the CSR degree array.
* **decide** (`DirectionPolicy.decide`): which expansion flavour runs
  this layer.  ``MODE_SCALAR`` is the plain-jnp Algorithm 2/3 layer,
  ``MODE_SIMD`` the §4 Pallas kernel (Listing 1), ``MODE_BOTTOMUP`` the
  frontier-testing kernel of the hybrid extension (arXiv:1704.02259).
  Policies are small frozen objects deciding from on-device counters,
  so the decision traces into the fused loop — no host round-trip.
* **expand**: the racy gather-test-mask-scatter hot loop (§3.2, §3.3.2
  Fig. 6).  Two pipelines exist (the ``pipeline`` axis):

  - ``fused_gather`` (default, ISSUE 3) — HBM traffic proportional to
    the live frontier: a tiny on-device planning pass
    (`plan_active_tiles`) builds a work-list of the rows-blocks the
    frontier's adjacency touches, and the kernel
    (kernels/gather_expand.py) gathers candidate edges HBM->VMEM
    in-kernel, recomputing edge->owner with a binary search over the
    VMEM-resident ``colstarts``.  Inactive tiles are clamped to a
    sentinel block by the scalar-prefetched index map (the DMA is
    elided) and skipped by a ``pl.when`` guard, so a thin layer costs
    ~1 tile instead of E_pad/tile tiles.
  - ``materialized`` (legacy, kept for the ablation axis) — the
    apportionment machinery (`edge_stream`) writes a full-E ``(u, v,
    valid)`` stream to HBM which the kernel then re-reads.

  The scalar (plain-jnp) layer keeps the materialized apportionment in
  both pipelines; the batched kernels add a leading root axis so many
  searches expand in one launch.
* **restore** (§3.3.2, Alg. 3 lines 15-29): every vertex discovered
  this layer is identified by its negative ``P`` entry and its bit is
  re-set exactly — what makes the non-atomic vectorization legal.

Two drivers expose the pipeline:

* ``traverse``          — the **fused** engine: the whole search (all
  layers, all roots) is ONE ``lax.while_loop`` over statically padded
  buffers.  No host synchronization inside the layer loop; per-layer
  stats (Table 1 counters + chosen mode) are written into a preallocated
  on-device buffer and read back once after the loop.  Supports batched
  multi-root search via a leading root axis on every state array.
* ``traverse_hostloop``  — the legacy Python layer loop with
  power-of-two shape buckets (exact per-layer shapes, a few recompiles).
  Kept for A/B measurement of the removed layer-loop overhead
  (benchmarks/bfs_batched.py) and for workload studies.

The public drivers ``bfs_parallel.run_bfs``,
``bfs_vectorized.run_bfs_vectorized`` and ``bfs_hybrid.run_bfs_hybrid``
are thin wrappers selecting a policy; ``bfs_distributed`` builds its
shard_map per-chip step from `rowsweep_stream` + `candidate_scatter`.

The engine is **format-generic** (repro/formats/): the per-layer
expansion steps are built by the graph format object — CSR keeps the
apportioned edge stream below, SELL-C-σ substitutes its aligned slab
sweep (kernels/sell_expand.py), the bitmap layout its dense word
sweep.  `traverse` accepts a `Csr` or any built `GraphFormat`; the
measure/decide/restore pipeline is layout-independent.

Since ISSUE 4 packed uint32 words are the engine's **native**
frontier/visited representation through the whole layer, not just at
rest: workload counters come from word popcounts and the word-aligned
degree matrix (`bitmap.masked_degree_sum`), and every bitmap->queue
conversion (planning, apportionment input lists, bottom-up candidate
lists) runs the SIMD compaction kernel (kernels/compact.py — the §4
vectorized queue generation) instead of a dense ``unpack``/``nonzero``
round trip.  The legacy dense-mask arm survives behind
``packed=False`` as the parity/ablation baseline; ``prefetch_depth``
selects the gather kernels' manual double-buffered DMA input pipeline
(§4's prefetch distance as an explicit knob).
"""
from __future__ import annotations

import functools
import operator
import warnings
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core.csr import Csr, init_visited, padding_premarked_visited
from repro.kernels import ops

MODE_SCALAR = 0     # plain-jnp Algorithm 2/3 layer
MODE_SIMD = 1       # §4 Pallas expansion kernel (top-down)
MODE_BOTTOMUP = 2   # frontier-testing kernel (hybrid bottom-up)

MODE_NAMES = {MODE_SCALAR: "topdown", MODE_SIMD: "topdown",
              MODE_BOTTOMUP: "bottomup"}

PIPELINES = ("fused_gather", "materialized", "megakernel",
             "persistent")


def _record_degrade(site: str, reason: str, fallback: str,
                    detail: str = ""):
    """Emit an observable `obs.metrics.DegradeEvent` from a fallback
    decision (ISSUE 8).  Imported lazily: `repro.obs` pulls the plan
    layer at package-import time, which pulls this module — the
    runtime call happens long after both are loaded, so the lazy form
    is cycle-free where a top-level import would not be."""
    from repro.obs.metrics import record_degrade
    return record_degrade(site, reason, fallback, detail)

# on-device per-layer stats buffer columns
(_ST_FRONTIER, _ST_EDGES, _ST_DISCOVERED, _ST_MODE, _ST_ACTIVE,
 _ST_TILES, _ST_TRUNC, _ST_LAUNCH) = range(8)
_N_ST = 8


class BfsState(NamedTuple):
    frontier: jax.Array     # input bitmap (W,) uint32 — (B, W) batched
    visited: jax.Array      # visited bitmap (W,) uint32
    parent: jax.Array       # P, (V_pad,) int32; init = V ("infinity")
    layer: jax.Array        # scalar int32


class LayerStats(NamedTuple):
    layer: int
    frontier_vertices: int  # |in|  (Table 1 "Vertices")
    edges_examined: int     # Σ deg(in)  (Table 1 "Edges")
    discovered: int         # |out| (Table 1 "Traversed vertices")
    active_tiles: int = 0   # grid tiles of real work this layer
    #                         (batch-summed; the fused pipeline's
    #                         frontier-proportionality counter)
    truncated_edges: int = 0  # edges clamped by apportionment overflow
    launches: int = 0       # Pallas calls this layer issued (ISSUE 6:
    #                         megakernel = 1, fused_gather = 3, ...)


class StepAux(NamedTuple):
    """Per-layer accounting every format step returns with its state.

    ``tiles`` is the number of grid tiles (DMA units) of real work the
    step scheduled, summed over the root batch — the analytic
    bytes-moved counter that makes the fused pipeline's win visible in
    CI even in interpret mode.  ``truncated`` counts edges the
    apportionment clamped (hub-overflow; 0 on the fused path, which
    never apportions).  ``launches`` is the number of Pallas calls the
    step issues per layer — counted at trace time by wrapping the step
    body in `ops.count_launches`, so the figure is the measured ground
    truth, not a declaration that can drift (the megakernel's
    fusion win: 1 vs the unfused pipeline's 3)."""
    tiles: jax.Array        # int32 scalar
    truncated: jax.Array    # int32 scalar
    launches: jax.Array | int = 0  # int32 scalar (static per step)


class Workload(NamedTuple):
    """On-device counters a `DirectionPolicy` decides from (§4.1).

    In batched mode the counters are summed over the root batch **in
    float32**: per-root edge counts are int32-bounded (E < 2^31, the
    CSR invariant), but a batch of B roots can sum past 2^31; policies
    only take ratios/thresholds of these, so float32 precision is
    ample.  ``n_roots`` lets per-graph thresholds (Beamer's V/beta)
    scale to the batch.
    """
    layer: jax.Array                 # int32 scalar
    frontier_vertices: jax.Array     # scalar (batch-summed, may be f32)
    frontier_edges: jax.Array        # scalar (batch-summed, may be f32)
    unvisited_vertices: jax.Array    # scalar (0 unless needed)
    unvisited_edges: jax.Array       # scalar
    n_vertices: int                  # static |V|
    bottom_up: jax.Array             # bool scalar, previous direction
    n_roots: int = 1                 # static batch width


class EngineResult(NamedTuple):
    state: BfsState          # final state; batched arrays iff multi-root
    depths: jax.Array        # (B,) int32: layers each root stayed active
    stats: jax.Array         # (max_layers, _N_ST) int32 device buffer
    values: jax.Array | None = None  # semiring value matrix (B, V_pad)
    #                          — distances/labels/depth rows for the
    #                          algorithm portfolio (ISSUE 10); None on
    #                          the hard-wired BFS paths


# ---------------------------------------------------------------------------
# Direction policies
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TopDown:
    """Always the scalar top-down layer (Algorithms 2/3)."""
    modes = (MODE_SCALAR,)
    needs_unvisited = False

    def decide(self, w: Workload):
        return jnp.int32(MODE_SCALAR), jnp.asarray(False)


@dataclass(frozen=True)
class ThresholdSimd:
    """§4.1 adaptive policy: SIMD kernel on layers examining at least
    ``simd_threshold`` edges, scalar elsewhere."""
    simd_threshold: int = 16_384
    modes = (MODE_SCALAR, MODE_SIMD)
    needs_unvisited = False

    def decide(self, w: Workload):
        mode = jnp.where(w.frontier_edges >= self.simd_threshold,
                         MODE_SIMD, MODE_SCALAR)
        return mode.astype(jnp.int32), jnp.asarray(False)


@dataclass(frozen=True)
class PaperLiteralLayers:
    """The paper's literal §4.1 policy: SIMD on an explicit layer set
    (the "first two [fat] layers"), scalar elsewhere."""
    simd_layers: tuple[int, ...] = (1, 2)
    modes = (MODE_SCALAR, MODE_SIMD)
    needs_unvisited = False

    def decide(self, w: Workload):
        hit = functools.reduce(
            operator.or_, [w.layer == l for l in self.simd_layers],
            jnp.asarray(False))
        mode = jnp.where(hit, MODE_SIMD, MODE_SCALAR)
        return mode.astype(jnp.int32), jnp.asarray(False)


@dataclass(frozen=True)
class BeamerHybrid:
    """Direction-optimizing switch [Beamer 2012] with hysteresis:
    down when the frontier's out-edges exceed unexplored/alpha, back up
    when the frontier shrinks below V/beta.  Top-down layers use the
    SIMD kernel (the arXiv:1704.02259 hybrid vectorization)."""
    alpha: float = 14.0
    beta: float = 24.0
    modes = (MODE_SIMD, MODE_BOTTOMUP)
    needs_unvisited = True

    def decide(self, w: Workload):
        f_edges = w.frontier_edges.astype(jnp.float32)
        u_edges = w.unvisited_edges.astype(jnp.float32)
        f_count = w.frontier_vertices.astype(jnp.float32)
        switch_down = (~w.bottom_up) & (f_edges > u_edges / self.alpha)
        # V/beta scales by the batch width: counters are batch-summed
        switch_up = w.bottom_up & (
            f_count < w.n_vertices * w.n_roots / self.beta)
        bottom_up = jnp.where(switch_down, True,
                              jnp.where(switch_up, False, w.bottom_up))
        mode = jnp.where(bottom_up & (w.unvisited_vertices > 0),
                         MODE_BOTTOMUP, MODE_SIMD)
        return mode.astype(jnp.int32), bottom_up


# ---------------------------------------------------------------------------
# Shared per-layer building blocks
# ---------------------------------------------------------------------------

def apportion(csr_colstarts: jax.Array, csr_rows: jax.Array,
              frontier_list: jax.Array, n_vertices: int, n_slots: int):
    """Map ``n_slots`` edge slots onto the frontier's adjacency lists.

    frontier_list is sentinel-padded (id == n_vertices => empty).
    Returns (u, v, valid, truncated) — the streams are length n_slots;
    ``truncated`` is the int32 count of edges that did NOT fit (a hub
    whose adjacency overruns the remaining slots is clamped
    *deterministically* to its list prefix — the clip below — instead
    of silently corrupting owners; the counter surfaces the loss in
    `LayerStats.truncated_edges`).

    Owner lookup is a scatter + prefix-sum instead of a binary search:
    ``owner[slot] = #frontier vertices whose adjacency ends at or
    before slot`` = cumsum of end-offset markers.  A vectorized
    searchsorted lowers to a log2(F)-iteration while loop that re-reads
    the full slot array every pass (measured 16.3 GB/layer at SCALE-27
    per chip); the prefix-sum form is two passes (§Perf iteration 2).
    """
    is_real = frontier_list < n_vertices
    safe = jnp.where(is_real, frontier_list, 0)
    deg = jnp.where(is_real,
                    csr_colstarts[safe + 1] - csr_colstarts[safe], 0)
    cum = jnp.cumsum(deg, dtype=jnp.int32)
    total = cum[-1] if cum.shape[0] else jnp.int32(0)
    truncated = jnp.maximum(total - n_slots, 0).astype(jnp.int32)
    slots = jnp.arange(n_slots, dtype=jnp.int32)
    # scatter a marker at each vertex's END offset; prefix-sum counts
    # how many adjacency lists finished at or before each slot.  End
    # offsets past n_slots drop out, so slots inside an overflowing
    # hub's range keep that hub as owner: the clamp keeps the edge
    # prefix, deterministically.
    markers = (jnp.zeros((n_slots,), jnp.int32)
               .at[cum].add(1, mode="drop"))
    owner = jnp.cumsum(markers, dtype=jnp.int32)
    owner_c = jnp.clip(owner, 0, frontier_list.shape[0] - 1)
    prev = jnp.where(owner_c > 0, cum[jnp.maximum(owner_c - 1, 0)], 0)
    u = frontier_list[owner_c]
    valid = slots < total
    u_safe = jnp.where(valid, u, 0)
    e_idx = csr_colstarts[u_safe] + (slots - prev)
    e_idx = jnp.clip(e_idx, 0, csr_rows.shape[0] - 1)
    v = csr_rows[e_idx]
    return u.astype(jnp.int32), v, valid, truncated


def edge_stream(colstarts, rows, frontier_words, list_size: int,
                n_vertices: int, n_slots: int, packed: bool = False):
    """The engine's gather phase: bitmap -> apportioned
    (u, v, valid, truncated) — the *materialized* pipeline's stream.

    ``packed=True`` compacts the bitmap with the SIMD rank-and-scatter
    kernel (kernels/compact.py — the paper's §4 vectorized queue
    generation) instead of the dense ``unpack_bool`` + ``nonzero``
    round trip; the resulting queue is identical (ascending ids,
    sentinel-padded), so the streams are bit-for-bit equal.
    """
    if packed:
        frontier_list, _ = ops.frontier_compact(
            frontier_words, size=list_size, fill=n_vertices)
    else:
        frontier_list = bm.compact(frontier_words, list_size, n_vertices)
    return apportion(colstarts, rows, frontier_list, n_vertices, n_slots)


def rowsweep_stream(colstarts, rows, active_words, n_vertices: int,
                    nbr_limit: int | None = None):
    """(u, v, valid) in **rows order** — the jnp form of the fused
    in-kernel gather (kernels/gather_expand.py) and its oracle.

    Owners come from a degree-expansion of ``colstarts`` and the
    frontier gate is a bitmap test per edge — one pass over ``rows``
    with no compaction, no marker scatter and no prefix-sum
    intermediates (the apportionment machinery the fused pipeline
    removes).  ``nbr_limit`` bounds valid neighbor ids; it differs
    from ``n_vertices`` only in the distributed per-chip step, where
    owners live in LOCAL ids (< v_loc) but neighbors are GLOBAL.
    """
    nbr_limit = n_vertices if nbr_limit is None else nbr_limit
    e_pad = rows.shape[0]
    deg = colstarts[1:] - colstarts[:-1]
    u = jnp.repeat(jnp.arange(n_vertices, dtype=jnp.int32), deg,
                   total_repeat_length=e_pad)
    # padding slots carry sentinel neighbors, so the v-test alone
    # invalidates them regardless of the repeat's tail fill
    valid = bm.test_bits(active_words, u) & (rows < nbr_limit)
    return u, rows, valid


def compact_worklist(active, n: int):
    """Bool mask (n,) -> (worklist (n,) int32, n_active int32).

    The single home of the scalar-prefetch work-list contract every
    active-scheduled kernel assumes: active indices first, and every
    entry past ``n_active`` clamped to the LAST active index — the
    kernel's index map then feeds Mosaic an unchanged block index,
    which elides the repeated DMA (the sentinel-block trick that
    makes inactive tiles free; a ``pl.when`` guard skips their
    compute).  Shared by `plan_active_tiles` (CSR rows-blocks) and
    `formats.sell.SellFormat._plan_slab_steps` (slab groups).
    """
    n_active = active.sum(dtype=jnp.int32)
    (wl,) = jnp.nonzero(active, size=n, fill_value=0)
    wl = wl.astype(jnp.int32)
    last = wl[jnp.clip(n_active - 1, 0, n - 1)]
    wl = jnp.where(jnp.arange(n) < n_active, wl, last)
    return wl, n_active


def _mark_blocks(start, end, has, tile: int, n_blocks: int):
    """Range-mark + compact: the single home of the block-marking
    algorithm (+1/-1 difference scatter with drop sentinel, prefix
    sum, `compact_worklist`) shared by the queue-based (packed) and
    dense-mask planning arms — they differ only in how the active
    (start, end) adjacency ranges are produced."""
    blk_lo = start // tile
    blk_hi = (end - 1) // tile
    drop = n_blocks + 1
    diff = jnp.zeros((n_blocks + 1,), jnp.int32)
    diff = diff.at[jnp.where(has, blk_lo, drop)].add(1, mode="drop")
    diff = diff.at[jnp.where(has, blk_hi + 1, drop)].add(-1, mode="drop")
    covered = jnp.cumsum(diff)[:n_blocks] > 0
    return compact_worklist(covered, n_blocks)


def mark_blocks_from_queue(colstarts, queue, n_vertices: int, tile: int,
                           n_blocks: int):
    """Range-mark the rows-blocks a compacted vertex queue's adjacency
    touches.  The queue is sentinel-padded (id >= n_vertices => empty
    slot)."""
    is_real = queue < n_vertices
    safe = jnp.where(is_real, queue, 0)
    start = colstarts[safe]
    end = colstarts[safe + 1]
    return _mark_blocks(start, end, is_real & (end > start), tile,
                        n_blocks)


def plan_active_tiles(colstarts, active_words, n_vertices: int,
                      tile: int, n_blocks: int, packed: bool = False):
    """The fused pipeline's per-layer scheduling pass (one root).

    Marks every ``tile``-sized block of ``rows`` that intersects an
    active vertex's adjacency (range-mark via a +1/-1 difference
    scatter + prefix sum — no E-sized arrays) and compacts the marks
    into a `compact_worklist`.  Returns (worklist (n_blocks,) int32,
    n_active int32).

    ``packed=False`` (legacy) expands the bitmap to a dense V-mask and
    range-marks from it; ``packed=True`` compacts the bitmap with the
    SIMD kernel first (V/8 bytes of mask reads + a queue of the live
    vertices) and range-marks from the queue — the packed engine's
    planning arm.  Oversized working sets take the dense arm
    (`ops.compact_fits`), so huge graphs keep traversing like they
    did before the packed default — and since ISSUE 8 the fallback
    emits a ``serve.degrade.vmem_fallback`` `DegradeEvent` instead of
    happening silently.
    """
    v_pad = active_words.shape[0] * bm.BITS_PER_WORD
    if packed:
        if ops.compact_fits(1, v_pad):
            queue, _ = ops.frontier_compact(active_words, size=v_pad,
                                            fill=n_vertices)
            return mark_blocks_from_queue(colstarts, queue, n_vertices,
                                          tile, n_blocks)
        _record_degrade(
            "vmem_fallback",
            reason=ops.budget_detail(
                f"frontier_compact(1x{v_pad})",
                ops.compact_budget(1, v_pad)),
            fallback="dense planner (plan_active_tiles, packed arm "
                     "disabled)")
    dense = bm.unpack_bool(active_words)[:n_vertices]
    start, end = colstarts[:-1], colstarts[1:]
    return _mark_blocks(start, end, dense & (end > start), tile,
                        n_blocks)


def plan_active_tiles_batched(colstarts, active_words, n_vertices: int,
                              tile: int, n_blocks: int,
                              packed: bool = True):
    """Batched planning: (B, W) active bitmaps -> ((B, n_blocks)
    work-lists, (B,) live counts).  The packed arm runs ONE batched
    compaction launch then vmaps the pure-jnp block marking; the
    legacy arm (and any batch x V_pad working set past the compaction
    kernel's VMEM budget) vmaps the dense planner."""
    n_batch, w = active_words.shape
    v_pad = w * bm.BITS_PER_WORD
    if packed:
        if ops.compact_fits(n_batch, v_pad):
            queues, _ = ops.frontier_compact_batched(
                active_words, size=v_pad, fill=n_vertices)
            return jax.vmap(
                lambda q: mark_blocks_from_queue(colstarts, q,
                                                 n_vertices, tile,
                                                 n_blocks))(queues)
        _record_degrade(
            "vmem_fallback",
            reason=ops.budget_detail(
                f"frontier_compact({n_batch}x{v_pad})",
                ops.compact_budget(n_batch, v_pad)),
            fallback="dense planner (plan_active_tiles_batched, "
                     "packed arm disabled)")
    return jax.vmap(
        lambda a: plan_active_tiles(colstarts, a, n_vertices, tile,
                                    n_blocks, packed=False))(
        active_words)


def candidate_scatter(u, v, valid, visited, n_vertices: int, v_cap: int):
    """Encode a layer's discoveries as a min-parent candidate array.

    The deterministic merge primitive of the distributed engine step:
    INF (== n_vertices) everywhere, min discovering parent where a
    valid undiscovered candidate exists.  ``pmin``/``all_to_all`` of
    these arrays resolves inter-chip duplicates reproducibly.
    """
    undiscovered = ~bm.test_bits(visited, v)
    mask = valid & undiscovered & (v < n_vertices)
    idx = jnp.where(mask, v, v_cap)
    cand = jnp.full((v_cap,), n_vertices, jnp.int32)
    return cand.at[idx].min(u, mode="drop")


def restore_jnp(parent, out, visited, n_vertices: int):
    """Pure-jnp restoration (§3.3.2): repair racy bitmap drops from the
    negative P marks.  Returns (parent, out, visited) all fixed."""
    marked = parent < 0
    repaired = bm.pack_bool(marked)
    return (jnp.where(marked, parent + n_vertices, parent),
            out | repaired, visited | repaired)


@jax.jit
def row_popcounts(words):
    """Set-bit count over the trailing word axis: (B, W) -> (B,) or
    (W,) -> scalar.  The one popcount used by loop conditions, depth
    tracking, and the serve engine's finished-slot scan."""
    return jax.lax.population_count(words).astype(jnp.int32).sum(axis=-1)


def masked_edge_sum(dense, deg):
    """Σ deg over True lanes of a dense vertex mask (trailing V axis) —
    the Table 1 'Edges' counter (int32; E < 2^31 is a framework
    invariant asserted at CSR build)."""
    return jnp.where(dense, deg, 0).sum(axis=-1, dtype=jnp.int32)


def _next_pow2(n: int, lo: int = 128) -> int:
    n = max(int(n), lo)
    return 1 << (n - 1).bit_length()


def _auto_tile(e_size: int, interpret: bool) -> int:
    """The CSR edge-stream tile rule.

    Tile selection is owned by the graph *format* (the layout fixes
    the aligned unit — §4.2): `formats.CsrFormat.resolve_tile`
    delegates here, SELL fixes its slab geometry instead.  This
    module-level home survives for `traverse_hostloop`, whose
    ``tile=`` argument drives the A/B prefetch-distance sweeps.
    """
    if not interpret:
        return 1024
    # interpret mode unrolls the grid at trace time: keep it short
    return max(1024, e_size // 32)


_TILE_ENV = "REPRO_BFS_TILE"


def default_tile_csr(fmt=None) -> int:
    """The auto tile through the shared affinity mechanism
    (`formats.affinity.resolve` — ISSUE 6 generalized this PR-4
    one-off into the lookup every auto knob reads).  Priority:
    ``REPRO_BFS_TILE`` env override > the geometry-keyed committed
    row (when ``fmt`` is given) > the PR-4 flat ``affinity.tile<N>``
    rows > the legacy 1024 heuristic."""
    from repro.formats import affinity
    return int(affinity.resolve(fmt, "tile", 1024))


def _resolve_tile_csr(tile: int | None, e_pad: int, fmt=None) -> int:
    """The CSR tile rule (`formats.CsrFormat.resolve_tile`).

    The tile is the fused pipeline's DMA unit AND its prefetch
    distance (§4's knob); it bottoms out at 128 (one lane set) so
    small graphs still resolve to several blocks and the active-tile
    schedule has something to skip.  The auto choice comes from
    `default_tile_csr` (env override > the geometry-keyed BENCH
    affinity row for ``fmt`` > the flat sweep rows > 1024), capped at
    ``e_pad/8`` so small graphs keep >= 8 blocks to skip.  The
    interpret-mode floor keeps the unrolled grid <=32 steps, same
    budget as `_auto_tile`.
    """
    interpret = jax.default_backend() != "tpu"
    floor = max(128, e_pad // 32) if interpret else 128
    if tile is None:
        # auto tiles (table or env) never exceed the edge stream —
        # _pad_rows_to_tile pads rows UP to a tile multiple, so an
        # oversized tile would balloon the padded stream itself
        tile = max(128, min(default_tile_csr(fmt), max(e_pad // 8, 128)))
        tile = min(tile, max(e_pad, 128))
    return max(int(tile), floor)


# ---------------------------------------------------------------------------
# The three expansion flavours (batched: leading root axis on state)
# ---------------------------------------------------------------------------

def expand_candidates(u, v, valid, frontier, visited, parent,
                      n_vertices: int, algorithm: str, semiring=None,
                      vals=None):
    """The post-gather Algorithm 2/3 body on any layout's edge stream.

    The single home of the test-mask-scatter(-restore) sequence:
    ``(u, v, valid)`` is a gathered candidate stream — CSR's
    apportioned `edge_stream`, SELL's flattened slab sweep — and the
    body is layout-independent.  Returns (out, visited, parent).

    Passing a `repro.algorithms.semiring.Semiring` (with its ``vals``
    row) switches the body to the generic relaxation — the pure-jnp
    reference of the scatter-min kernels: fold each frontier edge's
    ``vals[u] ⊗ w`` candidate with ⊕ (= min, commutative: no race, no
    restoration), then resolve min-id parents against the finalized
    values.  Returns ``(improved_words, new_vals, parent)`` — the
    frontier-generation triple of `algorithms.traversal`.  With
    ``semiring=None`` (the BFS default) the bit test-and-set paths
    below run byte-identically to every release since ISSUE 1.
    """
    v_pad = parent.shape[0]
    if semiring is not None:
        in_front = bm.test_bits(frontier, u)
        mask = valid & in_front & (v < n_vertices)
        u_val = vals[jnp.clip(u, 0, v_pad - 1)]
        cand = semiring.mul(u_val, u, v)
        idx = jnp.where(mask, v, v_pad)
        new_vals = vals.at[idx].min(cand, mode="drop")
        cur = new_vals[jnp.clip(v, 0, v_pad - 1)]
        win = mask & (cand == cur) \
            & semiring.improved(vals[jnp.clip(v, 0, v_pad - 1)], cur)
        p_layer = jnp.full((v_pad,), jnp.iinfo(jnp.int32).max,
                           jnp.int32).at[jnp.where(win, v, v_pad)] \
            .min(u, mode="drop")
        improved = semiring.improved(vals, new_vals)
        parent = jnp.where(improved, p_layer, parent)
        return bm.pack_bool(improved), new_vals, parent
    if algorithm == "nonsimd":         # Algorithm 2: exact dense updates
        vis_dense = bm.unpack_bool(visited)
        mask = valid & ~vis_dense[jnp.clip(v, 0, v_pad - 1)]
        idx = jnp.where(mask, v, v_pad)
        parent = parent.at[idx].set(u, mode="drop")
        out_dense = (jnp.zeros((v_pad,), bool)
                     .at[idx].set(True, mode="drop"))
        out = bm.pack_bool(out_dense)
        return out, visited | out, parent
    # Algorithm 3: racy bitmap scatter + restoration
    undiscovered = ~(bm.test_bits(visited, v)
                     | bm.test_bits(frontier, v))
    mask = valid & undiscovered
    idx = jnp.where(mask, v, v_pad)
    parent = parent.at[idx].set(u - n_vertices, mode="drop")
    out = bm.set_bits_racy(bm.zeros(v_pad), v, mask)
    parent, out, visited = restore_jnp(parent, out, visited, n_vertices)
    return out, visited, parent


def scalar_expand(colstarts, rows, n_vertices: int, frontier, visited,
                  parent, f_size: int, e_size: int, algorithm: str):
    """One plain-jnp top-down CSR layer (Algorithm 2/3): apportioned
    gather + the shared `expand_candidates` body.  The hostloop driver
    and ``bfs_parallel.expand_*`` call this (single root, dense
    compaction — the legacy drivers); the fused engine's batched
    scalar step routes through `_batched_edge_stream` instead.
    Returns (out, visited, parent, truncated)."""
    u, v, valid, truncated = edge_stream(colstarts, rows, frontier,
                                         f_size, n_vertices, e_size)
    out, visited, parent = expand_candidates(
        u, v, valid, frontier, visited, parent, n_vertices, algorithm)
    return out, visited, parent, truncated


def _batched_edge_stream(colstarts, rows, frontier, list_size: int,
                         n_vertices: int, n_slots: int, packed: bool):
    """(B, W) frontier bitmaps -> batched apportioned streams.

    The packed arm compacts the whole batch in one kernel launch and
    vmaps only the pure-jnp apportionment; the legacy arm (and any
    working set past the compaction kernel's VMEM budget, observably —
    ``serve.degrade.vmem_fallback``) vmaps the dense `edge_stream`
    whole."""
    n_batch = frontier.shape[0]
    if packed:
        if ops.compact_fits(n_batch, list_size):
            fl, _ = ops.frontier_compact_batched(
                frontier, size=list_size, fill=n_vertices)
            return jax.vmap(
                lambda l: apportion(colstarts, rows, l, n_vertices,
                                    n_slots))(fl)
        _record_degrade(
            "vmem_fallback",
            reason=ops.budget_detail(
                f"frontier_compact({n_batch}x{list_size})",
                ops.compact_budget(n_batch, list_size)),
            fallback="dense edge_stream (materialized frontier lists, "
                     "packed arm disabled)")
    return jax.vmap(
        lambda f: edge_stream(colstarts, rows, f, list_size, n_vertices,
                              n_slots))(frontier)


def _make_scalar_step(colstarts, rows, n_vertices: int, v_pad: int,
                      e_pad: int, algorithm: str, tile: int,
                      packed: bool = True):
    """Plain-jnp Algorithm 2/3 layer, vmapped over the root axis.

    Always materialized (the apportioned stream IS the scalar
    algorithm); its StepAux reports the full stream's tile count so
    the accounting stays comparable across modes.  Under ``packed``
    the frontier-list build is the SIMD compaction kernel instead of
    the dense unpack/nonzero pass."""
    tiles_per_root = -(-e_pad // tile)

    def step(frontier, visited, parent):
        with ops.count_launches() as c:
            u, v, valid, trunc = _batched_edge_stream(
                colstarts, rows, frontier, v_pad, n_vertices, e_pad,
                packed)
            out, visited, parent = jax.vmap(
                lambda u1, v1, val1, f1, vi1, p1: expand_candidates(
                    u1, v1, val1, f1, vi1, p1, n_vertices, algorithm)
            )(u, v, valid, frontier, visited, parent)
        aux = StepAux(jnp.int32(frontier.shape[0] * tiles_per_root),
                      trunc.sum(dtype=jnp.int32), c.count)
        return out, visited, parent, aux

    return step


def kernel_expand_restore(expand_fn, nbr, cand, valid, frontier,
                          visited, parent, n_vertices: int, tile: int,
                          check_frontier: bool = False):
    """Racy kernel expansion + restoration + delta merge (§3.3.2).

    The single home of the expand -> restore -> OR-delta sequence;
    ``expand_fn`` is `ops.expand` (single root) or `ops.expand_batched`
    (leading root axis).  Returns (out, visited, parent)."""
    out_racy, p_racy = expand_fn(
        nbr, cand, valid.astype(jnp.int32), frontier, visited,
        jnp.zeros_like(frontier), parent, n_vertices=n_vertices,
        tile=tile, check_frontier=check_frontier)
    p_fixed, delta = ops.restore(p_racy, n_vertices=n_vertices)
    return out_racy | delta, visited | delta, p_fixed


def _make_simd_step(colstarts, rows, n_vertices: int, v_pad: int,
                    e_pad: int, tile: int, packed: bool = True):
    """§4 SIMD layer, *materialized* pipeline: apportioned HBM stream
    + batched Pallas expansion + kernel restoration."""
    tiles_per_root = -(-e_pad // tile)

    def step(frontier, visited, parent):
        with ops.count_launches() as c:
            u, v, valid, trunc = _batched_edge_stream(
                colstarts, rows, frontier, v_pad, n_vertices, e_pad,
                packed)
            out, visited, parent = kernel_expand_restore(
                ops.expand_batched, u, v, valid, frontier, visited,
                parent, n_vertices, tile)
        aux = StepAux(jnp.int32(frontier.shape[0] * tiles_per_root),
                      trunc.sum(dtype=jnp.int32), c.count)
        return out, visited, parent, aux

    return step


def _pad_rows_to_tile(rows, n_vertices: int, tile: int):
    """Sentinel-pad the CSR rows to a tile multiple — once, at step
    build time (a loop constant), never inside the layer loop."""
    pad = (-int(rows.shape[0])) % tile
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.full((pad,), n_vertices, jnp.int32)])
    return rows


def _make_fused_step(colstarts, rows_t, n_vertices: int, tile: int,
                     bottom_up: bool, packed: bool = True,
                     prefetch_depth: int = 0):
    """One fused_gather layer (ISSUE 3), both directions.

    Top-down plans the active rows-blocks from the *frontier*'s
    adjacency; bottom-up from the *unvisited* set's (``~visited`` —
    padding is premarked, so the complement is exactly the real
    undiscovered vertices), with the kernel testing each gathered
    neighbor against the frontier bitmap.  Either way: no
    materialized (u, v, valid) round trip.  ``rows_t`` is the
    tile-padded rows array (padded once in `_make_steps`).

    ``packed`` routes the planning pass through the SIMD compaction
    kernel (V/8 mask bytes instead of a dense V-mask);
    ``prefetch_depth`` > 0 switches the gather kernel to its manual
    double-buffered DMA input pipeline (tile N+1 in flight while tile
    N computes — the §4 prefetch-distance knob)."""
    n_blocks = int(rows_t.shape[0]) // tile

    def step(frontier, visited, parent):
        with ops.count_launches() as c:
            active = ~visited if bottom_up else frontier
            wl, na = plan_active_tiles_batched(colstarts, active,
                                               n_vertices, tile,
                                               n_blocks, packed=packed)
            out_racy, p_racy = ops.gather_expand_batched(
                wl, na, rows_t, colstarts, frontier, visited,
                jnp.zeros_like(frontier), parent, n_vertices=n_vertices,
                tile=tile, bottom_up=bottom_up,
                prefetch_depth=prefetch_depth)
            p_fixed, delta = ops.restore(p_racy, n_vertices=n_vertices)
        aux = StepAux(na.sum(dtype=jnp.int32), jnp.int32(0), c.count)
        return out_racy | delta, visited | delta, p_fixed, aux

    return step


def _make_megakernel_step(colstarts, rows_t, n_vertices: int, tile: int,
                          bottom_up: bool, prefetch_depth: int = 0):
    """One whole layer in ONE Pallas call (ISSUE 6): the in-kernel
    plan + compact + gather-expand + restoration megakernel.  The
    work-list never leaves SMEM/VMEM; restoration is inlined at the
    final grid step, so the returned ``out`` is already repaired and
    the visited merge is a plain word OR (``out == delta | out_racy``
    holds because every true discovery carries a negative P mark —
    see kernels/layer_fused.py)."""

    def step(frontier, visited, parent):
        with ops.count_launches() as c:
            out, parent, na = ops.layer_fused_batched(
                rows_t, colstarts, frontier, visited, parent,
                n_vertices=n_vertices, tile=tile, bottom_up=bottom_up,
                prefetch_depth=prefetch_depth)
        aux = StepAux(na.sum(dtype=jnp.int32), jnp.int32(0), c.count)
        return out, visited | out, parent, aux

    return step


def _bottomup_stream(colstarts, rows, visited_words, n_vertices: int,
                     c_size: int, e_size: int):
    """Apportion the adjacency of *unvisited* vertices (one root) —
    the hostloop / legacy dense arm; the fused engine's batched
    bottom-up step compacts ``~visited`` with the batched kernel
    instead (padding vertices are premarked visited, so the word
    complement is exactly the real undiscovered set)."""
    unvisited = ~bm.unpack_bool(visited_words)
    (cands,) = jnp.nonzero(unvisited, size=c_size,
                           fill_value=n_vertices)
    return apportion(colstarts, rows, cands.astype(jnp.int32),
                     n_vertices, e_size)


def _make_bottomup_step(colstarts, rows, n_vertices: int, v_pad: int,
                        e_pad: int, tile: int, packed: bool = True):
    """Bottom-up layer, materialized pipeline: apportion the
    *unvisited* adjacency, test each neighbor against the frontier
    bitmap inside the kernel."""
    tiles_per_root = -(-e_pad // tile)

    def step(frontier, visited, parent):
        with ops.count_launches() as ct:
            fits = ops.compact_fits(frontier.shape[0], v_pad)
            if packed and not fits:
                _record_degrade(
                    "vmem_fallback",
                    reason=ops.budget_detail(
                        f"frontier_compact({frontier.shape[0]}x"
                        f"{v_pad})",
                        ops.compact_budget(frontier.shape[0], v_pad)),
                    fallback="dense bottom-up candidate stream "
                             "(packed arm disabled)")
            if packed and fits:
                cands, _ = ops.frontier_compact_batched(
                    ~visited, size=v_pad, fill=n_vertices)
                cand, nbr, valid, trunc = jax.vmap(
                    lambda c: apportion(colstarts, rows, c, n_vertices,
                                        e_pad))(cands)
            else:
                cand, nbr, valid, trunc = jax.vmap(
                    lambda vis: _bottomup_stream(colstarts, rows, vis,
                                                 n_vertices, v_pad,
                                                 e_pad))(visited)
            out, visited, parent = kernel_expand_restore(
                ops.expand_batched, nbr, cand, valid, frontier, visited,
                parent, n_vertices, tile, check_frontier=True)
        aux = StepAux(jnp.int32(frontier.shape[0] * tiles_per_root),
                      trunc.sum(dtype=jnp.int32), ct.count)
        return out, visited, parent, aux

    return step


def check_pipeline(pipeline: str) -> None:
    """Fail loudly on a mistyped pipeline name — every step builder
    routes through this so a typo can't silently select the legacy
    materialized path."""
    if pipeline not in PIPELINES:
        raise ValueError(f"unknown pipeline {pipeline!r}; "
                         f"expected one of {PIPELINES}")


def _make_steps(colstarts, rows, n_vertices, v_pad, e_pad, algorithm,
                tile, pipeline: str = "fused_gather",
                packed: bool = True, prefetch_depth: int = 0):
    check_pipeline(pipeline)
    # the persistent pipeline's PER-LAYER steps (the serve tier's
    # layer_step tick) are the megakernel steps — whole-traversal
    # queries never reach here (they route through
    # `_traverse_persistent` before steps are built)
    if pipeline in ("megakernel", "persistent"):
        rows_t = _pad_rows_to_tile(rows, n_vertices, tile)
        n_blocks = int(rows_t.shape[0]) // tile
        if ops.megakernel_fits(v_pad // bm.BITS_PER_WORD, v_pad,
                               int(colstarts.shape[0]), tile,
                               prefetch_depth, n_blocks):
            simd = _make_megakernel_step(colstarts, rows_t, n_vertices,
                                         tile, bottom_up=False,
                                         prefetch_depth=prefetch_depth)
            bottomup = _make_megakernel_step(
                colstarts, rows_t, n_vertices, tile, bottom_up=True,
                prefetch_depth=prefetch_depth)
        else:
            # observable degrade, mirroring ops.compact_fits: a
            # working set past the fused VMEM budget traverses via the
            # unfused fused_gather steps (the stats launch counter
            # then honestly reports the unfused cost)
            _record_degrade(
                "vmem_fallback",
                reason=ops.budget_detail(
                    f"megakernel(v_pad={v_pad}, tile={tile}, "
                    f"blocks={n_blocks}, depth={prefetch_depth})",
                    ops.megakernel_budget(
                        v_pad // bm.BITS_PER_WORD, v_pad,
                        int(colstarts.shape[0]), tile, prefetch_depth,
                        n_blocks)),
                fallback="pipeline='fused_gather' unfused steps "
                         "(3 launches/layer instead of 1)")
            simd = _make_fused_step(colstarts, rows_t, n_vertices,
                                    tile, bottom_up=False,
                                    packed=packed,
                                    prefetch_depth=prefetch_depth)
            bottomup = _make_fused_step(colstarts, rows_t, n_vertices,
                                        tile, bottom_up=True,
                                        packed=packed,
                                        prefetch_depth=prefetch_depth)
    elif pipeline == "fused_gather":
        rows_t = _pad_rows_to_tile(rows, n_vertices, tile)
        simd = _make_fused_step(colstarts, rows_t, n_vertices, tile,
                                bottom_up=False, packed=packed,
                                prefetch_depth=prefetch_depth)
        bottomup = _make_fused_step(colstarts, rows_t, n_vertices,
                                    tile, bottom_up=True, packed=packed,
                                    prefetch_depth=prefetch_depth)
    else:
        simd = _make_simd_step(colstarts, rows, n_vertices, v_pad,
                               e_pad, tile, packed=packed)
        bottomup = _make_bottomup_step(colstarts, rows, n_vertices,
                                       v_pad, e_pad, tile,
                                       packed=packed)
    return {
        MODE_SCALAR: _make_scalar_step(colstarts, rows, n_vertices,
                                       v_pad, e_pad, algorithm, tile,
                                       packed=packed),
        MODE_SIMD: simd,
        MODE_BOTTOMUP: bottomup,
    }


# ---------------------------------------------------------------------------
# The fused driver: whole search (all layers, all roots) in one launch
# ---------------------------------------------------------------------------

def init_root_state(root, base_visited, n_vertices: int):
    """Frontier/visited/parent arrays for one fresh root.

    ``base_visited`` is the padding-premarked visited bitmap
    (`csr.init_visited`).  The single init convention shared by the
    fused engine and the serve engine's slot refill."""
    v_pad = base_visited.shape[0] * bm.BITS_PER_WORD
    frontier = bm.set_bits_exact(bm.zeros(v_pad), root)
    visited = bm.set_bits_exact(base_visited, root)
    parent = jnp.full((v_pad,), n_vertices, jnp.int32).at[root].set(root)
    return frontier, visited, parent


def _init_batched(roots, n_vertices: int, v_pad: int):
    base_vis = padding_premarked_visited(n_vertices)
    return jax.vmap(
        lambda r: init_root_state(r, base_vis, n_vertices)
    )(roots.astype(jnp.int32))


def _traverse_persistent(fmt, roots, spec) -> EngineResult:
    """The ISSUE 9 whole-traversal driver: init the batch state, hand
    it to the format's persistent kernel (ONE Pallas launch — layer
    loop, §4.1 direction decision and termination all in-kernel,
    state VMEM-resident across layers) and repackage its
    ``(frontier, visited, parent, depths, layers, stats)`` contract
    as an `EngineResult`.  The stats launch column charges 1 per
    *traversal* (at the layer-0 row), vs the megakernel's 1/layer."""
    frontier, visited, parent = _init_batched(roots, fmt.n_vertices,
                                              fmt.n_vertices_padded)
    frontier, visited, parent, depths, layers, stats = \
        fmt.persistent_run(frontier, visited, parent, spec)
    return EngineResult(BfsState(frontier, visited, parent, layers[0]),
                        depths, stats)


def _traverse_impl(fmt, roots, spec) -> EngineResult:
    """The fused engine body, generic over a `formats.GraphFormat`.

    ``spec`` is a *resolved* `repro.api.spec.TraversalSpec` — the one
    configuration object every knob now lives on (policy, algorithm,
    pipeline, packed, tile, prefetch_depth, max_layers).  Every
    per-layer step (scalar / SIMD kernel / bottom-up) is built by the
    *format* (``fmt.make_steps(spec)``) — the layout owns its gather
    primitive and its ``pipeline`` flavour — while the
    measure/decide/restore pipeline and the single ``lax.while_loop``
    stay layout-independent.  ``roots`` is a (B,) int32 array; every
    state array carries the leading root axis.  No host
    synchronization between layers.

    ``spec.packed=True`` (the native representation since ISSUE 4)
    keeps the whole per-layer pipeline on packed uint32 words:
    workload counters come from word popcounts and the word-aligned
    degree matrix, planning/compaction run the SIMD rank-and-scatter
    kernel — per-layer mask traffic is V/8 bytes instead of the
    4V-byte dense masks the ``packed=False`` (legacy parity) arm
    materializes.
    """
    if spec.pipeline == "persistent":
        # trace-time VMEM admission: the persistent kernel pins the
        # WHOLE batch's state across layers, so the budget scales
        # with the root batch — past it, degrade observably to the
        # megakernel per-layer path (1 launch/layer), which has its
        # own further degrade to the unfused steps in `_make_steps`
        if fmt.persistent_fits(int(roots.shape[0]), spec):
            return _traverse_persistent(fmt, roots, spec)
        fallback = ("megakernel" if fmt.supports_megakernel
                    else "fused_gather")
        _record_degrade(
            "vmem_fallback",
            reason=(f"persistent(v_pad={fmt.n_vertices_padded}, "
                    f"roots={int(roots.shape[0])}, tile={spec.tile}, "
                    f"max_layers={spec.max_layers}, "
                    f"depth={spec.prefetch_depth}) whole-batch "
                    f"working set exceeds the VMEM budget"),
            fallback=f"pipeline={fallback!r} per-layer steps "
                     f"(>=1 launch/layer instead of 1/traversal)")
        spec = spec.replace(pipeline=fallback)

    policy = spec.policy
    packed = spec.packed
    max_layers = spec.max_layers
    n_vertices = fmt.n_vertices
    v_pad = fmt.n_vertices_padded
    deg = fmt.degrees()
    deg_mat = bm.degree_matrix(deg, v_pad)     # loop constant
    steps = fmt.make_steps(spec)
    modes = tuple(policy.modes)

    def rows_workload(words):          # (B, W) -> per-root counters
        if packed:
            edges = jax.vmap(
                lambda w: bm.masked_degree_sum(w, deg_mat))(words)
            return row_popcounts(words), edges
        dense = jax.vmap(bm.unpack_bool)(words)[:, :n_vertices]
        return row_popcounts(words), masked_edge_sum(dense, deg)

    frontier, visited, parent = _init_batched(roots, n_vertices, v_pad)
    n_roots = roots.shape[0]
    carry0 = (frontier, visited, parent, jnp.int32(0), jnp.asarray(False),
              jnp.zeros((n_roots,), jnp.int32),
              jnp.zeros((max_layers, _N_ST), jnp.int32))

    def cond(s):
        frontier, layer = s[0], s[3]
        return (row_popcounts(frontier).sum() > 0) & (layer < max_layers)

    def body(s):
        frontier, visited, parent, layer, bottom_up, depths, stats = s
        # named scopes mark the engine phases in XLA profiles
        # (obs.trace.xla_profiler / TensorBoard) — trace-time only
        with jax.named_scope("bfs.measure_decide"):
            f_count_b, f_edges_b = rows_workload(frontier)
            # policy counters aggregate in float32: per-root values are
            # int32-safe, the batch sum may not be (see Workload
            # docstring)
            if policy.needs_unvisited and packed:
                # padding is premarked visited, so the word complement
                # IS the real undiscovered set — no dense mask round
                # trip
                u_words = ~visited
                u_count = row_popcounts(u_words).sum() \
                    .astype(jnp.float32)
                u_edges = jax.vmap(
                    lambda w: bm.masked_degree_sum(w, deg_mat))(u_words) \
                    .astype(jnp.float32).sum()
            elif policy.needs_unvisited:
                u_dense = ~jax.vmap(
                    bm.unpack_bool)(visited)[:, :n_vertices]
                u_count = u_dense.sum(dtype=jnp.float32)
                u_edges = masked_edge_sum(u_dense, deg) \
                    .astype(jnp.float32).sum()
            else:
                u_count = u_edges = jnp.float32(0)
            w = Workload(layer, f_count_b.astype(jnp.float32).sum(),
                         f_edges_b.astype(jnp.float32).sum(), u_count,
                         u_edges, n_vertices, bottom_up,
                         n_roots=roots.shape[0])
            mode, bottom_up = policy.decide(w)

        with jax.named_scope("bfs.expand"):
            if len({id(steps[m]) for m in modes}) == 1:
                # one distinct step (single-mode policy, or a format
                # that maps every mode onto one sweep): call directly
                # instead of tracing the same body once per switch
                # branch
                new_f, visited, parent, aux = steps[modes[0]](
                    frontier, visited, parent)
            else:
                branch = sum(jnp.where(mode == m, jnp.int32(i), 0)
                             for i, m in enumerate(modes))
                new_f, visited, parent, aux = jax.lax.switch(
                    branch,
                    [functools.partial(lambda fn, op: fn(*op), steps[m])
                     for m in modes],
                    (frontier, visited, parent))
        with jax.named_scope("bfs.stats"):
            discovered = row_popcounts(new_f).sum()
            # stats stay int32 (exact Table 1 counters; single-root
            # always fits, extreme batched sums may clip — diagnostics
            # only)
            stats = stats.at[layer].set(
                jnp.stack([f_count_b.sum(), f_edges_b.sum(), discovered,
                           mode, jnp.int32(1), aux.tiles, aux.truncated,
                           jnp.asarray(aux.launches, jnp.int32)]))
            depths = depths + (f_count_b > 0).astype(jnp.int32)
        return (new_f, visited, parent, layer + 1, bottom_up, depths,
                stats)

    frontier, visited, parent, layer, _, depths, stats = \
        jax.lax.while_loop(cond, body, carry0)
    return EngineResult(BfsState(frontier, visited, parent, layer),
                        depths, stats)


_UNSET = object()       # legacy-shim sentinel: "knob not passed"

_KNOB_DEFAULTS = dict(policy=None, algorithm="simd", tile=None,
                      max_layers=64, pipeline="fused_gather",
                      packed=True, prefetch_depth=0)


def _spec_from_knobs(entry: str, spec, knobs: dict):
    """The legacy shims' single spec builder.

    ``knobs`` maps knob name -> value-or-_UNSET.  Explicit loose knobs
    emit the DeprecationWarning (the spec is the supported surface);
    mixing ``spec=`` with loose knobs is an error.  Returns an
    *unresolved* spec — resolution happens once, in `api.plan.plan`.
    """
    explicit = {k: v for k, v in knobs.items() if v is not _UNSET}
    if spec is not None:
        if explicit:
            raise ValueError(
                f"{entry}: pass either spec= or the loose knobs "
                f"({sorted(explicit)}), not both")
        return spec
    if explicit:
        warnings.warn(
            f"{entry}: the loose-knob form "
            f"({', '.join(sorted(explicit))}) is deprecated; pass "
            f"spec=repro.bfs.TraversalSpec(...) instead",
            DeprecationWarning, stacklevel=3)
    return make_spec(**{**_KNOB_DEFAULTS, **explicit})


def make_spec(*, policy=None, algorithm: str = "simd",
              tile: int | None = None, max_layers: int = 64,
              pipeline: str = "fused_gather", packed: bool = True,
              prefetch_depth: int = 0):
    """Build a `TraversalSpec` from legacy-style knob values — the ONE
    knob->spec constructor (``policy=None`` -> `TopDown()`,
    ``tile=None`` -> the format's auto rule).  Shared by the deprecated
    shims (via `_spec_from_knobs`) and the `run_bfs*` wrapper drivers,
    so the legacy default mapping cannot drift between surfaces."""
    from repro.api.spec import TraversalSpec
    return TraversalSpec(
        policy=policy if policy is not None else TopDown(),
        algorithm=algorithm,
        pipeline=pipeline,
        packed=packed,
        tile="auto" if tile is None else tile,
        prefetch_depth=prefetch_depth,
        max_layers=max_layers)


def traverse_arrays(colstarts, rows, roots, *, n_vertices: int,
                    policy=_UNSET, algorithm=_UNSET, tile=_UNSET,
                    max_layers=_UNSET, pipeline=_UNSET, packed=_UNSET,
                    prefetch_depth=_UNSET, spec=None) -> EngineResult:
    """The fused engine on raw CSR arrays (shard_map/dry-run friendly).

    Kept as the array-level entry for callers that only hold arrays,
    not a `Csr` (distributed per-chip programs, ``.lower()`` dry
    runs).  A thin shim over `repro.api.plan` since ISSUE 5: the
    arrays are viewed through `CsrFormat` and the loose knobs
    (deprecated — pass ``spec=``) become a `TraversalSpec`, so this
    entry shares the plan cache's one executable per (geometry,
    resolved spec).  ``tile`` now defaults to the format's auto choice
    (the committed BENCH affinity sweep), not a hardwired 1024 — the
    resolved spec is the single source of truth.
    """
    from repro.api.plan import plan as _plan
    from repro.formats.csr_format import CsrFormat
    fmt = CsrFormat(colstarts, rows, n_vertices, int(rows.shape[0]))
    s = _spec_from_knobs(
        "traverse_arrays", spec,
        dict(policy=policy, algorithm=algorithm, tile=tile,
             max_layers=max_layers, pipeline=pipeline, packed=packed,
             prefetch_depth=prefetch_depth))
    return _plan(fmt, s).run_batched(roots)


def traverse_format(fmt, roots, *, policy=_UNSET, algorithm=_UNSET,
                    tile=_UNSET, max_layers=_UNSET, pipeline=_UNSET,
                    packed=_UNSET, prefetch_depth=_UNSET,
                    spec=None) -> EngineResult:
    """The fused engine on any registered `GraphFormat` pytree.

    A thin shim over `repro.api.plan` since ISSUE 5 (one compile per
    (format class, geometry, resolved spec)).  ``tile`` now defaults
    to the *format's* auto choice — the old ``tile=1`` default
    silently degraded callers that bypassed `fmt.resolve_tile`; the
    resolved spec is the single source of truth.
    """
    from repro.api.plan import plan as _plan
    s = _spec_from_knobs(
        "traverse_format", spec,
        dict(policy=policy, algorithm=algorithm, tile=tile,
             max_layers=max_layers, pipeline=pipeline, packed=packed,
             prefetch_depth=prefetch_depth))
    return _plan(fmt, s).run_batched(roots)


def traverse(graph, roots, *, policy=_UNSET, algorithm=_UNSET,
             tile=_UNSET, max_layers=_UNSET, pipeline=_UNSET,
             packed=_UNSET, prefetch_depth=_UNSET,
             spec=None) -> EngineResult:
    """Run the fused engine for one root or a batch of roots.

    A thin shim over `repro.api.plan`/`repro.bfs` since ISSUE 5: all
    knobs live on ONE `TraversalSpec` (pass ``spec=``; the loose
    keyword form below is deprecated but preserved), resolved once and
    compiled once per (format class, geometry, resolved spec).

    Args:
      graph: a `Csr` (traversed via `CsrFormat`) or any built
        `formats.GraphFormat` (SELL-C-σ, bitmap-compressed, ...).
      roots: an int (single-root — result arrays are unbatched) or a
        sequence of ints (multi-root in one launch; every result array
        gains a leading root axis).
      spec: a `repro.bfs.TraversalSpec`; its fields are the one home
        of the former loose knobs (policy, algorithm, pipeline,
        packed, tile, prefetch_depth, max_layers — see the spec's
        docstring for the field -> paper-knob map).
      policy/algorithm/tile/max_layers/pipeline/packed/prefetch_depth:
        deprecated loose-knob form; same semantics as the spec fields
        (policy=None -> TopDown(), tile=None -> the format's auto
        choice).

    In batched mode the policy decides ONCE per layer from the
    batch-summed counters (one mode for the whole batch keeps the loop
    single-branch); finished roots flow through as no-ops.
    """
    from repro.api.plan import plan as _plan
    s = _spec_from_knobs(
        "traverse", spec,
        dict(policy=policy, algorithm=algorithm, tile=tile,
             max_layers=max_layers, pipeline=pipeline, packed=packed,
             prefetch_depth=prefetch_depth))
    return _plan(graph, s).run(roots)


def layer_stats(result: EngineResult) -> list[LayerStats]:
    """Decode the on-device stats buffer (one transfer, post-loop)."""
    buf = np.asarray(result.stats)
    out = []
    for i in range(buf.shape[0]):
        if not buf[i, _ST_ACTIVE]:
            break
        out.append(LayerStats(
            layer=i,
            frontier_vertices=int(buf[i, _ST_FRONTIER]),
            edges_examined=int(buf[i, _ST_EDGES]),
            discovered=int(buf[i, _ST_DISCOVERED]),
            active_tiles=int(buf[i, _ST_TILES]),
            truncated_edges=int(buf[i, _ST_TRUNC]),
            launches=int(buf[i, _ST_LAUNCH])))
    return out


def direction_log(result: EngineResult) -> list[str]:
    """Per-layer direction strings ("topdown"/"bottomup") from stats."""
    buf = np.asarray(result.stats)
    return [MODE_NAMES[int(buf[i, _ST_MODE])]
            for i in range(buf.shape[0]) if buf[i, _ST_ACTIVE]]


# ---------------------------------------------------------------------------
# One batched layer tick (the serve engine's step function)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_vertices", "algorithm"))
def layer_step(colstarts, rows, frontier, visited, parent, *,
               n_vertices: int, algorithm: str = "simd"):
    """Advance every root in the batch by exactly one layer (raw CSR
    arrays).

    The array-level counterpart of `layer_step_format` — which is what
    `serve.graph_engine.GraphEngine` ticks through since the format
    subsystem landed; this entry remains for callers that only hold
    ``colstarts/rows``.  Slots with an empty frontier flow through as
    no-ops (their edge stream is all sentinel).
    """
    v_pad = parent.shape[-1]
    e_pad = int(rows.shape[0])
    step = _make_scalar_step(colstarts, rows, n_vertices, v_pad, e_pad,
                             algorithm, _resolve_tile_csr(None, e_pad))
    return step(frontier, visited, parent)[:3]


def layer_step_format(fmt, frontier, visited, parent, *,
                      algorithm=_UNSET, pipeline=_UNSET, packed=_UNSET,
                      prefetch_depth=_UNSET, spec=None):
    """Format-generic one-layer tick (the serve engine's step).

    Same contract as `layer_step`, but the per-layer step comes from
    the graph format (`fmt.make_steps(spec)`) — the serve layer picks
    the layout per graph at load time and ticks through it.  A thin
    shim over the plan cache's single-layer executable since ISSUE 5
    (`serve.graph_engine.GraphEngine` holds its `CompiledTraversal`
    directly and skips this shim).  Since ISSUE 3 the
    ``algorithm="simd"`` tick routes through the format's SIMD step —
    for CSR that is the fused in-kernel gather, so a serve batch full
    of thin frontiers (or drained slots, n_active == 0) costs tiles
    proportional to the live work instead of E_pad/tile.  Serve batch
    shapes never change, so this compiles once per (format geometry,
    resolved spec, batch shape).
    """
    from repro.api.plan import plan as _plan
    s = _spec_from_knobs(
        "layer_step_format", spec,
        dict(algorithm=algorithm, pipeline=pipeline, packed=packed,
             prefetch_depth=prefetch_depth))
    return _plan(fmt, s).layer_step(frontier, visited, parent)


# ---------------------------------------------------------------------------
# Legacy host-loop driver (pow2 buckets; for A/B and workload studies)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(2,))
def _layer_workload(frontier, colstarts, n_vertices):
    """Concrete (|frontier|, Σdeg) for bucket selection."""
    dense = bm.unpack_bool(frontier)[:n_vertices]
    deg = colstarts[1:] - colstarts[:-1]
    return row_popcounts(frontier), masked_edge_sum(dense, deg)


@functools.partial(jax.jit, static_argnums=(2,))
def _unvisited_workload(visited, colstarts, n_vertices):
    dense = ~bm.unpack_bool(visited)[:n_vertices]
    deg = colstarts[1:] - colstarts[:-1]
    return dense.sum(dtype=jnp.int32), masked_edge_sum(dense, deg)


@functools.partial(jax.jit,
                   static_argnames=("n_vertices", "mode", "algorithm",
                                    "f_size", "e_size", "tile"))
def _hostloop_layer(colstarts, rows, frontier, visited, parent, *,
                    n_vertices, mode, algorithm, f_size, e_size, tile):
    """One bucketed layer at exact pow2 shapes, any mode.

    Always the materialized pipeline (the hostloop is the legacy A/B
    driver); returns (out, visited, parent, truncated)."""
    if mode == MODE_SCALAR:
        return scalar_expand(colstarts, rows, n_vertices, frontier,
                             visited, parent, f_size, e_size, algorithm)
    if mode == MODE_SIMD:
        u, v, valid, trunc = edge_stream(colstarts, rows, frontier,
                                         f_size, n_vertices, e_size)
        return kernel_expand_restore(ops.expand, u, v, valid, frontier,
                                     visited, parent, n_vertices,
                                     tile) + (trunc,)
    # MODE_BOTTOMUP: f_size buckets the unvisited-candidate list
    cand, nbr, valid, trunc = _bottomup_stream(colstarts, rows, visited,
                                               n_vertices, f_size,
                                               e_size)
    return kernel_expand_restore(ops.expand, nbr, cand, valid, frontier,
                                 visited, parent, n_vertices, tile,
                                 check_frontier=True) + (trunc,)


def traverse_hostloop(csr: Csr, root: int, *, policy=None,
                      algorithm: str = "simd", tile: int | None = None,
                      max_layers: int = 1024,
                      collect_stats: bool = False):
    """Python layer-loop driver with power-of-two shape buckets.

    Exact work per layer (the paper's Table 1 workload), at the cost of
    one ``int(count)`` device sync and a possible recompile per new
    bucket pair.  The measured A/B counterpart of `traverse`.
    Returns (state, stats, direction_log).
    """
    policy = policy if policy is not None else TopDown()
    interpret = jax.default_backend() != "tpu"
    v_pad = csr.n_vertices_padded
    frontier = bm.set_bits_exact(bm.zeros(v_pad),
                                 jnp.asarray([root], jnp.int32))
    visited = bm.set_bits_racy(init_visited(csr),
                               jnp.asarray([root], jnp.int32))
    parent = jnp.full((v_pad,), csr.n_vertices, jnp.int32) \
        .at[root].set(root)
    bottom_up = jnp.asarray(False)
    stats: list[LayerStats] = []
    log: list[str] = []
    layer = 0
    for _ in range(max_layers):
        count, edges = _layer_workload(frontier, csr.colstarts,
                                       csr.n_vertices)
        count, edges = int(count), int(edges)
        if count == 0:
            break
        if policy.needs_unvisited:
            u_count, u_edges = _unvisited_workload(visited, csr.colstarts,
                                                   csr.n_vertices)
            u_count, u_edges = int(u_count), int(u_edges)
        else:
            u_count = u_edges = 0
        w = Workload(jnp.int32(layer), jnp.int32(count), jnp.int32(edges),
                     jnp.int32(u_count), jnp.int32(u_edges),
                     csr.n_vertices, bottom_up)
        mode_t, bottom_up = policy.decide(w)
        mode = int(mode_t)
        if mode == MODE_BOTTOMUP:
            f_size = _next_pow2(u_count)
            e_size = _next_pow2(max(u_edges, 1))
        else:
            f_size = _next_pow2(count)
            e_size = _next_pow2(max(edges, 1))
        t = tile if tile is not None else _auto_tile(e_size, interpret)
        frontier, visited, parent, trunc = _hostloop_layer(
            csr.colstarts, csr.rows, frontier, visited, parent,
            n_vertices=csr.n_vertices, mode=mode, algorithm=algorithm,
            f_size=f_size, e_size=e_size, tile=t)
        log.append(MODE_NAMES[mode])
        if collect_stats:
            stats.append(LayerStats(
                layer=layer, frontier_vertices=count,
                edges_examined=edges,
                discovered=int(bm.popcount(frontier)),
                active_tiles=-(-e_size // t),
                truncated_edges=int(trunc)))
        layer += 1
    state = BfsState(frontier, visited, parent, jnp.int32(layer))
    return state, stats, log
