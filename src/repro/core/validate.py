"""Graph500-style BFS spanning-tree validator — paper §5.3.

The paper uses the Graph500 'soft' validation: five checks that do not
prove the tree is *the* BFS tree (there are many valid ones, thanks to
the benign race of §3.2) but catch every real bug class:

  1. the root is its own parent;
  2. the parent pointers form a forest rooted at ``root`` (no cycles)
     — established by pointer-doubling depth computation;
  3. every tree edge (P[v], v) is an edge of the graph;
  4. every graph edge spans at most one BFS level, and never connects
     a reached vertex to an unreached one (component closure);
  5. depths are consistent: d[v] == d[P[v]] + 1.

An optional sixth, stricter check compares depths against the serial
oracle (any valid BFS tree of the same graph shares its depth array).

Vectorized jnp throughout — validation of a SCALE-20 graph is itself a
data-parallel kernel.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.csr import Csr


class Validation(NamedTuple):
    ok: bool
    root_ok: bool
    no_cycles: bool
    tree_edges_exist: bool
    edge_levels_ok: bool
    component_closed: bool
    depths_consistent: bool
    depth: jax.Array          # (V,) int32, -1 for unreached


def compute_depths(parent: jax.Array, root: int, n_vertices: int):
    """Pointer-doubling depths. Returns (depth, acyclic_ok).

    Invariant: ``d[v] == dist(v -> ptr[v])`` along the parent chain.
    Each round: ``d[v] += d[ptr[v]]; ptr[v] = ptr[ptr[v]]`` — the root
    self-loop contributes 0, so the recurrence is self-stabilizing and
    needs no conditionals.  After ceil(log2 V)+1 rounds every acyclic
    chain has collapsed onto the root; survivors indicate a cycle (or a
    reached vertex with an unreached parent — equally a corrupt tree).
    """
    parent = parent.astype(jnp.int32)
    reached = parent >= 0
    idx = jnp.arange(n_vertices, dtype=jnp.int32)
    ptr = jnp.where(reached, parent, idx)   # unreached: self-loop
    ptr = ptr.at[root].set(root)
    d = jnp.where(reached & (idx != root), 1, 0).astype(jnp.int32)
    rounds = max(1, math.ceil(math.log2(max(n_vertices, 2))) + 1)
    for _ in range(rounds):
        d = d + d[ptr]
        ptr = ptr[ptr]
    acyclic = bool(jnp.all(~reached | (ptr == root)))
    depth = jnp.where(reached, d, -1)
    return depth, acyclic


def _tree_edge_exists(csr: Csr, parent: jax.Array) -> jax.Array:
    """For each reached non-root v, binary-search v in adj(P[v])."""
    v_ids = jnp.arange(csr.n_vertices, dtype=jnp.int32)
    reached = parent >= 0
    p = jnp.where(reached, parent, 0)
    is_root = p == v_ids
    lo = csr.colstarts[p]
    hi = csr.colstarts[p + 1]
    # rows are sorted per-vertex (csr.from_edges sorts by (src, dst))
    def find(v, lo, hi):
        # binary search v in rows[lo:hi]
        def body(_, state):
            l, h = state
            mid = (l + h) // 2
            val = csr.rows[jnp.clip(mid, 0, csr.rows.shape[0] - 1)]
            go_right = val < v
            return jnp.where(go_right, mid + 1, l), jnp.where(go_right, h, mid)
        steps = max(1, math.ceil(math.log2(max(int(csr.n_edges), 2))) + 1)
        l, h = jax.lax.fori_loop(0, steps, body, (lo, hi))
        found = (l < hi) & (csr.rows[jnp.clip(l, 0, csr.rows.shape[0] - 1)]
                            == v)
        return found
    found = jax.vmap(find)(v_ids, lo, hi)
    return jnp.all(~reached | is_root | found)


def validate(csr: Csr, parent_g500: jax.Array, root: int,
             reference_depth=None) -> Validation:
    """Run all soft checks on a Graph500-convention parent array."""
    v = csr.n_vertices
    parent = jnp.asarray(parent_g500)
    reached = parent >= 0

    root_ok = bool(parent[root] == root)
    depth, acyclic = compute_depths(parent, root, v)

    tree_edges = bool(_tree_edge_exists(csr, parent))

    # per-edge checks over the (symmetrized) edge list implicit in CSR
    e_pad = csr.rows.shape[0]
    src = jnp.repeat(jnp.arange(v, dtype=jnp.int32), csr.degrees(),
                     total_repeat_length=e_pad)
    dst = csr.rows
    real = jnp.arange(e_pad) < csr.n_edges
    s_reach = reached[jnp.clip(src, 0, v - 1)]
    d_reach = reached[jnp.clip(dst, 0, v - 1)]
    closure = bool(jnp.all(~real | (s_reach == d_reach)))
    ds = depth[jnp.clip(src, 0, v - 1)]
    dd = depth[jnp.clip(dst, 0, v - 1)]
    levels = bool(jnp.all(~(real & s_reach & d_reach)
                          | (jnp.abs(ds - dd) <= 1)))

    p_safe = jnp.where(reached, parent, 0)
    dc = jnp.all(~reached
                 | (jnp.arange(v) == root)
                 | (depth == depth[p_safe] + 1))
    depths_consistent = bool(dc)
    if reference_depth is not None:
        depths_consistent = depths_consistent and bool(
            jnp.array_equal(depth, jnp.asarray(reference_depth)))

    ok = (root_ok and acyclic and tree_edges and levels and closure
          and depths_consistent)
    return Validation(ok, root_ok, acyclic, tree_edges, levels, closure,
                      depths_consistent, depth)
