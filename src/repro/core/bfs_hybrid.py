"""Direction-optimizing (hybrid) BFS — beyond-paper extension.

The paper (§3, §8) notes its vectorization techniques "can be applied
to the bottom-up phase, which can lead to speed up the hybrid BFS
algorithm" [Beamer et al. 2012] — this module does exactly that.

Bottom-up step: iterate the *unvisited* vertices' adjacency and test
each neighbor against the frontier bitmap.  On TPU this is *friendlier*
than top-down: the hot loop is gather-only (frontier-bit tests); the
only scatter is the benign P write, so the bit race of §3.3.2 cannot
even occur — restoration still runs to unify the code path, but it is
repairing nothing.  Both directions reuse the same Pallas kernel
(``check_frontier=True`` flips the direction) and the same
apportionment machinery.

Switching heuristic (Beamer): top-down -> bottom-up when the frontier's
out-edge count exceeds the unexplored edge count / alpha; back when the
frontier shrinks below V / beta.  Defaults alpha=14, beta=24 (Beamer's
published constants).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core.bfs_parallel import (BfsState, _layer_workload, _next_pow2,
                                     apportion, init_state)
from repro.core.bfs_vectorized import (_apply_restore, _auto_tile,
                                       _gather_stream)
from repro.core.csr import Csr
from repro.kernels import ops


@functools.partial(jax.jit, static_argnames=("n_vertices", "c_size",
                                             "e_size"))
def _bottomup_stream(colstarts, rows, visited, n_vertices, c_size, e_size):
    """Apportion the adjacency of *unvisited* vertices.

    Returns (cand, nbr, valid): cand = unvisited vertex to discover,
    nbr = its neighbor to test against the frontier.
    """
    unvisited = ~bm.unpack_bool(visited)
    (cands,) = jnp.nonzero(unvisited, size=c_size, fill_value=n_vertices)
    cand_list = cands.astype(jnp.int32)
    cand, nbr, valid = apportion(colstarts, rows, cand_list, n_vertices,
                                 e_size)
    return cand, nbr, valid.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_vertices",))
def _unvisited_workload(visited, colstarts, n_vertices):
    dense = ~bm.unpack_bool(visited)[:n_vertices]
    deg = colstarts[1:] - colstarts[:-1]
    count = dense.sum(dtype=jnp.int32)
    edges = jnp.where(dense, deg, 0).sum(dtype=jnp.int32)
    return count, edges


def _bottomup_layer(csr: Csr, state: BfsState, c_size: int, e_size: int,
                    tile: int) -> BfsState:
    cand, nbr, valid = _bottomup_stream(csr.colstarts, csr.rows,
                                        state.visited, csr.n_vertices,
                                        c_size, e_size)
    out_racy, parent_racy = ops.expand(
        nbr, cand, valid, state.frontier, state.visited,
        bm.zeros(state.parent.shape[0]), state.parent,
        n_vertices=csr.n_vertices, tile=tile, check_frontier=True)
    return _apply_restore(state, out_racy, parent_racy, csr.n_vertices)


def run_bfs_hybrid(csr: Csr, root: int, *, alpha: float = 14.0,
                   beta: float = 24.0, tile: int | None = None,
                   collect_stats: bool = False, max_layers: int = 1024):
    """Direction-optimizing BFS with vectorized kernels both ways."""
    state = init_state(csr, root)
    v = csr.n_vertices
    direction_log: list[str] = []
    bottom_up = False
    for _ in range(max_layers):
        f_count, f_edges = _layer_workload(state.frontier, csr.colstarts, v)
        f_count, f_edges = int(f_count), int(f_edges)
        if f_count == 0:
            break
        u_count, u_edges = _unvisited_workload(state.visited,
                                               csr.colstarts, v)
        u_count, u_edges = int(u_count), int(u_edges)

        if not bottom_up and f_edges > u_edges / alpha:
            bottom_up = True                     # growing: switch down
        elif bottom_up and f_count < v / beta:
            bottom_up = False                    # shrinking: switch back

        if bottom_up and u_count > 0:
            c_size = _next_pow2(u_count)
            e_size = _next_pow2(max(u_edges, 1))
            t = tile or _auto_tile(e_size, interpret=True)
            state = _bottomup_layer(csr, state, c_size, e_size, t)
            direction_log.append("bottomup")
        else:
            from repro.core.bfs_vectorized import _simd_layer
            f_size = _next_pow2(f_count)
            e_size = _next_pow2(max(f_edges, 1))
            t = tile or _auto_tile(e_size, interpret=True)
            state = _simd_layer(csr, state, f_size, e_size, t)
            direction_log.append("topdown")
    if collect_stats:
        return state, direction_log
    return state
