"""Direction-optimizing (hybrid) BFS — beyond-paper extension.

The paper (§3, §8) notes its vectorization techniques "can be applied
to the bottom-up phase, which can lead to speed up the hybrid BFS
algorithm" [Beamer et al. 2012] — this wrapper selects the engine's
`BeamerHybrid` policy, which does exactly that.

Bottom-up step: iterate the *unvisited* vertices' adjacency and test
each neighbor against the frontier bitmap.  On TPU this is *friendlier*
than top-down: the hot loop is gather-only (frontier-bit tests); the
only scatter is the benign P write, so the bit race of §3.3.2 cannot
even occur — restoration still runs to unify the code path, but it is
repairing nothing.  Both directions reuse the same Pallas kernel
(``check_frontier=True`` flips the direction) and the same
apportionment machinery (`engine.edge_stream`).

Switching heuristic (Beamer): top-down -> bottom-up when the frontier's
out-edge count exceeds the unexplored edge count / alpha; back when the
frontier shrinks below V / beta.  Defaults alpha=14, beta=24 (Beamer's
published constants).  The decision runs *on device* inside the fused
layer loop — no per-layer host sync.
"""
from __future__ import annotations

from repro.core import engine
from repro.core.csr import Csr


def run_bfs_hybrid(csr: Csr, root, *, alpha: float = 14.0,
                   beta: float = 24.0, tile: int | None = None,
                   collect_stats: bool = False, max_layers: int = 1024):
    """Direction-optimizing BFS with vectorized kernels both ways.

    With ``collect_stats`` returns ``(state, direction_log)`` where the
    log holds one "topdown"/"bottomup" entry per executed layer.
    """
    policy = engine.BeamerHybrid(float(alpha), float(beta))
    from repro.api.plan import plan as _plan
    spec = engine.make_spec(policy=policy, tile=tile,
                            max_layers=max_layers)
    res = _plan(csr, spec).run(root)
    if collect_stats:
        return res.state, engine.direction_log(res)
    return res.state
