"""Bitmap (bit-array) data structure — the paper's §3.3.1.

Vertices are represented as single bits packed into uint32 words
(BITS_PER_WORD = 32), giving the 32x working-set compression the paper
relies on.  On the Xeon Phi this compression improved L2 hit rates; on
TPU it is what lets the whole visited/frontier set of a SCALE-25 graph
(4 MB) live in VMEM next to the vector unit.

All helpers are pure-jnp, shape-static and jittable.  Two flavours of
"scatter bits" are provided:

* ``set_bits_exact``    — deterministic OR-scatter (dense-bool + pack).
  Used by the restoration process and by reference implementations.
* ``set_bits_racy``     — gather-word / OR / scatter-word.  Duplicate
  word indices inside one call lose each other's updates ("some lane
  wins"), which is precisely the paper's *bit race condition* (§3.3.2,
  Fig. 6).  Used by the vectorized expansion hot loop, exactly as the
  paper uses non-atomic AVX-512 scatters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BITS_PER_WORD = 32
WORD_SHIFT = 5          # log2(BITS_PER_WORD)
WORD_MASK = BITS_PER_WORD - 1

__all__ = [
    "BITS_PER_WORD",
    "num_words",
    "zeros",
    "word_and_bit",
    "test_bits",
    "set_bits_exact",
    "set_bits_racy",
    "pack_bool",
    "unpack_bool",
    "popcount",
    "compact",
    "bit2vertex",
    "word_bits",
    "degree_matrix",
    "masked_degree_sum",
]


def num_words(n_vertices: int) -> int:
    """Number of uint32 words needed to hold ``n_vertices`` bits."""
    return (int(n_vertices) + BITS_PER_WORD - 1) // BITS_PER_WORD


def zeros(n_vertices: int) -> jax.Array:
    """A fresh all-zeros bitmap covering ``n_vertices`` bits."""
    return jnp.zeros((num_words(n_vertices),), dtype=jnp.uint32)


def word_and_bit(vertices: jax.Array):
    """Index transformation vertex -> (word index, bit offset).

    The paper performs this with ``_mm512_div_epi32`` /
    ``_mm512_rem_epi32``; shifts and masks are the TPU-friendly form.
    """
    v = vertices.astype(jnp.int32)
    return v >> WORD_SHIFT, (v & WORD_MASK).astype(jnp.uint32)


def test_bits(bitmap: jax.Array, vertices: jax.Array) -> jax.Array:
    """Gather words and test each vertex's bit (TestBit of Alg. 3).

    Out-of-range vertex ids read word 0 in "clip" mode; callers that
    pad use a sentinel vertex whose bit is pre-set in ``visited`` so
    padding lanes always filter out (our replacement for the paper's
    peel/remainder handling).
    """
    word_idx, bit = word_and_bit(vertices)
    words = bitmap[jnp.clip(word_idx, 0, bitmap.shape[0] - 1)]
    return (words >> bit) & jnp.uint32(1) != 0


def pack_bool(dense: jax.Array) -> jax.Array:
    """Pack a (W*32,) bool array into a (W,) uint32 bitmap. Exact."""
    n = dense.shape[0]
    assert n % BITS_PER_WORD == 0, "pad to a word multiple first"
    bits = dense.reshape(-1, BITS_PER_WORD).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(BITS_PER_WORD, dtype=jnp.uint32))
    return (bits * weights).sum(axis=1, dtype=jnp.uint32)


def word_bits(words: jax.Array) -> jax.Array:
    """Expand packed words into per-bit lanes: (..., W) uint32 ->
    (..., W, 32) int32 of 0/1.

    The single home of the word->lanes bit expansion shared by
    `unpack_bool`, `masked_degree_sum` and the compaction kernel's
    in-register rank-and-scatter (kernels/compact.py) — any change to
    the bit order or word width happens here once."""
    shifts = jnp.arange(BITS_PER_WORD, dtype=jnp.uint32)
    return ((words[..., None] >> shifts) & jnp.uint32(1)) \
        .astype(jnp.int32)


def unpack_bool(bitmap: jax.Array) -> jax.Array:
    """Expand a (W,) uint32 bitmap into a (W*32,) bool array. Exact."""
    return word_bits(bitmap).reshape(-1).astype(bool)


def set_bits_exact(bitmap: jax.Array, vertices: jax.Array,
                   valid: jax.Array | None = None) -> jax.Array:
    """Deterministic OR of the given vertices' bits into the bitmap.

    Implemented as a dense-bool scatter (duplicate ``set(True)`` is
    idempotent) followed by a pack.  This is the primitive used by the
    *restoration process* — it plays the role of the paper's per-word
    bit walk (Alg. 3 lines 16-29) but is exact and vectorized.
    """
    n = bitmap.shape[0] * BITS_PER_WORD
    v = vertices.astype(jnp.int32)
    if valid is not None:
        # route invalid lanes out of range; 'drop' mode discards them
        v = jnp.where(valid, v, n)
    dense = jnp.zeros((n,), dtype=bool).at[v].set(True, mode="drop")
    return bitmap | pack_bool(dense)


def set_bits_racy(bitmap: jax.Array, vertices: jax.Array,
                  valid: jax.Array | None = None) -> jax.Array:
    """Racy word-level OR-scatter — the paper's non-atomic SetBit.

    Each lane reads its word (pre-update), ORs its bit, and scatters
    the word back.  When several lanes target the same word, one lane's
    write wins and the others' bits are lost — the *bit race condition*
    of §3.3.2.  The restoration process repairs this from ``P``.
    """
    word_idx, bit = word_and_bit(vertices)
    if valid is not None:
        word_idx = jnp.where(valid, word_idx, bitmap.shape[0])  # dropped
    gathered = bitmap[jnp.clip(word_idx, 0, bitmap.shape[0] - 1)]
    updated = gathered | (jnp.uint32(1) << bit)
    return bitmap.at[word_idx].set(updated, mode="drop")


def popcount(bitmap: jax.Array) -> jax.Array:
    """Total number of set bits (frontier size)."""
    return jax.lax.population_count(bitmap).astype(jnp.int32).sum()


def compact(bitmap: jax.Array, size: int, fill_value: int) -> jax.Array:
    """Bitmap -> padded list of set-bit vertex ids (the input list).

    Returns exactly ``size`` int32 ids, padded with ``fill_value``.
    This is the queue-to-layer conversion of §3: vertices inside one
    layer may be emitted in any order, so a vectorized bit-expansion +
    nonzero compaction is legal.
    """
    dense = unpack_bool(bitmap)
    (idx,) = jnp.nonzero(dense, size=size, fill_value=fill_value)
    return idx.astype(jnp.int32)


def bit2vertex(word_idx: jax.Array, bit: jax.Array) -> jax.Array:
    """Inverse index transformation (bit2vertex of Alg. 3)."""
    return (word_idx.astype(jnp.int32) << WORD_SHIFT) | bit.astype(jnp.int32)


def degree_matrix(degrees: jax.Array, n_bits: int) -> jax.Array:
    """(V,) degrees -> (W, 32) word-aligned degree matrix.

    The loop constant `masked_degree_sum` consumes: row w holds the
    degrees of the 32 vertices packed into bitmap word w (zero for
    padding vertices), so the Table 1 edge counter becomes a word-local
    product against the packed bitmap — no dense V-mask round trip.
    """
    deg = jnp.zeros((n_bits,), jnp.int32).at[:degrees.shape[0]] \
        .set(degrees.astype(jnp.int32))
    return deg.reshape(-1, BITS_PER_WORD)


def masked_degree_sum(words: jax.Array, deg_mat: jax.Array) -> jax.Array:
    """Σ deg over the set bits of a packed bitmap (Table 1 "Edges").

    ``deg_mat`` is `degree_matrix(degrees, W * 32)`.  Consumes the
    packed words directly (the `word_bits` expansion fuses into the
    reduction) — the engine's Table 1 counter without carrying a
    dense (V,) int32 mask through the layer.
    """
    return (word_bits(words) * deg_mat).sum(dtype=jnp.int32)
