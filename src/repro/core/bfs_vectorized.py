"""The paper's §4 vectorized BFS: Pallas kernels + layer-adaptive switch.

Pipeline per layer (top-down):
  compact -> apportion -> [SIMD kernel | scalar path] -> restoration

The *layer-adaptive* switch is §4.1: small-world graphs concentrate
~95% of edge traffic in the two fat middle layers, so the SIMD path
(kernel launch, VMEM pinning) only pays for itself there.  The paper
hard-codes "the first two layers"; we default to an *edge-count
threshold* — same effect on RMAT graphs (the fat layers are exactly the
ones above threshold), robust on other graph shapes — and offer
``simd_layers`` for the paper-literal policy.  Both are benchmarked in
benchmarks/bfs_opt_ablation.py.

Prefetch-distance analogue: the Pallas grid double-buffers edge-stream
tiles HBM->VMEM; ``tile`` controls how far ahead the DMA runs, the role
the paper's ``_MM_HINT_T0/T1`` prefetch distance played.  On the CPU
container the kernels run in interpret mode, so ``tile`` is auto-sized
to keep the grid short; on TPU the default is 1024 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core.bfs_parallel import (BfsState, LayerStats, _layer_workload,
                                     _next_pow2, apportion, init_state)
from repro.core.csr import Csr
from repro.kernels import ops


@functools.partial(jax.jit,
                   static_argnames=("n_vertices", "f_size", "e_size"))
def _gather_stream(colstarts, rows, frontier, n_vertices, f_size, e_size):
    """Compact + apportion: build the layer's (nbr, cand, valid) stream."""
    frontier_list = bm.compact(frontier, f_size, n_vertices)
    u, v, valid = apportion(colstarts, rows, frontier_list, n_vertices,
                            e_size)
    return u, v, valid.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_vertices",))
def _apply_restore(state: BfsState, out_racy, parent_racy, n_vertices):
    parent, delta = ops.restore(parent_racy, n_vertices=n_vertices,
                                interpret=True)
    out = out_racy | delta
    visited = state.visited | delta
    return BfsState(out, visited, parent, state.layer + 1)


def _simd_layer(csr: Csr, state: BfsState, f_size: int, e_size: int,
                tile: int) -> BfsState:
    """One §4 SIMD layer: kernel expansion + kernel restoration."""
    u, v, valid = _gather_stream(csr.colstarts, csr.rows, state.frontier,
                                 csr.n_vertices, f_size, e_size)
    out_racy, parent_racy = ops.expand(
        u, v, valid, state.frontier, state.visited,
        bm.zeros(state.parent.shape[0]), state.parent,
        n_vertices=csr.n_vertices, tile=tile)
    return _apply_restore(state, out_racy, parent_racy, csr.n_vertices)


def _scalar_layer(csr: Csr, state: BfsState, f_size: int,
                  e_size: int) -> BfsState:
    """Skinny-layer fallback: Algorithm 2/3 in plain jnp (non-simd)."""
    from repro.core.bfs_parallel import expand_simd_semantics
    return expand_simd_semantics(csr.colstarts, csr.rows, csr.n_vertices,
                                 state, f_size, e_size)


def _auto_tile(e_size: int, interpret: bool) -> int:
    if not interpret:
        return 1024
    # interpret mode unrolls the grid at trace time: keep it short
    return max(1024, e_size // 32)


def run_bfs_vectorized(csr: Csr, root: int, *,
                       simd_threshold: int = 16_384,
                       simd_layers: tuple[int, ...] | None = None,
                       tile: int | None = None,
                       collect_stats: bool = False,
                       max_layers: int = 1024):
    """Top-down BFS with the paper's vectorized fat layers.

    Args:
      simd_threshold: use the SIMD kernel when the layer examines at
        least this many edges (adaptive §4.1 policy).
      simd_layers: explicit layer indices for the SIMD path (the
        paper's literal "first two [fat] layers" policy); overrides the
        threshold when given.
      tile: kernel edge-tile size (None = auto).
    """
    state = init_state(csr, root)
    stats: list[LayerStats] = []
    layer = 0
    for _ in range(max_layers):
        count, edges = _layer_workload(state.frontier, csr.colstarts,
                                       csr.n_vertices)
        count, edges = int(count), int(edges)
        if count == 0:
            break
        f_size = _next_pow2(count)
        e_size = _next_pow2(edges)
        use_simd = (layer in simd_layers) if simd_layers is not None \
            else (edges >= simd_threshold)
        if use_simd:
            t = tile or _auto_tile(e_size, interpret=True)
            state = _simd_layer(csr, state, f_size, e_size, t)
        else:
            state = _scalar_layer(csr, state, f_size, e_size)
        if collect_stats:
            stats.append(LayerStats(
                layer=layer, frontier_vertices=count,
                edges_examined=edges,
                discovered=int(bm.popcount(state.frontier))))
        layer += 1
    if collect_stats:
        return state, stats
    return state
