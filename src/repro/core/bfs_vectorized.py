"""The paper's §4 vectorized BFS: Pallas kernels + layer-adaptive switch.

Thin wrapper over `core.engine`.  Pipeline per layer (top-down):
  compact -> apportion -> [SIMD kernel | scalar path] -> restoration

The *layer-adaptive* switch is §4.1: small-world graphs concentrate
~95% of edge traffic in the two fat middle layers, so the SIMD path
(kernel launch, VMEM pinning) only pays for itself there.  The paper
hard-codes "the first two layers"; we default to an *edge-count
threshold* (`engine.ThresholdSimd`) — same effect on RMAT graphs,
robust on other shapes — and offer ``simd_layers``
(`engine.PaperLiteralLayers`) for the paper-literal policy.  Both are
benchmarked in benchmarks/bfs_opt_ablation.py.

The whole search now runs as one fused ``lax.while_loop``: the policy
decides scalar-vs-SIMD per layer from on-device counters, with no host
round-trip between layers.

Prefetch-distance analogue: the Pallas grid double-buffers edge-stream
tiles HBM->VMEM; ``tile`` controls how far ahead the DMA runs, the role
the paper's ``_MM_HINT_T0/T1`` prefetch distance played.  On the CPU
container the kernels run in interpret mode, so ``tile`` is auto-sized
to keep the grid short; on TPU the default is 1024 lanes.
"""
from __future__ import annotations

from repro.core import engine
from repro.core.csr import Csr


def run_bfs_vectorized(csr: Csr, root, *,
                       simd_threshold: int = 16_384,
                       simd_layers: tuple[int, ...] | None = None,
                       tile: int | None = None,
                       collect_stats: bool = False,
                       max_layers: int = 1024):
    """Top-down BFS with the paper's vectorized fat layers.

    Args:
      simd_threshold: use the SIMD kernel when the layer examines at
        least this many edges (adaptive §4.1 policy).
      simd_layers: explicit layer indices for the SIMD path (the
        paper's literal "first two [fat] layers" policy); overrides the
        threshold when given.
      tile: kernel edge-tile size (None = auto).  NB in interpret mode
        (non-TPU) the fused engine clamps small tiles to bound
        trace-time grid unrolling; for exact tile sweeps use
        ``engine.traverse_hostloop`` (see benchmarks/affinity.py).
    """
    if simd_layers is not None:
        policy = engine.PaperLiteralLayers(tuple(int(l)
                                                 for l in simd_layers))
    else:
        policy = engine.ThresholdSimd(int(simd_threshold))
    from repro.api.plan import plan as _plan
    spec = engine.make_spec(policy=policy, tile=tile,
                            max_layers=max_layers)
    res = _plan(csr, spec).run(root)
    if collect_stats:
        return res.state, engine.layer_stats(res)
    return res.state
