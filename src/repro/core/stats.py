"""Graph500 experimental harness — paper §5.3.

64 BFS executions from randomly chosen start vertices; per-run wall
time and TEPS (Traversed Edges Per Second, with the Graph500 edge
count: half the sum of reached vertices' directed degrees); harmonic
mean across runs.

The paper reports the harmonic mean *without filtering* unconnected
start vertices and notes the artifact this causes.  A zero-TEPS run
makes the true harmonic mean zero (1/teps diverges), so like most
Graph500 submissions we report BOTH: ``hmean_teps`` over connected
runs, plus ``n_zero_runs`` so the unfiltered picture is recoverable —
the deviation is deliberate and documented here.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core.csr import Csr, traversed_edges
from repro.core.bfs_parallel import parents_graph500
from repro.core.validate import validate


@dataclass
class RunResult:
    root: int
    seconds: float
    edges: int
    teps: float
    reached: int
    valid: bool | None = None


@dataclass
class HarnessResult:
    runs: list[RunResult] = field(default_factory=list)

    @property
    def n_zero_runs(self) -> int:
        return sum(1 for r in self.runs if r.edges == 0)

    @property
    def hmean_teps(self) -> float:
        ts = [r.teps for r in self.runs if r.teps > 0]
        if not ts:
            return 0.0
        return len(ts) / sum(1.0 / t for t in ts)

    @property
    def max_teps(self) -> float:
        return max((r.teps for r in self.runs), default=0.0)

    @property
    def mean_seconds(self) -> float:
        return float(np.mean([r.seconds for r in self.runs]))

    def summary(self) -> str:
        return (f"runs={len(self.runs)} hmean_teps={self.hmean_teps:.3e} "
                f"max_teps={self.max_teps:.3e} zero_runs={self.n_zero_runs} "
                f"mean_s={self.mean_seconds:.4f}")


def choose_roots(key: jax.Array, n_vertices: int, n_roots: int = 64,
                 degrees: np.ndarray | None = None,
                 require_connected: bool = False) -> np.ndarray:
    """Random start vertices. Paper: unfiltered; Graph500 ref filters
    degree-0 roots — both available."""
    roots = jax.random.randint(key, (4 * n_roots,), 0, n_vertices)
    roots = np.asarray(roots)
    if require_connected and degrees is not None:
        roots = roots[np.asarray(degrees)[roots] > 0]
    return roots[:n_roots]


def run_harness(csr: Csr, bfs_fn, key: jax.Array, n_roots: int = 64,
                validate_runs: bool = False,
                reference_depths_fn=None,
                roots=None) -> HarnessResult:
    """Time ``bfs_fn(csr, root) -> BfsState`` over ``n_roots`` roots.

    ``bfs_fn`` must return a ``BfsState`` (or any object with
    ``.parent``).  One warmup run is excluded from timing (jit).
    ``roots`` overrides the random draw (deterministic tests; the
    paper's unfiltered-root artifact is reproducible by passing a
    degree-0 vertex explicitly).
    """
    if roots is None:
        roots = choose_roots(key, csr.n_vertices, n_roots)
    else:
        roots = np.asarray(roots)
    result = HarnessResult()

    # warmup/compile on the first root
    jax.block_until_ready(bfs_fn(csr, int(roots[0])).parent)

    for root in roots:
        root = int(root)
        t0 = time.perf_counter()
        state = bfs_fn(csr, root)
        jax.block_until_ready(state.parent)
        dt = time.perf_counter() - t0

        p = parents_graph500(state, csr.n_vertices)
        reached = p >= 0
        edges = int(traversed_edges(csr, reached))
        teps = edges / dt if dt > 0 else 0.0
        ok = None
        if validate_runs:
            ref = (reference_depths_fn(root)
                   if reference_depths_fn else None)
            ok = validate(csr, p, root, reference_depth=ref).ok
        result.runs.append(RunResult(
            root=root, seconds=dt, edges=edges, teps=teps,
            reached=int(reached.sum()), valid=ok))
    return result
