"""Distributed BFS across a TPU mesh — the paper's "multi-device
solutions that will be needed to tackle very large graph-based
datasets" (§1), built out.

Decomposition (Graph500 1-D): vertices are striped in contiguous
ranges of ``v_loc`` per chip; each chip owns the *out-edges* of its
range (a rebased CSR slice).  The frontier/visited bitmaps and the
predecessor array are replicated — at bitmap compression (32
vertices/word) a SCALE-27 frontier costs 16 MB/chip, which is what
makes replication affordable and is the distributed payoff of the
paper's §3.3.1 data structure.

Per layer, under ``shard_map`` over the full mesh:
  1. each chip sweeps its local adjacency in rows order, gating every
     edge on its slice of the (replicated) frontier bitmap
     (`engine.rowsweep_stream` — the fused-gather pipeline's jnp arm;
     no compaction/apportionment intermediates) — all compute local;
  2. local discoveries are written into an *encoded parent-candidate*
     array (``INF = V`` for "no update", else the parent id) with a
     deterministic ``.at[].min`` to resolve intra-chip duplicates;
  3. one ``lax.pmin`` all-reduce merges candidates across chips —
     min-parent is deterministic, so unlike the single-chip algorithm
     the distributed tree is reproducible run-to-run;
  4. every chip then derives the next frontier bitmap, visited update,
     and P update locally from the merged candidates.

Collective cost: ONE all-reduce of ``4*V`` bytes per layer, ~7 layers
per RMAT BFS — the collective roofline term is negligible next to the
local edge streaming (EXPERIMENTS.md §Roofline-BFS), which is why 1-D
suffices here and 2-D decompositions buy nothing until V outgrows
replication.

The whole search is one ``lax.while_loop`` of static shape, so it
lowers/compiles for the production meshes in launch/dryrun.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import bitmap as bm
from repro.core import engine
from repro.core.csr import Csr, round_up


# ---------------------------------------------------------------------------
# Host-side partitioner (Graph500 kernel-2 equivalent for the mesh)
# ---------------------------------------------------------------------------

def partition_sizes(n_vertices: int, n_edges_directed: int,
                    n_devices: int, slack: float = 1.5):
    """Static (v_loc, e_loc) partition shapes.

    v_loc: owned vertex range per chip (128-aligned).
    e_loc: per-chip edge capacity — balanced share times ``slack`` to
      absorb RMAT degree skew (measured ~1.3 at SCALE 20, D=256).
    """
    v_loc = round_up(math.ceil(n_vertices / n_devices), 128)
    e_loc = round_up(math.ceil(n_edges_directed / n_devices * slack), 128)
    return v_loc, e_loc


def partition_csr(csr: Csr, n_devices: int, slack: float = 1.5):
    """Split a CSR into per-device contiguous vertex ranges (numpy).

    Returns (rows_sh (D, e_loc), colstarts_sh (D, v_loc+1)).

    The per-device edge capacity is the *measured* maximum over ranges
    (128-aligned) — real data beats the ``slack`` heuristic, which only
    sizes spec-only dry-runs (``partition_sizes``).  RMAT degree skew
    makes the max noticeably above the balanced share at small
    scale/device counts; the measured imbalance is reported by
    benchmarks/affinity.py and attacked in §Perf (equal-edge split).
    """
    v = csr.n_vertices
    v_loc, _ = partition_sizes(v, csr.n_edges, n_devices, slack)
    cs = np.asarray(csr.colstarts)
    rows = np.asarray(csr.rows)
    bounds = [(min(d * v_loc, v), min(d * v_loc + v_loc, v))
              for d in range(n_devices)]
    e_loc = round_up(max(int(cs[hi] - cs[lo]) for lo, hi in bounds), 128)
    rows_sh = np.full((n_devices, e_loc), v, dtype=np.int32)
    colstarts_sh = np.zeros((n_devices, v_loc + 1), dtype=np.int32)
    for d, (lo, hi) in enumerate(bounds):
        local_cs = cs[lo:hi + 1] - cs[lo]
        n_local_edges = int(local_cs[-1]) if len(local_cs) else 0
        colstarts_sh[d, :len(local_cs)] = local_cs
        colstarts_sh[d, len(local_cs):] = local_cs[-1] if len(local_cs) \
            else 0
        rows_sh[d, :n_local_edges] = rows[cs[lo]:cs[hi]]
    return jnp.asarray(rows_sh), jnp.asarray(colstarts_sh)


# ---------------------------------------------------------------------------
# The per-chip program
# ---------------------------------------------------------------------------

def _local_step(rows_l, colstarts_l, frontier, visited, v_loc: int,
                n_vertices: int, v_cap: int, base):
    """One chip's expansion, built from the engine's step pieces:
    `engine.rowsweep_stream` gathers the local frontier slice's
    adjacency in rows order (LOCAL owner ids, GLOBAL neighbor ids) —
    the per-chip arm of the ISSUE 3 fused pipeline: one pass over the
    local rows with a per-edge bitmap gate, no compaction and no
    marker/prefix-sum intermediates — and `engine.candidate_scatter`
    encodes discoveries as the min-parent candidate array the
    collective merge resolves deterministically."""
    w_loc = v_loc // bm.BITS_PER_WORD
    local_words = jax.lax.dynamic_slice(
        frontier, (base // bm.BITS_PER_WORD,), (w_loc,))
    u_loc, v_nbr, valid = engine.rowsweep_stream(
        colstarts_l, rows_l, local_words, v_loc,
        nbr_limit=n_vertices)
    # u is consumed only under ``valid`` by the candidate scatter, so
    # the unconditional rebase is safe for padding slots
    u_glob = u_loc + base
    return engine.candidate_scatter(u_glob, v_nbr, valid, visited,
                                    n_vertices, v_cap)


def make_bfs_program(v_loc: int, n_vertices: int, n_devices: int,
                     axis_names: tuple[str, ...], max_layers: int = 64,
                     merge: str = "allreduce",
                     single_layer: bool = False):
    """Build the shard_map-able per-chip BFS program (static shapes).

    merge = "allreduce" — the baseline: one dense ``pmin`` over the
      full (V,) candidate array per layer (replicated P everywhere).
      Wire bytes/layer ~= 2 * 4V * (g-1)/g.

    merge = "owner" — §Perf optimization (owner-computes, the Graph500
      1-D classic): parent candidates are exchanged with ONE
      ``all_to_all`` so each chip min-reduces only the slice of P it
      owns, then the (32x smaller) frontier *bitmap* is all-gathered
      for the next layer's edge selection.  Wire bytes/layer ~=
      4V * (g-1)/g + V/8 — measured 1.94x less than the baseline and
      P memory drops from V to V/D per chip (EXPERIMENTS.md §Perf).
      The returned parent array is the LOCAL slice (v_loc,).

    merge = "packed" — ISSUE 4's packed-word exchange: the ONLY
      per-layer collective is an all-gather + OR of the 32x-compressed
      *discovered bitmap* (V/8 bytes — int32 candidate masks never hit
      the wire inside the loop).  Parent candidates accumulate
      locally as a running min; a vertex only ever receives candidates
      in the single layer before its bit enters the globally merged
      visited bitmap, so ONE post-loop ``pmin`` resolves parents to
      exactly the per-layer-pmin tree (deterministic).  Wire
      bytes/layer ~= V/8 * (g-1)/g + one final 4V — the win scales
      with the diameter.
    """
    if merge not in ("allreduce", "owner", "packed"):
        raise ValueError(f"unknown merge {merge!r}; expected "
                         f"'allreduce', 'owner' or 'packed'")
    v_cap = v_loc * n_devices
    assert v_cap >= n_vertices
    w_cap = v_cap // bm.BITS_PER_WORD
    w_loc = v_loc // bm.BITS_PER_WORD
    inf = jnp.int32(n_vertices)

    def program(rows_l, colstarts_l, root):
        rows_l = rows_l.reshape(-1)
        colstarts_l = colstarts_l.reshape(-1)
        d = jax.lax.axis_index(axis_names).astype(jnp.int32)
        base = d * v_loc

        frontier = bm.set_bits_exact(
            jnp.zeros((w_cap,), jnp.uint32), root.astype(jnp.int32))
        visited = frontier

        def cond(s):
            return (bm.popcount(s[0]) > 0) & (s[3] < max_layers)

        if merge == "allreduce":
            parent = (jnp.full((v_cap,), inf, jnp.int32)
                      .at[root].set(root.astype(jnp.int32)))

            def body(s):
                frontier, visited, parent, layer = s
                cand = _local_step(rows_l, colstarts_l, frontier,
                                   visited, v_loc, n_vertices, v_cap,
                                   base)
                merged = jax.lax.pmin(cand, axis_names)  # ONE collective
                newly = merged < inf
                new_frontier = bm.pack_bool(newly)
                return (new_frontier, visited | new_frontier,
                        jnp.where(newly, merged, parent), layer + 1)

            state = (frontier, visited, parent, jnp.int32(0))
            if single_layer:   # roofline probe: exact per-layer costs
                frontier, visited, parent, layer = body(state)
            else:
                frontier, visited, parent, layer = jax.lax.while_loop(
                    cond, body, state)
            return parent, layer

        if merge == "packed":
            # packed-word exchange: discoveries cross chips as OR'd
            # uint32 bitmap words; parents stay local until the end.
            frontier = compat.pcast_varying(frontier, axis_names)
            visited = compat.pcast_varying(visited, axis_names)
            parent_acc = (jnp.full((v_cap,), inf, jnp.int32)
                          .at[root].set(root.astype(jnp.int32)))
            parent_acc = compat.pcast_varying(parent_acc, axis_names)

            def body(s):
                frontier, visited, parent_acc, layer = s
                cand = _local_step(rows_l, colstarts_l, frontier,
                                   visited, v_loc, n_vertices, v_cap,
                                   base)
                parent_acc = jnp.minimum(parent_acc, cand)
                newly_l = bm.pack_bool(cand < inf)   # local, V/8 B
                gathered = jax.lax.all_gather(
                    newly_l, axis_names).reshape(n_devices, w_cap)
                merged = functools.reduce(
                    jnp.bitwise_or,
                    [gathered[d] for d in range(n_devices)])
                return (merged, visited | merged, parent_acc,
                        layer + 1)

            state = (frontier, visited, parent_acc, jnp.int32(0))
            if single_layer:   # roofline probe: exact per-layer costs
                frontier, visited, parent_acc, layer = body(state)
            else:
                frontier, visited, parent_acc, layer = \
                    jax.lax.while_loop(cond, body, state)
            # ONE dense collective for the whole search
            parent = jax.lax.pmin(parent_acc, axis_names)
            return parent, layer

        # owner-computes: P holds only this chip's vertex range.
        # The carried bitmaps become device-varying after the first
        # all_gather; mark the (replicated) initial values as varying
        # so the while_loop carry types match.
        frontier = compat.pcast_varying(frontier, axis_names)
        visited = compat.pcast_varying(visited, axis_names)
        in_range = (root >= base) & (root < base + v_loc)
        parent_l = jnp.full((v_loc,), inf, jnp.int32)
        parent_l = jnp.where(
            in_range,
            parent_l.at[jnp.clip(root - base, 0, v_loc - 1)]
            .set(root.astype(jnp.int32)),
            parent_l)

        def body(s):
            frontier, visited, parent_l, layer = s
            cand = _local_step(rows_l, colstarts_l, frontier, visited,
                               v_loc, n_vertices, v_cap, base)
            # exchange: row j of (D, v_loc) -> chip j; received rows =
            # every chip's candidates for MY vertex range
            cand = cand.reshape(n_devices, v_loc)
            mine = jax.lax.all_to_all(cand, axis_names, split_axis=0,
                                      concat_axis=0, tiled=True)
            merged_l = mine.reshape(n_devices, v_loc).min(axis=0)
            newly_l = (merged_l < inf) & (parent_l == inf)
            parent_l = jnp.where(newly_l, merged_l, parent_l)
            # 32x-compressed frontier broadcast (the paper's bitmap
            # compression is what makes this cheap)
            front_l = bm.pack_bool(newly_l)
            new_frontier = jax.lax.all_gather(
                front_l, axis_names, tiled=True).reshape(w_cap)
            return (new_frontier, visited | new_frontier, parent_l,
                    layer + 1)

        state = (frontier, visited, parent_l, jnp.int32(0))
        if single_layer:       # roofline probe: exact per-layer costs
            frontier, visited, parent_l, layer = body(state)
        else:
            frontier, visited, parent_l, layer = jax.lax.while_loop(
                cond, body, state)
        return parent_l, layer

    return program


# ---------------------------------------------------------------------------
# Mesh-facing wrapper
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("merge", "mesh", "axis_names",
                                             "n_vertices", "max_layers"))
def _run(mesh, axis_names, n_vertices, max_layers, merge, rows_sh,
         colstarts_sh, root):
    n_devices = int(np.prod([mesh.shape[a] for a in axis_names]))
    v_loc = int(colstarts_sh.shape[1]) - 1
    program = make_bfs_program(v_loc, n_vertices, n_devices, axis_names,
                               max_layers, merge=merge)
    p_out = P(axis_names) if merge == "owner" else P()
    shard = compat.shard_map(
        program, mesh,
        in_specs=(P(axis_names), P(axis_names), P()),
        out_specs=(p_out, P()))
    return shard(rows_sh, colstarts_sh, root)


def run_bfs_distributed(csr: Csr, root: int, mesh,
                        axis_names: tuple[str, ...] | None = None,
                        max_layers: int | None = None,
                        slack: float = 1.5,
                        merge: str | None = None, spec=None):
    """Partition + run the distributed BFS on a mesh. Returns (P, depth_count).

    The per-chip program derives from the same resolved
    `TraversalSpec` as every single-chip entry point: pass ``spec=``
    and its ``merge``/``max_layers`` fields govern the exchange
    flavour and layer budget (``merge="auto"`` resolves to "packed",
    the wire-optimal full-tree merge).  The loose ``max_layers=`` /
    ``merge=`` kwargs keep their historical defaults (64,
    "allreduce") and may not be mixed with ``spec=``.

    P follows the internal convention (INF == V for unreached); use
    ``jnp.where(p >= V, -1, p)`` for Graph500 convention.  With
    merge="owner" (§Perf optimization) each chip keeps only its P
    slice during the search; the concatenated result is identical.
    """
    if spec is not None:
        if max_layers is not None or merge is not None:
            raise ValueError(
                "run_bfs_distributed: pass either spec= or the loose "
                "max_layers=/merge= knobs, not both")
        from repro.api.spec import as_format, warn_mesh_ignored_fields
        warn_mesh_ignored_fields(spec, "run_bfs_distributed")
        # the program never reads policy: pin an arbitrary concrete
        # one before resolving so policy="auto" doesn't pay the
        # autotune degree measurement per launch
        probe = (spec.replace(policy="topdown")
                 if spec.policy == "auto" else spec)
        resolved = probe.resolve(as_format(csr))
        max_layers, merge = resolved.max_layers, resolved.merge
    else:
        max_layers = 64 if max_layers is None else max_layers
        merge = "allreduce" if merge is None else merge
    axis_names = axis_names or tuple(mesh.axis_names)
    n_devices = int(np.prod([mesh.shape[a] for a in axis_names]))
    rows_sh, colstarts_sh = partition_csr(csr, n_devices, slack)
    parent, layers = _run(mesh, axis_names, csr.n_vertices, max_layers,
                          merge, rows_sh, colstarts_sh,
                          jnp.asarray(root, jnp.int32))
    return parent[:csr.n_vertices], layers
