"""Serial queue-based top-down BFS — the paper's Algorithm 1.

Pure numpy oracle used by every correctness test.  Returns both the
predecessor array ``P`` (the BFS spanning tree; the paper's output) and
the depth array ``d`` (used by the Graph500-style validator to check
the parallel implementations, which may legitimately produce a
*different* valid tree thanks to the benign race of §3.2).

Convention: ``P[root] = root``; unreachable vertices keep ``P = -1``
and ``d = -1``.
"""
from __future__ import annotations

from collections import deque

import numpy as np


def bfs_serial(rows: np.ndarray, colstarts: np.ndarray, n_vertices: int,
               root: int):
    """Algorithm 1: queue BFS. Returns (P, depth), each (V,) int32/-1."""
    rows = np.asarray(rows)
    colstarts = np.asarray(colstarts)
    parent = np.full(n_vertices, -1, dtype=np.int32)
    depth = np.full(n_vertices, -1, dtype=np.int32)
    parent[root] = root
    depth[root] = 0
    q = deque([root])
    while q:                                   # in != 0
        u = q.popleft()
        for e in range(colstarts[u], colstarts[u + 1]):
            v = rows[e]
            if v >= n_vertices:                # sentinel padding
                continue
            if parent[v] == -1:                # vis.Test(v) = 0
                parent[v] = u                  # P[v] = u
                depth[v] = depth[u] + 1
                q.append(v)                    # out.add(v)
    return parent, depth


def reference_depths(rows: np.ndarray, colstarts: np.ndarray,
                     n_vertices: int, root: int) -> np.ndarray:
    """Depths only — the layer structure every valid BFS tree shares."""
    return bfs_serial(rows, colstarts, n_vertices, root)[1]
