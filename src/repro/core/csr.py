"""Compressed Sparse Row graph representation — paper §3.3.1, Fig. 4.

``rows`` holds the concatenated adjacency lists, ``colstarts[u]`` /
``colstarts[u+1]`` delimit vertex ``u``'s neighbors.  Adjacency lists
are sorted, which the validator exploits for binary-searched edge
membership tests.

Data alignment (paper §4.2): the Xeon Phi wants 64-byte boundaries and
suffers peel/remainder loops when it doesn't get them.  The TPU
analogue is 128-lane alignment.  We therefore

* pad ``rows`` to a multiple of ``LANES`` (=128) with a **sentinel
  vertex** ``V``;
* size every vertex-indexed array (bitmaps, P) for
  ``padded_vertex_count(V)`` vertices; and
* pre-mark all padding vertices as *visited* at BFS init.

Padding lanes then flow through the full gather-test-mask-scatter
pipeline and always filter out — the masks replace the paper's peel and
remainder special cases, with zero branches.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core.rmat import EdgeList
from repro.errors import GraphValidationError

LANES = 128  # TPU vector lane count; the "64-byte boundary" analogue.


def round_up(x: int, m: int) -> int:
    return (int(x) + m - 1) // m * m


def padded_vertex_count(n_vertices: int) -> int:
    """Vertex-array size: V real vertices + sentinel V + lane padding."""
    return round_up(n_vertices + 1, LANES)


class Csr(NamedTuple):
    rows: jax.Array        # (n_edges_padded,) int32, sentinel-padded
    colstarts: jax.Array   # (n_vertices + 1,) int32
    n_vertices: int        # real vertex count V (sentinel id == V)
    n_edges: int           # real directed edge count (un-padded)

    @property
    def n_vertices_padded(self) -> int:
        return padded_vertex_count(self.n_vertices)

    @property
    def n_edges_padded(self) -> int:
        return int(self.rows.shape[0])

    @property
    def sentinel(self) -> int:
        return self.n_vertices

    def degrees(self) -> jax.Array:
        return self.colstarts[1:] - self.colstarts[:-1]

    def out_degree(self, u) -> jax.Array:
        return self.colstarts[u + 1] - self.colstarts[u]


@jax.jit
def _sort_edges(src: jax.Array, dst: jax.Array):
    """Lexicographic (src, dst) sort via two stable passes.

    Avoids the int64 composite key (x64 is disabled; E < 2^31 and
    V < 2^31 are framework invariants, asserted in from_edges).
    """
    order1 = jnp.argsort(dst, stable=True)
    src1, dst1 = src[order1], dst[order1]
    order2 = jnp.argsort(src1, stable=True)
    return src1[order2], dst1[order2]


def from_edges(edges: EdgeList) -> Csr:
    """Build a padded CSR from a COO edge list (Graph500 kernel 2)."""
    v = edges.n_vertices
    assert v < 2**31 and edges.src.shape[0] < 2**31, \
        "int32 representation requires V, E < 2^31 (enable x64 beyond)"
    src, dst = _sort_edges(edges.src, edges.dst)
    counts = jnp.bincount(src, length=v).astype(jnp.int32)
    colstarts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)])
    n_edges = int(src.shape[0])
    pad = round_up(n_edges, LANES) - n_edges
    rows = jnp.concatenate(
        [dst.astype(jnp.int32),
         jnp.full((pad,), v, dtype=jnp.int32)]) if pad else dst.astype(
             jnp.int32)
    return Csr(rows=rows, colstarts=colstarts, n_vertices=v,
               n_edges=n_edges)


def padding_premarked_visited(n_vertices: int) -> jax.Array:
    """Visited bitmap with every padding vertex pre-marked.

    This replaces the paper's peel/remainder loop handling: sentinel
    lanes always test as 'already visited' and drop out of the masks.
    The single home of the convention — `init_visited`, the fused
    engine's batched init and `formats.GraphFormat.init_visited` all
    derive from it.
    """
    v_pad = padded_vertex_count(n_vertices)
    vis = bm.zeros(v_pad)
    pad_ids = jnp.arange(n_vertices, v_pad, dtype=jnp.int32)
    return bm.set_bits_exact(vis, pad_ids)


def init_visited(csr: Csr) -> jax.Array:
    """`padding_premarked_visited` for a built CSR."""
    return padding_premarked_visited(csr.n_vertices)


def _as_count(name: str, value) -> int:
    """Coerce a geometry scalar to a non-negative int or raise
    `GraphValidationError` (NaN/inf/fractional/negative all name the
    invariant)."""
    if isinstance(value, bool):
        raise GraphValidationError(
            f"{name} must be a non-negative integer, got the bool "
            f"{value!r}; pass a vertex/edge count")
    if isinstance(value, float):
        if math.isnan(value) or math.isinf(value) \
                or value != int(value):
            raise GraphValidationError(
                f"{name} must be a non-negative integer, got {value!r} "
                f"(NaN/inf/fractional geometry would silently mis-size "
                f"every vertex-indexed array); pass an exact int")
        value = int(value)
    if not isinstance(value, (int, np.integer)):
        raise GraphValidationError(
            f"{name} must be a non-negative integer, got "
            f"{type(value).__name__} {value!r}")
    value = int(value)
    if value < 0:
        raise GraphValidationError(
            f"{name} must be >= 0, got {value}")
    return value


def check_structure(csr: Csr) -> Csr:
    """Strict admission-time structural validation (ISSUE 8).

    Raises `repro.errors.GraphValidationError` (which IS-A
    ``ValueError``) when the CSR could produce a *wrong traversal*
    rather than an error: non-monotone ``colstarts``, out-of-range
    neighbor ids, float/NaN geometry, mismatched edge counts, wrong
    dtypes.  Every message names the violated invariant and the fix.

    Tracer-held arrays (a `Csr` flowing through a jitted legacy shim)
    skip the data checks — values are unreadable at trace time; the
    geometry scalars, which are always Python ints, are still checked.
    Returns ``csr`` so call sites can chain.
    """
    v = _as_count("n_vertices", csr.n_vertices)
    e = _as_count("n_edges", csr.n_edges)
    if v < 1:
        raise GraphValidationError(
            "n_vertices must be >= 1 (a BFS needs at least a root "
            "vertex); got 0")
    try:
        rows = np.asarray(csr.rows)
        colstarts = np.asarray(csr.colstarts)
    except Exception:
        return csr  # tracer-held: data checked at concrete admission
    for name, arr in (("rows", rows), ("colstarts", colstarts)):
        if arr.ndim != 1:
            raise GraphValidationError(
                f"{name} must be 1-D, got shape {arr.shape}")
        if arr.dtype.kind not in "iu":
            raise GraphValidationError(
                f"{name} must have an integer dtype (vertex ids), got "
                f"{arr.dtype}; cast with .astype(jnp.int32)")
    if colstarts.shape[0] != v + 1:
        raise GraphValidationError(
            f"colstarts must have n_vertices+1 = {v + 1} entries "
            f"(one past-the-end offset per vertex), got "
            f"{colstarts.shape[0]}")
    if colstarts.shape[0] and int(colstarts[0]) != 0:
        raise GraphValidationError(
            f"colstarts[0] must be 0 (offsets index into rows from the "
            f"start), got {int(colstarts[0])}")
    if np.any(np.diff(colstarts) < 0):
        bad = int(np.argmax(np.diff(colstarts) < 0))
        raise GraphValidationError(
            f"colstarts must be non-decreasing (adjacency extents "
            f"cannot have negative length); colstarts[{bad}]="
            f"{int(colstarts[bad])} > colstarts[{bad + 1}]="
            f"{int(colstarts[bad + 1])}")
    if int(colstarts[-1]) != e:
        raise GraphValidationError(
            f"colstarts[-1] ({int(colstarts[-1])}) must equal n_edges "
            f"({e}); the offsets and the declared edge count disagree")
    if rows.shape[0] < e:
        raise GraphValidationError(
            f"rows has {rows.shape[0]} entries but colstarts addresses "
            f"{e} edges; the adjacency array is truncated")
    if e:
        real = rows[:e]
        lo, hi = int(real.min()), int(real.max())
        if lo < 0 or hi >= v:
            bad_val = lo if lo < 0 else hi
            raise GraphValidationError(
                f"rows contains neighbor id {bad_val} outside "
                f"[0, n_vertices={v}); every real adjacency entry must "
                f"name an existing vertex (the sentinel {v} is only "
                f"legal in the padding tail)")
    if rows.shape[0] > e:
        pad = rows[e:]
        if np.any(pad < 0) or np.any(pad > v):
            raise GraphValidationError(
                f"rows padding tail contains ids outside [0, "
                f"sentinel={v}]; pad with the sentinel vertex id {v}")
    return csr


def traversed_edges(csr: Csr, reached: jax.Array) -> jax.Array:
    """Graph500 edge count for TEPS: sum of reached vertices' degrees / 2.

    ``reached`` is a (V,) bool mask of vertices in the BFS tree.
    Division by two converts directed (symmetrized) edges to the
    undirected count the Graph500 metric uses.
    """
    return (jnp.where(reached, csr.degrees(), 0)
            .sum(dtype=jnp.int32) // 2)
