"""Graph500 Kronecker (R-MAT) graph generator — paper §5.2.

Synthetic small-world graph generator following the Graph500 reference
(octave kernel 1) and the R-MAT model of Chakrabarti et al.  The graph
is defined by SCALE and edgefactor: ``V = 2**SCALE`` vertices and
``M = V * edgefactor`` generated (directed) edge tuples, which become
``2*M`` directed edges after symmetrization (the Graph500 factor of 2
the paper quotes).  Standard initiator: A=0.57, B=0.19, C=0.19, D=0.05.

Self-loops and duplicate edges are kept, exactly as the paper does
(§4.1: "including self-loops and repeated edges").  Vertex labels are
randomly permuted so vertex id carries no degree information
(Graph500 requirement).

Fully vectorized in jnp and jittable: one (SCALE, M) round of quadrant
choices per bit — the generator itself is an example of turning a
per-edge scalar loop into data-parallel form, in the spirit of the
paper's vectorization.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

# Graph500 standard initiator parameters (paper §5.2).
A, B, C, D = 0.57, 0.19, 0.19, 0.05


class EdgeList(NamedTuple):
    """COO edge list. ``src``/``dst`` are int32 arrays of equal length."""
    src: jax.Array
    dst: jax.Array
    n_vertices: int


@functools.partial(jax.jit, static_argnums=(1, 2))
def _rmat_pairs(key: jax.Array, scale: int, n_edges: int) -> jax.Array:
    """Generate (2, n_edges) int32 R-MAT endpoints, Graph500 kernel 1."""
    ab = A + B
    c_norm = C / (C + D)
    a_norm = A / (A + B)

    k_bits, k_perm = jax.random.split(key)
    # One uniform draw per (bit level, edge, side).
    u = jax.random.uniform(k_bits, (scale, 2, n_edges))
    ii_bit = u[:, 0, :] > ab                                   # row half
    jj_thresh = jnp.where(ii_bit, c_norm, a_norm)
    jj_bit = u[:, 1, :] > jj_thresh                            # col half
    weights = (jnp.int32(1) << jnp.arange(scale, dtype=jnp.int32))[:, None]
    src = (ii_bit.astype(jnp.int32) * weights).sum(0, dtype=jnp.int32)
    dst = (jj_bit.astype(jnp.int32) * weights).sum(0, dtype=jnp.int32)

    # Random vertex-label permutation (Graph500 kernel 1 requirement).
    perm = jax.random.permutation(k_perm, jnp.arange(1 << scale,
                                                     dtype=jnp.int32))
    return jnp.stack([perm[src], perm[dst]])


def generate(key: jax.Array, scale: int, edgefactor: int = 16,
             symmetrize: bool = True) -> EdgeList:
    """Generate a Graph500 R-MAT edge list.

    Args:
      key: PRNG key.
      scale: log2 of the vertex count.
      edgefactor: generated edges per vertex (Graph500 default 16).
      symmetrize: if True, append the reversed edges so the adjacency
        is undirected — matching the paper's ``2^SCALE * edgefactor * 2``
        directed-edge count.
    """
    n_vertices = 1 << scale
    m = n_vertices * edgefactor
    pairs = _rmat_pairs(key, scale, m)
    src, dst = pairs[0], pairs[1]
    if symmetrize:
        src, dst = jnp.concatenate([src, dst]), jnp.concatenate([dst, src])
    return EdgeList(src=src, dst=dst, n_vertices=n_vertices)
