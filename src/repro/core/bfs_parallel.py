"""Layer-synchronous parallel top-down BFS — Algorithms 2 and 3.

Thin public wrapper over `core.engine` (the unified traversal engine).
Two scalar expansion flavours survive as the ``algorithm`` switch:

* ``nonsimd`` — Algorithm 2 semantics.  Dense bool arrays for
  in/out/visited: no bit race exists because every vertex owns a whole
  element; only the *benign* parent race of §3.2 remains.
* ``simd``    — Algorithm 3.  Bitmap arrays + the racy word scatter of
  the hot loop + the **restoration process** (§3.3.2).  No atomics
  anywhere — what made the paper's AVX-512 vectorization legal, and
  equally what makes the XLA/TPU scatter formulation legal.

Both drivers now run the whole search as ONE fused ``lax.while_loop``
on device (no per-layer host sync); pass ``policy=`` to switch the
engine's direction policy, or use `engine.traverse_hostloop` for the
legacy bucketed layer loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core import engine
from repro.core.csr import Csr, init_visited
# Re-exports: these historically lived here; canonical home is engine.
from repro.core.engine import BfsState, LayerStats, apportion  # noqa: F401


def init_state(csr: Csr, root) -> BfsState:
    v_pad = csr.n_vertices_padded
    frontier = bm.set_bits_exact(bm.zeros(v_pad),
                                 jnp.asarray([root], jnp.int32))
    visited = bm.set_bits_racy(init_visited(csr),
                               jnp.asarray([root], jnp.int32))
    parent = jnp.full((v_pad,), csr.n_vertices, jnp.int32)
    parent = parent.at[root].set(root)
    return BfsState(frontier, visited, parent, jnp.int32(0))


def expand_simd_semantics(colstarts, rows, n_vertices: int,
                          state: BfsState, frontier_size: int,
                          edge_slots: int) -> BfsState:
    """One layer of Algorithm 3 (bitmaps, racy scatter, restoration)."""
    out, visited, parent, _ = engine.scalar_expand(
        colstarts, rows, n_vertices, state.frontier, state.visited,
        state.parent, frontier_size, edge_slots, "simd")
    return BfsState(out, visited, parent, state.layer + 1)


def expand_nonsimd(colstarts, rows, n_vertices: int, state: BfsState,
                   frontier_size: int, edge_slots: int) -> BfsState:
    """One layer of Algorithm 2 on dense bool arrays (exact updates)."""
    out, visited, parent, _ = engine.scalar_expand(
        colstarts, rows, n_vertices, state.frontier, state.visited,
        state.parent, frontier_size, edge_slots, "nonsimd")
    return BfsState(out, visited, parent, state.layer + 1)


def run_bfs(csr: Csr, root, *, algorithm: str = "simd",
            collect_stats: bool = False, max_layers: int = 1024,
            policy=None, tile: int | None = None):
    """Fused single-launch BFS driver (plan-cache-backed).

    Args unchanged from the historical bucketed driver; additionally
    accepts ``policy`` (any `engine` direction policy — default
    `engine.TopDown()`) and ``tile`` for policies that use the SIMD
    kernel.  ``root`` may be a sequence for batched multi-root search
    (state arrays then carry a leading root axis).  Routes through
    `repro.bfs.plan`'s cached `CompiledTraversal` (one trace per
    (geometry, resolved spec)).
    """
    from repro.api.plan import plan as _plan
    spec = engine.make_spec(policy=policy, algorithm=algorithm,
                            tile=tile, max_layers=max_layers)
    res = _plan(csr, spec).run(root)
    if collect_stats:
        return res.state, engine.layer_stats(res)
    return res.state


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def run_bfs_jit(colstarts, rows, root, n_vertices: int,
                algorithm: str = "simd", max_layers: int = 64) -> BfsState:
    """Fully-jitted driver on raw arrays (static full-E shapes).

    Alias for the engine's fused loop; used for ``.lower()``/dry-run
    paths that only have arrays, not a `Csr`.  Builds its spec
    explicitly (a concrete policy — "auto" resolution needs concrete
    degree statistics, unavailable under trace) and routes through the
    plan cache like every other entry.
    """
    from repro.api.spec import TraversalSpec
    res = engine.traverse_arrays(
        colstarts, rows, jnp.reshape(jnp.asarray(root, jnp.int32), (1,)),
        n_vertices=n_vertices,
        spec=TraversalSpec(policy=engine.TopDown(), algorithm=algorithm,
                           max_layers=max_layers))
    st = res.state
    return BfsState(st.frontier[0], st.visited[0], st.parent[0],
                    st.layer)


def parents_graph500(state: BfsState, n_vertices: int) -> jax.Array:
    """Convert internal P (∞ == V sentinel) to Graph500 convention (-1)."""
    p = state.parent[..., :n_vertices]
    return jnp.where(p >= n_vertices, -1, p)
