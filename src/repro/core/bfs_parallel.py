"""Layer-synchronous parallel top-down BFS — Algorithms 2 and 3.

Two implementations of one expansion pipeline:

* ``expand_nonsimd``   — Algorithm 2 semantics.  Dense bool arrays for
  in/out/visited (the pre-bitmap version): no bit race exists because
  every vertex owns a whole element; only the *benign* parent race of
  §3.2 remains (any discovering parent is a valid parent).

* ``expand_simd_semantics`` — Algorithm 3.  Bitmap arrays + the racy
  word scatter of the hot loop + the **restoration process** (§3.3.2):
  after the racy expansion, every vertex discovered this layer is
  identified by its negative ``P`` entry (``P[v] = u - V``), its bit is
  re-set exactly in both ``out`` and ``visited``, and ``P`` is fixed up
  by adding ``V`` back.  No atomics anywhere — that is what made the
  paper's AVX-512 vectorization legal, and it is equally what makes
  the XLA/TPU scatter formulation legal (neither has bit atomics).

Work distribution ("gather apportionment"): the paper gives each
OpenMP thread a slice of the input list and lets the vector unit walk
16 neighbors at a time.  The TPU-native equivalent computes, for every
*edge slot* of the layer, its source vertex by a vectorized binary
search over the cumulative frontier degrees — perfectly load-balanced
across lanes regardless of degree skew, which is the property OpenMP
dynamic scheduling approximated.

Drivers:
* ``run_bfs``          — Python layer loop with power-of-two shape
  buckets (exact work; used for timing/benchmarks; a handful of
  recompiles total).
* ``run_bfs_jit``      — single ``lax.while_loop`` with full-``E``
  padding per layer (static shapes; used for ``.lower()`` dry-runs and
  as the body that ``shard_map`` distributes).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitmap as bm
from repro.core.csr import Csr, init_visited


class BfsState(NamedTuple):
    frontier: jax.Array     # input bitmap (W,) uint32
    visited: jax.Array      # visited bitmap (W,) uint32
    parent: jax.Array       # P, (V_pad,) int32; init = V ("infinity")
    layer: jax.Array        # scalar int32


class LayerStats(NamedTuple):
    layer: int
    frontier_vertices: int  # |in|  (Table 1 "Vertices")
    edges_examined: int     # Σ deg(in)  (Table 1 "Edges")
    discovered: int         # |out| (Table 1 "Traversed vertices")


def init_state(csr: Csr, root) -> BfsState:
    v_pad = csr.n_vertices_padded
    frontier = bm.set_bits_exact(bm.zeros(v_pad),
                                 jnp.asarray([root], jnp.int32))
    visited = bm.set_bits_racy(init_visited(csr),
                               jnp.asarray([root], jnp.int32))
    parent = jnp.full((v_pad,), csr.n_vertices, jnp.int32)
    parent = parent.at[root].set(root)
    return BfsState(frontier, visited, parent, jnp.int32(0))


# ---------------------------------------------------------------------------
# Edge apportionment: frontier bitmap -> per-edge-slot (u, v, valid)
# ---------------------------------------------------------------------------

def apportion(csr_colstarts: jax.Array, csr_rows: jax.Array,
              frontier_list: jax.Array, n_vertices: int, n_slots: int):
    """Map ``n_slots`` edge slots onto the frontier's adjacency lists.

    frontier_list is sentinel-padded (id == n_vertices => empty).
    Returns (u, v, valid) arrays of length n_slots.

    Owner lookup is a scatter + prefix-sum instead of a binary search:
    ``owner[slot] = #frontier vertices whose adjacency ends at or
    before slot`` = cumsum of end-offset markers.  A vectorized
    searchsorted lowers to a log2(F)-iteration while loop that re-reads
    the full slot array every pass (measured 16.3 GB/layer at SCALE-27
    per chip); the prefix-sum form is two passes (§Perf iteration 2).
    """
    is_real = frontier_list < n_vertices
    safe = jnp.where(is_real, frontier_list, 0)
    deg = jnp.where(is_real,
                    csr_colstarts[safe + 1] - csr_colstarts[safe], 0)
    cum = jnp.cumsum(deg, dtype=jnp.int32)
    total = cum[-1] if cum.shape[0] else jnp.int32(0)
    slots = jnp.arange(n_slots, dtype=jnp.int32)
    # scatter a marker at each vertex's END offset; prefix-sum counts
    # how many adjacency lists finished at or before each slot
    markers = (jnp.zeros((n_slots,), jnp.int32)
               .at[cum].add(1, mode="drop"))
    owner = jnp.cumsum(markers, dtype=jnp.int32)
    owner_c = jnp.clip(owner, 0, frontier_list.shape[0] - 1)
    prev = jnp.where(owner_c > 0, cum[jnp.maximum(owner_c - 1, 0)], 0)
    u = frontier_list[owner_c]
    valid = slots < total
    u_safe = jnp.where(valid, u, 0)
    e_idx = csr_colstarts[u_safe] + (slots - prev)
    e_idx = jnp.clip(e_idx, 0, csr_rows.shape[0] - 1)
    v = csr_rows[e_idx]
    return u.astype(jnp.int32), v, valid


# ---------------------------------------------------------------------------
# Algorithm 3 layer: racy bitmap expansion + restoration
# ---------------------------------------------------------------------------

def expand_simd_semantics(colstarts, rows, n_vertices: int,
                          state: BfsState, frontier_size: int,
                          edge_slots: int) -> BfsState:
    """One layer of Algorithm 3 (bitmaps, racy scatter, restoration)."""
    v_pad = state.parent.shape[0]
    frontier_list = bm.compact(state.frontier, frontier_size, n_vertices)
    u, v, valid = apportion(colstarts, rows, frontier_list, n_vertices,
                            edge_slots)

    # --- hot loop (lines 9-13): gather, test, mask, racy scatter -----------
    undiscovered = ~(bm.test_bits(state.visited, v)
                     | bm.test_bits(state.frontier, v))
    mask = valid & undiscovered
    # P[v] = u - nodes  (negative marking; int scatter => word-atomic,
    # duplicate-v lanes race benignly: either parent is valid)
    scatter_idx = jnp.where(mask, v, v_pad)
    parent = state.parent.at[scatter_idx].set(u - n_vertices, mode="drop")
    # out.SetBit(v) — racy word OR; colliding words lose bits (Fig. 6)
    out = bm.set_bits_racy(bm.zeros(v_pad), v, mask)

    # --- restoration process (lines 15-29) ---------------------------------
    marked = parent < 0
    repaired = bm.pack_bool(marked)
    out = out | repaired
    visited = state.visited | repaired
    parent = jnp.where(marked, parent + n_vertices, parent)

    return BfsState(out, visited, parent, state.layer + 1)


# ---------------------------------------------------------------------------
# Algorithm 2 layer: dense bool arrays, no bit race (non-simd reference)
# ---------------------------------------------------------------------------

def expand_nonsimd(colstarts, rows, n_vertices: int, state: BfsState,
                   frontier_size: int, edge_slots: int) -> BfsState:
    """One layer of Algorithm 2 on dense bool arrays (exact updates)."""
    v_pad = state.parent.shape[0]
    frontier_list = bm.compact(state.frontier, frontier_size, n_vertices)
    u, v, valid = apportion(colstarts, rows, frontier_list, n_vertices,
                            edge_slots)
    visited_dense = bm.unpack_bool(state.visited)
    mask = valid & ~visited_dense[jnp.clip(v, 0, v_pad - 1)]
    scatter_idx = jnp.where(mask, v, v_pad)
    parent = state.parent.at[scatter_idx].set(u, mode="drop")
    out_dense = (jnp.zeros((v_pad,), bool)
                 .at[scatter_idx].set(True, mode="drop"))
    out = bm.pack_bool(out_dense)
    visited = state.visited | out
    return BfsState(out, visited, parent, state.layer + 1)


_EXPANDERS = {"simd": expand_simd_semantics, "nonsimd": expand_nonsimd}


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def _next_pow2(n: int, lo: int = 128) -> int:
    n = max(int(n), lo)
    return 1 << (n - 1).bit_length()


@functools.partial(jax.jit, static_argnums=(2,))
def _layer_workload(frontier, colstarts, n_vertices):
    """Concrete (|frontier|, Σdeg) for bucket selection."""
    count = bm.popcount(frontier)
    dense = bm.unpack_bool(frontier)[:n_vertices]
    deg = colstarts[1:] - colstarts[:-1]
    edges = jnp.where(dense, deg, 0).sum(dtype=jnp.int32)
    return count, edges


@functools.partial(jax.jit, static_argnums=(0, 3, 4, 5))
def _layer_step(expander_name, colstarts, rows, n_vertices,
                frontier_size, edge_slots, state):
    return _EXPANDERS[expander_name](colstarts, rows, n_vertices, state,
                                     frontier_size, edge_slots)


def run_bfs(csr: Csr, root: int, *, algorithm: str = "simd",
            collect_stats: bool = False, max_layers: int = 1024):
    """Python layer-loop driver with power-of-two shape buckets.

    Exact work per layer (the paper's Table 1 workload), at the cost of
    one small recompile per new (frontier, edges) bucket pair.
    """
    state = init_state(csr, root)
    stats: list[LayerStats] = []
    for _ in range(max_layers):
        count, edges = _layer_workload(state.frontier, csr.colstarts,
                                       csr.n_vertices)
        count, edges = int(count), int(edges)
        if count == 0:
            break
        f_size = _next_pow2(count)
        e_size = _next_pow2(edges)
        state = _layer_step(algorithm, csr.colstarts, csr.rows,
                            csr.n_vertices, f_size, e_size, state)
        if collect_stats:
            stats.append(LayerStats(
                layer=int(state.layer) - 1, frontier_vertices=count,
                edges_examined=edges,
                discovered=int(bm.popcount(state.frontier))))
    if collect_stats:
        return state, stats
    return state


@functools.partial(jax.jit, static_argnums=(3, 4, 5))
def run_bfs_jit(colstarts, rows, root, n_vertices: int,
                algorithm: str = "simd", max_layers: int = 64) -> BfsState:
    """Fully-jitted ``lax.while_loop`` driver (static full-E shapes).

    Every layer processes the padded edge capacity with masks — O(E)
    slots per layer.  Used for ``.lower()``/dry-run and inside
    ``shard_map`` for the distributed BFS.
    """
    v_pad = (int(n_vertices) + 128) // 128 * 128  # padded_vertex_count
    expander = _EXPANDERS[algorithm]

    frontier = bm.set_bits_exact(
        bm.zeros(v_pad), jnp.asarray([root], jnp.int32).reshape(()))
    pad_ids = jnp.arange(n_vertices, v_pad, dtype=jnp.int32)
    visited = bm.set_bits_exact(bm.zeros(v_pad), pad_ids)
    visited = bm.set_bits_exact(visited, jnp.asarray(root, jnp.int32))
    parent = jnp.full((v_pad,), n_vertices, jnp.int32).at[root].set(root)
    state = BfsState(frontier, visited, parent, jnp.int32(0))

    e_pad = int(rows.shape[0])

    def cond(s: BfsState):
        return (bm.popcount(s.frontier) > 0) & (s.layer < max_layers)

    def body(s: BfsState):
        return expander(colstarts, rows, n_vertices, s, v_pad, e_pad)

    return jax.lax.while_loop(cond, body, state)


def parents_graph500(state: BfsState, n_vertices: int) -> jax.Array:
    """Convert internal P (∞ == V sentinel) to Graph500 convention (-1)."""
    p = state.parent[:n_vertices]
    return jnp.where(p >= n_vertices, -1, p)
