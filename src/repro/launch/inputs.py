"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — exactly what
``jax.jit(...).lower()`` needs for the dry-run.  ``concrete_batch``
materializes small real batches for smoke tests/examples.

Conventions per family:
  dense/moe/ssm : tokens + labels (train) / token + standing state
  vlm           : + "prefix" (B, 256, D) SigLIP-stub patch embeddings
  audio enc-dec : + "src_embeddings" (B, S/4, D) frame embeddings
                  (4x acoustic downsampling convention, stubbed)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import Shape
from repro.models import lm
from repro.models.config import ModelConfig

S = jax.ShapeDtypeStruct


def _frames(seq_len: int) -> int:
    return max(seq_len // 4, 8)


def train_batch_specs(cfg: ModelConfig, shape: Shape) -> dict:
    b, t = shape.global_batch, shape.seq_len
    batch = {"tokens": S((b, t), jnp.int32),
             "labels": S((b, t), jnp.int32)}
    if cfg.prefix_len:
        batch["prefix"] = S((b, cfg.prefix_len, cfg.d_model),
                            jnp.float32)
    if cfg.encoder_layers:
        batch["src_embeddings"] = S((b, _frames(t), cfg.d_model),
                                    jnp.float32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """serve_step inputs: one new token + the standing cache/state.

    The cache covers ``shape.seq_len`` already-generated context (the
    ring buffer truncates to the SWA window when the arch has one).
    """
    b = shape.global_batch
    params_shape = jax.eval_shape(
        lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
    states = jax.eval_shape(
        lambda p: lm.init_decode_state(p, cfg, b, shape.seq_len),
        params_shape)
    d = {"tokens": S((b,), jnp.int32),
         "position": S((b,), jnp.int32),
         "states": states}
    if cfg.encoder_layers:
        d["memory"] = S((b, _frames(min(shape.seq_len, 16_384)),
                         cfg.d_model), jnp.float32)
    return d


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda k: lm.init_params(cfg, k),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Concrete batches (smoke tests, examples)
# ---------------------------------------------------------------------------

def concrete_batch(cfg: ModelConfig, key, batch: int, seq: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (batch, seq + 1), 0, cfg.vocab_size)
    out = {"tokens": tokens[:, :-1].astype(jnp.int32),
           "labels": tokens[:, 1:].astype(jnp.int32)}
    if cfg.prefix_len:
        out["prefix"] = 0.02 * jax.random.normal(
            k2, (batch, cfg.prefix_len, cfg.d_model))
    if cfg.encoder_layers:
        out["src_embeddings"] = 0.02 * jax.random.normal(
            k3, (batch, _frames(seq), cfg.d_model))
    return out
