import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512"))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device
count at first init, and the production meshes need 512 host devices.
This file (and only this file) may be the process entry point for the
dry-run; smoke tests and benches see the real 1-CPU device list.

Per cell:
    with mesh:
        lowered = jax.jit(step, in_shardings=..., out_shardings=...)\
            .lower(**input_specs(arch))
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective parse  -> JSON

Usage:
    python -m repro.launch.dryrun                      # all cells, both meshes
    python -m repro.launch.dryrun --arch qwen3 --shape train_4k --mesh multi
    python -m repro.launch.dryrun --bfs                # distributed BFS cells
    python -m repro.launch.dryrun --list
Artifacts: results/dryrun/<arch>__<shape>__<mesh>.json (cached by key).
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import registry
from repro.configs.bfs_graph500 import GRAPHS
from repro.launch import inputs
from repro.launch.mesh import (batch_specs, data_axes,
                               make_production_mesh, named_shardings,
                               param_specs, rules_for)
from repro.models import lm
from repro.models.config import param_count
from repro.models.sharding import logical_axis_rules
from repro.roofline.analysis import (model_flops_for, parse_collectives,
                                     Roofline)
from repro.train.train_step import (make_prefill_step, make_serve_step,
                                    make_train_step, TrainConfig)

RESULTS = Path(os.environ.get("DRYRUN_RESULTS", "results/dryrun"))


# ---------------------------------------------------------------------------
# Sharding policies for decode state pytrees
# ---------------------------------------------------------------------------

def decode_state_shardings(mesh, states, shape):
    """KV caches (L,B,S,K,hd): B over data when divisible, cache length
    S over model (sequence-parallel decode).  SSM/WKV states: B over
    data, last dim over model when divisible."""
    da = data_axes(mesh)
    d_batch = int(np.prod([mesh.shape[a] for a in da]))
    d_model = mesh.shape["model"]

    def one(leaf):
        dims = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % d_batch == 0:
            dims[1] = da                       # batch dim (after L)
        if leaf.ndim >= 3 and leaf.shape[2] % d_model == 0 \
                and leaf.shape[2] >= 16:
            dims[2] = "model"                  # cache length / heads
        elif leaf.ndim >= 4 and leaf.shape[-1] % d_model == 0:
            dims[-1] = "model"
        if dims[1] is None and leaf.ndim >= 3 \
                and leaf.shape[2] % (d_batch * d_model) == 0 \
                and leaf.shape[2] >= 4096:
            dims[2] = (*da, "model")           # batch=1 long context
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(one, states)


def vector_sharding(mesh, n):
    da = data_axes(mesh)
    d_batch = int(np.prod([mesh.shape[a] for a in da]))
    return NamedSharding(mesh, P(da if n % d_batch == 0 else None))


# ---------------------------------------------------------------------------
# Cell runners
# ---------------------------------------------------------------------------

def _mesh(mesh_name: str):
    return make_production_mesh(multi_pod=(mesh_name == "multi"))


def lower_cell(arch: str, shape_name: str, mesh_name: str,
               extra_cfg=None):
    """Build + lower + compile one cell. Returns the result dict."""
    cfg = registry.get(arch)
    if extra_cfg:
        cfg = cfg.with_(**extra_cfg)
    shape = registry.SHAPES[shape_name]
    # 400B-class: bf16 master weights (fp32 master can't fit 16 GB HBM
    # at these param/chip ratios; standard production trade-off)
    from repro.models.config import param_count as _pc
    mesh_chips = 512 if mesh_name == "multi" else 256
    if shape.kind == "train" and _pc(cfg) * 4 > mesh_chips * 4e9:
        cfg = cfg.with_(param_dtype="bfloat16")
    status = registry.cell_status(cfg, shape)
    if status != "run":
        return {"arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
                "status": status}

    mesh = _mesh(mesh_name)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = rules_for(mesh)
    params_shape = inputs.params_specs(cfg)
    d_batch = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    p_specs = param_specs(params_shape,
                          model_divisor=mesh.shape["model"],
                          data_divisor=d_batch)
    p_shardings = named_shardings(mesh, p_specs)
    t0 = time.time()

    with mesh:
        with logical_axis_rules(rules):
            if shape.kind == "train":
                # 400B-class cells need int8 optimizer state to fit a
                # single 256-chip pod (fp32 Adam alone exceeds HBM)
                from repro.models.config import param_count
                use_8bit = param_count(cfg) * 16 > n_chips * 12e9
                tcfg = TrainConfig(opt_8bit=use_8bit)
                tstep = make_train_step(cfg, tcfg)
                batch = inputs.train_batch_specs(cfg, shape)
                import repro.train.optimizer as opt
                opt_shape = jax.eval_shape(
                    opt.init_8bit if use_8bit else opt.init,
                    params_shape)
                o_shardings = jax.tree.map(
                    lambda _: NamedSharding(mesh, P()), opt_shape)
                # ZeRO-1: shard m/v over data (see optimizer.py)
                from repro.train.optimizer import zero1_specs
                z_specs = zero1_specs(p_specs, params_shape, d_batch)
                if use_8bit:
                    # {"q","s"} leaves: q shares the param's spec; the
                    # per-block scale keeps the last-dim axis only when
                    # the block count still divides it, else drops it
                    rules = rules_for(mesh)

                    def _axis_size(logical):
                        phys = rules.get(logical, logical)
                        names = (phys,) if isinstance(phys, str) \
                            else tuple(phys or ())
                        return int(np.prod([mesh.shape[a]
                                            for a in names]))

                    def qs_spec(spec, leaf):
                        dims = list(spec) + [None] * (
                            leaf.ndim - len(spec))
                        q_sp = P(*dims)
                        if not leaf.ndim:
                            return {"q": q_sp, "s": P()}
                        n = leaf.shape[-1]
                        s_dims = list(dims[:-1])
                        last = dims[-1]
                        if n % 128 == 0 and last is not None:
                            ax = ([last] if isinstance(last, str)
                                  else list(last))
                            div = int(np.prod([_axis_size(a)
                                               for a in ax]))
                            s_dims.append(
                                last if (n // 128) % div == 0
                                else None)
                        elif n % 128 == 0:
                            s_dims.append(None)
                        return {"q": q_sp, "s": P(*s_dims)}

                    m_specs = jax.tree.map(qs_spec, z_specs,
                                           params_shape,
                                           is_leaf=lambda x:
                                           isinstance(x, P))
                else:
                    m_specs = z_specs
                o_shardings = {
                    "m": named_shardings(mesh, m_specs),
                    "v": named_shardings(mesh, z_specs),
                    "step": NamedSharding(mesh, P()),
                }
                lowered = jax.jit(
                    tstep,
                    in_shardings=(p_shardings, o_shardings,
                                  batch_specs(mesh, batch)),
                    # params/opt-state update in place: halves peak HBM
                    donate_argnums=(0, 1),
                ).lower(params_shape, opt_shape, batch)
                n_tokens = shape.global_batch * shape.seq_len
            elif shape.kind == "prefill":
                pstep = make_prefill_step(cfg)
                batch = inputs.train_batch_specs(cfg, shape)
                batch.pop("labels")
                lowered = jax.jit(
                    pstep,
                    in_shardings=(p_shardings,
                                  batch_specs(mesh, batch)),
                ).lower(params_shape, batch)
                n_tokens = shape.global_batch * shape.seq_len
            else:  # decode
                sstep = make_serve_step(cfg)
                d = inputs.decode_input_specs(cfg, shape)
                st_shardings = decode_state_shardings(mesh, d["states"],
                                                      shape)
                args = [params_shape, d["states"], d["tokens"],
                        d["position"]]
                in_sh = [p_shardings, st_shardings,
                         vector_sharding(mesh, shape.global_batch),
                         vector_sharding(mesh, shape.global_batch)]
                if "memory" in d:
                    args.append(d["memory"])
                    in_sh.append(batch_specs(mesh, d["memory"]))
                lowered = jax.jit(
                    sstep, in_shardings=tuple(in_sh),
                    donate_argnums=(1,),   # KV cache updates in place
                ).lower(*args)
                n_tokens = shape.global_batch  # one token per sequence

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    # trip-count-aware analysis (cost_analysis counts scan bodies once)
    from repro.roofline.hlo_analyze import analyze
    acost = analyze(hlo, default_group=n_chips)

    n_embed = cfg.vocab_size * cfg.d_model \
        * (1 if cfg.tie_embeddings else 2)
    mf = model_flops_for(
        "train" if shape.kind == "train" else "serve",
        param_count(cfg, active_only=True), n_tokens, n_embed)
    roof = Roofline(
        flops=acost.flops,
        bytes_accessed=acost.bytes,
        wire_bytes=acost.wire_bytes,
        n_chips=n_chips,
        model_flops=mf,
    )
    result = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "n_chips": n_chips,
        "opt_state": ("int8-blockwise"
                      if (shape.kind == "train"
                          and param_count(cfg) * 16 > n_chips * 12e9)
                      else "fp32"),
        "param_dtype": cfg.param_dtype,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                                  getattr(mem, "temp_size_in_bytes", 0)),
        },
        "collectives": {"ops": acost.coll_ops,
                        "payload_bytes": acost.coll_payload,
                        "wire_bytes": acost.wire_bytes},
        "xla_cost_analysis": {
            "flops_no_trips": float(cost.get("flops", 0.0)),
            "bytes_no_trips": float(cost.get("bytes accessed", 0.0)),
        },
        "roofline": roof.to_dict(),
        "hlo_bytes": len(hlo),
    }
    return result


def lower_bfs_cell(graph_name: str, mesh_name: str,
                   merge: str = "allreduce"):
    """Dry-run the paper's distributed BFS on the production mesh."""
    from repro.core.bfs_distributed import (make_bfs_program,
                                            partition_sizes)
    g = GRAPHS[graph_name]
    mesh = _mesh(mesh_name)
    axes = tuple(mesh.axis_names)
    n_chips = int(np.prod(list(mesh.shape.values())))
    v_loc, e_loc = partition_sizes(g.n_vertices, g.n_edges_directed,
                                   n_chips)
    # single_layer=True: the roofline terms below are EXACT per-layer
    # costs (the full while-loop's trip count is data-dependent; the
    # compile-success proof still uses the full program)
    program = make_bfs_program(v_loc, g.n_vertices, n_chips, axes,
                               merge=merge, single_layer=True)
    program_full = make_bfs_program(v_loc, g.n_vertices, n_chips, axes,
                                    merge=merge)
    p_out = P() if merge == "allreduce" else P(axes)
    shard = compat.shard_map(
        program, mesh,
        in_specs=(P(axes), P(axes), P()), out_specs=(p_out, P()))
    shard_full = compat.shard_map(
        program_full, mesh,
        in_specs=(P(axes), P(axes), P()), out_specs=(p_out, P()))
    rows_s = jax.ShapeDtypeStruct((n_chips, e_loc), jnp.int32)
    cs_s = jax.ShapeDtypeStruct((n_chips, v_loc + 1), jnp.int32)
    root_s = jax.ShapeDtypeStruct((), jnp.int32)
    t0 = time.time()
    with mesh:
        # full program must compile (the dry-run proof) ...
        jax.jit(shard_full).lower(rows_s, cs_s, root_s).compile()
        # ... the single-layer probe provides the roofline terms
        lowered = jax.jit(shard).lower(rows_s, cs_s, root_s)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    from repro.roofline.hlo_analyze import analyze
    acost = analyze(compiled.as_text(), default_group=n_chips)
    # single-layer probe => terms below are exact PER-LAYER costs
    roof = Roofline(
        flops=acost.flops,
        bytes_accessed=acost.bytes,
        wire_bytes=acost.wire_bytes, n_chips=n_chips,
        model_flops=0.0)
    return {
        "arch": f"bfs-{graph_name}", "shape": "graph500",
        "mesh": mesh_name, "status": "ok", "n_chips": n_chips,
        "merge": merge,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        },
        "collectives": {"ops": acost.coll_ops,
                        "payload_bytes": acost.coll_payload,
                        "wire_bytes": acost.wire_bytes},
        "roofline": roof.to_dict(),
        "bytes_per_chip_edges": 4 * e_loc,
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def cell_path(arch, shape, mesh) -> Path:
    return RESULTS / f"{arch}__{shape}__{mesh}.json"


def run_and_save(arch, shape, mesh_name, force=False):
    cfgname = registry.get(arch).name
    path = cell_path(cfgname, shape, mesh_name)
    if path.exists() and not force:
        print(f"[cached] {path.name}")
        return json.loads(path.read_text())
    path.parent.mkdir(parents=True, exist_ok=True)
    print(f"[dryrun] {cfgname} x {shape} x {mesh_name} ...", flush=True)
    try:
        res = lower_cell(arch, shape, mesh_name)
    except Exception as e:  # a failing cell is a bug: record it loudly
        res = {"arch": cfgname, "shape": shape, "mesh": mesh_name,
               "status": f"FAILED: {type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    path.write_text(json.dumps(res, indent=1))
    print(f"  -> {res['status']}"
          + (f" compile={res.get('compile_s')}s"
             f" bottleneck={res.get('roofline', {}).get('bottleneck')}"
             if res["status"] == "ok" else ""), flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--bfs", action="store_true")
    ap.add_argument("--bfs-graph", default="rmat-24")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for cfg, shape, status in registry.all_cells():
            print(f"{cfg.name:28s} {shape.name:12s} {status}")
        return

    if args.bfs:
        for mesh_name in ([args.mesh] if args.mesh
                          else ["single", "multi"]):
            path = cell_path(f"bfs-{args.bfs_graph}", "graph500",
                             mesh_name)
            if path.exists() and not args.force:
                print(f"[cached] {path.name}")
                continue
            path.parent.mkdir(parents=True, exist_ok=True)
            print(f"[dryrun] BFS {args.bfs_graph} x {mesh_name}",
                  flush=True)
            try:
                res = lower_bfs_cell(args.bfs_graph, mesh_name)
            except Exception as e:
                res = {"arch": f"bfs-{args.bfs_graph}",
                       "shape": "graph500", "mesh": mesh_name,
                       "status": f"FAILED: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            path.write_text(json.dumps(res, indent=1))
            print(f"  -> {res['status']}", flush=True)
        return

    archs = [args.arch] if args.arch else sorted(registry.ARCHS)
    shapes = [args.shape] if args.shape else list(registry.SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                run_and_save(arch, shape, mesh_name, force=args.force)


if __name__ == "__main__":
    main()
