"""Production mesh construction + sharding rules.

``make_production_mesh`` is a FUNCTION (module import never touches
jax device state).  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips.  Generalizes to
N pods by growing the leading axis — the data-parallel axis is
(pod x data), so scaling pods scales global batch, the standard
1000+-node recipe.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

try:                       # jax >= 0.5; absent on the 0.4.x line
    from jax.sharding import AxisType
except ImportError:
    AxisType = None

from repro.models.sharding import DEFAULT_RULES, SINGLE_POD_RULES


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def rules_for(mesh) -> dict:
    return DEFAULT_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# ---------------------------------------------------------------------------
# Parameter / batch shardings
# ---------------------------------------------------------------------------

_MODEL_DIM_BY_PATH = (
    # (path substring, candidate dims to cut over "model", priority
    #  order; indices are for the UNSTACKED leaf, negatives from the
    #  end).  First candidate divisible by the model-axis size wins;
    #  otherwise the leaf replicates (GQA head counts like 40 or kv=1
    #  fall back to the d_model / ff dim).
    ("moe/w_gate/w", (0,)), ("moe/w_up/w", (0,)),   # expert dim
    ("moe/w_down/w", (0,)),
    ("embed/emb", (0,)), ("lm_head/emb", (0,)),     # vocab dim
    ("wq/w", (1, 0)), ("wk/w", (1, 0)), ("wv/w", (1, 0)),
    ("wo/w", (0, -1)),
    ("w_gate/w", (-1,)), ("w_up/w", (-1,)), ("w_down/w", (-2,)),
    ("moe/router", ()),
    ("in_proj/w", (-1,)), ("out_proj/w", (-2,)),
    ("bc_proj/w", ()), ("dt_proj/w", (-1,)),
    ("time_mix/w_k/w", (-1,)), ("time_mix/w_v/w", (-1,)),
    ("time_mix/w_r/w", (-1,)), ("time_mix/w_g/w", (-1,)),
    ("time_mix/w_o/w", (-2,)),
    ("channel_mix/w_k/w", (-1,)), ("channel_mix/w_v/w", (-2,)),
)


# FSDP: giant parameter stacks additionally cut a SECOND dim over the
# DATA axis (fully-sharded weights, all-gathered per layer inside the
# scan by GSPMD).  Without this, the 400B-class MoE experts replicate
# 100+ GiB/chip across the data axis (observed in the first dry-run
# sweep) — with it they fit (EXPERIMENTS.md SDry-run).
_DATA_DIM_BY_PATH = (
    ("moe/w_gate/w", (-1,)), ("moe/w_up/w", (-1,)),   # expert ff dim
    ("moe/w_down/w", (-1,)),                          # expert out dim
)


def _spec_for_path(path: str, shape, stacked: bool, divisor: int,
                   data_divisor: int = 0) -> P:
    ndim = len(shape)
    spec = [None] * ndim
    for frag, dims in _MODEL_DIM_BY_PATH:
        if frag in path:
            for dim in dims:
                d = dim if dim >= 0 else ndim + dim
                if dim >= 0 and stacked:
                    d += 1        # skip the leading layer-stack axis
                if 0 <= d < ndim and shape[d] % divisor == 0 \
                        and shape[d] >= divisor:
                    spec[d] = "model"
                    break
            break
    if data_divisor > 1:
        for frag, dims in _DATA_DIM_BY_PATH:
            if frag in path:
                for dim in dims:
                    d = dim if dim >= 0 else ndim + dim
                    if dim >= 0 and stacked:
                        d += 1
                    if 0 <= d < ndim and spec[d] is None \
                            and shape[d] % data_divisor == 0 \
                            and shape[d] >= data_divisor:
                        spec[d] = "data"
                        break
                break
    return P(*spec)


def param_specs(params, model_divisor: int = 16,
                data_divisor: int = 0) -> dict:
    """PartitionSpec pytree mirroring a param pytree (path-rule based).

    Layer-stacked arrays (under 'layers'/'encoder') keep their leading
    L axis unsharded.  ``model_divisor`` is the model-axis size; dims
    that don't divide fall back through the candidates or replicate.
    ``data_divisor`` > 1 enables FSDP cuts for the paths in
    _DATA_DIM_BY_PATH (the MoE expert stacks).
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat[0]:
        pstr = "/".join(getattr(k, "key", str(k)) for k in path)
        stacked = pstr.startswith(("layers/", "encoder/"))
        specs.append(_spec_for_path(pstr, leaf.shape, stacked,
                                    model_divisor, data_divisor))
    return jax.tree_util.tree_unflatten(flat[1], specs)


def named_shardings(mesh, spec_tree):
    rules = rules_for(mesh)

    def resolve(spec: P):
        phys = tuple(rules.get(a) if isinstance(a, str) else a
                     for a in spec)
        return NamedSharding(mesh, P(*phys))

    return jax.tree.map(resolve, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(mesh, batch_tree):
    """Shard the leading (batch) dim of every batch leaf over data."""
    da = data_axes(mesh)
    return jax.tree.map(
        lambda x: NamedSharding(mesh, P(da, *[None] * (x.ndim - 1))),
        batch_tree)
