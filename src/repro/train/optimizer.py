"""AdamW optimizer (pure pytree), schedules, clipping, ZeRO-1 sharding.

No optax in the container — this is the complete implementation the
framework ships.  State = {m, v, step}; ``zero1_specs`` produces
PartitionSpecs that additionally cut the largest divisible dim of each
m/v leaf over the DATA axis (optimizer-state sharding, ZeRO stage 1):
with AdamW fp32 state being 8 bytes/param, this is what fits the
400B-class archs on 16 GB chips (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
        * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, params, grads, state):
    """One AdamW step with global-norm clipping. Returns (params', state')."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    # separate maps (no tuple leaves: param trees may CONTAIN tuples —
    # the layer-group representation); XLA CSEs the repeated casts
    new_m = jax.tree.map(
        lambda g, m: cfg.b1 * m
        + (1 - cfg.b1) * g.astype(jnp.float32) * scale,
        grads, state["m"])
    new_v = jax.tree.map(
        lambda g, v: cfg.b2 * v
        + (1 - cfg.b2) * jnp.square(g.astype(jnp.float32) * scale),
        grads, state["v"])

    def upd_p(p, m, v):
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

    new_params = jax.tree.map(upd_p, params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Quantized optimizer state (bitsandbytes-style, TPU-native)
#
# m: int8, one fp32 scale per 128-wide block of the last dim (per-leaf
#    scale when the last dim doesn't divide).  m is zero-centered, so
#    symmetric int8 works.
# v: bf16.  Symmetric int8 on the second moment zeros-out small
#    entries within a block (measured: AdamW stalls at ~40% of the
#    fp32 loss on a quadratic), because 1/sqrt(v) amplifies exactly
#    the coordinates quantization killed.  bf16 keeps fp32's exponent
#    range with ~0.4% relative error — harmless under the sqrt.
# Net: ~3.1 B/param of state vs 8 B fp32; the difference between the
# 400B-class archs fitting a single 256-chip pod or not.
# Accuracy cross-checked against fp32 AdamW in
# tests/test_optimizer_8bit.py (loss curves track within tolerance).
# ---------------------------------------------------------------------------

Q_BLOCK = 128


def _quantize(x: jax.Array):
    n = x.shape[-1] if x.ndim else 1
    if x.ndim == 0 or n % Q_BLOCK != 0:
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
        q = jnp.round(x / scale).astype(jnp.int8)
        return {"q": q, "s": scale.astype(jnp.float32)}
    blocked = x.reshape(*x.shape[:-1], n // Q_BLOCK, Q_BLOCK)
    scale = jnp.max(jnp.abs(blocked), axis=-1, keepdims=True) / 127.0 \
        + 1e-12
    q = jnp.round(blocked / scale).astype(jnp.int8)
    return {"q": q.reshape(x.shape),
            "s": scale.squeeze(-1).astype(jnp.float32)}


def _dequantize(qs, like_shape):
    q, s = qs["q"], qs["s"]
    if q.ndim == 0 or s.ndim == 0:
        return q.astype(jnp.float32) * s
    blocked = q.reshape(*q.shape[:-1], q.shape[-1] // Q_BLOCK, Q_BLOCK)
    return (blocked.astype(jnp.float32) * s[..., None]) \
        .reshape(like_shape)


def init_8bit(params):
    zq = lambda p: _quantize(jnp.zeros(p.shape, jnp.float32))
    zb = lambda p: jnp.zeros(p.shape, jnp.bfloat16)
    return {"m": jax.tree.map(zq, params),
            "v": jax.tree.map(zb, params),
            "step": jnp.zeros((), jnp.int32)}


_CHUNK_ELEMS = 64 * 1024 * 1024   # loop the update on leaves above this


def update_8bit(cfg: AdamWConfig, params, grads, state):
    """AdamW on int8-blockwise m/v (dequant -> update -> requant).

    Leaves above _CHUNK_ELEMS are updated with ``lax.map`` over their
    leading axis (the layer-stack dim), so the fp32 dequantized
    temporaries never exceed one layer's worth — without this, the
    400B expert stacks spike >10 GiB of transient fp32 per leaf.
    """
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    is_qs = lambda x: isinstance(x, dict) and set(x) == {"q", "s"}

    def upd(p, g, mq, vb):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * _dequantize(mq, g.shape) + (1 - cfg.b1) * g
        v = cfg.b2 * vb.astype(jnp.float32) \
            + (1 - cfg.b2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return newp, _quantize(m), v.astype(jnp.bfloat16)

    def upd_leaf(mq, vb, p, g):
        if p.size <= _CHUNK_ELEMS or p.ndim < 2 \
                or mq["s"].ndim != p.ndim:
            return upd(p, g, mq, vb)
        n0 = p.shape[0]
        body = lambda args: upd(args[2], args[3], args[0], args[1])
        if n0 <= 64:                       # layer stacks: map as-is
            return jax.lax.map(body, (mq, vb, p, g))
        # big flat leaves (embeddings): map over a FIXED ~32-way
        # reshape — mapping over the raw leading dim would emit a
        # 200k-iteration loop (measured: 800 TB of HBM churn)
        nc = next((c for c in (32, 16, 8, 4, 2) if n0 % c == 0), 1)
        if nc == 1:
            return upd(p, g, mq, vb)
        rs = lambda a: a.reshape(nc, n0 // nc, *a.shape[1:])
        parts = jax.lax.map(body, (jax.tree.map(rs, mq), rs(vb),
                                   rs(p), rs(g)))
        un = lambda a: a.reshape(n0, *a.shape[2:])
        return un(parts[0]), jax.tree.map(un, parts[1]), un(parts[2])

    # m goes first so is_leaf stops traversal at the {"q","s"} dicts;
    # flatten_up_to then accepts the plain-array leaves of params/grads
    out = {}
    for i, name in enumerate(("p", "m", "v")):
        out[name] = jax.tree.map(
            lambda mq, vb, p, g, i=i: upd_leaf(mq, vb, p, g)[i],
            state["m"], state["v"], params, grads, is_leaf=is_qs)
    return out["p"], {"m": out["m"], "v": out["v"], "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# ZeRO-1: optimizer-state sharding over the data axis
# ---------------------------------------------------------------------------

def zero1_specs(param_spec_tree, params_shape, data_divisor: int):
    """m/v specs: param spec + cut the largest free dim over "data".

    A dim is eligible if unsharded in the param spec and divisible by
    the data-axis size.  Falls back to the param spec (replicated over
    data) when nothing divides — correctness never depends on it.
    """
    def one(spec: P, leaf):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        if "data" in dims:
            return P(*dims)       # FSDP leaf: data axis already used
        best, best_size = None, 0
        for i, (s, n) in enumerate(zip(dims, leaf.shape)):
            if s is None and n % data_divisor == 0 and n > best_size:
                best, best_size = i, n
        if best is not None:
            dims[best] = "data"
        return P(*dims)

    return jax.tree.map(one, param_spec_tree, params_shape,
                        is_leaf=lambda x: isinstance(x, P))
