"""The production train step: loss -> grads -> AdamW, with
microbatched gradient accumulation, optional gradient compression, and
the sharding constraints that make GSPMD overlap the data-parallel
all-reduce with backward compute.

This is the object the train_4k dry-run cells lower — params in,
params out, nothing mocked.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.train import optimizer as opt


@dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    accum_steps: int = 1          # microbatch gradient accumulation
    compress_grads: str | None = None   # None | "bf16"
    opt_8bit: bool = False        # int8 block-quantized m/v


def _compress(grads, mode):
    """Cast gradients before the cross-replica reduction.

    Under pjit the dp all-reduce materializes at the dtype flowing into
    it; casting here halves the wire bytes ("gradient compression").
    The optimizer re-casts to fp32, so the m/v accumulators keep full
    precision.
    """
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
    return grads


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)."""
    grad_fn = jax.value_and_grad(
        lambda p, b: lm.loss_fn(p, cfg, b), has_aux=True)

    def microbatched_grads(params, batch):
        if tcfg.accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, _compress(grads, tcfg.compress_grads)

        n = tcfg.accum_steps
        micro = jax.tree.map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

        def step(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            grads = _compress(grads, tcfg.compress_grads)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_acc + loss), None

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(step, (zero, jnp.float32(0.0)),
                                        micro)
        inv = 1.0 / n
        grads = jax.tree.map(lambda g: g * inv, grads)
        return loss * inv, {"ce": loss * inv}, grads

    update_fn = opt.update_8bit if tcfg.opt_8bit else opt.update

    def train_step(params, opt_state, batch):
        loss, metrics, grads = microbatched_grads(params, batch)
        params, opt_state, stats = update_fn(tcfg.adamw, params, grads,
                                             opt_state)
        return params, opt_state, {"loss": loss, **metrics, **stats}

    return train_step


def opt_init_for(tcfg: TrainConfig):
    return opt.init_8bit if tcfg.opt_8bit else opt.init


def make_prefill_step(cfg: ModelConfig):
    """Inference-prefill: full-context forward, last-token logits."""
    def prefill_step(params, batch):
        memory = (lm.encode(params, cfg, batch["src_embeddings"])
                  if cfg.encoder_layers else None)
        hidden, _ = lm.forward_hidden(params, cfg, batch["tokens"],
                                      prefix=batch.get("prefix"),
                                      memory=memory)
        return lm.logits_fn(params, cfg, hidden[:, -1])
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One-token decode against the standing cache (decode_* shapes)."""
    def serve_step(params, states, tokens, position, memory=None):
        return lm.decode_step(params, cfg, states, tokens, position,
                              memory)
    return serve_step
