"""Assigned architecture configs (exact) + the paper's graph configs."""
