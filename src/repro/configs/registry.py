"""Architecture + shape registry: every (arch x shape) dry-run cell.

``--arch <id>`` resolution for launchers, the assigned input-shape set,
and the applicability matrix (which cells run / why some are N/A).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig
from repro.configs import (arctic_480b, granite_20b, h2o_danube,
                           hymba_1p5b, llama4_maverick, paligemma_3b,
                           phi3_mini, qwen3_14b, rwkv6_3b, seamless_m4t)

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        qwen3_14b.CONFIG, phi3_mini.CONFIG, h2o_danube.CONFIG,
        granite_20b.CONFIG, llama4_maverick.CONFIG, arctic_480b.CONFIG,
        hymba_1p5b.CONFIG, seamless_m4t.CONFIG, paligemma_3b.CONFIG,
        rwkv6_3b.CONFIG,
    ]
}

# short aliases for --arch
ALIASES = {
    "qwen3": "qwen3-14b", "phi3": "phi3-mini-3.8b",
    "danube": "h2o-danube-1.8b", "granite": "granite-20b",
    "llama4": "llama4-maverick-400b-a17b", "arctic": "arctic-480b",
    "hymba": "hymba-1.5b", "seamless": "seamless-m4t-medium",
    "paligemma": "paligemma-3b", "rwkv6": "rwkv6-3b",
}


def get(name: str, reduced: bool = False) -> ModelConfig:
    cfg = ARCHS[ALIASES.get(name, name)]
    return cfg.reduced() if reduced else cfg


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # "train" | "prefill" | "decode"


SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def cell_status(cfg: ModelConfig, shape: Shape) -> str:
    """'run' or a skip reason — the 40-cell applicability matrix."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "skip: pure full attention at 500k (quadratic); " \
               "per assignment, run only for SSM/hybrid/linear-attn"
    return "run"


def all_cells():
    """Yield (arch, shape, status) for all 40 cells."""
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            yield cfg, shape, cell_status(cfg, shape)
