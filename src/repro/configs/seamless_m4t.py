"""seamless-m4t-medium [audio]: enc-dec 12L d1024 16H (kv=16) ff4096
vocab256206 per [arXiv:2308.11596; hf].

Transformer backbone only (assignment): 12 encoder + 12 decoder layers
with cross-attention.  The audio frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, frames, d_model).
Encoder-decoder with full attention => long_500k skipped; decode
shapes run (it has a decoder).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    encoder_layers=12, cross_attention=True, frontend="audio_stub",
    tie_embeddings=False,
)
