"""arctic-480b [moe]: 35L d7168 56H (GQA kv=8) ff4864 vocab32000,
MoE 128 experts top-2 + dense residual.

Snowflake arctic dense-MoE hybrid per [hf:Snowflake/snowflake-arctic-
base; hf]: a dense MLP runs in parallel (residual) with the 128-expert
top-2 MoE in every layer. head_dim 128 (56*128=7168).
Full attention => long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    moe=True, n_experts=128, top_k=2, capacity_factor=1.25,
    dense_residual=True, dense_residual_ff=4864,
    tie_embeddings=False,
)
