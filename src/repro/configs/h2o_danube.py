"""h2o-danube-1.8b [dense]: 24L d2560 32H (GQA kv=8) ff6912 vocab32000.

llama+mistral mix with sliding-window attention per
[arXiv:2401.16818; hf] (window 4096).  SWA caps the KV cache, so this
arch RUNS the long_500k decode shape (sub-quadratic).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80,
    sliding_window=4096, tie_embeddings=False,
)
