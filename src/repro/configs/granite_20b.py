"""granite-20b [dense]: 52L d6144 48H (MQA kv=1) ff24576 vocab49152.

llama-arch code model per [arXiv:2405.04324; hf]. head_dim 128.
Pure full attention => long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    tie_embeddings=False,
)
