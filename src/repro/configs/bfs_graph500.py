"""The paper's own workload configs: Graph500 RMAT graphs (§5.2).

SCALE 18/19/20 with edgefactor 16 are the paper's measured points
(Fig. 10 a-c); larger scales size the multi-chip dry-runs.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class GraphConfig:
    name: str
    scale: int
    edgefactor: int = 16
    n_roots: int = 64          # paper §5.3 experimental design
    graph_format: str = "auto"  # repro/formats layout ("auto" = tuner)

    @property
    def n_vertices(self) -> int:
        return 1 << self.scale

    @property
    def n_edges_directed(self) -> int:
        return 2 * self.n_vertices * self.edgefactor


@dataclass(frozen=True)
class BfsServeConfig:
    """Defaults for the batched BFS query service and benchmark.

    ``batch_slots`` is the fixed multi-root width (engine launch and
    serve batch alike); 8 is the smallest batch that amortizes the
    layer-loop fixed costs on the quick CPU scales and is the
    benchmark's reported configuration.  ``graph_format`` is the
    preprocess-on-load layout choice (`repro.formats`): "auto" runs
    the autotuner on the resident graph's degree statistics.
    """
    batch_slots: int = 8
    max_layers: int = 64
    algorithm: str = "simd"
    graph_format: str = "auto"


@dataclass(frozen=True)
class FormatSweepConfig:
    """The benchmarks/bfs_formats.py experiment grid: every registered
    layout x a representative policy subset, on the paper's skewed
    RMAT workload (where SELL-C-σ is expected to at least match CSR)."""
    formats: tuple = ("csr", "sell", "bitmap")
    policies: tuple = ("topdown", "threshold", "hybrid")
    simd_threshold: int = 2048   # ThresholdSimd knee at bench scales


GRAPHS = {
    f"rmat-{s}": GraphConfig(f"rmat-{s}", scale=s)
    for s in (10, 12, 14, 16, 18, 19, 20, 22, 24, 27)
}
PAPER_GRAPHS = ("rmat-18", "rmat-19", "rmat-20")
SERVE = BfsServeConfig()
FORMAT_SWEEP = FormatSweepConfig()
