"""The paper's own workload configs: Graph500 RMAT graphs (§5.2).

SCALE 18/19/20 with edgefactor 16 are the paper's measured points
(Fig. 10 a-c); larger scales size the multi-chip dry-runs.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class GraphConfig:
    name: str
    scale: int
    edgefactor: int = 16
    n_roots: int = 64          # paper §5.3 experimental design

    @property
    def n_vertices(self) -> int:
        return 1 << self.scale

    @property
    def n_edges_directed(self) -> int:
        return 2 * self.n_vertices * self.edgefactor


@dataclass(frozen=True)
class BfsServeConfig:
    """Defaults for the batched BFS query service and benchmark.

    ``batch_slots`` is the fixed multi-root width (engine launch and
    serve batch alike); 8 is the smallest batch that amortizes the
    layer-loop fixed costs on the quick CPU scales and is the
    benchmark's reported configuration.
    """
    batch_slots: int = 8
    max_layers: int = 64
    algorithm: str = "simd"


GRAPHS = {
    f"rmat-{s}": GraphConfig(f"rmat-{s}", scale=s)
    for s in (10, 12, 14, 16, 18, 19, 20, 22, 24, 27)
}
PAPER_GRAPHS = ("rmat-18", "rmat-19", "rmat-20")
SERVE = BfsServeConfig()
