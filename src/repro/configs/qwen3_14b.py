"""qwen3-14b [dense]: 40L d5120 40H (GQA kv=8) ff17408 vocab151936.

qk_norm + GQA per [hf:Qwen/Qwen3-8B; hf]. head_dim 128 (40*128=5120).
Pure full attention => long_500k is skipped (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab_size=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=False,
)
