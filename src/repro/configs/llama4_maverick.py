"""llama4-maverick-400b-a17b [moe]: 48L d5120 40H (GQA kv=8) ff8192
vocab202048, MoE 128 experts top-1.

Per [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  MoE layers
interleave with dense layers (moe_stride=2, the Llama-4 pattern) —
24 MoE layers x 128 experts x 3 x 5120 x 8192 = 387B expert params,
matching the 400B total / 17B active advertised by the name; with
moe_stride=1 the model would be 1.2T, contradicting its own name.
The shared-expert variant of the HF release is out of assignment
scope (noted in DESIGN.md).  Full attention => long_500k skipped
("early fusion" multimodality enters as tokens).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    moe=True, n_experts=128, top_k=1, moe_stride=2,
    capacity_factor=1.25,
    tie_embeddings=False,
)
