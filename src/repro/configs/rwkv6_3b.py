"""rwkv6-3b [ssm]: 32L d2560 (attention-free) ff8960 vocab65536 —
Finch, data-dependent per-channel decay [arXiv:2404.05892; hf].

40 WKV heads of 64 (2560/64); chunked-parallel linear attention for
train/prefill, O(1) state decode.  Attention-free => RUNS long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab_size=65536, head_dim=64,
    attn_free=True, tie_embeddings=False,
)
