"""paligemma-3b [vlm]: 18L d2048 8H (MQA kv=1) ff16384 vocab257216
per [arXiv:2407.07726; hf].

SigLIP vision tower is a STUB — input_specs() provides 256 precomputed
patch embeddings (B, 256, d_model) prepended as a prefix (gemma
head_dim 256, GeGLU).  Full attention => long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab_size=257216, head_dim=256,
    mlp="geglu", prefix_len=256, frontend="siglip_stub",
    tie_embeddings=True,
)
