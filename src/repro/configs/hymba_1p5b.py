"""hymba-1.5b [hybrid]: 32L d1600 25H (GQA kv=5) ff5504 vocab32001,
ssm_state=16 — parallel attention + mamba heads per layer
[arXiv:2411.13676; hf].

Each layer runs GQA attention and a selective SSM on the same normed
input and averages their (re-normed) outputs — the Hymba parallel-head
fusion.  Meta-tokens from the paper are out of assignment scope (noted
in DESIGN.md).  Hybrid SSM => RUNS long_500k (attention path uses a
sliding window at that length via serve config).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm=True, ssm_state=16, sliding_window=2048,
    tie_embeddings=True,
)
