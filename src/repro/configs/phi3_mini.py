"""phi3-mini-3.8b [dense]: 32L d3072 32H (GQA kv=32) ff8192 vocab32064.

RoPE + SwiGLU + GQA (kv=32 == MHA) per [arXiv:2404.14219; unverified].
Pure full attention => long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064, head_dim=96,
    tie_embeddings=False,
)
