"""Fault-tolerant training runtime.

The driver a 1000-node job actually needs, exercised end-to-end in the
single-process container:

  * checkpoint/restart: on ANY step failure the driver reloads the
    latest committed checkpoint and resumes — the data pipeline is a
    pure function of step (data/tokens.py) so the replayed stream is
    bit-identical;
  * failure injection: ``FailureInjector`` raises at configured steps
    (tests kill the job mid-run and assert the loss curve continues
    seamlessly);
  * straggler mitigation: per-step wall-time watchdog — steps slower
    than ``straggler_factor`` x the running median are logged and
    counted; on real pods this signal feeds the scheduler's
    drain-and-replace decision (documented hook: ``on_straggler``),
    while deterministic data sharding means a replaced host rejoins
    without re-coordination;
  * elastic restart: resume onto a different mesh by passing new
    shardings to the manager (checkpoint/ckpt.py handles re-sharding).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from repro.checkpoint.ckpt import CheckpointManager, latest_step


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raise SimulatedFailure the first time each listed step runs."""
    at_steps: tuple[int, ...] = ()
    fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class RunStats:
    steps: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


def train_loop(*, train_step, params, opt_state, data_stream_fn,
               ckpt: CheckpointManager, total_steps: int,
               injector: FailureInjector | None = None,
               straggler_factor: float = 3.0,
               on_straggler=None, max_restarts: int = 10) -> RunStats:
    """Run to ``total_steps`` with restart-on-failure.

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    data_stream_fn(start_step) -> iterator of (step, batch)
    """
    stats = RunStats()
    state = {"params": params, "opt": opt_state}
    start = 0

    restarts = 0
    while True:
        try:
            stream = data_stream_fn(start)
            for step, batch in stream:
                if step >= total_steps:
                    return stats
                if injector is not None:
                    injector.check(step)
                t0 = time.perf_counter()
                state["params"], state["opt"], metrics = train_step(
                    state["params"], state["opt"], batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                stats.steps += 1
                stats.losses.append(float(metrics["loss"]))
                stats.step_times.append(dt)
                med = sorted(stats.step_times)[len(stats.step_times) // 2]
                if len(stats.step_times) > 5 and dt > straggler_factor * med:
                    stats.stragglers += 1
                    if on_straggler is not None:
                        on_straggler(step, dt, med)
                ckpt.maybe_save(step + 1,
                                {"params": state["params"],
                                 "opt": state["opt"]},
                                metadata={"loss": float(metrics["loss"])})
            return stats
        except SimulatedFailure:
            restarts += 1
            stats.restarts += 1
            if restarts > max_restarts:
                raise
            resumed = latest_step(ckpt.directory)
            if resumed is None:
                start = 0          # no checkpoint yet: restart cold
                continue
            restored, _, step = ckpt.restore_latest(
                {"params": state["params"], "opt": state["opt"]})
            state["params"] = restored["params"]
            state["opt"] = restored["opt"]
            start = step
