"""Substrate: runtime."""
