"""Format registry: name -> GraphFormat class.

Formats self-register at import time (the ``@register`` decorator in
each format module); `repro.formats.__init__` imports every built-in
module so ``available()`` is complete after ``import repro.formats``.
"""
from __future__ import annotations

from repro.formats.base import GraphFormat

_REGISTRY: dict[str, type[GraphFormat]] = {}


def register(cls: type[GraphFormat]) -> type[GraphFormat]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    name = getattr(cls, "name", None)
    if not isinstance(name, str) or not name:
        raise ValueError(f"{cls.__name__} needs a non-empty `name`")
    if name in _REGISTRY and _REGISTRY[name] is not cls:
        raise ValueError(f"format {name!r} already registered "
                         f"({_REGISTRY[name].__name__})")
    _REGISTRY[name] = cls
    return cls


def get(name: str) -> type[GraphFormat]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown graph format {name!r}; "
                       f"available: {available()}") from None


def available() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def build(graph, name: str = "auto", **kwargs) -> GraphFormat:
    """Build a named format from an EdgeList/Csr/format instance.

    ``name="auto"`` delegates to the autotuner (`autotune.build`).
    """
    if name == "auto":
        from repro.formats import autotune
        return autotune.build(graph, **kwargs)
    return get(name).from_graph(graph, **kwargs)
