"""CsrFormat — the existing §3.3.1 CSR as a registered GraphFormat.

A thin adapter around `core/csr.py`: the arrays and the §4.2 padding
convention are unchanged.  Since ISSUE 3 the default gather primitive
is the **fused in-kernel gather** (kernels/gather_expand.py): a
per-layer planning pass marks the rows-blocks the frontier's
adjacency touches and the kernel DMAs only those, recomputing
edge->owner with a VMEM binary search — HBM traffic proportional to
the live frontier.  ``pipeline="materialized"`` rebuilds the legacy
bitmap->apportion edge stream (`engine.edge_stream`, a full-E (u, v,
valid) HBM round trip per SIMD layer) for the ablation axis.  The
baseline every other format is measured against.
"""
from __future__ import annotations

import jax

from repro.core.csr import Csr, from_edges as csr_from_edges
from repro.core.rmat import EdgeList
from repro.formats.base import Footprint, GraphFormat, nbytes
from repro.formats.registry import register


@register
@jax.tree_util.register_pytree_node_class
class CsrFormat(GraphFormat):
    name = "csr"
    # the whole-layer megakernel (kernels/layer_fused.py) is built on
    # the CSR rows-block schedule; see GraphFormat.supports_megakernel
    supports_megakernel = True
    # the whole-traversal persistent kernel (ISSUE 9,
    # kernels/traversal_fused.py) keeps the in-kernel scalar arm
    # mode-blended into the same racy sweep, so both scalar
    # algorithms' reached sets are honored (the racy-parent tie-break
    # is tile-partition-determined either way)
    supports_persistent = True
    persistent_algorithms = ("simd", "nonsimd")
    # the semiring portfolio (ISSUE 10) rides the fused gather's
    # active-tile schedule with the scatter-min relax kernel
    # (kernels/gather_expand.py `gather_relax_batched`); see
    # GraphFormat.supported_semirings
    supported_semirings = ("sssp", "cc", "ksource_bfs")

    def __init__(self, colstarts, rows, n_vertices: int, n_edges: int):
        self.colstarts = colstarts
        self.rows = rows
        self._n_vertices = int(n_vertices)
        self._n_edges = int(n_edges)

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return ((self.colstarts, self.rows),
                (self._n_vertices, self._n_edges))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], *aux)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_edges(cls, edges: EdgeList) -> "CsrFormat":
        # no build options: unknown kwargs fail loudly at the call
        return cls.from_csr(csr_from_edges(edges))

    @classmethod
    def from_csr(cls, csr: Csr) -> "CsrFormat":
        return cls(csr.colstarts, csr.rows, csr.n_vertices, csr.n_edges)

    def to_csr(self) -> Csr:
        return Csr(rows=self.rows, colstarts=self.colstarts,
                   n_vertices=self._n_vertices, n_edges=self._n_edges)

    def validate_structure(self) -> "CsrFormat":
        # memoized per instance: the data checks read the device
        # arrays back to host (O(E)), and the plan cache's hot path
        # re-plans the same format object many times
        if not getattr(self, "_structure_ok", False):
            from repro.core.csr import check_structure
            check_structure(self.to_csr())
            self._structure_ok = True
        return self

    # -- static geometry -------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._n_vertices

    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def n_edges_padded(self) -> int:
        return int(self.rows.shape[0])

    # -- engine contract -------------------------------------------------
    def degrees(self) -> jax.Array:
        return self.colstarts[1:] - self.colstarts[:-1]

    def _build_steps(self, spec) -> dict:
        from repro.core import engine
        return engine._make_steps(self.colstarts, self.rows,
                                  self._n_vertices,
                                  self.n_vertices_padded,
                                  self.n_edges_padded, spec.algorithm,
                                  spec.tile, spec.pipeline, spec.packed,
                                  spec.prefetch_depth)

    def _build_semiring_step(self, spec, semiring):
        import jax.numpy as jnp

        from repro.core import engine
        from repro.kernels import ops
        tile = spec.tile
        rows_t = engine._pad_rows_to_tile(self.rows, self._n_vertices,
                                          tile)
        n_blocks = rows_t.shape[0] // tile
        v = self._n_vertices
        full_wl = jnp.arange(n_blocks, dtype=jnp.int32)

        def step(frontier, vals, dense):
            with ops.count_launches() as c:
                wl, na = engine.plan_active_tiles_batched(
                    self.colstarts, frontier, v, tile, n_blocks,
                    packed=spec.packed)
                # dense arm (CC endgame): skip the compacted schedule,
                # sweep every block — the planner still ran (its cost
                # is charged), but a near-full frontier makes the full
                # work-list the cheaper schedule
                wl = jnp.where(dense[:, None], full_wl[None], wl)
                na = jnp.where(dense, jnp.int32(n_blocks), na)
                new_vals, p_layer = ops.gather_relax_batched(
                    wl, na, rows_t, self.colstarts, frontier, vals,
                    n_vertices=v, tile=tile, unit=semiring.unit,
                    weighted=semiring.weighted)
            aux = engine.StepAux(na.sum(dtype=jnp.int32),
                                 jnp.int32(0), c.count)
            return new_vals, p_layer, aux

        return step

    def persistent_fits(self, n_roots: int, spec) -> bool:
        from repro.core import bitmap as bm
        from repro.core.engine import _pad_rows_to_tile
        from repro.kernels import ops
        rows_t = _pad_rows_to_tile(self.rows, self._n_vertices,
                                   spec.tile)
        return ops.persistent_fits(
            self.n_vertices_padded // bm.BITS_PER_WORD,
            self.n_vertices_padded, int(self.colstarts.shape[0]),
            spec.tile, int(n_roots), spec.max_layers,
            spec.prefetch_depth, int(rows_t.shape[0]) // spec.tile)

    def persistent_run(self, frontier, visited, parent, spec):
        from repro.core.engine import _pad_rows_to_tile
        from repro.kernels import ops
        rows_t = _pad_rows_to_tile(self.rows, self._n_vertices,
                                   spec.tile)
        return ops.traversal_fused_batched(
            rows_t, self.colstarts, frontier, visited, parent,
            n_vertices=self._n_vertices, tile=spec.tile,
            policy=spec.policy, max_layers=spec.max_layers,
            prefetch_depth=spec.prefetch_depth)

    def resolve_tile(self, tile: int | None) -> int:
        # CSR tiles the rows array: the fused pipeline's DMA block ==
        # the §4 prefetch distance.  The fused rule bottoms out at one
        # lane set (128) so small graphs still split into several
        # blocks for the active-tile schedule to skip; the hostloop
        # A/B driver keeps the legacy `_auto_tile` rule separately.
        # The auto choice reads the geometry-keyed affinity table
        # (formats/affinity.py) through the format instance.
        from repro.core import engine
        return engine._resolve_tile_csr(tile, self.n_edges_padded,
                                        fmt=self)

    # -- accounting ------------------------------------------------------
    def footprint(self) -> Footprint:
        return Footprint(self.name,
                         (("rows", nbytes(self.rows)),
                          ("colstarts", nbytes(self.colstarts))))

    @property
    def edge_slots(self) -> int:
        return self.n_edges_padded

    def layer_bytes(self) -> int:
        # the materialized pipeline WRITES the apportioned (u, v,
        # valid) stream to HBM and the kernel re-reads it: 2 x 3 words
        # x 4 B per slot per layer — the round trip the fused gather
        # eliminates
        return 2 * 3 * 4 * self.edge_slots

    def plan_bytes(self, tile: int, packed: bool = True) -> int:
        # the CSR planner also streams colstarts (degree marks)
        return (4 * (self.n_vertices + 1)
                + super().plan_bytes(tile, packed))
