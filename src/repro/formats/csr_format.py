"""CsrFormat — the existing §3.3.1 CSR as a registered GraphFormat.

A thin adapter around `core/csr.py`: the arrays and the §4.2 padding
convention are unchanged; the gather primitive is the engine's
bitmap->apportion edge stream (`engine.edge_stream`), so per-layer
work is O(frontier edges) at the price of the apportionment pass
(compaction + prefix-sum) every layer.  The baseline every other
format is measured against.
"""
from __future__ import annotations

import jax

from repro.core.csr import Csr, from_edges as csr_from_edges
from repro.core.rmat import EdgeList
from repro.formats.base import Footprint, GraphFormat, nbytes
from repro.formats.registry import register


@register
@jax.tree_util.register_pytree_node_class
class CsrFormat(GraphFormat):
    name = "csr"

    def __init__(self, colstarts, rows, n_vertices: int, n_edges: int):
        self.colstarts = colstarts
        self.rows = rows
        self._n_vertices = int(n_vertices)
        self._n_edges = int(n_edges)

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return ((self.colstarts, self.rows),
                (self._n_vertices, self._n_edges))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], *aux)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_edges(cls, edges: EdgeList) -> "CsrFormat":
        # no build options: unknown kwargs fail loudly at the call
        return cls.from_csr(csr_from_edges(edges))

    @classmethod
    def from_csr(cls, csr: Csr) -> "CsrFormat":
        return cls(csr.colstarts, csr.rows, csr.n_vertices, csr.n_edges)

    def to_csr(self) -> Csr:
        return Csr(rows=self.rows, colstarts=self.colstarts,
                   n_vertices=self._n_vertices, n_edges=self._n_edges)

    # -- static geometry -------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._n_vertices

    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def n_edges_padded(self) -> int:
        return int(self.rows.shape[0])

    # -- engine contract -------------------------------------------------
    def degrees(self) -> jax.Array:
        return self.colstarts[1:] - self.colstarts[:-1]

    def make_steps(self, *, algorithm: str, tile: int) -> dict:
        from repro.core import engine
        return engine._make_steps(self.colstarts, self.rows,
                                  self._n_vertices,
                                  self.n_vertices_padded,
                                  self.n_edges_padded, algorithm, tile)

    def resolve_tile(self, tile: int | None) -> int:
        # CSR tiles the apportioned edge stream; the shared auto rule
        # (interpret-mode grid clamp) lives in engine and stays the
        # `traverse_hostloop` behavior too.
        from repro.core import engine
        return engine._resolve_tile(tile, self.n_edges_padded)

    # -- accounting ------------------------------------------------------
    def footprint(self) -> Footprint:
        return Footprint(self.name,
                         (("rows", nbytes(self.rows)),
                          ("colstarts", nbytes(self.colstarts))))

    @property
    def edge_slots(self) -> int:
        return self.n_edges_padded
