"""Geometry-keyed affinity table: the ONE auto-knob lookup (ISSUE 6).

Every ``"auto"`` field on a `TraversalSpec` resolves through
`resolve()` below — the generalization of the PR-4 CSR-tile one-off
(`engine.default_tile_csr`) into a single mechanism any knob (and any
future knob: 2-D mesh shape, out-of-core slab size) reads through.

The committed table lives in ``BENCH_bfs.json`` (regenerate with
``make bench-affinity``).  Sweep rows are keyed by *format* and
*geometry class*, so a skewed RMAT graph and a uniform mesh resolve
to different tuned values from the same table:

    affinity.{format}.{geometry}.{knob}{value}

    affinity.csr.skew16.tile4096      {"us_per_call": ...}
    affinity.csr.skew16.prefetch1     {"us_per_call": ...}
    affinity.csr.skew16.pipeline_megakernel
    affinity.sell.skew16.sigma1024

Numeric knobs append the value directly (``tile4096``); string knobs
separate it with ``_`` (``pipeline_megakernel``).  Within one
(format, geometry, knob) group the row with the lowest ``us_per_call``
wins.  The geometry class buckets `autotune.measure` statistics:
``dense`` when density crosses the bitmap regime threshold, else a
power-of-4 degree-skew bucket (``skew1`` | ``skew4`` | ``skew16`` |
``skew64`` — the label is the bucket's lower bound; RMAT graphs land
in ``skew16``/``skew64``, meshes and paths in ``skew1``).

Precedence, highest first:

1. env override (``REPRO_BFS_TILE``, tile knob only — the A/B lever);
2. the geometry-keyed committed row;
3. the PR-4 flat rows (``affinity.tile<N>``, tile knob only) — the
   back-compat read path for tables committed before ISSUE 6;
4. the caller's default (the pre-table heuristics).

Geometry classification needs concrete degree values; under tracing
(a legacy shim planning inside ``jit``) it returns None and the
lookup falls through to tiers 3-4.  Classes are memoized by the
graph's geometry (shapes/dtypes + static aux), so a traced resolve of
an already-seen geometry still lands in its class.
"""
from __future__ import annotations

import functools
import json
import os
import pathlib

import jax

from repro.formats import autotune

_TILE_ENV = "REPRO_BFS_TILE"

# knobs whose table value is a string (key form ``{knob}_{value}``);
# everything else parses as int (key form ``{knob}{value}``)
_STR_KNOBS = frozenset({"pipeline", "policy", "algorithm", "merge"})

# spec field -> key token (compact, underscore-free numeric tokens)
_KEY_TOKEN = {"prefetch_depth": "prefetch", "max_layers": "maxlayers"}

# degree-skew bucket lower bounds (powers of 4), label = lower bound
_SKEW_BUCKETS = (64, 16, 4)

_GEOM_CACHE: dict[tuple, str] = {}


def _table_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[3] / "BENCH_bfs.json"


@functools.lru_cache(maxsize=1)
def _table() -> dict:
    """The committed BENCH table (cached; `clear_cache` to re-read)."""
    try:
        return json.loads(_table_path().read_text())
    except (OSError, ValueError):
        return {}


def clear_cache() -> None:
    """Drop the cached table and geometry classes (tests, and the
    affinity benchmark after it rewrites BENCH_bfs.json)."""
    _table.cache_clear()
    _GEOM_CACHE.clear()


def _bucket(stats: autotune.GraphStats) -> str:
    if stats.density >= autotune.DENSITY_THRESHOLD:
        return "dense"
    for lo in _SKEW_BUCKETS:
        if stats.degree_skew >= lo:
            return f"skew{lo}"
    return "skew1"


def _memo_key(graph) -> tuple:
    leaves = jax.tree_util.tree_leaves(graph)
    return (type(graph).__name__,
            tuple((tuple(getattr(x, "shape", ())),
                   str(getattr(x, "dtype", type(x).__name__)))
                  for x in leaves))


def geometry_class(graph) -> str | None:
    """Density/skew bucket of a graph (GraphFormat or Csr) — the
    middle segment of the affinity keys.  None when the graph's
    values are traced AND its geometry has never been classified
    concretely (auto knobs then fall through to the flat/default
    tiers)."""
    key = _memo_key(graph)
    hit = _GEOM_CACHE.get(key)
    if hit is not None:
        return hit
    try:
        geom = _bucket(autotune.measure(graph))
    except jax.errors.TracerArrayConversionError:
        return None
    except jax.errors.ConcretizationTypeError:
        return None
    _GEOM_CACHE[key] = geom
    return geom


def _best_row(prefix: str, knob: str):
    """argmin over ``us_per_call`` of every table row under
    ``prefix`` -> parsed knob value (int or str), or None."""
    token = _KEY_TOKEN.get(knob, knob)
    sep = f"{token}_" if knob in _STR_KNOBS else token
    best, best_us = None, None
    for key, rec in _table().items():
        tail = key[len(prefix):] if key.startswith(prefix) else None
        if tail is None or not tail.startswith(sep):
            continue
        raw = tail[len(sep):]
        try:
            value = raw if knob in _STR_KNOBS else int(raw)
            us = float(rec["us_per_call"])
        except (KeyError, TypeError, ValueError):
            continue
        if best_us is None or us < best_us:
            best, best_us = value, us
    return best


def key_for(fmt_name: str, geometry: str, knob: str, value) -> str:
    """The canonical sweep-row key — the writer-side counterpart of
    `resolve` (benchmarks/affinity.py emits through this so the
    schema cannot drift between the sweep and the lookup)."""
    token = _KEY_TOKEN.get(knob, knob)
    sep = "_" if knob in _STR_KNOBS else ""
    return f"affinity.{fmt_name}.{geometry}.{token}{sep}{value}"


def resolve(graph, knob: str, default, *, fmt_name: str | None = None):
    """Resolve one auto knob: env > geometry-keyed row > legacy flat
    row > ``default``.  ``graph`` may be None (no geometry tier —
    legacy array-level callers); ``fmt_name`` overrides the format
    segment when ``graph`` is not a built format (e.g. a Csr headed
    for the SELL builder)."""
    if knob == "tile":
        env = os.environ.get(_TILE_ENV)
        if env:
            try:
                return max(128, int(env))
            except ValueError:
                raise ValueError(
                    f"{_TILE_ENV}={env!r} is not an integer tile size"
                ) from None
    if graph is not None:
        name = fmt_name if fmt_name is not None \
            else getattr(graph, "name", None)
        geom = geometry_class(graph) if name else None
        if geom is not None:
            row = _best_row(f"affinity.{name}.{geom}.", knob)
            if row is not None:
                return row
    if knob == "tile":
        # PR-4 flat rows: the pre-ISSUE-6 table schema
        flat = _best_row("affinity.", "tile")
        if flat is not None:
            return flat
    return default
