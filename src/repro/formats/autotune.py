"""Format autotuner: pick a layout from graph statistics.

The scenario axis the format subsystem opens (ROADMAP): the serve
layer preprocesses each graph on load and picks the layout the
traversal engine will run on, from three cheap statistics:

* **density** E / V² — dense-and-small graphs take the word-compressed
  adjacency (`bitmap`): the whole matrix fits a byte budget and one
  layer is a pure word sweep (the bottom-up/dense regime).
* **degree skew** max_deg / mean_deg — skewed (power-law / RMAT)
  graphs take SELL-C-σ (`sell`): degree sorting makes the per-slice
  padding small exactly when the degree distribution is skewed, and
  the SpMV sweep wins when most edges sit in a few fat layers.
* otherwise CSR (`csr`): uniform-degree / high-diameter graphs, where
  O(frontier edges) per layer beats any whole-adjacency sweep.

Thresholds are intentionally coarse (this is a per-graph, build-time
decision, not a per-layer one — the per-layer decision is the
direction policy's job).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core.csr import Csr, from_edges as csr_from_edges, \
    padded_vertex_count
from repro.core.rmat import EdgeList
from repro.formats import registry
from repro.formats.base import GraphFormat

# decision thresholds (see module docstring)
BITMAP_BUDGET_BYTES = 4 << 20     # adjacency-bitmap cap (fits VMEM-ish)
DENSITY_THRESHOLD = 0.05          # E/V^2 floor for the dense regime
SKEW_THRESHOLD = 4.0              # max_deg/mean_deg floor for SELL


class GraphStats(NamedTuple):
    n_vertices: int
    n_edges: int
    mean_degree: float
    max_degree: int
    degree_skew: float            # max_degree / mean_degree
    density: float                # n_edges / n_vertices^2
    bitmap_bytes: int             # what BitmapCompressedFormat would pin


class Choice(NamedTuple):
    format: str
    reason: str
    stats: GraphStats


def _as_csr(graph) -> Csr:
    if isinstance(graph, Csr):
        return graph
    if isinstance(graph, EdgeList):
        return csr_from_edges(graph)
    raise TypeError(f"cannot autotune over {type(graph).__name__}")


def measure(graph) -> GraphStats:
    """Degree/density statistics from a Csr, EdgeList or GraphFormat."""
    if isinstance(graph, GraphFormat):
        deg = np.asarray(graph.degrees(), np.int64)
        v, e = graph.n_vertices, graph.n_edges
    else:
        csr = _as_csr(graph)
        deg = np.asarray(csr.degrees(), np.int64)
        v, e = csr.n_vertices, csr.n_edges
    mean = float(deg.mean()) if v else 0.0
    mx = int(deg.max()) if v else 0
    v_pad = padded_vertex_count(v)
    return GraphStats(
        n_vertices=v, n_edges=e, mean_degree=mean, max_degree=mx,
        degree_skew=(mx / mean) if mean > 0 else 0.0,
        density=(e / (v * v)) if v else 0.0,
        bitmap_bytes=v_pad * (v_pad // bm.BITS_PER_WORD) * 4)


def choose(graph, *,
           bitmap_budget_bytes: int = BITMAP_BUDGET_BYTES,
           density_threshold: float = DENSITY_THRESHOLD,
           skew_threshold: float = SKEW_THRESHOLD) -> Choice:
    """Pick a registered format name for this graph."""
    s = measure(graph)
    if (s.bitmap_bytes <= bitmap_budget_bytes
            and s.density >= density_threshold):
        return Choice("bitmap",
                      f"dense regime: density {s.density:.3f} >= "
                      f"{density_threshold} and adjacency bitmap "
                      f"{s.bitmap_bytes/2**20:.2f} MiB fits budget", s)
    if s.degree_skew >= skew_threshold:
        return Choice("sell",
                      f"skewed degrees: max/mean {s.degree_skew:.1f} >= "
                      f"{skew_threshold} — σ-sorted slices absorb the "
                      f"skew (SlimSell)", s)
    return Choice("csr",
                  f"near-uniform degrees (skew {s.degree_skew:.1f}), "
                  f"sparse (density {s.density:.4f}): frontier-"
                  f"proportional gather wins", s)


def build(graph, name: str = "auto", **choose_kwargs) -> GraphFormat:
    """Build the chosen (or named) format — preprocess-on-load entry.

    ``name="auto"`` runs `choose`; any registered name forces that
    layout.  Accepts Csr / EdgeList / an already-built format (kept
    as-is under "auto" or its own name; re-laying out a built format
    needs its `to_csr` — see `GraphFormat.from_graph`).
    """
    if isinstance(graph, GraphFormat) and name in ("auto", graph.name):
        return graph
    if name == "auto":
        name = choose(graph, **choose_kwargs).format
    return registry.get(name).from_graph(graph)
