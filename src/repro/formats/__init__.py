"""Pluggable graph-format subsystem (paper §4.2's layout axis).

``import repro.formats`` registers every built-in layout:

* ``csr``    — the §3.3.1 CSR baseline (core/csr.py adapter);
* ``sell``   — SELL-C-σ sliced ELLPACK (SlimSell), format-specialized
  Pallas sweep kernel in kernels/sell_expand.py;
* ``bitmap`` — word-compressed adjacency for the dense/bottom-up
  regime.

Entry points: `registry.build(graph, name)` ("auto" = autotuner),
`autotune.choose(graph)` for the decision + reasoning, and
`engine.traverse(fmt, roots)` to run the fused engine on any format.
"""
from repro.formats import autotune, registry
from repro.formats.base import Footprint, GraphFormat, csr_to_edges, \
    membership_bytes, traversal_bytes
from repro.formats.bitmap_format import BitmapCompressedFormat
from repro.formats.csr_format import CsrFormat
from repro.formats.registry import available, build, get
from repro.formats.sell import SellFormat

__all__ = [
    "autotune", "registry", "available", "build", "get",
    "Footprint", "GraphFormat", "csr_to_edges", "membership_bytes",
    "traversal_bytes",
    "CsrFormat", "SellFormat", "BitmapCompressedFormat",
]
