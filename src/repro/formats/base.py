"""Graph-format protocol — the paper's §4.2 layout axis made pluggable.

§4.2 spends a full section on data alignment and padding so the Xeon
Phi's gathers never fall into peel/remainder loops; our CSR mimics
that with 128-lane sentinel padding (core/csr.py).  SlimSell
[Besta et al., arXiv:2010.09913] shows the *layout itself* is a free
variable: a sliced-ELLPACK (SELL-C-σ) adjacency is strictly better
suited to wide-SIMD BFS on skewed-degree graphs, and the hybrid
follow-up [Paredes et al., arXiv:1704.02259] notes the bottom-up
phase wants a different layout than top-down.

`GraphFormat` is the contract the traversal engine consumes:

* **build**     — ``from_edges`` / ``from_graph`` (preprocess-on-load;
  Graph500 kernel-2 territory, untimed in the benchmark).
* **gather**    — ``make_steps`` returns the batched per-layer step
  for each engine mode (scalar / SIMD-kernel / bottom-up), the
  format-specialized replacement for the raw ``colstarts/rows``
  apportionment.  All steps share one signature
  ``(frontier, visited, parent) -> (out, visited, parent, StepAux)``
  with a leading root axis, so direction policies work unmodified;
  the `engine.StepAux` tail carries the step's active-tile and
  truncation counters.  The ``pipeline`` build flag selects between
  the frontier-proportional **fused_gather** steps (ISSUE 3:
  in-kernel gather + scalar-prefetched active-tile work-lists) and
  the legacy **materialized** full-stream steps (the ablation
  baseline).
* **counters**  — ``degrees`` feeds the engine's on-device Table 1
  workload counters; ``edge_slots``/``layer_bytes``/``tile_bytes``/
  ``plan_bytes`` are the format's per-layer stream-width and
  bytes-moved accounting for both pipelines (`traversal_bytes` sums
  them over a traversal's layer stats).
* **footprint** — ``footprint`` reports device bytes per array so the
  autotuner and benchmarks can compare layouts.

Formats are registered JAX pytrees (arrays as leaves, static shape
metadata as aux data), so a format instance can be passed straight
into the jitted fused engine (`engine.traverse_format`).
"""
from __future__ import annotations

import abc
from typing import ClassVar, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.csr import Csr, padded_vertex_count, \
    padding_premarked_visited
from repro.core.rmat import EdgeList


class Footprint(NamedTuple):
    """Device-memory report for one built format."""
    format: str
    arrays: tuple[tuple[str, int], ...]   # (array name, bytes)

    @property
    def total_bytes(self) -> int:
        return sum(b for _, b in self.arrays)

    def summary(self) -> str:
        parts = ", ".join(f"{n}={b/2**20:.2f}MiB" for n, b in self.arrays)
        return (f"{self.format}: {self.total_bytes/2**20:.2f} MiB "
                f"({parts})")


def nbytes(arr: jax.Array) -> int:
    return int(arr.size) * arr.dtype.itemsize


def csr_to_edges(csr: Csr) -> EdgeList:
    """Recover the (sorted, symmetrized) COO edge list from a CSR.

    Sentinel padding lives at the tail of ``rows``, so the first
    ``n_edges`` entries are exactly the real destination list.
    """
    src = jnp.repeat(jnp.arange(csr.n_vertices, dtype=jnp.int32),
                     csr.degrees(),
                     total_repeat_length=csr.n_edges_padded)
    return EdgeList(src=src[:csr.n_edges],
                    dst=csr.rows[:csr.n_edges],
                    n_vertices=csr.n_vertices)


class GraphFormat(abc.ABC):
    """Abstract adjacency layout consumed by the traversal engine.

    Subclasses are pytree-registered dataclass-likes: jax arrays in
    ``tree_flatten`` leaves, static ints (vertex/edge counts, slice
    geometry) in aux data — which is what lets `engine.traverse_format`
    jit over a format instance directly.
    """

    name: ClassVar[str]

    #: whether the layout streams edge tiles an input-DMA pipeline can
    #: run ahead of (``TraversalSpec.prefetch_depth > 0``); formats
    #: with no streamed input (the bitmap word sweep) set this False
    #: and `spec.validate(fmt)` rejects the combination
    supports_prefetch: ClassVar[bool] = True

    #: whether the layout implements the whole-layer megakernel
    #: (``TraversalSpec.pipeline="megakernel"`` — ISSUE 6: plan +
    #: compact + gather-expand + restoration in ONE Pallas call).
    #: Opt-in: the format must build megakernel steps in
    #: `_build_steps`; `spec.validate(fmt)` rejects the pipeline on
    #: formats that don't (bitmap has no per-layer launches to fuse).
    #: Since ISSUE 9 both streamed layouts fuse: CSR via the rows-block
    #: schedule, SELL via manual `make_async_copy` cols DMA consuming
    #: an in-kernel slab work-list (kernels/sell_expand.py)
    supports_megakernel: ClassVar[bool] = False

    #: whether the layout implements the whole-TRAVERSAL persistent
    #: kernel (``TraversalSpec.pipeline="persistent"`` — ISSUE 9: the
    #: layer loop, direction decision and termination run INSIDE one
    #: Pallas launch, frontier/visited/parents VMEM-resident across
    #: layers).  Opt-in via `persistent_run`/`persistent_fits`;
    #: `spec.validate(fmt)` rejects the pipeline on formats that don't
    supports_persistent: ClassVar[bool] = False

    #: scalar algorithms the persistent kernel can honor — the
    #: in-kernel layer loop has no plain-jnp scalar arm, so a format
    #: whose MODE_SCALAR semantics differ per algorithm (SELL's
    #: "nonsimd" dense sweep) restricts the set and `spec.validate`
    #: rejects the rest
    persistent_algorithms: ClassVar[tuple] = ()

    #: semiring `TraversalSpec.algorithm` values this layout can run
    #: (ISSUE 10: "sssp" / "cc" / "ksource_bfs").  Opt-in via
    #: `_build_semiring_step`: the layout must offer a per-layer
    #: relaxation step (the scatter-min kernels) — the bitmap word
    #: sweep stores no per-edge stream to relax over and keeps the
    #: empty default, which `spec.validate(fmt)` turns into a typed
    #: rejection instead of a silent wrong answer
    supported_semirings: ClassVar[tuple] = ()

    # -- construction ----------------------------------------------------
    @classmethod
    @abc.abstractmethod
    def from_edges(cls, edges: EdgeList, **kwargs) -> "GraphFormat":
        """Build the layout from a COO edge list (preprocess-on-load)."""

    @classmethod
    def from_graph(cls, graph, **kwargs) -> "GraphFormat":
        """Build from whatever the caller holds: EdgeList, Csr, an
        already-built format of this class (passthrough), or a built
        format that can recover its CSR (``to_csr``)."""
        if isinstance(graph, cls):
            return graph
        if isinstance(graph, GraphFormat):
            to_csr = getattr(graph, "to_csr", None)
            if to_csr is None:
                raise TypeError(
                    f"cannot re-lay-out a built {type(graph).__name__} "
                    f"as {cls.__name__}; pass the Csr or EdgeList it "
                    f"was built from")
            graph = to_csr()
        if isinstance(graph, Csr):
            from_csr = getattr(cls, "from_csr", None)
            if from_csr is not None:     # skip the edge-list round trip
                return from_csr(graph, **kwargs)
            return cls.from_edges(csr_to_edges(graph), **kwargs)
        if isinstance(graph, EdgeList):
            return cls.from_edges(graph, **kwargs)
        raise TypeError(
            f"cannot build {cls.__name__} from {type(graph).__name__}")

    # -- static geometry -------------------------------------------------
    @property
    @abc.abstractmethod
    def n_vertices(self) -> int:
        """Real vertex count V (the sentinel id)."""

    @property
    @abc.abstractmethod
    def n_edges(self) -> int:
        """Real directed edge count (un-padded)."""

    @property
    def n_vertices_padded(self) -> int:
        """Vertex-array size — the engine-wide §4.2 padding convention."""
        return padded_vertex_count(self.n_vertices)

    @property
    def sentinel(self) -> int:
        return self.n_vertices

    # -- engine contract -------------------------------------------------
    @abc.abstractmethod
    def degrees(self) -> jax.Array:
        """(V,) int32 out-degrees — the Table 1 workload counter input."""

    def make_steps(self, spec=None, *, algorithm=None, tile=None,
                   pipeline=None, packed=None,
                   prefetch_depth=None) -> dict:
        """Batched per-layer steps keyed by engine mode.

        Since ISSUE 5 the configuration argument is ONE resolved
        `repro.api.spec.TraversalSpec` — validated here against this
        format (`spec.validate(fmt)`, the single home of invalid-combo
        rejection) and handed to the format's `_build_steps`.  The
        loose keyword form (``algorithm=/tile=/...``) is deprecated
        but still accepted: it is normalized into a spec (tile through
        `resolve_tile`) and follows the same path.

        Returns ``{MODE_SCALAR: fn, MODE_SIMD: fn, MODE_BOTTOMUP: fn}``
        where each ``fn(frontier, visited, parent)`` advances every
        root in the leading batch axis by one layer and returns
        ``(out, visited, parent, engine.StepAux)``.

        Spec fields a format may ignore: ``pipeline`` where one sweep
        serves both flavours (the bitmap layout); ``packed`` where
        planning is already word-native (SELL's membership test, the
        bitmap sweep); ``prefetch_depth`` is *rejected* (not ignored)
        where there is no streamed input to prefetch (bitmap).
        """
        if spec is None:
            # reuse the engine shims' single knob->spec normalizer so
            # the legacy defaults live in exactly one place
            # (engine._KNOB_DEFAULTS) — the defaults-drift class this
            # redesign exists to kill
            from repro.core.engine import _UNSET, _spec_from_knobs
            knobs = dict(algorithm=algorithm, tile=tile,
                         pipeline=pipeline, packed=packed,
                         prefetch_depth=prefetch_depth)
            spec = _spec_from_knobs(
                f"{type(self).__name__}.make_steps",
                None,
                {k: (_UNSET if v is None else v)
                 for k, v in knobs.items()}).resolve(self)
        elif not spec.is_resolved:
            autos = [f for f in spec.field_names()
                     if getattr(spec, f) == "auto"]
            why = (f"fields still 'auto': {autos}" if autos
                   else f"policy is the name {spec.policy!r}, not a "
                        f"policy object")
            raise ValueError(
                f"{type(self).__name__}.make_steps needs a *resolved* "
                f"TraversalSpec ({why}); call spec.resolve(fmt) — or "
                f"repro.bfs.plan, which resolves once and caches the "
                f"executable")
        else:
            spec.validate(self)
        return self._build_steps(spec)

    @abc.abstractmethod
    def _build_steps(self, spec) -> dict:
        """Format-owned step construction from a resolved, validated
        `TraversalSpec` (see `make_steps` for the contract)."""

    def make_semiring_step(self, spec, semiring):
        """One batched per-layer semiring relaxation step (ISSUE 10).

        ``spec`` must be resolved with ``spec.algorithm`` in this
        format's ``supported_semirings`` (`spec.validate(fmt)` is the
        one rejection home, as for `make_steps`); ``semiring`` is the
        registered `algorithms.semiring.Semiring` instance.  Returns
        ``fn(frontier, vals, dense) -> (new_vals, p_layer, StepAux)``
        where ``frontier`` is (B, W) packed words, ``vals`` the
        (B, V_pad) value rows, ``dense`` a (B,) bool selecting the
        full-work-list sweep (the CC endgame's dense arm), and
        ``p_layer`` the per-layer min-id parent scatter the driver
        merges under the improved mask.
        """
        spec.validate(self)
        return self._build_semiring_step(spec, semiring)

    def _build_semiring_step(self, spec, semiring):
        """Format-owned semiring step construction; formats that list
        nothing in ``supported_semirings`` never reach here (validate
        rejects first), so the default is a hard error."""
        raise NotImplementedError(
            f"{type(self).__name__} declares no supported_semirings")

    def resolve_tile(self, tile: int | None) -> int:
        """The format owns tile selection (§4.2: the layout fixes the
        aligned unit).  ``tile`` is the user's override where the
        format honors one; the default accepts any and returns 1."""
        return int(tile) if tile else 1

    # -- persistent (whole-traversal) contract (ISSUE 9) -----------------
    def persistent_fits(self, n_roots: int, spec) -> bool:
        """True when the whole-traversal persistent kernel's working
        set (the full batch's state, resident across layers) fits the
        VMEM budget for this geometry under the *resolved* ``spec``.
        The engine consults this at trace time and degrades
        ``pipeline="persistent"`` observably when False.  Formats
        without a persistent kernel never fit."""
        return False

    def persistent_run(self, frontier, visited, parent, spec):
        """Run the WHOLE multi-root traversal in ONE Pallas launch
        (``supports_persistent`` formats only): layer loop, §4.1
        direction decision and termination all in-kernel.  Arguments
        are the `engine._init_batched` state arrays; returns
        ``(frontier, visited, parent, depths, layers, stats)`` — the
        fused engine's whole-traversal contract, with the stats launch
        column charging 1 per *traversal* (at layer 0)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no whole-traversal persistent "
            f"kernel (supports_persistent=False)")

    # -- accounting ------------------------------------------------------
    @abc.abstractmethod
    def footprint(self) -> Footprint:
        """Per-array device bytes."""

    @property
    @abc.abstractmethod
    def edge_slots(self) -> int:
        """Edge-stream slots one SIMD layer examines (incl. padding)."""

    def layer_bytes(self) -> int:
        """Analytic bytes one *materialized* SIMD layer streams from
        HBM (the bytes-moved counter of benchmarks/bfs_formats.py).
        Default: the edge stream at 4 B/slot for the (nbr, cand,
        valid) triple; CSR overrides with the write+read round trip
        its pipeline actually performs."""
        return 3 * 4 * self.edge_slots

    # -- fused-pipeline accounting (ISSUE 3) -----------------------------
    def tile_bytes(self, tile: int) -> int:
        """Bytes ONE active tile DMAs in the fused pipeline — ``tile``
        is in the format's own grid units (CSR: rows slots; SELL:
        slabs per step)."""
        return 4 * tile

    def mask_bytes(self, packed: bool = True) -> int:
        """Per-layer frontier/visited/next *membership* bytes the
        engine holds/streams (ISSUE 4's packed-bytes model): packed
        uint32 words cost ``3 * V_pad / 8`` per layer; the legacy
        dense int32-mask representation cost ``3 * 4 * V_pad`` — the
        32x the paper's §3.3.1 compression buys."""
        w_bytes = self.n_vertices_padded // 8
        return 3 * w_bytes if packed else 3 * 4 * self.n_vertices_padded

    def plan_mask_bytes(self, packed: bool = True) -> int:
        """Bytes of active-set membership the planning pass reads per
        layer: the packed bitmap (V/8) vs the dense V-mask (4V)."""
        if packed:
            return self.n_vertices_padded // 8
        return 4 * self.n_vertices_padded

    def plan_bytes(self, tile: int, packed: bool = True) -> int:
        """Per-layer traffic of the fused pipeline's planning pass
        (the active-tile marking + work-list round trip) — charged
        once per layer regardless of frontier size, which is exactly
        why fused bytes stay ~flat on thin layers."""
        n_blocks = -(-self.edge_slots // max(tile, 1))
        return (self.plan_mask_bytes(packed)    # active mask read
                + 2 * 4 * n_blocks)             # work-list write+read

    # -- admission-time validation (ISSUE 8) ----------------------------
    def validate_structure(self) -> "GraphFormat":
        """Strict structural validation at admission time.

        Raises `repro.errors.GraphValidationError` when the built
        layout could produce a *wrong traversal* (out-of-range ids,
        non-monotone extents, NaN geometry).  The default covers the
        geometry scalars every format shares; layouts with checkable
        adjacency arrays override (CsrFormat routes through
        `core.csr.check_structure`).  Tracer-held arrays skip data
        checks.  Returns ``self`` so call sites can chain.
        """
        from repro.core.csr import _as_count
        from repro.errors import GraphValidationError
        v = _as_count("n_vertices", self.n_vertices)
        _as_count("n_edges", self.n_edges)
        if v < 1:
            raise GraphValidationError(
                "n_vertices must be >= 1 (a BFS needs at least a root "
                "vertex); got 0")
        return self

    # -- shared init helpers --------------------------------------------
    def init_visited(self) -> jax.Array:
        """Visited bitmap with every padding vertex pre-marked — the
        mask-replaces-remainder-loops convention of §4.2 (shared with
        the CSR drivers via `csr.padding_premarked_visited`)."""
        return padding_premarked_visited(self.n_vertices)

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(V={self.n_vertices}, "
                f"E={self.n_edges})")


def traversal_bytes(fmt: GraphFormat, stats, *, tile: int,
                    pipeline: str = "fused_gather",
                    packed: bool = True) -> int:
    """Analytic HBM bytes a whole traversal's expansion layers moved.

    ``stats`` is `engine.layer_stats(result)` — the fused pipeline
    charges each layer its *measured* active tiles plus the planning
    pass; the materialized pipeline charges the full stream every
    layer.  Single-root accounting (batched stats sum tiles across
    roots, so the fused term scales; the materialized term would need
    an explicit root multiplier).  ``packed`` selects the planning
    pass's mask-byte model (packed words vs dense masks).
    """
    if pipeline == "materialized":
        return fmt.layer_bytes() * len(stats)
    return sum(fmt.tile_bytes(tile) * s.active_tiles
               + fmt.plan_bytes(tile, packed) for s in stats)


def membership_bytes(fmt: GraphFormat, stats, *,
                     packed: bool = True) -> int:
    """Analytic frontier/visited/next *membership* bytes a traversal
    carried per its representation (the ISSUE 4 acceptance counter):
    per layer, the three state bitmaps plus the planning pass's
    active-set read — V/8-scaled under ``packed``, 4V-scaled under
    the legacy dense-mask representation.

    Scope: this counts the representation-dependent DELTA only.  Both
    planning arms additionally materialize V-sized int32 working
    arrays (the packed arm's compacted queue and gathered colstarts
    ranges; the dense arm's per-vertex colstarts slices and block-id
    intermediates) — those are common to both and cancel, so they are
    deliberately excluded.  The live-state counterpart (measured from
    actual traversal arrays, immune to model drift) is checked by
    `benchmarks.check_bytes_regression`."""
    per_layer = fmt.mask_bytes(packed) + fmt.plan_mask_bytes(packed)
    return per_layer * len(stats)
