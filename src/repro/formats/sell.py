"""SellFormat — SELL-C-σ adjacency (SlimSell) for wide-SIMD BFS.

SELL-C-σ [Kreutzer et al.; SlimSell, Besta et al. arXiv:2010.09913]:

* split each vertex's adjacency into **virtual rows** of at most
  ``max_width`` neighbors (row splitting — bounds the slice width by
  the chunk size instead of the hub degree on power-law graphs);
* sort virtual rows by length (descending) inside windows of **σ**
  rows — local sorting keeps similar-length rows adjacent without
  destroying locality globally;
* group the sorted rows into **slices** of C=128 (one slice = one TPU
  lane set, the AVX-512 register analogue of the paper's §4);
* store each slice's adjacency **column-major**, padded to the slice's
  own maximum row length — so one vector load reads one neighbor of
  128 different rows, fully aligned, and the padding cost is per-slice
  instead of the global ELLPACK max-degree.

We quantize slice widths to W_QUANT=8 columns so the storage unit is a
**slab**: an (8, 128) int32 block — exactly one aligned 8x128 vector
tile, the §4.2 alignment goal by construction.  Degree sorting (σ)
is what keeps the quantized padding small on skewed-degree graphs:
hub vertices share slices with hub vertices, so a slice of leaves is
1 slab wide instead of max-degree wide.

Traversal is the SpMV-style sweep of `kernels/sell_expand.py`.  Since
ISSUE 3 the sweep is **active-slab scheduled** under the default
``fused_gather`` pipeline: a per-layer planning pass tests each
slab's ``slab_rows`` against the frontier bitmap and compacts the
hits into a scalar-prefetched work-list, so a thin layer touches only
the slices holding frontier rows (O(frontier slices) slabs) instead
of all of nnz_sell — while still paying **no apportionment pass**
(CSR's per-layer compaction + prefix-sum over the edge stream) and no
gather irregularity in the stream itself.  ``materialized`` keeps the
full O(nnz_sell) sweep for the ablation axis; on skewed
small-diameter graphs (RMAT) almost all edges sit in 2-3 fat layers
anyway, so the full sweep's extra touched slots are small while its
aligned loads are strictly cheaper — the SlimSell argument.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.csr import Csr, from_edges as csr_from_edges, round_up
from repro.core.rmat import EdgeList
from repro.formats.base import Footprint, GraphFormat, nbytes
from repro.formats.registry import register
from repro.kernels import ops
from repro.kernels.sell_expand import SLICE_C, W_QUANT


@register
@jax.tree_util.register_pytree_node_class
class SellFormat(GraphFormat):
    name = "sell"
    # since ISSUE 9 the slab sweep fuses: `sell_layer_fused` rebuilds
    # the cols DMA around manual `make_async_copy`, so the kernel's
    # own t==0 slab plan (an SMEM work-list) drives the pipeline
    # instead of a scalar-prefetched BlockSpec index map that binds
    # before launch — the whole layer (plan + sweep + restoration) is
    # ONE Pallas call, and the whole traversal one launch under
    # pipeline="persistent"
    supports_megakernel = True
    # persistent is SIMD-only: the in-kernel layer loop has no dense
    # jnp arm, and SELL's "nonsimd" MODE_SCALAR semantics (Algorithm
    # 2 exact updates) need exactly that arm — `spec.validate`
    # rejects the combination
    supports_persistent = True
    persistent_algorithms = ("simd",)
    # the semiring portfolio (ISSUE 10) is the SlimSell SpMV reading
    # taken literally: the slab sweep over the (min, ⊗) pair
    # (kernels/sell_expand.py `sell_relax_batched`); see
    # GraphFormat.supported_semirings
    supported_semirings = ("sssp", "cc", "ksource_bfs")

    DEFAULT_SIGMA = 8 * SLICE_C   # SlimSell's typical local-sort window

    def __init__(self, cols, slab_rows, deg, n_vertices: int,
                 n_edges: int, sigma: int, nnz_stored: int):
        self.cols = cols            # (n_slabs, W_QUANT, C) int32
        self.slab_rows = slab_rows  # (n_slabs, C) int32
        self.deg = deg              # (V,) int32
        self._n_vertices = int(n_vertices)
        self._n_edges = int(n_edges)
        self.sigma = int(sigma)
        self.nnz_stored = int(nnz_stored)   # un-quantized padded slots

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return ((self.cols, self.slab_rows, self.deg),
                (self._n_vertices, self._n_edges, self.sigma,
                 self.nnz_stored))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_edges(cls, edges: EdgeList, *, sigma: int | None = None,
                   max_width: int = 64) -> "SellFormat":
        return cls.from_csr(csr_from_edges(edges), sigma=sigma,
                            max_width=max_width)

    @classmethod
    def from_csr(cls, csr: Csr, *, sigma: int | None = None,
                 max_width: int = 64) -> "SellFormat":
        """Row-split, degree-sort, slice, quantize and pack — Graph500
        kernel-2 preprocessing, vectorized in numpy on the host.

        **Row splitting**: a vertex of degree d becomes ceil(d /
        ``max_width``) *virtual rows* of at most ``max_width``
        neighbors each.  On a power-law graph this is what keeps the
        per-slice width (= max row length in the slice) bounded by
        ``max_width`` instead of by the hub degree — without it a
        single SCALE-12 RMAT hub pads its whole 128-lane slice to
        ~2000 columns and the sweep touches ~10x more slots than CSR.
        With splitting, padding is bounded by the W_QUANT quantum per
        virtual row, so stored slots ~= E + O(V).  The σ-sort then
        groups full-width chunks (zero padding) apart from the sorted
        tails (padding < W_QUANT per row).
        """
        c, wq = SLICE_C, W_QUANT
        assert max_width % wq == 0 and max_width > 0
        v = csr.n_vertices
        deg = np.asarray(csr.degrees(), dtype=np.int64)
        colstarts = np.asarray(csr.colstarts, dtype=np.int64)
        dst = np.asarray(csr.rows[:csr.n_edges], dtype=np.int32)

        # virtual row table: vertex id + chunk length per row
        n_full = deg // max_width
        tail = deg % max_width
        rows_per_vertex = n_full + (tail > 0)
        n_vrows = int(rows_per_vertex.sum())
        n_rows = round_up(max(n_vrows, 1), c)
        vrow_vertex = np.full(n_rows, v, np.int64)      # sentinel pad
        vrow_len = np.zeros(n_rows, np.int64)
        if n_vrows:
            vrow_vertex[:n_vrows] = np.repeat(
                np.arange(v, dtype=np.int64), rows_per_vertex)
            row_start = np.concatenate(
                [np.zeros(1, np.int64), np.cumsum(rows_per_vertex)])
            chunk = np.arange(n_vrows, dtype=np.int64) \
                - row_start[vrow_vertex[:n_vrows]]
            vrow_len[:n_vrows] = np.where(
                chunk < n_full[vrow_vertex[:n_vrows]], max_width,
                tail[vrow_vertex[:n_vrows]])

        if sigma is None:
            # auto σ reads the geometry-keyed affinity table like any
            # other tuned knob (affinity.sell.<geom>.sigma<N> rows)
            from repro.formats import affinity
            sig = int(affinity.resolve(csr, "sigma", cls.DEFAULT_SIGMA,
                                       fmt_name="sell"))
        else:
            sig = int(sigma)
        sig = min(round_up(max(sig, c), c), n_rows)

        # σ-windowed descending length sort (stable: ties keep order)
        order = np.arange(n_rows, dtype=np.int64)
        for w0 in range(0, n_rows, sig):
            sl = slice(w0, min(w0 + sig, n_rows))
            order[sl] = order[sl][np.argsort(-vrow_len[sl],
                                             kind="stable")]

        n_slices = n_rows // c
        widths = vrow_len[order].reshape(n_slices, c).max(axis=1)
        slab_counts = (widths + wq - 1) // wq            # quantized
        slab_base = np.concatenate(
            [np.zeros(1, np.int64), np.cumsum(slab_counts)])
        n_slabs = int(slab_base[-1])
        nnz_stored = int((widths * c).sum())

        rows_sorted = np.where(vrow_vertex[order] < v, vrow_vertex[order],
                               v).astype(np.int32)
        if n_slabs == 0:       # edgeless graph: one all-sentinel slab
            cols = np.full((1, wq, c), v, np.int32)
            slab_rows = np.full((1, c), v, np.int32)
        else:
            cols = np.full((n_slabs, wq, c), v, np.int32)
            slab_rows = np.repeat(rows_sorted.reshape(n_slices, c),
                                  slab_counts, axis=0)
            # scatter every real edge to its (slab, column, lane) slot
            if csr.n_edges:
                src = np.repeat(np.arange(v, dtype=np.int64), deg)
                j = np.arange(csr.n_edges, dtype=np.int64) \
                    - colstarts[src]                     # nth neighbor
                vrow = row_start[src] + j // max_width
                jj = j % max_width                       # col in chunk
                inv = np.empty(n_rows, np.int64)
                inv[order] = np.arange(n_rows, dtype=np.int64)
                pos = inv[vrow]
                slab_idx = slab_base[pos // c] + jj // wq
                cols[slab_idx, jj % wq, pos % c] = dst
        return cls(jnp.asarray(cols), jnp.asarray(slab_rows),
                   jnp.asarray(deg, jnp.int32), v, csr.n_edges,
                   sig, nnz_stored)

    # -- static geometry -------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._n_vertices

    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def n_slabs(self) -> int:
        return int(self.cols.shape[0])

    @property
    def fill_ratio(self) -> float:
        """Real edges / stored (quantized) slots — the σ payoff."""
        return self._n_edges / max(self.edge_slots, 1)

    # -- engine contract -------------------------------------------------
    def degrees(self) -> jax.Array:
        return self.deg

    def _sweep_jnp(self, frontier, visited, parent, algorithm: str):
        """Pure-jnp reference sweep (one root) — the scalar-mode step
        and the oracle for the Pallas kernel.  SELL's gather (the
        flattened slab stream with the source-in-frontier lane mask)
        feeding the shared Algorithm 2/3 body."""
        from repro.core import bitmap as bm
        from repro.core.engine import expand_candidates
        v = self._n_vertices
        nbr = self.cols.reshape(-1)
        src = jnp.broadcast_to(self.slab_rows[:, None, :],
                               self.cols.shape).reshape(-1)
        in_front = bm.test_bits(frontier, src) & (src < v)
        valid = in_front & (nbr < v)
        return expand_candidates(src, nbr, valid, frontier, visited,
                                 parent, v, algorithm)

    def _plan_slab_steps(self, active_words, slabs_per_step: int,
                         n_steps: int):
        """Active slab-group work-list for one root (ISSUE 3/4).

        ``active_words`` is a packed membership bitmap over vertices:
        a slab group is active iff any of its lanes' owning rows has
        its bit set — exactly the kernel's gating/discovery mask for
        that direction, so skipping inactive groups changes nothing.
        Top-down passes the *frontier* (slabs without frontier rows
        are skipped); bottom-up passes ``~visited`` (fully-visited
        slices drop out — the late-search early exit).  Sentinel
        (padding) rows are never members, so empty/padding slabs are
        excluded by the same test instead of being re-DMA'd through
        the clamped tail.  The clamp-to-last-active tail contract
        lives in `engine.compact_worklist`."""
        from repro.core import bitmap as bm
        from repro.core.engine import compact_worklist
        v = self._n_vertices
        rows = self.slab_rows
        active = (bm.test_bits(active_words, rows)
                  & (rows < v)).any(axis=1)
        pad = n_steps * slabs_per_step - active.shape[0]
        if pad:       # ops-level sentinel slabs are never active
            active = jnp.concatenate(
                [active, jnp.zeros((pad,), bool)])
        act_step = active.reshape(n_steps, slabs_per_step).any(axis=1)
        return compact_worklist(act_step, n_steps)

    def _build_semiring_step(self, spec, semiring):
        from repro.core import engine
        tile = spec.tile                       # slabs per step
        n_steps = -(-self.n_slabs // tile)
        v = self._n_vertices
        full_wl = jnp.arange(n_steps, dtype=jnp.int32)

        def step(frontier, vals, dense):
            with ops.count_launches() as c:
                wl, na = jax.vmap(
                    lambda a: self._plan_slab_steps(a, tile, n_steps)
                )(frontier)
                # dense arm (CC endgame): a near-full frontier sweeps
                # the full slab work-list instead of the compaction
                wl = jnp.where(dense[:, None], full_wl[None], wl)
                na = jnp.where(dense, jnp.int32(n_steps), na)
                new_vals, p_layer = ops.sell_relax_batched(
                    self.cols, self.slab_rows, wl, na, frontier, vals,
                    n_vertices=v, slabs_per_step=tile,
                    unit=semiring.unit, weighted=semiring.weighted)
            aux = engine.StepAux(na.sum(dtype=jnp.int32),
                                 jnp.int32(0), c.count)
            return new_vals, p_layer, aux

        return step

    def _build_steps(self, spec) -> dict:
        # SELL's planning is word-native already (a packed-bitmap
        # membership test over slab_rows), so ``spec.packed`` does
        # not change the step bodies — both parity arms run the same
        # packed-word plan.
        from repro.core import bitmap as bm
        from repro.core import engine
        algorithm, tile = spec.algorithm, spec.tile
        prefetch_depth = spec.prefetch_depth
        v = self._n_vertices
        n_steps = -(-self.n_slabs // tile)
        # the persistent pipeline's PER-LAYER steps (the serve tier's
        # tick path) are the megakernel steps — whole-traversal
        # queries bypass steps entirely via `persistent_run`
        mega = spec.pipeline in ("megakernel", "persistent")
        if mega:
            n_words = self.n_vertices_padded // bm.BITS_PER_WORD
            if not ops.sell_megakernel_fits(n_words,
                                            self.n_vertices_padded,
                                            self.n_slabs, tile,
                                            prefetch_depth):
                # observable degrade, mirroring engine._make_steps'
                # CSR megakernel arm: past the VMEM budget the layer
                # traverses via the unfused active-slab steps
                engine._record_degrade(
                    "vmem_fallback",
                    reason=ops.budget_detail(
                        f"sell_megakernel(v_pad="
                        f"{self.n_vertices_padded}, "
                        f"slabs={self.n_slabs}, spp={tile}, "
                        f"depth={prefetch_depth})",
                        ops.sell_megakernel_budget(
                            n_words, self.n_vertices_padded,
                            self.n_slabs, tile, prefetch_depth)),
                    fallback="pipeline='fused_gather' unfused slab "
                             "steps (3 launches/layer instead of 1)")
                mega = False
        fused = (not mega) and spec.pipeline != "materialized"

        def make_kernel_step(bottom_up: bool):
            def kernel_step(frontier, visited, parent):
                with ops.count_launches() as c:
                    kw = {}
                    if fused:
                        # the planning bitmap is the direction's
                        # *discovery-relevant* membership set: frontier
                        # rows (top-down) vs unvisited rows (bottom-up)
                        active = ~visited if bottom_up else frontier
                        wl, na = jax.vmap(
                            lambda a: self._plan_slab_steps(
                                a, tile, n_steps))(active)
                        kw = dict(worklist=wl, n_active=na)
                        tiles = na.sum(dtype=jnp.int32)
                    else:
                        tiles = jnp.int32(frontier.shape[0] * n_steps)
                    out_racy, p_racy = ops.sell_batched(
                        self.cols, self.slab_rows, frontier, visited,
                        jnp.zeros_like(frontier), parent, n_vertices=v,
                        slabs_per_step=tile, bottom_up=bottom_up,
                        prefetch_depth=prefetch_depth, **kw)
                    p_fixed, delta = ops.restore(p_racy, n_vertices=v)
                return (out_racy | delta, visited | delta, p_fixed,
                        engine.StepAux(tiles, jnp.int32(0), c.count))
            return kernel_step

        def make_mega_step(bottom_up: bool):
            # ONE Pallas call per layer: in-kernel slab plan + manual
            # cols DMA + sweep + restoration (kernels/sell_expand.py)
            def mega_step(frontier, visited, parent):
                with ops.count_launches() as c:
                    out, p_fixed, na = ops.sell_layer_fused_batched(
                        self.cols, self.slab_rows, frontier, visited,
                        parent, n_vertices=v, slabs_per_step=tile,
                        bottom_up=bottom_up,
                        prefetch_depth=prefetch_depth)
                return (out, visited | out, p_fixed,
                        engine.StepAux(na.sum(dtype=jnp.int32),
                                       jnp.int32(0), c.count))
            return mega_step

        make_step = make_mega_step if mega else make_kernel_step
        kernel_step = make_step(bottom_up=False)

        def jnp_step(frontier, visited, parent):
            out, vis, par = jax.vmap(
                lambda f, vi, p: self._sweep_jnp(f, vi, p,
                                                 algorithm))(
                frontier, visited, parent)
            return out, vis, par, engine.StepAux(
                jnp.int32(frontier.shape[0] * n_steps), jnp.int32(0), 0)

        # MODE_BOTTOMUP is a true role swap since ISSUE 4: the kernel
        # discovers *rows* gated on "neighbor in frontier", so its
        # planner schedules only the slabs of unvisited rows — on the
        # fat late layers of a hybrid search that is a handful of
        # slabs instead of every slab holding frontier rows.
        # MODE_SCALAR maps to the top-down kernel — SELL has no
        # cheaper "scalar" gather, so a thin layer costs the same
        # (active-scheduled) sweep either way — except under
        # algorithm="nonsimd", whose Algorithm-2 exact-update
        # semantics need the dense jnp path.
        scalar_step = kernel_step if algorithm == "simd" else jnp_step
        return {engine.MODE_SCALAR: scalar_step,
                engine.MODE_SIMD: kernel_step,
                engine.MODE_BOTTOMUP: make_step(bottom_up=True)}

    def persistent_fits(self, n_roots: int, spec) -> bool:
        from repro.core import bitmap as bm
        return ops.sell_persistent_fits(
            self.n_vertices_padded // bm.BITS_PER_WORD,
            self.n_vertices_padded, self.n_slabs, spec.tile,
            int(n_roots), spec.max_layers, spec.prefetch_depth)

    def persistent_run(self, frontier, visited, parent, spec):
        return ops.sell_traversal_fused_batched(
            self.cols, self.slab_rows, self.deg, frontier, visited,
            parent, n_vertices=self._n_vertices,
            slabs_per_step=spec.tile, policy=spec.policy,
            max_layers=spec.max_layers,
            prefetch_depth=spec.prefetch_depth)

    def resolve_tile(self, tile: int | None) -> int:
        """SELL's tile is *slabs per grid step*; the slice geometry
        fixes the aligned unit, so on TPU the grid is literally one
        slab (= one slice column-group) per step.  Interpret mode
        unrolls the grid at trace time, so clamp to <=32 steps there
        (the engine's `_auto_tile` rule, in slab units)."""
        n_slabs = self.n_slabs
        interpret = jax.default_backend() != "tpu"
        floor = max(1, -(-n_slabs // 32)) if interpret else 1
        if tile is None:
            return floor
        return max(int(tile), floor) if interpret else max(1, int(tile))

    # -- accounting ------------------------------------------------------
    def footprint(self) -> Footprint:
        return Footprint(self.name,
                         (("cols", nbytes(self.cols)),
                          ("slab_rows", nbytes(self.slab_rows)),
                          ("degrees", nbytes(self.deg))))

    @property
    def edge_slots(self) -> int:
        return self.n_slabs * W_QUANT * SLICE_C

    def layer_bytes(self) -> int:
        # one full (materialized) sweep streams every cols slab + its
        # slab_rows ids
        return 4 * self.n_slabs * (W_QUANT + 1) * SLICE_C

    def tile_bytes(self, tile: int) -> int:
        # one active slab group: `tile` slabs of cols + slab_rows
        return 4 * tile * (W_QUANT + 1) * SLICE_C

    def plan_mask_bytes(self, packed: bool = True) -> int:
        # SELL's planner is word-native in BOTH arms (`make_steps`
        # ignores ``packed``): the membership test gathers from the
        # packed bitmap either way, so the dense-mask model would
        # charge bytes no SELL code path ever moves
        return self.n_vertices_padded // 8

    def plan_bytes(self, tile: int, packed: bool = True) -> int:
        # the slab planner scans every slab's row ids, gathers
        # membership from the packed bitmap, + the work-list round
        # trip
        n_steps = -(-self.n_slabs // max(tile, 1))
        return (4 * self.n_slabs * SLICE_C
                + self.plan_mask_bytes(packed) + 2 * 4 * n_steps)
