"""BitmapCompressedFormat — word-compressed adjacency for the dense
regime.

The §3.3.1 bitmap idea applied to the *graph itself*: vertex u's
adjacency list becomes a (W,) uint32 row of the (V_pad, W) adjacency
bitmap — 1 bit per potential neighbor, the 32x compression the paper
uses for frontiers, now for edges.  Quadratic in V, so only small or
genuinely dense graphs qualify (the autotuner gates on a byte budget
and a density floor).

Where it wins: the bottom-up/dense regime the hybrid follow-up
[Paredes et al., arXiv:1704.02259] targets.  One layer is a pure
word-wise sweep ``adj & frontier`` — every unvisited vertex tests all
its neighbors against the frontier in W uint32 AND operations, with
**no gather, no scatter, no apportionment and no race at all** (the
discovered mask is computed densely, so updates are exact and the
restoration pass is unnecessary).  Each layer is effectively a
bitwise matrix-vector product, the densest possible use of the VPU.

The same sweep serves every engine mode: on the symmetrized Graph500
adjacency, "unvisited vertex with a neighbor in the frontier" is both
the bottom-up test and the top-down result.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core.csr import Csr, from_edges as csr_from_edges
from repro.core.rmat import EdgeList
from repro.formats.base import Footprint, GraphFormat, nbytes
from repro.formats.registry import register


@register
@jax.tree_util.register_pytree_node_class
class BitmapCompressedFormat(GraphFormat):
    name = "bitmap"
    supports_prefetch = False    # dense word sweep: no edge stream
    # the word sweep stores bits, not neighbor ids — there is no
    # per-edge candidate stream to relax a semiring over, so the
    # algorithm portfolio (ISSUE 10) is rejected by `spec.validate`
    supported_semirings = ()

    def __init__(self, adj, deg, n_vertices: int, n_edges: int):
        self.adj = adj              # (V_pad, W) uint32 adjacency rows
        self.deg = deg              # (V,) int32
        self._n_vertices = int(n_vertices)
        self._n_edges = int(n_edges)

    # -- pytree ----------------------------------------------------------
    def tree_flatten(self):
        return ((self.adj, self.deg), (self._n_vertices, self._n_edges))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_edges(cls, edges: EdgeList) -> "BitmapCompressedFormat":
        # no build options: unknown kwargs fail loudly at the call
        return cls.from_csr(csr_from_edges(edges))

    @classmethod
    def from_csr(cls, csr: Csr) -> "BitmapCompressedFormat":
        v = csr.n_vertices
        v_pad = csr.n_vertices_padded
        w = v_pad // bm.BITS_PER_WORD
        deg = np.asarray(csr.degrees(), np.int64)
        src = np.repeat(np.arange(v, dtype=np.int64), deg)
        dst = np.asarray(csr.rows[:csr.n_edges], np.int64)
        adj = np.zeros((v_pad, w), np.uint32)
        np.bitwise_or.at(
            adj, (src, dst >> bm.WORD_SHIFT),
            (np.uint32(1) << (dst & bm.WORD_MASK).astype(np.uint32)))
        return cls(jnp.asarray(adj),
                   jnp.asarray(deg, jnp.int32), v, csr.n_edges)

    # -- static geometry -------------------------------------------------
    @property
    def n_vertices(self) -> int:
        return self._n_vertices

    @property
    def n_edges(self) -> int:
        return self._n_edges

    # -- engine contract -------------------------------------------------
    def degrees(self) -> jax.Array:
        return self.deg

    def _sweep(self, frontier, visited, parent):
        """One exact dense layer (single root): word-wise adj & frontier.

        Parent of a discovered vertex is its lowest-id frontier
        neighbor (first set bit of the intersection) — deterministic,
        so no negative marking / restoration round is needed.
        """
        v = self._n_vertices
        v_pad = parent.shape[0]
        inter = self.adj & frontier[None, :]          # (V_pad, W)
        hit = jnp.any(inter != 0, axis=1)
        # membership stays packed: the visited test is a word AND on
        # the freshly packed hit bitmap (zero-conversion, ISSUE 4)
        new_words = bm.pack_bool(hit) & ~visited
        mask = bm.unpack_bool(new_words)
        # first set bit of the row: first nonzero word, then its lsb
        widx = jnp.argmax(inter != 0, axis=1).astype(jnp.int32)
        word = jnp.take_along_axis(inter, widx[:, None], axis=1)[:, 0]
        lsb = word & (~word + jnp.uint32(1))
        bit = jax.lax.population_count(lsb - jnp.uint32(1))
        parent_id = bm.bit2vertex(widx, bit.astype(jnp.int32))
        parent = jnp.where(mask, parent_id, parent)
        return new_words, visited | new_words, parent

    def _build_steps(self, spec) -> dict:
        # The dense word sweep is ZERO-conversion under the packed
        # engine: it consumes the packed frontier words directly
        # (``adj & frontier``) and emits packed output words — there
        # is no mask to compact and no stream to prefetch, so
        # ``spec.packed`` changes nothing here (and
        # ``spec.prefetch_depth > 0`` is rejected upstream by
        # `spec.validate(fmt)` — there is nothing to prefetch).
        from repro.core import engine
        vm = jax.vmap(self._sweep)

        # the dense sweep has no stream to materialize and no tiles to
        # skip, so both pipelines are the same step; one sweep per
        # root is its tile unit
        def step(frontier, visited, parent):
            out, vis, par = vm(frontier, visited, parent)
            return out, vis, par, engine.StepAux(
                jnp.int32(frontier.shape[0]), jnp.int32(0), 0)

        # one sweep is simultaneously the scalar, SIMD and bottom-up
        # flavour: the dense word AND *is* the bottom-up frontier test
        return {engine.MODE_SCALAR: step,
                engine.MODE_SIMD: step,
                engine.MODE_BOTTOMUP: step}

    # -- accounting ------------------------------------------------------
    def footprint(self) -> Footprint:
        return Footprint(self.name,
                         (("adj", nbytes(self.adj)),
                          ("degrees", nbytes(self.deg))))

    @property
    def edge_slots(self) -> int:
        # one sweep examines every potential edge, one bit per slot
        return int(self.adj.size) * bm.BITS_PER_WORD

    def layer_bytes(self) -> int:
        return nbytes(self.adj)       # the sweep streams the adj matrix

    def tile_bytes(self, tile: int) -> int:
        # StepAux reports one "tile" per root sweep: the whole matrix
        return nbytes(self.adj)

    def plan_bytes(self, tile: int, packed: bool = True) -> int:
        return 0                      # nothing to plan — no schedule

    def plan_mask_bytes(self, packed: bool = True) -> int:
        return 0                      # zero-conversion: no plan read
