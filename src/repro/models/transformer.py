"""Block composition + scanned layer stacks for every pool family.

One decoder block covers: dense GQA (qwen3/phi3/danube/granite/
paligemma), MoE (llama4/arctic), hybrid parallel attn+SSM (hymba),
attention-free RWKV6, and cross-attention decoders (seamless).  Blocks
expose three entry points with a uniform layer-state contract so a
single ``lax.scan`` drives 52-layer stacks in one-layer HLO:

  seq    : (params, x, positions[, memory])  -> (x', aux)
  decode : (params, state, x, position[, memory]) -> (state', x')
  state0 : initial per-layer decode state

Training remat: each scan body is wrapped in ``jax.checkpoint`` so
activation memory stays O(layers * B*T*D) instead of O(everything).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention, common as cm, mlp, moe, rwkv, ssm
from repro.models.config import ModelConfig
from repro.models.sharding import shard

ZERO_AUX = {"lb_loss": jnp.float32(0.0), "z_loss": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Single blocks
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, *, encoder: bool = False,
               use_moe: bool | None = None):
    d = cfg.d_model
    ks = cm.split_key(key, 8)
    use_moe = (cfg.moe if use_moe is None else use_moe) and not encoder
    if cfg.attn_free and not encoder:
        return {"ln1": cm.rmsnorm_init(d), "ln2": cm.rmsnorm_init(d),
                "rwkv": rwkv.init(ks[0], cfg)}
    p = {"ln1": cm.rmsnorm_init(d), "attn": attention.init(ks[0], cfg),
         "ln2": cm.rmsnorm_init(d)}
    if cfg.ssm and not encoder:
        p["ssm"] = ssm.init(ks[1], cfg)
        p["ln_attn_out"] = cm.rmsnorm_init(d)
        p["ln_ssm_out"] = cm.rmsnorm_init(d)
    if cfg.cross_attention and not encoder:
        p["ln_cross"] = cm.rmsnorm_init(d)
        p["cross"] = attention.init(ks[2], cfg)
    if use_moe:
        p["moe"] = moe.init(ks[3], cfg)
    else:
        p["ffn"] = mlp.init(ks[3], d, cfg.d_ff)
    return p


def _mixer_seq(p, cfg: ModelConfig, x, positions, *, causal):
    """Self-attention (+ parallel SSM for hymba) on normed input."""
    xn = cm.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    a = attention.apply(p["attn"], cfg, xn, positions, causal=causal)
    if "ssm" in p:
        s = ssm.apply_seq(p["ssm"], cfg, xn)
        a = 0.5 * (cm.rmsnorm_apply(p["ln_attn_out"], a, cfg.norm_eps)
                   + cm.rmsnorm_apply(p["ln_ssm_out"], s, cfg.norm_eps))
    return a


def block_seq(p, cfg: ModelConfig, x, positions, memory=None, *,
              causal: bool = True):
    """Full-sequence block. Returns (x, aux)."""
    if cfg.seq_parallel:
        # Megatron-style sequence parallelism: the residual stream is
        # seq-sharded over "model" between blocks, so GSPMD lowers each
        # TP boundary to reduce-scatter (+ all-gather where attention
        # needs the full sequence) — half the wire of plain all-reduce
        x = shard(x, "data", "model", None)
    if "rwkv" in p:
        st = rwkv.init_block_state(cfg, x.shape[0], x.dtype)
        tm_out, _, _ = rwkv.time_mix_seq(
            p["rwkv"]["time_mix"], cfg,
            cm.rmsnorm_apply(p["ln1"], x, cfg.norm_eps),
            st["shift_t"], st["wkv"])
        x = x + tm_out
        cm_out, _ = rwkv.channel_mix(
            p["rwkv"]["channel_mix"],
            cm.rmsnorm_apply(p["ln2"], x, cfg.norm_eps), st["shift_c"])
        return x + cm_out, dict(ZERO_AUX)

    x = x + _mixer_seq(p, cfg, x, positions, causal=causal)
    if cfg.seq_parallel:
        x = shard(x, "data", "model", None)   # RS after attn residual
    if "cross" in p and memory is not None:
        xn = cm.rmsnorm_apply(p["ln_cross"], x, cfg.norm_eps)
        x = x + attention.cross_apply(p["cross"], cfg, xn, memory,
                                      positions)
    xn = cm.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        f, aux = moe.apply(p["moe"], cfg, xn)
    else:
        f, aux = mlp.apply(p["ffn"], xn, cfg.mlp), dict(ZERO_AUX)
    return x + f, aux


def block_state0(p, cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Initial decode state matching this block's structure."""
    st = {}
    if "rwkv" in p:
        st["rwkv"] = rwkv.init_block_state(cfg, batch, dtype)
        return st
    st["kv"] = attention.init_cache(cfg, batch, max_len, dtype)
    if "ssm" in p:
        st["ssm"] = ssm.init_state(p["ssm"], cfg, batch, dtype)
    return st


def block_decode(p, cfg: ModelConfig, st, x, position, memory=None):
    """One-token block step. x: (B,1,D). Returns (st', x')."""
    if "rwkv" in p:
        r = st["rwkv"]
        tm_out, sh_t, wkv = rwkv.time_mix_step(
            p["rwkv"]["time_mix"], cfg,
            cm.rmsnorm_apply(p["ln1"], x, cfg.norm_eps),
            r["shift_t"], r["wkv"])
        x = x + tm_out
        cm_in = cm.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        cm_out, sh_c = rwkv.channel_mix(p["rwkv"]["channel_mix"], cm_in,
                                        r["shift_c"])
        # token-shift states carry the *normed* inputs, matching seq
        st = {"rwkv": {"wkv": wkv, "shift_t": sh_t, "shift_c": sh_c}}
        return st, x + cm_out

    xn = cm.rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    kv, a = attention.decode_step(p["attn"], cfg, st["kv"], xn, position)
    new_st = {"kv": kv}
    if "ssm" in p:
        s_st, s = ssm.apply_step(p["ssm"], cfg, st["ssm"], xn)
        new_st["ssm"] = s_st
        a = 0.5 * (cm.rmsnorm_apply(p["ln_attn_out"], a, cfg.norm_eps)
                   + cm.rmsnorm_apply(p["ln_ssm_out"], s, cfg.norm_eps))
    x = x + a
    if "cross" in p and memory is not None:
        xc = cm.rmsnorm_apply(p["ln_cross"], x, cfg.norm_eps)
        x = x + attention.cross_apply(p["cross"], cfg, xc, memory,
                                      position[:, None])
    xn = cm.rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        f, _ = moe.apply(p["moe"], cfg, xn)
    else:
        f = mlp.apply(p["ffn"], xn, cfg.mlp)
    return new_st, x + f


# ---------------------------------------------------------------------------
# Scanned stacks
#
# Representation: a TUPLE of per-position stacked trees.  With
# moe_stride == s, layer g*s + j lives in element j stacked over the
# n_layers/s scan groups — heterogeneous interleavings (llama4's
# dense/MoE alternation) scan as one group of s blocks per step.
# Homogeneous models are the 1-tuple case.
# ---------------------------------------------------------------------------

def _stride(cfg: ModelConfig, encoder: bool) -> int:
    return cfg.moe_stride if (cfg.moe and cfg.moe_stride > 1
                              and not encoder) else 1


def stack_init(key, cfg: ModelConfig, n_layers: int, *,
               encoder: bool = False):
    stride = _stride(cfg, encoder)
    assert n_layers % stride == 0
    keys = cm.split_key(key, n_layers)
    blocks = [
        block_init(k, cfg, encoder=encoder,
                   use_moe=cfg.moe and (i % stride == stride - 1))
        for i, k in enumerate(keys)
    ]
    return tuple(
        jax.tree.map(lambda *xs: jnp.stack(xs), *blocks[j::stride])
        for j in range(stride))


def stack_seq(stacked, cfg: ModelConfig, x, positions, memory=None, *,
              causal: bool = True):
    """scan over layer groups; aux accumulated. Returns (x, aux)."""
    def body(carry, group_params):
        h, lb, zl = carry
        for bp in group_params:
            h, aux = block_seq(bp, cfg, h, positions, memory,
                               causal=causal)
            lb = lb + aux["lb_loss"]
            zl = zl + aux["z_loss"]
        return (h, lb, zl), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        (x, lb, zl), _ = jax.lax.scan(
            body, (x, jnp.float32(0.0), jnp.float32(0.0)), stacked)
    else:
        n = jax.tree.leaves(stacked[0])[0].shape[0]
        carry = (x, jnp.float32(0.0), jnp.float32(0.0))
        for i in range(n):
            group = jax.tree.map(lambda a, i=i: a[i], stacked)
            carry, _ = body(carry, group)
        x, lb, zl = carry
    return x, {"lb_loss": lb, "z_loss": zl}


def stack_state0(stacked, cfg: ModelConfig, batch: int, max_len: int,
                 dtype):
    out = []
    for sub in stacked:
        layer0 = jax.tree.map(lambda a: a[0], sub)
        st = block_state0(layer0, cfg, batch, max_len, dtype)
        n = jax.tree.leaves(sub)[0].shape[0]
        out.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n, *a.shape)).copy(),
            st))
    return tuple(out)


def stack_decode(stacked, cfg: ModelConfig, states, x, position,
                 memory=None):
    """scan one token through all layer groups. Returns (states', x')."""
    def body(h, group):
        group_params, group_states = group
        new_states = []
        for bp, st in zip(group_params, group_states):
            st, h = block_decode(bp, cfg, st, h, position, memory)
            new_states.append(st)
        return h, tuple(new_states)

    x, new_states = jax.lax.scan(body, x, (stacked, states))
    return new_states, x
