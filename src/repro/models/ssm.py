"""Mamba-style selective SSM head (hymba's parallel-SSM path).

Diagonal selective state space: per channel c and state dim n,
  h_t = exp(dt_t * A)[c,n] * h_{t-1} + (dt_t * B_t)[n] * u_t[c]
  y_t = C_t . h_t + D[c] * u_t[c]
with dt, B, C data-dependent (the "selective" part) and a causal
depthwise conv in front.  Training uses ``lax.associative_scan`` over
time (parallel prefix over the affine maps), decode is the single-step
recurrence.  The inner channel dim is cut over "model".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig
from repro.models.sharding import shard


def init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in = d                       # inner width == d_model (parallel head)
    n = cfg.ssm_state
    ks = cm.split_key(key, 7)
    return {
        "in_proj": cm.dense_init(ks[0], d, 2 * d_in),
        "conv": {"w": cm.truncated_normal(ks[1], (cfg.ssm_conv, d_in),
                                          cfg.ssm_conv ** -0.5)},
        "dt_proj": cm.dense_init(ks[2], d_in, d_in, std=0.01),
        "bc_proj": cm.dense_init(ks[3], d_in, 2 * n),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n)) * 1.0),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": cm.dense_init(ks[6], d_in, d),
    }


def _conv_causal(w, u, init_state=None):
    """Depthwise causal conv. u: (B,T,C); w: (K,C)."""
    k = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    padded = jnp.concatenate([init_state, u], axis=1)
    out = sum(padded[:, i:i + u.shape[1]] * w[i] for i in range(k))
    return out, padded[:, -(k - 1):] if k > 1 else init_state


def _ssm_inputs(params, cfg: ModelConfig, x, conv_state=None):
    u, z = jnp.split(cm.dense_apply(params["in_proj"], x, x.dtype), 2,
                     axis=-1)
    u = shard(u, "data", None, "model")
    u, conv_state = _conv_causal(params["conv"]["w"].astype(x.dtype), u,
                                 conv_state)
    u = jax.nn.silu(u)
    dt = jax.nn.softplus(
        cm.dense_apply(params["dt_proj"], u, jnp.float32))
    bc = cm.dense_apply(params["bc_proj"], u, jnp.float32)
    b, c = jnp.split(bc, 2, axis=-1)                   # (B,T,N) each
    a = -jnp.exp(params["a_log"])                      # (C,N)
    decay = jnp.exp(dt[..., None] * a)                 # (B,T,C,N)
    drive = (dt * u.astype(jnp.float32))[..., None] \
        * b[..., None, :]                              # (B,T,C,N)
    return u, z, c, decay, drive, conv_state


def apply_seq(params, cfg: ModelConfig, x):
    """Full-sequence SSM (training/prefill). x: (B,T,D)."""
    u, z, c, decay, drive, _ = _ssm_inputs(params, cfg, x)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    y = jnp.einsum("btcn,btn->btc", h, c).astype(x.dtype)
    y = y + params["d_skip"].astype(x.dtype) * u
    y = y * jax.nn.silu(z)
    y = shard(y, "data", None, "model")
    return cm.dense_apply(params["out_proj"], y, x.dtype)


def init_state(params, cfg: ModelConfig, batch: int, dtype):
    d_in = params["d_skip"].shape[0]
    return {
        "h": jnp.zeros((batch, d_in, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
    }


def apply_step(params, cfg: ModelConfig, state, x):
    """One-token decode. x: (B,1,D)."""
    u, z, c, decay, drive, conv_state = _ssm_inputs(
        params, cfg, x, state["conv"])
    h = state["h"] * decay[:, 0] + drive[:, 0]         # (B,C,N)
    y = jnp.einsum("bcn,bn->bc", h, c[:, 0])[:, None].astype(x.dtype)
    y = y + params["d_skip"].astype(x.dtype) * u
    y = y * jax.nn.silu(z)
    out = cm.dense_apply(params["out_proj"], y, x.dtype)
    return {"h": h, "conv": conv_state}, out
