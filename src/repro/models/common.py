"""Shared model primitives: init, norms, RoPE, embeddings, dense.

Pure-pytree framework: parameters are nested dicts of jnp arrays,
layers are ``init(key, ...) -> params`` plus ``apply(params, x, ...)``
function pairs.  Per-layer parameter stacks carry a leading L axis and
are driven by ``lax.scan`` (models/transformer.py) so 52-layer models
lower to one-layer HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in: int, d_out, std: float | None = None):
    """(d_in, *d_out) kernel with fan-in scaling (no bias, LLaMA-style)."""
    if isinstance(d_out, int):
        d_out = (d_out,)
    std = std if std is not None else d_in ** -0.5
    return {"w": truncated_normal(key, (d_in, *d_out), std)}


def dense_apply(params, x, dtype):
    w = params["w"].astype(dtype)
    return jnp.einsum("...i,ij->...j", x, w.reshape(w.shape[0], -1)) \
        .reshape(*x.shape[:-1], *w.shape[1:])


def dense_apply_out(params, x, dtype):
    """Attention output projection: (...,H,hd) x (H,hd,D) -> (...,D)."""
    w = params["w"].astype(dtype)
    return jnp.einsum("...hk,hkd->...d", x, w)


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    """fp32 statistics, cast back to input dtype (TPU best practice)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(dt)


def embedding_init(key, vocab: int, d: int):
    return {"emb": truncated_normal(key, (vocab, d), 1.0)}


def embedding_lookup(params, tokens, dtype):
    return params["emb"].astype(dtype)[tokens]


def embedding_logits(params, h):
    """Tied read-out: (…, d) @ (d, vocab) in fp32 for stability."""
    return jnp.einsum("...d,vd->...v", h.astype(jnp.float32),
                      params["emb"].astype(jnp.float32))


# RoPE ----------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, D); positions: (..., T) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., T, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def split_key(key, n: int):
    return list(jax.random.split(key, n))
