"""Mixture-of-Experts layer (GShard-style) with grouped dispatch.

Covers llama4-maverick (128e top-1) and arctic (128e top-2 + parallel
dense residual MLP).  Experts are sharded over the "model" axis; the
dispatch/combine einsums against expert-major tensors make GSPMD insert
the canonical all-to-all pair (verified in the dry-run HLO).

Tokens are processed in *groups* (GShard's trick) so the dispatch
tensor is (g, n, E, c) with n = moe_group_size instead of the full
token count — the difference between a 64 MB and a 5 GB dispatch at
train_4k scale.

Connection to the paper (DESIGN.md §5): routing is a scatter with
collisions (many tokens -> one expert slot range) and a capacity limit.
We resolve it exactly like the BFS restoration process resolves bitmap
races: a deterministic position-by-prefix-sum (cumsum over the group)
instead of atomics — the same segment-sum primitive, reused.  Tokens
overflowing capacity are dropped (their combine weight is zero), the
standard GShard behaviour.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig
from repro.models.sharding import shard


def init(key, cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = cm.split_key(key, 5)
    params = {
        "router": cm.dense_init(ks[0], d, e, std=0.02),
        "w_gate": {"w": cm.truncated_normal(ks[1], (e, d, ff), d ** -0.5)},
        "w_up": {"w": cm.truncated_normal(ks[2], (e, d, ff), d ** -0.5)},
        "w_down": {"w": cm.truncated_normal(ks[3], (e, ff, d),
                                            ff ** -0.5)},
    }
    if cfg.dense_residual:
        from repro.models import mlp
        params["dense"] = mlp.init(ks[4], d,
                                   cfg.dense_residual_ff or cfg.d_ff)
    return params


def _capacity(n: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n * top_k / n_experts * factor) + 1
    return max(4, -(-c // 4) * 4)  # align to 4


def apply(params, cfg: ModelConfig, x):
    """x: (B, T, D) -> (out (B,T,D), aux losses dict).

    Two dispatch modes (cfg.moe_dispatch):
      "einsum" — GShard-faithful one-hot dispatch/combine einsums (the
        baseline; simple, but burns 2*N*E*c*D flops per layer moving
        zeros through the MXU);
      "sort"   — §Perf optimization: gather/scatter routing.  Tokens
        are ordered by expert with a stable argsort, slotted by a
        prefix-sum (the SAME deterministic collision-resolution the
        BFS restoration process uses — DESIGN.md §5), gathered into
        (E,c,D) expert buffers, and combined back through the inverse
        permutation.  Flop cost: O(N log N) sort keys + O(N*D)
        gathers — the dispatch einsums disappear from the roofline
        (measured in EXPERIMENTS.md §Perf).  Both modes drop the same
        overflow tokens, so outputs match (tests/test_moe_dispatch.py).
    """
    b, t, d = x.shape
    total = b * t
    n = min(cfg.moe_group_size, total)
    g = max(total // n, 1)
    assert g * n == total, (
        f"token count {total} not divisible by moe_group_size {n}")
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(n, e, k, cfg.capacity_factor)

    tokens = x.reshape(g, n, d)
    tokens = shard(tokens, "data", None, None)
    logits = jnp.einsum("gnd,de->gne", tokens.astype(jnp.float32),
                        params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)       # (g,n,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)          # renormalize

    if cfg.moe_dispatch == "sort":
        return _apply_sorted(params, cfg, x, tokens, probs, gate_vals,
                             gate_idx, logits, g, n, e, k, c)

    # deterministic slot assignment: prefix-sum per expert (the
    # restoration-style replacement for an atomic counter)
    dispatch = jnp.zeros((g, n, e, c), x.dtype)
    combine = jnp.zeros((g, n, e, c), jnp.float32)
    count_so_far = jnp.zeros((g, 1, e), jnp.int32)
    for kk in range(k):
        mask_k = jax.nn.one_hot(gate_idx[..., kk], e, dtype=jnp.int32)
        pos = jnp.cumsum(mask_k, axis=1) - 1 + count_so_far  # (g,n,e)
        keep = (mask_k == 1) & (pos < c)
        slot = jax.nn.one_hot(jnp.where(keep, pos, c), c,
                              dtype=x.dtype)             # (g,n,e,c)
        slot = slot * keep[..., None].astype(x.dtype)
        dispatch = dispatch + slot
        combine = combine + slot.astype(jnp.float32) \
            * gate_vals[..., kk][..., None, None]
        count_so_far = count_so_far + mask_k.sum(axis=1, keepdims=True)

    # dispatch: tokens -> expert-major (E, g, c, D); E cut over "model"
    expert_in = jnp.einsum("gnec,gnd->egcd", dispatch, tokens)
    expert_in = shard(expert_in, "model", None, None, None)
    wg = params["w_gate"]["w"].astype(x.dtype)
    wu = params["w_up"]["w"].astype(x.dtype)
    wd = params["w_down"]["w"].astype(x.dtype)
    hidden = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, wg)) \
        * jnp.einsum("egcd,edf->egcf", expert_in, wu)
    hidden = shard(hidden, "model", None, None, None)
    expert_out = jnp.einsum("egcf,efd->egcd", hidden, wd)

    out = jnp.einsum("gnec,egcd->gnd", combine.astype(x.dtype),
                     expert_out)
    out = out.reshape(b, t, d)

    if cfg.dense_residual:                               # arctic
        from repro.models import mlp
        out = out + mlp.apply(params["dense"], x, cfg.mlp)

    # aux losses: load-balance (Switch) + router z-loss
    me = probs.mean(axis=1)                              # (g,e)
    ce = (dispatch.sum(-1) > 0).astype(jnp.float32).mean(axis=1)
    lb_loss = e * (me * ce).sum(-1).mean()
    z_loss = jnp.square(jax.nn.logsumexp(logits, axis=-1)).mean()
    return out, {"lb_loss": lb_loss, "z_loss": z_loss}


def _expert_ffn(params, cfg: ModelConfig, expert_in, dtype):
    """(E, g, c, D) -> (E, g, c, D) through the expert GLU stacks."""
    wg = params["w_gate"]["w"].astype(dtype)
    wu = params["w_up"]["w"].astype(dtype)
    wd = params["w_down"]["w"].astype(dtype)
    hidden = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, wg)) \
        * jnp.einsum("egcd,edf->egcf", expert_in, wu)
    hidden = shard(hidden, "model", None, None, None)
    return jnp.einsum("egcf,efd->egcd", hidden, wd)


def _apply_sorted(params, cfg: ModelConfig, x, tokens, probs, gate_vals,
                  gate_idx, logits, g, n, e, k, c):
    """Sort-based gather/scatter dispatch (see apply docstring)."""
    b, t, d = x.shape

    def route_group(tok, gidx, gval):
        # (n,d), (n,k), (n,k) -> (out (n,d), counts (e,))
        nk = n * k
        eid = gidx.reshape(nk)                      # expert per entry
        src = jnp.tile(jnp.arange(n, dtype=jnp.int32)[:, None],
                       (1, k)).reshape(nk)          # token per entry
        order = jnp.argsort(eid, stable=True)       # tokens grouped
        e_sorted = eid[order]
        src_sorted = src[order]
        # slot via prefix-sum (restoration-style collision resolution)
        start = jnp.searchsorted(e_sorted,
                                 jnp.arange(e, dtype=jnp.int32),
                                 side="left").astype(jnp.int32)
        pos = jnp.arange(nk, dtype=jnp.int32) - start[e_sorted]
        keep = pos < c
        slot = jnp.where(keep, e_sorted * c + pos, e * c)
        # gather tokens into (e*c, d) expert buffers (scatter: unique
        # slots by construction — deterministic, no races)
        buf = jnp.zeros((e * c, d), tok.dtype) \
            .at[slot].set(tok[src_sorted], mode="drop")
        counts = jnp.bincount(e_sorted, length=e)
        return buf.reshape(e, c, d), (order, keep, slot, src_sorted,
                                      counts)

    routed = jax.vmap(route_group)(tokens, gate_idx, gate_vals)
    expert_in = routed[0].transpose(1, 0, 2, 3)      # (e,g,c,d)
    expert_in = shard(expert_in, "model", None, None, None)
    expert_out = _expert_ffn(params, cfg, expert_in, x.dtype)
    out_buf = expert_out.transpose(1, 0, 2, 3).reshape(g, e * c, d)

    def combine_group(buf, meta, gval):
        order, keep, slot, src_sorted, counts = meta
        picked = buf[jnp.clip(slot, 0, e * c - 1)] \
            * keep[:, None].astype(buf.dtype)        # (n*k, d)
        # invert the sort: entry j came from (token src_sorted[j],
        # choice order[j] % k); weight and scatter-add back
        weights = gval.reshape(n * k)[order].astype(buf.dtype)
        out = jnp.zeros((n, d), buf.dtype) \
            .at[src_sorted].add(picked * weights[:, None])
        return out

    out = jax.vmap(combine_group)(out_buf, routed[1], gate_vals)
    out = out.reshape(b, t, d)
    if cfg.dense_residual:                           # arctic
        from repro.models import mlp
        out = out + mlp.apply(params["dense"], x, cfg.mlp)

    counts = routed[1][4]                            # (g,e)
    me = probs.mean(axis=1)
    ce = counts.astype(jnp.float32) / (n * k)
    lb_loss = e * (me * ce).sum(-1).mean()
    z_loss = jnp.square(jax.nn.logsumexp(logits, axis=-1)).mean()
    return out, {"lb_loss": lb_loss, "z_loss": z_loss}
