"""Public model API: init / loss / forward / decode for every config.

``train_step``-facing: ``loss_fn(params, cfg, batch)`` where batch is
  {"tokens": (B,T) int32, "labels": (B,T) int32 (-1 = ignore)}
plus, per family:
  vlm/audio prefix stubs:  "prefix": (B,P,D) precomputed embeddings
  encoder-decoder:         "src_embeddings": (B,S,D) frame embeddings

``serve_step``-facing: ``decode_step(params, cfg, states, tokens,
position[, memory])`` — one token against a standing KV-cache/SSM
state, the object the decode_* / long_* dry-run shapes lower.

Cross-entropy is chunked over tokens (``cfg.vocab_chunk`` per block,
checkpointed) with the vocabulary dimension sharded over "model", so
the 257k-vocab archs never materialize a full (tokens, V) fp32 tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import common as cm, transformer as tf
from repro.models.config import ModelConfig
from repro.models.sharding import shard


def init_params(cfg: ModelConfig, key):
    ks = cm.split_key(key, 5)
    p = {
        "embed": cm.embedding_init(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": cm.rmsnorm_init(cfg.d_model),
        "layers": tf.stack_init(ks[1], cfg, cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.embedding_init(ks[2], cfg.vocab_size,
                                         cfg.d_model)
    if cfg.encoder_layers:
        p["encoder"] = tf.stack_init(ks[3], cfg, cfg.encoder_layers,
                                     encoder=True)
        p["enc_norm"] = cm.rmsnorm_init(cfg.d_model)
    pd = jnp.dtype(cfg.param_dtype)
    if pd != jnp.float32:   # bf16 master weights (the optimizer still
        p = jax.tree.map(   # updates in fp32; m/v keep full precision)
            lambda a: a.astype(pd) if a.dtype == jnp.float32 else a, p)
    return p


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def encode(params, cfg: ModelConfig, src_embeddings):
    """Encoder stack over stub frontend embeddings (B,S,D)."""
    x = src_embeddings.astype(_dtype(cfg))
    pos = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
    x, _ = tf.stack_seq(params["encoder"], cfg, x, pos, causal=False)
    return cm.rmsnorm_apply(params["enc_norm"], x, cfg.norm_eps)


def forward_hidden(params, cfg: ModelConfig, tokens, prefix=None,
                   memory=None):
    """(B,T[,+P]) -> (hidden (B,T_total,D), aux)."""
    x = cm.embedding_lookup(params["embed"], tokens, _dtype(cfg))
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    x = shard(x, "data", None, None)
    pos = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
    x, aux = tf.stack_seq(params["layers"], cfg, x, pos, memory)
    return cm.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps), aux


def _readout_table(params):
    return params.get("lm_head", params["embed"])["emb"]


def logits_fn(params, cfg: ModelConfig, hidden):
    table = _readout_table(params)
    out = jnp.einsum("...d,vd->...v", hidden.astype(jnp.float32),
                     table.astype(jnp.float32))
    return shard(out, "data", None, "model")


def chunked_ce(params, cfg: ModelConfig, hidden, labels):
    """Token-chunked cross entropy; labels < 0 are masked."""
    b, t, d = hidden.shape
    h = hidden.reshape(b * t, d)
    l = labels.reshape(b * t)
    chunk = min(cfg.vocab_chunk, h.shape[0])
    pad = (-h.shape[0]) % chunk
    if pad:
        h = jnp.concatenate([h, jnp.zeros((pad, d), h.dtype)])
        l = jnp.concatenate([l, -jnp.ones((pad,), l.dtype)])
    n = h.shape[0] // chunk
    table = _readout_table(params)

    def one(args):
        hc, lc = args
        logits = jnp.einsum("td,vd->tv", hc.astype(jnp.float32),
                            table.astype(jnp.float32))
        logits = shard(logits, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[:, None], axis=-1)[:, 0]
        return jnp.where(lc >= 0, lse - gold, 0.0)

    per_tok = jax.lax.map(jax.checkpoint(one),
                          (h.reshape(n, chunk, d), l.reshape(n, chunk)))
    n_valid = jnp.maximum((l >= 0).sum(), 1)
    return per_tok.sum() / n_valid


LB_COEF = 1e-2
Z_COEF = 1e-4


def loss_fn(params, cfg: ModelConfig, batch):
    """Scalar training loss + metrics."""
    memory = None
    if cfg.encoder_layers:
        memory = encode(params, cfg, batch["src_embeddings"])
    hidden, aux = forward_hidden(params, cfg, batch["tokens"],
                                 prefix=batch.get("prefix"),
                                 memory=memory)
    if cfg.prefix_len:
        hidden = hidden[:, cfg.prefix_len:]
    ce = chunked_ce(params, cfg, hidden, batch["labels"])
    loss = ce + LB_COEF * aux["lb_loss"] + Z_COEF * aux["z_loss"]
    return loss, {"ce": ce, **aux}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_decode_state(params, cfg: ModelConfig, batch: int,
                      cache_len: int):
    """Per-layer stacked KV caches / SSM / WKV states."""
    return tf.stack_state0(params["layers"], cfg, batch, cache_len,
                           _dtype(cfg))


def decode_step(params, cfg: ModelConfig, states, tokens, position,
                memory=None):
    """One-token serve step.

    tokens: (B,) int32; position: (B,) int32 absolute positions.
    Returns (states', logits (B,V)).
    """
    x = cm.embedding_lookup(params["embed"], tokens[:, None],
                            _dtype(cfg))
    states, x = tf.stack_decode(params["layers"], cfg, states, x,
                                position, memory)
    h = cm.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    return states, logits_fn(params, cfg, h[:, 0])


def prefill(params, cfg: ModelConfig, tokens, prefix=None, memory=None):
    """Sequential prefill via the decode path (exactness over speed;
    used by examples/tests — the dry-run shapes take the standing
    cache as an input instead)."""
    b, t = tokens.shape
    x = cm.embedding_lookup(params["embed"], tokens, _dtype(cfg))
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    total = x.shape[1]
    states = init_decode_state(params, cfg, b, total)
    logits = None

    def step(carry, i):
        states = carry
        pos = jnp.full((b,), i, jnp.int32)
        st, xi = tf.stack_decode(params["layers"], cfg, states,
                                 x[:, i][:, None], pos, memory)
        h = cm.rmsnorm_apply(params["final_norm"], xi, cfg.norm_eps)
        return st, h[:, 0]

    states, hs = jax.lax.scan(step, states,
                              jnp.arange(total, dtype=jnp.int32))
    logits = logits_fn(params, cfg, hs[-1])
    return states, logits
