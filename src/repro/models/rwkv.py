"""RWKV-6 "Finch" block: data-dependent per-channel decay linear
attention (time-mix) + squared-ReLU channel-mix.

Per head (key dim dk = value dim dv = 64):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state (dk, dv))
    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
with w_t in (0,1) *data-dependent per channel* (the Finch novelty) via
a two-layer LoRA on the token-shifted input.

Training/prefill runs the **sub-chunked parallel form**: time is cut
into chunks of 16; within a chunk the exact decay tensor
exp(cw[t-1] - cw[s]) is materialized (all exponents <= 0, so no
overflow — the reason for sub-chunking), across chunks a (dk, dv)
state is carried by ``lax.scan``.  This is the standard chunked linear
attention scheme (cf. flash-linear-attention), expressed in jnp so it
lowers everywhere; the MXU sees (16,16)x(16,dv) matmuls.

Decode is the O(1) recurrence — the reason rwkv6 runs the long_500k
shape that quadratic archs skip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig
from repro.models.sharding import shard

CHUNK = 16
HEAD_DIM = 64
DECAY_LORA = 64


def n_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def init(key, cfg: ModelConfig):
    d = cfg.d_model
    h = n_heads(cfg)
    ks = cm.split_key(key, 10)
    tm = {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,w,g shifts
        "w_r": cm.dense_init(ks[0], d, d),
        "w_k": cm.dense_init(ks[1], d, d),
        "w_v": cm.dense_init(ks[2], d, d),
        "w_g": cm.dense_init(ks[3], d, d),
        "w_o": cm.dense_init(ks[4], d, d),
        "decay_base": -6.0 * jnp.ones((d,), jnp.float32),
        "decay_lora_a": cm.dense_init(ks[5], d, DECAY_LORA, std=0.01),
        "decay_lora_b": cm.dense_init(ks[6], DECAY_LORA, d, std=0.01),
        "bonus_u": jnp.zeros((h, HEAD_DIM), jnp.float32),
        "ln_x": cm.rmsnorm_init(d),
    }
    cmix = {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),
        "w_k": cm.dense_init(ks[7], d, cfg.d_ff),
        "w_v": cm.dense_init(ks[8], cfg.d_ff, d),
        "w_r": cm.dense_init(ks[9], d, d),
    }
    return {"time_mix": tm, "channel_mix": cmix}


def _token_shift(x, prev):
    """x_{t-1} with ``prev`` as the t=0 predecessor. x: (B,T,D)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def _decays(tm, xw):
    """Per-channel log-decay lw <= 0 (data-dependent, Finch)."""
    lora = cm.dense_apply(
        tm["decay_lora_b"],
        jnp.tanh(cm.dense_apply(tm["decay_lora_a"], xw, jnp.float32)),
        jnp.float32)
    return -jnp.exp(tm["decay_base"] + lora)            # (B,T,D) in (-inf,0)


def time_mix_seq(tm, cfg: ModelConfig, x, shift_prev, state):
    """Chunked-parallel WKV. x: (B,T,D), T % CHUNK == 0.

    state: (B,H,dk,dv) float32 carried across calls (prefill chunks).
    Returns (out, new_shift, new_state).
    """
    b, t, d = x.shape
    h = n_heads(cfg)
    xp = _token_shift(x, shift_prev)
    xr, xk, xv, xw, xg = (_mix(x, xp, tm["mu"][i]) for i in range(5))
    r = cm.dense_apply(tm["w_r"], xr, x.dtype).reshape(b, t, h, HEAD_DIM)
    k = cm.dense_apply(tm["w_k"], xk, x.dtype).reshape(b, t, h, HEAD_DIM)
    v = cm.dense_apply(tm["w_v"], xv, x.dtype).reshape(b, t, h, HEAD_DIM)
    g = jax.nn.silu(cm.dense_apply(tm["w_g"], xg, x.dtype))
    lw = _decays(tm, xw).reshape(b, t, h, HEAD_DIM)     # (B,T,H,dk)
    u = tm["bonus_u"]                                   # (H,dk)

    nc = t // CHUNK
    rc = r.reshape(b, nc, CHUNK, h, HEAD_DIM).transpose(1, 0, 3, 2, 4)
    kc = k.reshape(b, nc, CHUNK, h, HEAD_DIM).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nc, CHUNK, h, HEAD_DIM).transpose(1, 0, 3, 2, 4)
    lwc = lw.astype(jnp.float32) \
        .reshape(b, nc, CHUNK, h, HEAD_DIM).transpose(1, 0, 3, 2, 4)

    def chunk_step(s, args):
        rr, kk, vv, ww = args          # (B,H,C,dk) / vv: (B,H,C,dv)
        rrf = rr.astype(jnp.float32)
        kkf = kk.astype(jnp.float32)
        vvf = vv.astype(jnp.float32)
        cw = jnp.cumsum(ww, axis=2)                     # (B,H,C,dk)
        cw_prev = cw - ww                               # cw[t-1], cw[-1]=0
        # intra-chunk: exact decay tensor, exponents <= 0 by masking
        diff = cw_prev[:, :, :, None, :] - cw[:, :, None, :, :]
        tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)
        decay_ts = jnp.where(tri[None, None, :, :, None], diff, -1e30)
        a = jnp.einsum("bhtd,bhtsd,bhsd->bhts",
                       rrf, jnp.exp(decay_ts), kkf)
        a_diag = jnp.einsum("bhtd,hd,bhtd->bht", rrf,
                            u.astype(jnp.float32), kkf)
        out = jnp.einsum("bhts,bhsd->bhtd", a, vvf) \
            + a_diag[..., None] * vvf
        # cross-chunk: state contribution decayed to each t
        out = out + jnp.einsum("bhtd,bhdv->bhtv",
                               rrf * jnp.exp(cw_prev), s)
        # state update: decay to chunk end, absorb chunk keys
        k_dec = kkf * jnp.exp(cw[:, :, -1:, :] - cw)
        s_new = s * jnp.exp(cw[:, :, -1, :])[..., None] \
            + jnp.einsum("bhtd,bhtv->bhdv", k_dec, vvf)
        return s_new, out

    state, outs = jax.lax.scan(chunk_step, state, (rc, kc, vc, lwc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, t, d).astype(x.dtype)
    out = cm.rmsnorm_apply(tm["ln_x"], out, cfg.norm_eps) * g
    out = cm.dense_apply(tm["w_o"], out, x.dtype)
    return out, x[:, -1], state


def time_mix_step(tm, cfg: ModelConfig, x, shift_prev, state):
    """O(1) decode step. x: (B,1,D)."""
    b, _, d = x.shape
    h = n_heads(cfg)
    xp = shift_prev[:, None]
    xr, xk, xv, xw, xg = (_mix(x, xp, tm["mu"][i]) for i in range(5))
    r = cm.dense_apply(tm["w_r"], xr, jnp.float32).reshape(b, h, HEAD_DIM)
    k = cm.dense_apply(tm["w_k"], xk, jnp.float32).reshape(b, h, HEAD_DIM)
    v = cm.dense_apply(tm["w_v"], xv, jnp.float32).reshape(b, h, HEAD_DIM)
    g = jax.nn.silu(cm.dense_apply(tm["w_g"], xg, x.dtype))
    w = jnp.exp(_decays(tm, xw)[:, 0].reshape(b, h, HEAD_DIM))
    u = tm["bonus_u"].astype(jnp.float32)
    kv = jnp.einsum("bhd,bhv->bhdv", k, v)
    out = jnp.einsum("bhd,bhdv->bhv", r, state + u[None, :, :, None] * kv)
    state = state * w[..., None] + kv
    out = out.reshape(b, 1, d).astype(x.dtype)
    out = cm.rmsnorm_apply(tm["ln_x"], out, cfg.norm_eps) * g
    return cm.dense_apply(tm["w_o"], out, x.dtype), x[:, -1], state


def channel_mix(cmix, x, shift_prev):
    """Squared-ReLU FFN with token shift. Returns (out, new_shift)."""
    xp = _token_shift(x, shift_prev)
    xk = _mix(x, xp, cmix["mu"][0])
    xr = _mix(x, xp, cmix["mu"][1])
    kk = jnp.square(jax.nn.relu(cm.dense_apply(cmix["w_k"], xk, x.dtype)))
    kk = shard(kk, "data", None, "model")
    rr = jax.nn.sigmoid(cm.dense_apply(cmix["w_r"], xr, x.dtype))
    return rr * cm.dense_apply(cmix["w_v"], kk, x.dtype), x[:, -1]


def init_block_state(cfg: ModelConfig, batch: int, dtype):
    h = n_heads(cfg)
    return {
        "wkv": jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        "shift_t": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_c": jnp.zeros((batch, cfg.d_model), dtype),
    }
