"""Model configuration — one dataclass covering the assigned pool.

Families: dense / moe / hybrid (attn+SSM) / ssm (rwkv) / audio
(enc-dec) / vlm (prefix-LM).  Every knob corresponds to a concrete
architecture requirement from the assignment table; configs/<id>.py
instantiates them exactly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int | None = None          # default d_model // n_heads
    qk_norm: bool = False                # qwen3
    sliding_window: int | None = None    # h2o-danube SWA
    rope_theta: float = 10_000.0
    mlp: str = "swiglu"                  # swiglu | geglu
    tie_embeddings: bool = True

    # MoE (llama4-maverick, arctic)
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_stride: int = 1                  # llama4: MoE every Nth layer
    capacity_factor: float = 1.25
    dense_residual: bool = False         # arctic: dense MLP + MoE in parallel
    dense_residual_ff: int | None = None # hidden of the parallel dense MLP
    moe_group_size: int = 512            # tokens per dispatch group
    moe_dispatch: str = "einsum"         # einsum (GShard) | sort (SPerf)

    # hybrid (hymba): parallel attention + SSM heads per layer
    ssm: bool = False
    ssm_state: int = 16
    ssm_conv: int = 4

    # rwkv6
    attn_free: bool = False

    # encoder-decoder (seamless-m4t)
    encoder_layers: int = 0
    cross_attention: bool = False

    # modality frontend stubs (paligemma patches / seamless frames)
    prefix_len: int = 0                  # stub embeddings prepended
    frontend: str | None = None          # "siglip_stub" | "audio_stub"

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # master weights (bf16: 400B-class)
    remat: bool = True
    attn_q_chunk: int = 2048             # memory-efficient attention tiles
    attn_kv_chunk: int = 1024
    vocab_chunk: int = 16_384            # chunked cross-entropy
    scan_layers: bool = True             # lax.scan over stacked layers
    seq_parallel: bool = False           # shard seq over "model" between
                                         # blocks (Megatron-SP: RS+AG
                                         # replaces TP all-reduce)

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k decode shape?"""
        return (self.attn_free or self.ssm
                or self.sliding_window is not None)

    @property
    def is_decoder(self) -> bool:
        return True  # all pool archs have a decoder (enc-dec included)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/features, tiny sizes."""
        return self.with_(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.moe else 0,
            dense_residual_ff=64 if self.dense_residual else None,
            moe_group_size=64,
            encoder_layers=2 if self.encoder_layers else 0,
            prefix_len=8 if self.prefix_len else 0,
            sliding_window=32 if self.sliding_window else None,
            attn_q_chunk=16,
            attn_kv_chunk=16,
            vocab_chunk=256,
        )


# Parameter counting (for MODEL_FLOPS = 6*N*D roofline term) -----------------

def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic parameter count; active_only counts top-k experts only."""
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    glu = 3 * d * cfg.d_ff
    per_layer = attn + 2 * d   # + norms
    if cfg.moe:
        n_e = cfg.top_k if active_only else cfg.n_experts
        moe_frac = 1.0 / cfg.moe_stride        # llama4: every 2nd layer
        per_layer += moe_frac * (n_e * 3 * d * cfg.d_ff
                                 + d * cfg.n_experts)  # router
        per_layer += (1 - moe_frac) * glu      # interleaved dense MLPs
        if cfg.dense_residual:
            per_layer += 3 * d * (cfg.dense_residual_ff or cfg.d_ff)
    elif cfg.attn_free:
        # rwkv: r,k,v,g,o projections + decay lora, no attention
        per_layer = 5 * d * d + 2 * d * 64 + 2 * d
        per_layer += 3 * d * cfg.d_ff // 1   # channel-mix (ffn)
    else:
        per_layer += glu
    if cfg.ssm:
        d_in = d
        per_layer += 2 * d * d_in + d_in * cfg.ssm_conv \
            + 2 * d_in * cfg.ssm_state + d_in * d
    total = cfg.n_layers * per_layer
    if cfg.encoder_layers:
        enc_layer = attn + glu + 2 * d
        total += cfg.encoder_layers * (enc_layer + attn + 2 * d)  # +cross
    total += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return int(total)
