"""Sharding annotations decoupled from model code.

Model code calls ``shard(x, "data", None, "model")`` at the natural
cut points; outside a mesh context (CPU unit tests) these are no-ops,
under ``with mesh:`` in the launchers they become
``with_sharding_constraint`` with the mesh's axis names.

Logical axes:
  "data"   — batch (mapped to the physical ('pod', 'data') axes)
  "model"  — tensor-parallel (heads / ff hidden / vocab / experts)
  "seq"    — optional sequence parallelism (mapped to 'data' for
             prefill shapes; see EXPERIMENTS §Perf)
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _rules() -> dict | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_axis_rules(rules: dict[str, tuple[str, ...] | str | None]):
    """Map logical axis names to physical mesh axes for this scope.

    Example: {"data": ("pod", "data"), "model": "model"}.
    """
    prev = _rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def resolve(*logical: str | None) -> P:
    """Logical names -> PartitionSpec under the active rules."""
    rules = _rules() or {}
    return P(*[rules.get(a) if a is not None else None for a in logical])


def shard(x, *logical: str | None):
    """Constrain ``x`` (no-op outside a mesh / without rules)."""
    if _rules() is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, resolve(*logical))
    except (ValueError, RuntimeError):
        return x  # no mesh in scope (unit tests)


DEFAULT_RULES = {"data": ("pod", "data"), "model": "model"}
SINGLE_POD_RULES = {"data": "data", "model": "model"}
