"""LM substrate: pure-pytree models for the assigned architecture pool."""
