"""GQA attention with memory-efficient (flash-style) chunking.

Features driven by ModelConfig: grouped-query/multi-query KV heads,
qk-norm (qwen3), sliding-window (h2o-danube), RoPE, cross-attention
(seamless decoder), KV-cache decode.  The chunked running-softmax is
what lets 32k-token prefill lower within HBM on the dry-run meshes —
scores never materialize beyond (B, H, q_chunk, kv_chunk).

Sharding: head axes are cut over "model"; batch over "data".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.config import ModelConfig
from repro.models.sharding import shard

NEG_INF = -1e30


def init(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    h, k = cfg.n_heads, cfg.n_kv_heads
    ks = cm.split_key(key, 4)
    params = {
        "wq": cm.dense_init(ks[0], d, (h, hd)),
        "wk": cm.dense_init(ks[1], d, (k, hd)),
        "wv": cm.dense_init(ks[2], d, (k, hd)),
        "wo": {"w": cm.truncated_normal(ks[3], (h, hd, d),
                                        (h * hd) ** -0.5)},
    }
    if cfg.qk_norm:
        params["q_norm"] = cm.rmsnorm_init(hd)
        params["k_norm"] = cm.rmsnorm_init(hd)
    return params


def _project_qkv(params, cfg: ModelConfig, x, positions, rope: bool = True):
    dt = x.dtype
    q = cm.dense_apply(params["wq"], x, dt)           # (B,T,H,hd)
    k = cm.dense_apply(params["wk"], x, dt)           # (B,T,K,hd)
    v = cm.dense_apply(params["wv"], x, dt)
    if cfg.qk_norm:
        q = cm.rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = cm.rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    if rope:
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _repeat_kv(k, n_heads: int):
    """(B,S,K,hd) -> (B,S,H,hd) by group broadcast."""
    b, s, kh, hd = k.shape
    reps = n_heads // kh
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, reps, hd)) \
        .reshape(b, s, n_heads, hd)


def _chunked_attention(q, k, v, q_pos, kv_pos, *, causal: bool,
                       window: int | None, q_chunk: int, kv_chunk: int):
    """Running-softmax attention. q: (B,Tq,H,D); k,v: (B,Tk,H,D)."""
    b, tq, h, d = q.shape
    tk = k.shape[1]
    scale = d ** -0.5
    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    nq = -(-tq // q_chunk)
    nk = -(-tk // kv_chunk)
    # pad to chunk multiples (masked out via positions)
    def pad_t(x, n, fill=0):
        padlen = n - x.shape[1]
        if padlen == 0:
            return x
        cfgpad = [(0, 0)] * x.ndim
        cfgpad[1] = (0, padlen)
        return jnp.pad(x, cfgpad, constant_values=fill)
    q = pad_t(q, nq * q_chunk)
    k = pad_t(k, nk * kv_chunk)
    v = pad_t(v, nk * kv_chunk)
    q_pos = pad_t(q_pos, nq * q_chunk, fill=-1)       # padded q: masked rows
    kv_pos = pad_t(kv_pos, nk * kv_chunk, fill=2**30)  # padded kv: future

    qc = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)
    kc = k.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    qp = q_pos.reshape(b, nq, q_chunk).transpose(1, 0, 2)
    kp = kv_pos.reshape(b, nk, kv_chunk).transpose(1, 0, 2)

    def one_q_chunk(args):
        qi, qpi = args                                  # (B,H,Cq,D), (B,Cq)

        def kv_step(carry, args_k):
            m, l, acc = carry
            ki, vi, kpi = args_k
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones_like(s, dtype=bool)
            if causal:
                mask &= qpi[:, None, :, None] >= kpi[:, None, None, :]
            if window is not None:
                mask &= (qpi[:, None, :, None] - kpi[:, None, None, :]
                         < window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, qi.shape[2]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qi.shape[2]), jnp.float32)
        a0 = jnp.zeros((b, h, qi.shape[2], d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kc, vc, kp))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(one_q_chunk, (qc, qp))           # (nq,B,H,Cq,D)
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, nq * q_chunk, h, d)
    return out[:, :tq].astype(v.dtype)


def apply(params, cfg: ModelConfig, x, positions, *, causal: bool = True):
    """Full-sequence attention (training / prefill). x: (B,T,D)."""
    q, k, v = _project_qkv(params, cfg, x, positions)
    q = shard(q, "data", None, "model", None)
    k = shard(k, "data", None, "model", None)
    h = cfg.n_heads
    k, v = _repeat_kv(k, h), _repeat_kv(v, h)
    out = _chunked_attention(
        q, k, v, positions, positions, causal=causal,
        window=cfg.sliding_window, q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk)
    out = shard(out, "data", None, "model", None)
    return cm.dense_apply_out(params["wo"], out, x.dtype)


def cross_apply(params, cfg: ModelConfig, x, memory, positions):
    """Cross-attention: queries from x, KV from encoder memory."""
    dt = x.dtype
    memory = memory.astype(dt)   # frontend stubs may feed fp32
    q = cm.dense_apply(params["wq"], x, dt)
    k = cm.dense_apply(params["wk"], memory, dt)
    v = cm.dense_apply(params["wv"], memory, dt)
    k, v = _repeat_kv(k, cfg.n_heads), _repeat_kv(v, cfg.n_heads)
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32)[None],
        memory.shape[:2])
    out = _chunked_attention(
        q, k, v, positions, mem_pos, causal=False, window=None,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    return cm.dense_apply_out(params["wo"], out, dt)


# Decode path ----------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Ring-buffer KV cache; SWA caps it at the window size."""
    length = min(max_len, cfg.sliding_window or max_len)
    kd = (batch, length, cfg.n_kv_heads, cfg.resolved_head_dim())
    return {"k": jnp.zeros(kd, dtype), "v": jnp.zeros(kd, dtype),
            "pos": jnp.zeros((batch, length), jnp.int32) - 1}


def decode_step(params, cfg: ModelConfig, cache, x, position):
    """One-token decode. x: (B,1,D); position: (B,) absolute index.

    Returns (cache', out (B,1,D)).  The cache is a ring buffer indexed
    by position % length, so sliding-window archs hold only the window.
    """
    q, k_new, v_new = _project_qkv(
        params, cfg, x, position[:, None])
    length = cache["k"].shape[1]
    slot = (position % length).astype(jnp.int32)        # (B,)
    b_idx = jnp.arange(x.shape[0])
    cache = {
        "k": cache["k"].at[b_idx, slot].set(k_new[:, 0]),
        "v": cache["v"].at[b_idx, slot].set(v_new[:, 0]),
        "pos": cache["pos"].at[b_idx, slot].set(position),
    }
    h = cfg.n_heads
    k = _repeat_kv(cache["k"], h)                       # (B,S,H,hd)
    v = _repeat_kv(cache["v"], h)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhd,bshd->bhqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    valid = cache["pos"] >= 0
    mask = valid[:, None, None, :] \
        & (cache["pos"][:, None, None, :] <= position[:, None, None, None])
    if cfg.sliding_window is not None:
        mask &= (position[:, None, None, None]
                 - cache["pos"][:, None, None, :] < cfg.sliding_window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", p, v)
    return cache, cm.dense_apply_out(params["wo"], out, x.dtype)
