"""Gated MLPs: SwiGLU (llama-family) and GeGLU (gemma/paligemma)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.sharding import shard


def init(key, d_model: int, d_ff: int):
    k1, k2, k3 = cm.split_key(key, 3)
    return {
        "w_gate": cm.dense_init(k1, d_model, d_ff),
        "w_up": cm.dense_init(k2, d_model, d_ff),
        "w_down": cm.dense_init(k3, d_ff, d_model),
    }


def apply(params, x, kind: str = "swiglu"):
    act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
    gate = cm.dense_apply(params["w_gate"], x, x.dtype)
    up = cm.dense_apply(params["w_up"], x, x.dtype)
    hidden = act(gate) * up
    hidden = shard(hidden, "data", None, "model")
    return cm.dense_apply(params["w_down"], hidden, x.dtype)
