"""Typed failure taxonomy — the serve/plan tier's error contract.

The ROADMAP's north-star serve tier ("heavy traffic from millions of
users") needs failures that are *classifiable at the call site*: an
operator script must be able to distinguish "your graph is malformed"
(client bug, never retry) from "the queue is full" (backpressure,
retry later) from "your query ran out of budget" (partial result,
decide) from "the device step failed" (infrastructure, the engine
already retried).  Python's builtin exceptions can't carry that
taxonomy, so every failure the BFS plan/serve path raises or attaches
derives from `ReproError`:

    ReproError
    ├── GraphValidationError   (also ValueError)   admission-time input
    ├── AdmissionRejected                          load-shed at submit
    │   └── QueueFullError                         bounded-queue overflow
    ├── DeadlineExceeded                           query budget expired
    ├── InjectedFault          (also RuntimeError) chaos-test fault
    └── TickRetriesExhausted   (also RuntimeError) retry budget spent

Design rules:

* **Dual inheritance keeps old callers working.**
  `GraphValidationError` IS a `ValueError` — code that guarded
  ``plan()`` with ``except ValueError`` still catches it, while new
  code can catch the precise class.  Likewise `InjectedFault` /
  `TickRetriesExhausted` are `RuntimeError`\\ s.
* **Errors are data.** `DeadlineExceeded` is *attached* to a
  truncated query result (``BfsQuery.error``) rather than raised from
  the tick loop — a deadline miss is a degraded result to deliver,
  not a serving failure; see `repro.serve.graph_engine`.
* **This module is import-leaf.**  It depends on nothing inside the
  package so every layer (kernels, formats, api, serve) can raise
  typed errors without import cycles.
"""
from __future__ import annotations


class ReproError(Exception):
    """Base class of every typed failure this package raises."""


class GraphValidationError(ReproError, ValueError):
    """A graph (or root) failed admission-time structural validation.

    Raised by ``repro.bfs.plan`` / `GraphEngine` construction /
    ``submit`` when the input could produce a *wrong answer* rather
    than an error: non-monotone ``colstarts``, out-of-range neighbor
    ids, wrong dtypes, NaN-shaped geometry, roots outside ``[0, V)``.
    The message always names the violated invariant and the fix.
    """


class AdmissionRejected(ReproError):
    """The serve tier declined to enqueue a query (load shedding).

    Carries the `repro.serve.robust.AdmissionDecision` that rejected
    it as ``decision`` — the typed record of *why* (circuit state,
    queue depth) for the client's retry policy.
    """

    def __init__(self, message: str, decision=None):
        super().__init__(message)
        self.decision = decision


class QueueFullError(AdmissionRejected):
    """The engine's bounded submit queue is at capacity.

    The backpressure signal the ISSUE-8 admission control emits
    *instead of* unbounded queue growth (or a silently-dropping
    ``deque(maxlen=...)``): the client sees the rejection and can
    retry after draining, with jitter, or route elsewhere.
    """


class DeadlineExceeded(ReproError):
    """A query's wall-clock (or global run) budget expired.

    Attached to the harvested `BfsQuery` as ``query.error`` with
    ``truncated=True`` — the parent array, when present, is PARTIAL.

    Attributes:
      uid: the query's uid (None for engine-global budgets).
      elapsed_s: wall seconds from submit when the budget tripped.
      budget_s: the configured budget.
      where: ``"queued"`` (expired before ever running),
        ``"in_flight"`` (expired mid-traversal) or ``"global"``
        (the `run_until_done` budget harvested it).
    """

    def __init__(self, message: str, *, uid=None, elapsed_s=None,
                 budget_s=None, where: str = "in_flight"):
        super().__init__(message)
        self.uid = uid
        self.elapsed_s = elapsed_s
        self.budget_s = budget_s
        self.where = where


class InjectedFault(ReproError, RuntimeError):
    """A `repro.serve.robust.ServeFaultInjector` fired.

    The serve-path sibling of `repro.runtime.fault.SimulatedFailure`:
    raised from inside the engine tick to prove the retry/requeue
    machinery recovers (chaos tests kill ticks mid-run and assert
    zero lost queries).
    """


class TickRetriesExhausted(ReproError, RuntimeError):
    """A serve tick kept failing past the capped-backoff retry budget.

    Before raising, the engine re-queues every in-flight query (their
    state restarts from the root), so even this terminal path loses
    nothing — a later `run_until_done` drains them.
    """
