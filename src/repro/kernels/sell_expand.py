"""Pallas TPU kernel: SELL-C-σ slice expansion (SlimSell traversal).

The format-specialized counterpart of `frontier_expand.py`.  The CSR
kernel consumes an *apportioned* edge stream built on the host side of
the layer (compaction + prefix-sum over the frontier); the SELL kernel
instead sweeps the SELL-C-σ adjacency itself, SpMV-style [SlimSell,
arXiv:2010.09913]: every layer touches every stored slot, but every
load is a fully aligned slab and the frontier test is a lane mask —
no gather irregularity in the stream, no apportionment pass at all.

Layout (built in formats/sell.py):

* vertices are degree-sorted within σ-windows and grouped into
  **slices** of C=128 rows (one slice row set = one TPU lane set);
* each slice stores its adjacency column-major, padded to the slice's
  own width rounded up to W_Q=8 columns — so the unit of storage is a
  **slab**: an (8, 128) int32 block, exactly one aligned 8x128 vector
  tile.  ``cols[slab, q, lane]`` is a neighbor id (sentinel V pads),
  ``slab_rows[slab, lane]`` the owning vertex id.

Grid = slices (``slabs_per_step`` slabs per grid step; on TPU one
step per slab, i.e. literally one slice column-group).  Since ISSUE 3
the grid is **active-step scheduled**: a scalar-prefetched work-list
(`formats.sell.SellFormat` plans it from the frontier x ``slab_rows``
membership test) picks which slab group each grid step DMAs; entries
past the live count are clamped to the last active group (unchanged
block index => Mosaic elides the repeated DMA) and a ``pl.when``
guard skips their compute — so a thin layer sweeps only the slices
that actually hold frontier rows instead of all of nnz_sell.  Passing
the identity work-list recovers the full SpMV sweep (the
``materialized`` pipeline of the ablation axis).  Per step:

  1. load the slab's neighbor ids + row ids  (aligned vector loads —
     the §4.2 alignment goal with zero peel/remainder handling)
  2. lane mask: row in frontier  AND  neighbor unvisited  AND  not
     sentinel — masks replace the paper's peel/remainder loops exactly
     as §4.2's padding does
  3. masked scatter P[nbr] = row - |V|   (negative mark, §3.3.2)
  4. masked racy word scatter out |= bit (Fig. 6 race; restoration
     repairs)

Because the (row, nbr) direction of the test is symmetric in the
symmetrized Graph500 adjacency, the same sweep serves top-down and
bottom-up: "row in frontier, neighbor undiscovered" is exactly the
bottom-up "candidate unvisited, parent in frontier" read along the
reverse edge.  `formats/sell.py` therefore maps both engine modes
onto this one kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitmap import WORD_MASK, WORD_SHIFT
from repro.kernels.gather_expand import (P_UNSET, _dma_pipeline,
                                         _relax_scatter_parents,
                                         _relax_scatter_vals)
from repro.kernels.layer_fused import _restore_in_kernel
from repro.kernels.pallas_compat import CompilerParams

SLICE_C = 128   # rows per slice = TPU vector lane count (csr.LANES)
W_QUANT = 8     # columns per slab: 8x128 int32 = one aligned tile


def _sell_tile(n_vertices: int, bottom_up: bool, cols, rows, frontier,
               vis, out, p):
    """One grid step of the sweep on loaded VMEM values.

    cols: (S, W_QUANT, C) neighbor ids; rows: (S, C) owning vertex ids.
    Returns the updated (out, p) for this step's writes.

    ``bottom_up`` swaps the roles on the symmetrized adjacency: the
    top-down sweep gates on "row in frontier" and discovers the
    *neighbor*; the bottom-up sweep gates on "neighbor in frontier"
    and discovers the *row* — the hybrid's "unvisited candidate scans
    its parents" read, which is what lets the planner schedule only
    the slabs of *unvisited* rows late in the search (fully-visited
    slices drop out entirely)."""
    nbr = cols
    src = jnp.broadcast_to(rows[:, None, :], cols.shape)
    # the frontier-gated side vs the discovered side (role swap)
    gate, disc = (nbr, src) if bottom_up else (src, nbr)

    # lane mask 1: gated side in the frontier
    sw = jnp.clip(gate >> WORD_SHIFT, 0, frontier.shape[0] - 1)
    sb = (gate & WORD_MASK).astype(jnp.uint32)
    in_front = (frontier[sw] >> sb) & jnp.uint32(1) != 0

    # lane mask 2: discovered side undiscovered; sentinels filter out
    word = disc >> WORD_SHIFT
    bit = (disc & WORD_MASK).astype(jnp.uint32)
    bits = jnp.uint32(1) << bit
    w_clip = jnp.clip(word, 0, out.shape[0] - 1)
    out_words = out[w_clip]
    undiscovered = ((vis[w_clip] | out_words) & bits) == 0

    mask = (in_front & undiscovered
            & (nbr < n_vertices) & (src < n_vertices))

    # masked scatter of P (negative marking) — benign duplicate race
    p_idx = jnp.where(mask, disc, p.shape[0])
    new_p = p.at[p_idx].set(gate - n_vertices, mode="drop")

    # masked racy word scatter of the output queue (Fig. 6 race)
    new_words = out_words | bits
    w_idx = jnp.where(mask, word, out.shape[0])
    new_out = out.at[w_idx].set(new_words, mode="drop")
    return new_out, new_p


def _sell_kernel(n_vertices: int, bottom_up: bool, wl_ref, na_ref,
                 cols_ref, rows_ref, frontier_ref, vis_ref, out0_ref,
                 p0_ref, out_ref, p_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():  # carry initial out/P into the accumulating outputs
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    @pl.when(t < na_ref[0])
    def _work():  # inactive steps: no DMA (clamped index), no compute
        out, p = _sell_tile(n_vertices, bottom_up, cols_ref[...],
                            rows_ref[...], frontier_ref[...],
                            vis_ref[...], out_ref[...], p_ref[...])
        out_ref[...] = out
        p_ref[...] = p


def _sell_batched_kernel(n_vertices: int, bottom_up: bool, wl_ref,
                         na_ref, cols_ref, rows_ref, frontier_ref,
                         vis_ref, out0_ref, p0_ref, out_ref, p_ref):
    """Batched variant: grid (roots, slice steps).  The adjacency slabs
    are root-independent (shared blocks); bitmaps/P carry a leading
    size-1 root axis, each root accumulating into its own rows; each
    root schedules its own active-slab work-list."""
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    @pl.when(t < na_ref[b])
    def _work():
        out, p = _sell_tile(n_vertices, bottom_up, cols_ref[...],
                            rows_ref[...], frontier_ref[0], vis_ref[0],
                            out_ref[0], p_ref[0])
        out_ref[...] = out[None]
        p_ref[...] = p[None]


def _sell_dma_pipeline(cols_hbm, rows_hbm, cols_buf, rows_buf, sems,
                       wl, spp: int, depth: int, n_steps: int, t, warm,
                       work):
    """Manual double-buffered input pipeline over BOTH slab arrays.

    Per step two DMAs (cols slab group + its slab_rows) share a slot;
    ``depth`` steps stay in flight ahead of the compute step, exactly
    the gather kernel's pipeline shape (see
    `gather_expand._dma_pipeline`)."""
    n_buf = depth + 1

    def dmas(step):
        slot = jax.lax.rem(step, n_buf)
        g = wl(step)
        return (pltpu.make_async_copy(
                    cols_hbm.at[pl.ds(g * spp, spp)], cols_buf.at[slot],
                    sems.at[0, slot]),
                pltpu.make_async_copy(
                    rows_hbm.at[pl.ds(g * spp, spp)], rows_buf.at[slot],
                    sems.at[1, slot]))

    @pl.when(warm)
    def _warmup():
        for k in range(min(depth, n_steps)):
            for d in dmas(jnp.int32(k)):
                d.start()

    @pl.when(t + depth < n_steps)
    def _ahead():
        for d in dmas(t + depth):
            d.start()

    for d in dmas(t):
        d.wait()
    slot = jax.lax.rem(t, n_buf)
    work(cols_buf[slot], rows_buf[slot])


def _sell_dma_kernel(n_vertices: int, bottom_up: bool, spp: int,
                     depth: int, n_steps: int, wl_ref, na_ref,
                     cols_ref, rows_ref, frontier_ref, vis_ref,
                     out0_ref, p0_ref, out_ref, p_ref, cols_buf,
                     rows_buf, sems):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    def work(cols_blk, rows_blk):
        @pl.when(t < na_ref[0])
        def _work():
            out, p = _sell_tile(n_vertices, bottom_up, cols_blk,
                                rows_blk, frontier_ref[...],
                                vis_ref[...], out_ref[...], p_ref[...])
            out_ref[...] = out
            p_ref[...] = p

    _sell_dma_pipeline(cols_ref, rows_ref, cols_buf, rows_buf, sems,
                       lambda s: wl_ref[s], spp, depth, n_steps, t,
                       t == 0, work)


def _sell_dma_batched_kernel(n_vertices: int, bottom_up: bool,
                             spp: int, depth: int, n_steps: int,
                             wl_ref, na_ref, cols_ref, rows_ref,
                             frontier_ref, vis_ref, out0_ref, p0_ref,
                             out_ref, p_ref, cols_buf, rows_buf, sems):
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    def work(cols_blk, rows_blk):
        @pl.when(t < na_ref[b])
        def _work():
            out, p = _sell_tile(n_vertices, bottom_up, cols_blk,
                                rows_blk, frontier_ref[0], vis_ref[0],
                                out_ref[0], p_ref[0])
            out_ref[...] = out[None]
            p_ref[...] = p[None]

    _sell_dma_pipeline(cols_ref, rows_ref, cols_buf, rows_buf, sems,
                       lambda s: wl_ref[b, s], spp, depth, n_steps, t,
                       t == 0, work)


def vmem_budget(n_words: int, v_pad: int, slabs_per_step: int,
                prefetch_depth: int = 0, n_steps: int | None = None) -> int:
    """Bytes of VMEM pinned (bitmaps x4 + P x2 + slab buffers — 2 for
    the automatic BlockSpec pipeline, ``depth + 1`` for the manual DMA
    pipeline).  ``depth`` is the *resolved* pipeline depth: the
    wrappers clamp ``prefetch_depth`` to the step count, so the budget
    must too — charging the unclamped depth rejects shallow sweeps
    that the kernel would actually run with fewer buffers (ISSUE 9
    satellite: budgets compute from the resolved spec only)."""
    slab = slabs_per_step * (W_QUANT + 1) * SLICE_C * 4
    depth = max(int(prefetch_depth), 0)
    if n_steps is not None:
        depth = min(depth, max(int(n_steps), 1))
    return 4 * (4 * n_words + 2 * v_pad) + max(2, depth + 1) * slab


@functools.partial(jax.jit, static_argnames=("n_vertices",
                                             "slabs_per_step",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def sell_expand(cols, slab_rows, worklist, n_active, frontier, visited,
                out_init, p_init, *, n_vertices: int,
                slabs_per_step: int = 1, bottom_up: bool = False,
                prefetch_depth: int = 0, interpret: bool = True):
    """Single-root SELL sweep over the active slab groups.

    Args:
      cols: (n_slabs, W_QUANT, C) int32 neighbor slabs (sentinel-padded;
        n_slabs must be a multiple of ``slabs_per_step``).
      slab_rows: (n_slabs, C) int32 owning vertex ids per slab.
      worklist: (n_steps,) int32 slab-group id per grid step, active
        prefix first, tail clamped to the last active group.
        ``jnp.arange(n_steps)`` + ``n_active == n_steps`` recovers the
        full sweep.
      n_active: (1,) int32 live prefix length of ``worklist``.
      frontier, visited, out_init: (W,) uint32 bitmaps.
      p_init: (V_pad,) int32 predecessor array.
    Returns:
      (out, parent) after the racy sweep (restoration NOT applied) —
      the same contract as `frontier_expand.frontier_expand`.
    """
    n_slabs = cols.shape[0]
    assert n_slabs % slabs_per_step == 0, \
        "pad the slab count to the step size"
    n_steps = n_slabs // slabs_per_step
    assert worklist.shape[0] == n_steps
    n_words = visited.shape[0]
    v_pad = p_init.shape[0]

    whole = lambda n: pl.BlockSpec((n,), lambda t, wl, na: (0,))
    if prefetch_depth > 0:
        depth = min(int(prefetch_depth), n_steps)
        any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
        cols_spec, rows_spec = any_spec, any_spec
        scratch = [pltpu.VMEM((depth + 1, slabs_per_step, W_QUANT,
                               SLICE_C), jnp.int32),
                   pltpu.VMEM((depth + 1, slabs_per_step, SLICE_C),
                              jnp.int32),
                   pltpu.SemaphoreType.DMA((2, depth + 1))]
        kernel = functools.partial(_sell_dma_kernel, n_vertices,
                                   bottom_up, slabs_per_step, depth,
                                   n_steps)
    else:
        cols_spec = pl.BlockSpec((slabs_per_step, W_QUANT, SLICE_C),
                                 lambda t, wl, na: (wl[t], 0, 0))
        rows_spec = pl.BlockSpec((slabs_per_step, SLICE_C),
                                 lambda t, wl, na: (wl[t], 0))
        scratch = []
        kernel = functools.partial(_sell_kernel, n_vertices, bottom_up)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_steps,),
        in_specs=[cols_spec, rows_spec, whole(n_words), whole(n_words),
                  whole(n_words), whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad)],
        scratch_shapes=scratch,
    )
    out, parent = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_words,), jnp.uint32),
                   jax.ShapeDtypeStruct((v_pad,), jnp.int32)],
        compiler_params=CompilerParams(
            # accumulating outputs => sequential grid on the core
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_sell_expand",
    )(worklist, n_active, cols, slab_rows, frontier, visited, out_init,
      p_init)
    return out, parent


@functools.partial(jax.jit, static_argnames=("n_vertices",
                                             "slabs_per_step",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def sell_expand_batched(cols, slab_rows, worklist, n_active, frontier,
                        visited, out_init, p_init, *, n_vertices: int,
                        slabs_per_step: int = 1, bottom_up: bool = False,
                        prefetch_depth: int = 0,
                        interpret: bool = True):
    """Multi-root SELL sweep: one launch expands B independent searches.

    The adjacency (cols, slab_rows) has NO root axis — the layout is
    shared; bitmaps/P carry a leading (B,) and so do ``worklist``
    ((B, n_steps)) and ``n_active`` ((B,)) — a finished root has
    ``n_active == 0`` and costs nothing.  Grid is (B, slice steps):
    the root axis is embarrassingly parallel, the slice axis stays
    sequential so later slabs observe earlier slabs' updates.
    """
    n_slabs = cols.shape[0]
    assert n_slabs % slabs_per_step == 0, \
        "pad the slab count to the step size"
    n_steps = n_slabs // slabs_per_step
    n_batch, n_words = visited.shape
    assert worklist.shape == (n_batch, n_steps)
    v_pad = p_init.shape[1]

    whole = lambda n: pl.BlockSpec((1, n), lambda b, t, wl, na: (b, 0))
    if prefetch_depth > 0:
        depth = min(int(prefetch_depth), n_steps)
        any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
        cols_spec, rows_spec = any_spec, any_spec
        scratch = [pltpu.VMEM((depth + 1, slabs_per_step, W_QUANT,
                               SLICE_C), jnp.int32),
                   pltpu.VMEM((depth + 1, slabs_per_step, SLICE_C),
                              jnp.int32),
                   pltpu.SemaphoreType.DMA((2, depth + 1))]
        kernel = functools.partial(_sell_dma_batched_kernel, n_vertices,
                                   bottom_up, slabs_per_step, depth,
                                   n_steps)
        semantics = ("arbitrary", "arbitrary")
    else:
        cols_spec = pl.BlockSpec((slabs_per_step, W_QUANT, SLICE_C),
                                 lambda b, t, wl, na: (wl[b, t], 0, 0))
        rows_spec = pl.BlockSpec((slabs_per_step, SLICE_C),
                                 lambda b, t, wl, na: (wl[b, t], 0))
        scratch = []
        kernel = functools.partial(_sell_batched_kernel, n_vertices,
                                   bottom_up)
        semantics = ("parallel", "arbitrary")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_batch, n_steps),
        in_specs=[cols_spec, rows_spec, whole(n_words), whole(n_words),
                  whole(n_words), whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad)],
        scratch_shapes=scratch,
    )
    out, parent = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
        name="bfs_sell_expand_batched",
    )(worklist, n_active, cols, slab_rows, frontier, visited, out_init,
      p_init)
    return out, parent


# ---------------------------------------------------------------------------
# SELL megakernel: the whole layer in ONE Pallas call (ISSUE 9).
#
# The active-step scheduling above rides scalar-prefetched BlockSpec
# index maps, which forces the slab plan onto the host side of the
# launch — the reason `SellFormat.supports_megakernel` stayed False
# through PR 6.  These kernels restructure the sweep around manual
# `make_async_copy` DMA exactly like `layer_fused.py`: the plan runs
# *inside* the kernel at step 0 (frontier x slab_rows membership,
# compacted with the same rank-scatter idiom — no host work-list), the
# SMEM work-list drives the cols DMA pipeline, and step n-1 inlines
# the restoration pass.  ``slab_rows`` stays fully VMEM-resident: the
# plan must read every slab's lane owners anyway, and at 128 int32 per
# slab it is W_QUANT x smaller than the cols stream it lets us skip.
# ---------------------------------------------------------------------------


def _plan_slabs_in_kernel(n_vertices: int, spp: int, n_steps: int,
                          words, slab_rows):
    """The in-kernel transcription of `formats.sell._plan_slab_steps`:
    from the (W,) planning bitmap (frontier, or ~visited bottom-up)
    and the resident (n_slabs, C) ``slab_rows``, build the compacted
    active slab-group work-list.  Same contract as
    `layer_fused._plan_in_kernel`: active prefix first, tail clamped
    to the last active group, plus the live count.  ``slab_rows`` must
    be pre-padded to an ``spp`` multiple (sentinel rows are never
    members, so padding slabs plan inactive — the zero-pad of the host
    planner)."""
    sw = jnp.clip(slab_rows >> WORD_SHIFT, 0, words.shape[0] - 1)
    sb = (slab_rows & WORD_MASK).astype(jnp.uint32)
    member = ((words[sw] >> sb) & jnp.uint32(1)) != 0
    act_slab = (member & (slab_rows < n_vertices)).any(axis=1)
    covered = act_slab.reshape(n_steps, spp).any(axis=1).astype(jnp.int32)
    n_active = covered.sum(dtype=jnp.int32)
    # rank-scatter compaction (jnp.nonzero is unavailable in-kernel)
    rank = jnp.cumsum(covered) - covered
    steps = jnp.arange(n_steps, dtype=jnp.int32)
    wl = jnp.zeros((n_steps,), jnp.int32) \
        .at[jnp.where(covered != 0, rank, n_steps)] \
        .set(steps, mode="drop")
    last = wl[jnp.clip(n_active - 1, 0, n_steps - 1)]
    wl = jnp.where(steps < n_active, wl, last)
    return wl, n_active


def _sell_layer_kernel(n_vertices: int, bottom_up: bool, spp: int,
                       depth: int, n_steps: int, cols_ref, rows_ref,
                       frontier_ref, vis_ref, p0_ref, out_ref, p_ref,
                       na_out_ref, wl_ref, na_ref, cols_buf, sems):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _plan():
        out_ref[...] = jnp.zeros(out_ref.shape, jnp.uint32)
        p_ref[...] = p0_ref[...]
        words = ~vis_ref[...] if bottom_up else frontier_ref[...]
        wl, na = _plan_slabs_in_kernel(n_vertices, spp, n_steps, words,
                                       rows_ref[...])
        wl_ref[...] = wl
        na_ref[0] = na
        na_out_ref[0] = na

    def work(cols_blk):
        @pl.when(t < na_ref[0])
        def _work():
            rows_blk = rows_ref[pl.ds(wl_ref[t] * spp, spp), :]
            out, p = _sell_tile(n_vertices, bottom_up, cols_blk,
                                rows_blk, frontier_ref[...],
                                vis_ref[...], out_ref[...], p_ref[...])
            out_ref[...] = out
            p_ref[...] = p

    _dma_pipeline(cols_ref, cols_buf, sems, lambda s: wl_ref[s], spp,
                  depth, n_steps, t, t == 0, work)

    @pl.when(t == n_steps - 1)
    def _restore():
        out, p = _restore_in_kernel(n_vertices, out_ref[...], p_ref[...])
        out_ref[...] = out
        p_ref[...] = p


def _sell_layer_batched_kernel(n_vertices: int, bottom_up: bool,
                               spp: int, depth: int, n_steps: int,
                               cols_ref, rows_ref, frontier_ref,
                               vis_ref, p0_ref, out_ref, p_ref,
                               na_out_ref, wl_ref, na_ref, cols_buf,
                               sems):
    """Batched variant: grid (roots, slice steps), root axis outer and
    sequential — each root re-plans into the shared SMEM scratch at
    its step 0, exactly the `layer_fused._layer_batched_kernel`
    shape."""
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _plan():
        out_ref[...] = jnp.zeros(out_ref.shape, jnp.uint32)
        p_ref[...] = p0_ref[...]
        words = ~vis_ref[0] if bottom_up else frontier_ref[0]
        wl, na = _plan_slabs_in_kernel(n_vertices, spp, n_steps, words,
                                       rows_ref[...])
        wl_ref[...] = wl
        na_ref[0] = na
        na_out_ref[0] = na

    def work(cols_blk):
        @pl.when(t < na_ref[0])
        def _work():
            rows_blk = rows_ref[pl.ds(wl_ref[t] * spp, spp), :]
            out, p = _sell_tile(n_vertices, bottom_up, cols_blk,
                                rows_blk, frontier_ref[0], vis_ref[0],
                                out_ref[0], p_ref[0])
            out_ref[...] = out[None]
            p_ref[...] = p[None]

    _dma_pipeline(cols_ref, cols_buf, sems, lambda s: wl_ref[s], spp,
                  depth, n_steps, t, t == 0, work)

    @pl.when(t == n_steps - 1)
    def _restore():
        out, p = _restore_in_kernel(n_vertices, out_ref[0], p_ref[0])
        out_ref[...] = out[None]
        p_ref[...] = p[None]


def megakernel_vmem_budget(n_words: int, v_pad: int, n_slabs: int,
                           slabs_per_step: int, prefetch_depth: int = 0,
                           n_steps: int = 1) -> int:
    """Bytes of VMEM the SELL megakernel pins: bitmaps x3 + P x2 + the
    fully resident ``slab_rows`` (x2 for the plan's membership working
    set) + the cols slab DMA buffers at the *clamped* pipeline
    depth + the SMEM work-list."""
    depth = min(max(int(prefetch_depth), 0), max(int(n_steps), 1))
    slab_cols = slabs_per_step * W_QUANT * SLICE_C * 4
    plan = 2 * 4 * n_slabs * SLICE_C + 4 * 3 * (n_steps + 1)
    return 4 * (3 * n_words + 2 * v_pad) \
        + (depth + 1) * slab_cols + plan


@functools.partial(jax.jit, static_argnames=("n_vertices",
                                             "slabs_per_step",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def sell_layer_fused(cols, slab_rows, frontier, visited, p_init, *,
                     n_vertices: int, slabs_per_step: int = 1,
                     bottom_up: bool = False, prefetch_depth: int = 0,
                     interpret: bool = True):
    """One SELL layer in ONE Pallas call: in-kernel slab plan + manual
    cols DMA + slab sweep + restoration.

    Same contract as `layer_fused.layer_fused`: returns the RESTORED
    ``(out, parent, n_active)`` — no host planning pass, no separate
    restore launch.  ``cols``/``slab_rows`` must be pre-padded to a
    ``slabs_per_step`` multiple (`ops._pad_slabs`).
    """
    n_slabs = cols.shape[0]
    assert n_slabs % slabs_per_step == 0, \
        "pad the slab count to the step size"
    n_steps = n_slabs // slabs_per_step
    n_words = visited.shape[0]
    v_pad = p_init.shape[0]
    depth = min(max(int(prefetch_depth), 0), n_steps)

    whole = lambda n: pl.BlockSpec((n,), lambda t: (0,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(n_steps,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                  pl.BlockSpec((n_slabs, SLICE_C), lambda t: (0, 0)),
                  whole(n_words), whole(n_words), whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad), whole(1)],
        scratch_shapes=[pltpu.SMEM((n_steps,), jnp.int32),
                        pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((depth + 1, slabs_per_step, W_QUANT,
                                    SLICE_C), jnp.int32),
                        pltpu.SemaphoreType.DMA((depth + 1,))],
    )
    out, parent, n_active = pl.pallas_call(
        functools.partial(_sell_layer_kernel, n_vertices, bottom_up,
                          slabs_per_step, depth, n_steps),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_words,), jnp.uint32),
                   jax.ShapeDtypeStruct((v_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        compiler_params=CompilerParams(
            # SMEM work-list + accumulating outputs => sequential grid
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_sell_layer_fused",
    )(cols, slab_rows, frontier, visited, p_init)
    return out, parent, n_active


@functools.partial(jax.jit, static_argnames=("n_vertices",
                                             "slabs_per_step",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def sell_layer_fused_batched(cols, slab_rows, frontier, visited,
                             p_init, *, n_vertices: int,
                             slabs_per_step: int = 1,
                             bottom_up: bool = False,
                             prefetch_depth: int = 0,
                             interpret: bool = True):
    """Multi-root SELL megakernel: B independent layer sweeps in one
    launch, each root planning its own in-kernel work-list."""
    n_slabs = cols.shape[0]
    assert n_slabs % slabs_per_step == 0, \
        "pad the slab count to the step size"
    n_steps = n_slabs // slabs_per_step
    n_batch, n_words = visited.shape
    v_pad = p_init.shape[1]
    depth = min(max(int(prefetch_depth), 0), n_steps)

    whole = lambda n: pl.BlockSpec((1, n), lambda b, t: (b, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(n_batch, n_steps),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                  pl.BlockSpec((n_slabs, SLICE_C), lambda b, t: (0, 0)),
                  whole(n_words), whole(n_words), whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad),
                   pl.BlockSpec((1,), lambda b, t: (b,))],
        scratch_shapes=[pltpu.SMEM((n_steps,), jnp.int32),
                        pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((depth + 1, slabs_per_step, W_QUANT,
                                    SLICE_C), jnp.int32),
                        pltpu.SemaphoreType.DMA((depth + 1,))],
    )
    out, parent, n_active = pl.pallas_call(
        functools.partial(_sell_layer_batched_kernel, n_vertices,
                          bottom_up, slabs_per_step, depth, n_steps),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32),
                   jax.ShapeDtypeStruct((n_batch,), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="bfs_sell_layer_fused_batched",
    )(cols, slab_rows, frontier, visited, p_init)
    return out, parent, n_active


# ---------------------------------------------------------------------------
# Semiring relaxation over SELL slabs (ISSUE 10): the SpMV reading of
# SlimSell taken literally — the slab sweep IS a semiring
# matrix-vector product, and this kernel runs it over the (min, ⊗)
# pair of `algorithms/semiring.py` instead of the BFS bit test-and-set.
# Same two-phase shape as `gather_expand.gather_relax_batched`: grid
# (B, 2, steps), phase 0 folds candidates into the value row with a
# masked scatter-min (commutative — no §3.3.2 race, no restoration),
# phase 1 re-walks the same slabs and resolves the deterministic
# min-id parent among edges achieving the finalized optimum.
# ---------------------------------------------------------------------------


def _sell_relax_edges(n_vertices: int, unit: int, weighted: bool, cols,
                      rows, frontier, vals):
    """Per-slab edge enumeration for the semiring sweep: (src, nbr,
    mask, cand) with ``cand = vals[src] ⊗ w(src, nbr)``."""
    from repro.algorithms.semiring import edge_weight

    nbr = cols
    src = jnp.broadcast_to(rows[:, None, :], cols.shape)
    valid = (src < n_vertices) & (nbr < n_vertices)
    sw = jnp.clip(src >> WORD_SHIFT, 0, frontier.shape[0] - 1)
    sb = (src & WORD_MASK).astype(jnp.uint32)
    in_front = ((frontier[sw] >> sb) & jnp.uint32(1)) != 0
    mask = valid & in_front
    u_val = vals[jnp.clip(src, 0, vals.shape[0] - 1)]
    if weighted:
        cand = u_val + edge_weight(src, nbr)
    elif unit:
        cand = u_val + jnp.asarray(unit, vals.dtype)
    else:
        cand = u_val
    return src, nbr, mask, cand


def _sell_relax_batched_kernel(n_vertices: int, unit: int,
                               weighted: bool, wl_ref, na_ref, cols_ref,
                               rows_ref, frontier_ref, vals_ref,
                               out_ref, p_ref):
    b = pl.program_id(0)
    ph = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when((ph == 0) & (t == 0))
    def _init():
        out_ref[...] = vals_ref[...]
        p_ref[...] = jnp.full(p_ref.shape, P_UNSET, jnp.int32)

    @pl.when(t < na_ref[b])
    def _work():
        src, nbr, mask, cand = _sell_relax_edges(
            n_vertices, unit, weighted, cols_ref[...], rows_ref[...],
            frontier_ref[0], vals_ref[0])
        v_slots = p_ref.shape[1]

        @pl.when(ph == 0)
        def _vals():
            out_ref[...] = _relax_scatter_vals(
                v_slots, src, nbr, mask, cand, out_ref[0])[None]

        @pl.when(ph == 1)
        def _parents():
            p_ref[...] = _relax_scatter_parents(
                v_slots, src, nbr, mask, cand, vals_ref[0], out_ref[0],
                p_ref[0])[None]


@functools.partial(jax.jit, static_argnames=("n_vertices",
                                             "slabs_per_step", "unit",
                                             "weighted", "interpret"))
def sell_relax_batched(cols, slab_rows, worklist, n_active, frontier,
                       vals, *, n_vertices: int, slabs_per_step: int = 1,
                       unit: int = 0, weighted: bool = False,
                       interpret: bool = True):
    """Multi-root semiring SpMV sweep over the active slab groups.

    Same schedule contract as `sell_expand_batched` (per-root scalar-
    prefetched work-lists, clamped tails); same return contract as
    `gather_expand.gather_relax_batched`: ``(out_vals, p_layer)`` with
    ``p_layer == P_UNSET`` where no edge won — the driver merges under
    the improved mask.  No restoration (scatter-min commutes).
    """
    n_slabs = cols.shape[0]
    assert n_slabs % slabs_per_step == 0, \
        "pad the slab count to the step size"
    n_steps = n_slabs // slabs_per_step
    n_batch, n_words = frontier.shape
    assert worklist.shape == (n_batch, n_steps)
    v_pad = vals.shape[1]

    whole = lambda n: pl.BlockSpec((1, n),
                                   lambda b, ph, t, wl, na: (b, 0))
    cols_spec = pl.BlockSpec((slabs_per_step, W_QUANT, SLICE_C),
                             lambda b, ph, t, wl, na: (wl[b, t], 0, 0))
    rows_spec = pl.BlockSpec((slabs_per_step, SLICE_C),
                             lambda b, ph, t, wl, na: (wl[b, t], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # phase-major sequential: phase 1 reads finalized values
        grid=(n_batch, 2, n_steps),
        in_specs=[cols_spec, rows_spec, whole(n_words), whole(v_pad)],
        out_specs=[whole(v_pad), whole(v_pad)],
    )
    out_vals, p_layer = pl.pallas_call(
        functools.partial(_sell_relax_batched_kernel, n_vertices, unit,
                          weighted),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, v_pad), vals.dtype),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
        name="bfs_sell_relax_batched",
    )(worklist, n_active, cols, slab_rows, frontier, vals)
    return out_vals, p_layer
