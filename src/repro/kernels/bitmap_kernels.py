"""Pallas TPU kernels for bitmap reductions.

``popcount`` — frontier-size reduction over the bitmap words, tiled
through VMEM with a scalar accumulator.  Used by the BFS drivers for
the termination test (``while in != 0``, Alg. 3 line 7).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE = 4096


def _popcount_kernel(words_ref, acc_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    counts = jax.lax.population_count(words_ref[...]).astype(jnp.int32)
    acc_ref[...] += counts.sum(keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def popcount(words, *, tile: int = DEFAULT_TILE, interpret: bool = True):
    """Total set bits in a (W,) uint32 bitmap (W padded to tile)."""
    n = words.shape[0]
    pad = (-n) % tile
    if pad:
        words = jnp.concatenate([words, jnp.zeros((pad,), jnp.uint32)])
    n_tiles = words.shape[0] // tile
    out = pl.pallas_call(
        _popcount_kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile,), lambda t: (t,))],
        out_specs=pl.BlockSpec((1,), lambda t: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bitmap_popcount",
    )(words)
    return out[0]
