"""Pallas TPU kernel: SIMD frontier compaction (paper §4, queue
generation).

The paper's headline vectorization replaces the per-edge scalar queue
append of Algorithm 2 with a *vector* sequence: test a lane mask,
prefix-sum the mask to rank each surviving lane, and scatter the
survivors to their ranked queue slots in one masked store.  This
kernel is that sequence applied to the engine's native **packed
uint32 bitmap** representation: a packed candidate bitmap goes in, a
dense vertex queue + count comes out, in one pass over ``W = V/32``
words — never materializing the dense ``V``-sized bool/int32 mask
that `core.bitmap.compact` (``unpack_bool`` + ``jnp.nonzero``)
round-trips through HBM every layer.

Structure (the §4 "vectorized queue generation", re-tiled):

* **per-tile popcount** — a tiny jnp planning pass popcounts each
  ``tile_words`` block of the bitmap and exclusive-prefix-sums the
  counts into per-tile *queue base offsets*.  This is O(W) packed-word
  work (V/8 bytes read), the 32x-compressed replacement for the
  full-V scan.
* **scalar-prefetched grid** — the base offsets ride in scalar
  prefetch memory; grid step t DMAs word-block t and already knows
  where its survivors land.
* **in-tile rank-and-scatter** — inside the tile the words unpack
  in-register to a (tile_words, 32) lane matrix; an exclusive prefix
  sum over the bit lanes ranks each set bit (the paper's
  ``_mm512_mask_compressstore`` analogue) and a masked scatter writes
  ``vertex_id`` to ``queue[base[t] + rank]``.

Bits beyond the queue capacity are dropped (``mode="drop"``), exactly
like `bitmap.compact`'s ``size=`` truncation; callers size the queue
from the workload counters (hostloop pow2 buckets) or at V_pad (the
fused engine's static planning queue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitmap import BITS_PER_WORD, word_bits
from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE_WORDS = 256   # 256 words = 8192 bits per grid step


def _rank_scatter(tile_words: int, t, words, base, queue):
    """In-tile rank-and-scatter on a loaded (tile_words,) word block.

    Returns the updated queue.  ``base`` is this tile's exclusive
    global offset (scalar)."""
    bits = word_bits(words).reshape(-1)
    vid = (t * tile_words + jnp.arange(tile_words, dtype=jnp.int32))
    vid = (vid[:, None] * BITS_PER_WORD
           + jnp.arange(BITS_PER_WORD, dtype=jnp.int32)).reshape(-1)
    # exclusive prefix sum over the flattened lanes = queue rank
    rank = jnp.cumsum(bits) - bits
    idx = jnp.where(bits != 0, base + rank, queue.shape[0])
    return queue.at[idx].set(vid, mode="drop")


def _compact_kernel(tile_words: int, fill: int, off_ref, words_ref,
                    q_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        q_ref[...] = jnp.full(q_ref.shape, fill, jnp.int32)

    q_ref[...] = _rank_scatter(tile_words, t, words_ref[...],
                               off_ref[t], q_ref[...])


def _compact_batched_kernel(tile_words: int, fill: int, off_ref,
                            words_ref, q_ref):
    """All roots per grid step: the grid runs over WORD TILES only and
    each step rank-and-scatters every root's (tile_words,) block into
    its queue row.  A root axis on the grid would cost B interpret
    steps per layer (and B sequential steps on a core); the row-wise
    scatter keeps the launch B-independent."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        q_ref[...] = jnp.full(q_ref.shape, fill, jnp.int32)

    words = words_ref[...]                   # (B, tile_words)
    n_batch = words.shape[0]
    bits = word_bits(words).reshape(n_batch, -1)   # (B, tiles * 32)
    vid = (t * tile_words + jnp.arange(tile_words, dtype=jnp.int32))
    vid = (vid[:, None] * BITS_PER_WORD
           + jnp.arange(BITS_PER_WORD, dtype=jnp.int32)).reshape(-1)
    rank = jnp.cumsum(bits, axis=1) - bits   # exclusive, per root
    size = q_ref.shape[1]
    col = jnp.where(bits != 0, off_ref[:, t][:, None] + rank, size)
    row = jnp.broadcast_to(
        jnp.arange(n_batch, dtype=jnp.int32)[:, None], col.shape)
    q_ref[...] = q_ref[...].at[row, col].set(
        jnp.broadcast_to(vid[None, :], col.shape), mode="drop")


def _plan(words, tile_words: int):
    """Per-tile popcounts -> (padded words, exclusive offsets, total).

    The packed planning pass: O(W) on uint32 words, no dense mask."""
    w = words.shape[-1]
    pad = (-w) % tile_words
    if pad:
        z = jnp.zeros(words.shape[:-1] + (pad,), jnp.uint32)
        words = jnp.concatenate([words, z], axis=-1)
    counts = jax.lax.population_count(words).astype(jnp.int32)
    per_tile = counts.reshape(words.shape[:-1] + (-1, tile_words)) \
        .sum(axis=-1, dtype=jnp.int32)
    offs = jnp.cumsum(per_tile, axis=-1, dtype=jnp.int32) - per_tile
    total = per_tile.sum(axis=-1, dtype=jnp.int32)
    return words, offs, total


def vmem_budget(n_batch: int, size: int, tile_words: int) -> int:
    """Bytes of VMEM the kernel pins: the whole (B, size) queue block
    plus the (B, tile_words) word block (double-buffered)."""
    return 4 * n_batch * size + 2 * 4 * n_batch * tile_words


def _budget_check(n_batch: int, size: int, tile_words: int) -> None:
    # local import: ops imports this module
    from repro.kernels.ops import VMEM_BYTES, _VMEM_HEADROOM
    budget = vmem_budget(n_batch, size, tile_words)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"frontier_compact working set {budget/2**20:.1f} MiB "
            f"exceeds VMEM budget; shard the vertex range across "
            f"chips (core/bfs_distributed.py), reduce the batch "
            f"width, or run the dense arm (packed=False)")


def _tile_words(n_words: int, interpret: bool) -> int:
    """Grid sizing: interpret mode evaluates every grid step in
    Python, so one un-padded step over the whole bitmap is cheapest;
    compiled mode keeps one aligned block per step."""
    if not interpret:
        return min(DEFAULT_TILE_WORDS, max(n_words, 1))
    return max(n_words, 1)


@functools.partial(jax.jit, static_argnames=("size", "fill",
                                             "tile_words", "interpret"))
def frontier_compact(words, *, size: int, fill: int,
                     tile_words: int | None = None,
                     interpret: bool = True):
    """Packed bitmap -> (queue (size,) int32, count scalar int32).

    The queue holds the set-bit vertex ids in ascending order, padded
    with ``fill`` (the sentinel); bits past ``size`` are dropped.
    Drop-in replacement for `core.bitmap.compact` + `popcount` without
    the dense unpack/nonzero round trip.
    """
    if tile_words is None:
        tile_words = _tile_words(words.shape[0], interpret)
    _budget_check(1, size, tile_words)
    words_p, offs, total = _plan(words, tile_words)
    n_tiles = words_p.shape[0] // tile_words

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_words,), lambda t, off: (t,))],
        out_specs=pl.BlockSpec((size,), lambda t, off: (0,)),
    )
    queue = pl.pallas_call(
        functools.partial(_compact_kernel, tile_words, fill),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((size,), jnp.int32),
        compiler_params=CompilerParams(
            # accumulating output => sequential grid on the core
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_frontier_compact",
    )(offs, words_p)
    return queue, total


@functools.partial(jax.jit, static_argnames=("size", "fill",
                                             "tile_words", "interpret"))
def frontier_compact_batched(words, *, size: int, fill: int,
                             tile_words: int | None = None,
                             interpret: bool = True):
    """Batched compaction: (B, W) words -> ((B, size) queues, (B,)
    counts).  The grid runs over word tiles only — every root's block
    is ranked and scattered inside one step, so the launch cost is
    independent of the batch width (one interpret step per tile, not
    B)."""
    if tile_words is None:
        tile_words = _tile_words(words.shape[1], interpret)
    _budget_check(words.shape[0], size, tile_words)
    words_p, offs, total = _plan(words, tile_words)
    n_batch = words_p.shape[0]
    n_tiles = words_p.shape[1] // tile_words

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((n_batch, tile_words),
                               lambda t, off: (0, t))],
        out_specs=pl.BlockSpec((n_batch, size), lambda t, off: (0, 0)),
    )
    queue = pl.pallas_call(
        functools.partial(_compact_batched_kernel, tile_words, fill),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_batch, size), jnp.int32),
        compiler_params=CompilerParams(
            # accumulating output => sequential grid on the core
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_frontier_compact_batched",
    )(offs, words_p)
    return queue, total
