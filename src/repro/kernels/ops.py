"""Jit'd public wrappers around the Pallas BFS kernels.

Selects interpret mode automatically (CPU containers validate the
kernel bodies in Python; real TPUs compile them), pads edge streams to
tile multiples, and enforces the VMEM budget that makes the
bitmap-in-VMEM design legal (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitmap import BITS_PER_WORD
from repro.kernels import bitmap_kernels, frontier_expand as fe
from repro.kernels import compact as ck
from repro.kernels import gather_expand as ge
from repro.kernels import layer_fused as lf
from repro.kernels import restoration as rest
from repro.kernels import sell_expand as se
from repro.kernels import traversal_fused as tf

VMEM_BYTES = 16 * 1024 * 1024  # v5e VMEM per core
_VMEM_HEADROOM = 0.75          # leave room for pipeline double-buffers


def vmem_limit_bytes() -> int:
    """The working-set ceiling every ``*_fits`` predicate tests
    against (VMEM minus double-buffer headroom)."""
    return int(VMEM_BYTES * _VMEM_HEADROOM)


def budget_detail(name: str, budget_bytes: int) -> str:
    """One-line human record of a failed VMEM budget — what
    `obs.metrics.record_degrade` reasons are built from, so every
    degrade log names the budget that failed in the same format."""
    return (f"{name} working set {budget_bytes / 2**20:.2f} MiB > "
            f"VMEM budget {vmem_limit_bytes() / 2**20:.1f} MiB")


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------
# Every wrapper below charges the Pallas calls it issues to this
# module-level counter *at trace time* (the wrappers are plain Python;
# the inner kernels are jit'd).  Tracing one engine layer step under
# `count_launches()` therefore yields the exact number of Pallas
# launches that step issues per layer — the ground truth the static
# `StepAux.launches` declarations are tested against.

_LAUNCH_COUNT = [0]


def _charge_launch(n: int = 1) -> None:
    _LAUNCH_COUNT[0] += n


class count_launches:
    """Context manager counting Pallas calls traced inside the block.

    >>> with ops.count_launches() as c:
    ...     step(frontier, visited, parent)
    >>> c.count   # launches one layer of this step costs
    """
    count = 0

    def __enter__(self):
        self._base = _LAUNCH_COUNT[0]
        return self

    def __exit__(self, *exc):
        self.count = _LAUNCH_COUNT[0] - self._base
        return False


def _scoped(name: str):
    """Wrap a kernel wrapper in ``jax.named_scope`` so XLA profiles
    (`repro.obs.trace.xla_profiler` / TensorBoard) attribute device
    time to named BFS phases instead of anonymous fusions.  Trace-time
    only — zero runtime cost inside jit."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with jax.named_scope(name):
                return fn(*args, **kwargs)
        return wrapper
    return deco


@_scoped("bfs.expand")
def expand(nbr, cand, valid, frontier, visited, out_init, p_init, *,
           n_vertices: int, tile: int = fe.DEFAULT_TILE,
           check_frontier: bool = False, interpret: bool | None = None):
    """Pad + run the frontier-expansion kernel (top-down or bottom-up)."""
    if interpret is None:
        interpret = _interpret_default()
    budget = fe.vmem_budget(visited.shape[0], p_init.shape[0], tile)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"frontier_expand working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py) or reduce the tile")
    n = cand.shape[0]
    pad = (-n) % tile
    if pad:
        z = jnp.zeros((pad,), jnp.int32)
        nbr = jnp.concatenate([nbr, z])
        cand = jnp.concatenate([cand, z])
        valid = jnp.concatenate([valid.astype(jnp.int32), z])
    _charge_launch()
    return fe.frontier_expand(
        nbr, cand, valid.astype(jnp.int32), frontier, visited, out_init,
        p_init, n_vertices=n_vertices, tile=tile,
        check_frontier=check_frontier, interpret=interpret)


@_scoped("bfs.expand_batched")
def expand_batched(nbr, cand, valid, frontier, visited, out_init, p_init,
                   *, n_vertices: int, tile: int = fe.DEFAULT_TILE,
                   check_frontier: bool = False,
                   interpret: bool | None = None):
    """Pad + run the batched (leading root-axis) expansion kernel.

    All arrays carry a leading (B,) root axis; each root's search
    expands independently in one launch.  The VMEM budget is per-root
    (the kernel pins one root's bitmaps/P at a time).
    """
    if interpret is None:
        interpret = _interpret_default()
    budget = fe.vmem_budget(visited.shape[1], p_init.shape[1], tile)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"frontier_expand working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py) or reduce the tile")
    n = cand.shape[1]
    pad = (-n) % tile
    if pad:
        z = jnp.zeros((cand.shape[0], pad), jnp.int32)
        nbr = jnp.concatenate([nbr, z], axis=1)
        cand = jnp.concatenate([cand, z], axis=1)
        valid = jnp.concatenate([valid.astype(jnp.int32), z], axis=1)
    _charge_launch()
    return fe.frontier_expand_batched(
        nbr, cand, valid.astype(jnp.int32), frontier, visited, out_init,
        p_init, n_vertices=n_vertices, tile=tile,
        check_frontier=check_frontier, interpret=interpret)


def _gather_budget_check(n_words: int, v_pad: int, n_cs: int,
                         tile: int, prefetch_depth: int = 0,
                         n_blocks: int | None = None) -> None:
    budget = ge.vmem_budget(n_words, v_pad, n_cs, tile, prefetch_depth,
                            n_blocks)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"gather_expand working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py) or reduce the tile or "
            f"prefetch_depth")


@_scoped("bfs.gather_expand")
def gather_expand(worklist, n_active, rows, colstarts, frontier,
                  visited, out_init, p_init, *, n_vertices: int,
                  tile: int = ge.DEFAULT_TILE, bottom_up: bool = False,
                  prefetch_depth: int = 0,
                  interpret: bool | None = None):
    """Run the fused in-kernel CSR gather over one layer's active
    tiles (see kernels/gather_expand.py).  ``rows`` must already be
    padded to a tile multiple (done once at build by the format, NOT
    per layer — re-padding inside the layer loop would reintroduce
    the O(E) copy this kernel exists to remove).  ``prefetch_depth``
    > 0 selects the manual double-buffered DMA input pipeline."""
    if interpret is None:
        interpret = _interpret_default()
    _gather_budget_check(visited.shape[0], p_init.shape[0],
                         colstarts.shape[0], tile, prefetch_depth,
                         rows.shape[0] // tile)
    n_active = jnp.atleast_1d(jnp.asarray(n_active, jnp.int32))
    _charge_launch()
    return ge.gather_expand(
        worklist.astype(jnp.int32), n_active, rows, colstarts, frontier,
        visited, out_init, p_init, n_vertices=n_vertices, tile=tile,
        bottom_up=bottom_up, prefetch_depth=prefetch_depth,
        interpret=interpret)


@_scoped("bfs.gather_expand_batched")
def gather_expand_batched(worklist, n_active, rows, colstarts, frontier,
                          visited, out_init, p_init, *, n_vertices: int,
                          tile: int = ge.DEFAULT_TILE,
                          bottom_up: bool = False,
                          prefetch_depth: int = 0,
                          interpret: bool | None = None):
    """Batched (leading root-axis) fused gather-expand: worklist/
    n_active/bitmaps/P carry (B, ...); the CSR arrays are shared.
    The VMEM budget is per-root."""
    if interpret is None:
        interpret = _interpret_default()
    _gather_budget_check(visited.shape[1], p_init.shape[1],
                         colstarts.shape[0], tile, prefetch_depth,
                         rows.shape[0] // tile)
    _charge_launch()
    return ge.gather_expand_batched(
        worklist.astype(jnp.int32), n_active.astype(jnp.int32), rows,
        colstarts, frontier, visited, out_init, p_init,
        n_vertices=n_vertices, tile=tile, bottom_up=bottom_up,
        prefetch_depth=prefetch_depth, interpret=interpret)


@_scoped("bfs.gather_relax_batched")
def gather_relax_batched(worklist, n_active, rows, colstarts, frontier,
                         vals, *, n_vertices: int,
                         tile: int = ge.DEFAULT_TILE, unit: int = 0,
                         weighted: bool = False,
                         interpret: bool | None = None):
    """Batched semiring relaxation over the active CSR tiles
    (kernels/gather_expand.py `gather_relax_batched`): scatter-min of
    ``vals[u] ⊗ w`` candidates plus the phase-2 deterministic parent
    resolve.  Per-root VMEM working set: frontier words + 2 value rows
    + the parent row + colstarts + the double-buffered rows tiles."""
    if interpret is None:
        interpret = _interpret_default()
    n_words, v_pad = frontier.shape[1], vals.shape[1]
    budget = 4 * (n_words + 3 * v_pad + colstarts.shape[0]) \
        + 2 * 4 * tile
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"gather_relax working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py) or reduce the tile")
    _charge_launch()
    return ge.gather_relax_batched(
        worklist.astype(jnp.int32), n_active.astype(jnp.int32), rows,
        colstarts, frontier, vals, n_vertices=n_vertices, tile=tile,
        unit=unit, weighted=weighted, interpret=interpret)


def _pad_slabs(cols, slab_rows, n_vertices: int, step: int):
    """Pad the slab axis to a multiple of ``step`` with sentinel slabs
    (all-V neighbor ids and row ids mask out entirely in-kernel)."""
    n_slabs = cols.shape[0]
    pad = (-n_slabs) % step
    if pad:
        cols = jnp.concatenate(
            [cols, jnp.full((pad,) + cols.shape[1:], n_vertices,
                            jnp.int32)])
        slab_rows = jnp.concatenate(
            [slab_rows, jnp.full((pad, slab_rows.shape[1]), n_vertices,
                                 jnp.int32)])
    return cols, slab_rows


def _sell_budget_check(n_words: int, v_pad: int, step: int,
                       prefetch_depth: int = 0,
                       n_steps: int | None = None) -> None:
    budget = se.vmem_budget(n_words, v_pad, step, prefetch_depth,
                            n_steps)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"sell_expand working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py) or reduce slabs_per_step or "
            f"prefetch_depth")


@_scoped("bfs.sell")
def sell(cols, slab_rows, frontier, visited, out_init, p_init, *,
         n_vertices: int, slabs_per_step: int = 1, worklist=None,
         n_active=None, bottom_up: bool = False,
         prefetch_depth: int = 0, interpret: bool | None = None):
    """Pad + run the single-root SELL-C-σ sweep kernel.

    ``worklist``/``n_active`` schedule the active slab groups (the
    fused pipeline; `formats.sell.SellFormat` plans them); omitting
    both runs the full identity sweep (the materialized pipeline).
    ``bottom_up`` swaps the sweep's gate/discover roles (rows are
    discovered, neighbors tested against the frontier);
    ``prefetch_depth`` > 0 selects the manual double-buffered DMA
    input pipeline.
    """
    if interpret is None:
        interpret = _interpret_default()
    _sell_budget_check(visited.shape[0], p_init.shape[0],
                       slabs_per_step, prefetch_depth,
                       -(-cols.shape[0] // slabs_per_step))
    cols, slab_rows = _pad_slabs(cols, slab_rows, n_vertices,
                                 slabs_per_step)
    n_steps = cols.shape[0] // slabs_per_step
    if worklist is None:
        worklist = jnp.arange(n_steps, dtype=jnp.int32)
        n_active = jnp.full((1,), n_steps, jnp.int32)
    else:
        n_active = jnp.atleast_1d(jnp.asarray(n_active, jnp.int32))
    _charge_launch()
    return se.sell_expand(
        cols, slab_rows, worklist.astype(jnp.int32), n_active, frontier,
        visited, out_init, p_init, n_vertices=n_vertices,
        slabs_per_step=slabs_per_step, bottom_up=bottom_up,
        prefetch_depth=prefetch_depth, interpret=interpret)


@_scoped("bfs.sell_batched")
def sell_batched(cols, slab_rows, frontier, visited, out_init, p_init,
                 *, n_vertices: int, slabs_per_step: int = 1,
                 worklist=None, n_active=None, bottom_up: bool = False,
                 prefetch_depth: int = 0,
                 interpret: bool | None = None):
    """Pad + run the batched (leading root-axis) SELL-C-σ sweep.

    The adjacency slabs carry no root axis (the layout is shared);
    bitmaps/P are (B, W) / (B, V_pad); per-root ``worklist`` is
    (B, n_steps) with ``n_active`` (B,) — omitted = full sweep for
    every root.  VMEM budget is per-root.
    """
    if interpret is None:
        interpret = _interpret_default()
    _sell_budget_check(visited.shape[1], p_init.shape[1],
                       slabs_per_step, prefetch_depth,
                       -(-cols.shape[0] // slabs_per_step))
    cols, slab_rows = _pad_slabs(cols, slab_rows, n_vertices,
                                 slabs_per_step)
    n_steps = cols.shape[0] // slabs_per_step
    n_batch = visited.shape[0]
    if worklist is None:
        worklist = jnp.broadcast_to(jnp.arange(n_steps, dtype=jnp.int32),
                                    (n_batch, n_steps))
        n_active = jnp.full((n_batch,), n_steps, jnp.int32)
    _charge_launch()
    return se.sell_expand_batched(
        cols, slab_rows, worklist.astype(jnp.int32),
        n_active.astype(jnp.int32), frontier, visited, out_init, p_init,
        n_vertices=n_vertices, slabs_per_step=slabs_per_step,
        bottom_up=bottom_up, prefetch_depth=prefetch_depth,
        interpret=interpret)


@_scoped("bfs.sell_relax_batched")
def sell_relax_batched(cols, slab_rows, worklist, n_active, frontier,
                       vals, *, n_vertices: int, slabs_per_step: int = 1,
                       unit: int = 0, weighted: bool = False,
                       interpret: bool | None = None):
    """Batched semiring SpMV sweep over the active SELL slab groups
    (kernels/sell_expand.py `sell_relax_batched`).  Pads the slab axis
    itself; the per-root work-list contract matches `sell_batched`."""
    if interpret is None:
        interpret = _interpret_default()
    n_words, v_pad = frontier.shape[1], vals.shape[1]
    slab = slabs_per_step * (se.W_QUANT + 1) * se.SLICE_C * 4
    budget = 4 * (n_words + 3 * v_pad) + 2 * slab
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"sell_relax working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py) or reduce slabs_per_step")
    cols, slab_rows = _pad_slabs(cols, slab_rows, n_vertices,
                                 slabs_per_step)
    _charge_launch()
    return se.sell_relax_batched(
        cols, slab_rows, worklist.astype(jnp.int32),
        n_active.astype(jnp.int32), frontier, vals,
        n_vertices=n_vertices, slabs_per_step=slabs_per_step, unit=unit,
        weighted=weighted, interpret=interpret)


@_scoped("bfs.restore")
def restore(parent, *, n_vertices: int, tile: int = rest.DEFAULT_TILE,
            interpret: bool | None = None):
    """Run the restoration kernel; tile auto-shrinks to divide V_pad.

    Accepts a batched (B, V_pad) parent too: restoration is
    tile-independent, so the batch flattens through the same kernel
    (the tile divides V_pad, so no tile straddles two roots); the
    delta bitmap comes back as (B, W).
    """
    if interpret is None:
        interpret = _interpret_default()
    _charge_launch()
    v_pad = parent.shape[-1]
    t = min(tile, v_pad)
    while v_pad % t:
        t //= 2
    t = max(t, 32)
    if parent.ndim == 2:
        b = parent.shape[0]
        p, delta = rest.restoration(parent.reshape(-1),
                                    n_vertices=n_vertices, tile=t,
                                    interpret=interpret)
        return (p.reshape(b, v_pad),
                delta.reshape(b, v_pad // BITS_PER_WORD))
    return rest.restoration(parent, n_vertices=n_vertices, tile=t,
                            interpret=interpret)


@_scoped("bfs.popcount")
def popcount(words, *, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    _charge_launch()
    return bitmap_kernels.popcount(words, interpret=interpret)


def compact_budget(n_batch: int, size: int) -> int:
    """Bytes the compaction kernel's (B, size) queue block pins in
    VMEM — the number `compact_fits` tests and degrade events report."""
    return ck.vmem_budget(n_batch, size, ck.DEFAULT_TILE_WORDS)


def compact_fits(n_batch: int, size: int) -> bool:
    """True when the compaction kernel's (B, size) queue block fits
    the VMEM budget.  The engine's packed planning arms consult this
    at trace time and fall back to the dense planner when it is False
    — large graphs keep working exactly as they did before the packed
    default, instead of failing on the budget check.  Since ISSUE 8
    the fallback is *observable*: every caller that degrades emits a
    ``serve.degrade.vmem_fallback`` `obs.metrics.DegradeEvent` naming
    this budget and the planner actually used."""
    return compact_budget(n_batch, size) <= VMEM_BYTES * _VMEM_HEADROOM


@_scoped("bfs.frontier_compact")
def frontier_compact(words, *, size: int, fill: int,
                     interpret: bool | None = None):
    """Run the SIMD compaction kernel (kernels/compact.py): packed
    bitmap -> (dense vertex queue (size,), count).  The packed
    replacement for `bitmap.compact` + `bitmap.popcount`."""
    if interpret is None:
        interpret = _interpret_default()
    _charge_launch()
    return ck.frontier_compact(words, size=size, fill=fill,
                               interpret=interpret)


@_scoped("bfs.frontier_compact_batched")
def frontier_compact_batched(words, *, size: int, fill: int,
                             interpret: bool | None = None):
    """Batched compaction: (B, W) packed bitmaps -> ((B, size)
    queues, (B,) counts) in one launch."""
    if interpret is None:
        interpret = _interpret_default()
    _charge_launch()
    return ck.frontier_compact_batched(words, size=size, fill=fill,
                                       interpret=interpret)


def megakernel_budget(n_words: int, v_pad: int, n_cs: int, tile: int,
                      prefetch_depth: int, n_blocks: int) -> int:
    """Bytes the whole-layer megakernel pins in VMEM — the number
    `megakernel_fits` tests and degrade events report."""
    return lf.vmem_budget(n_words, v_pad, n_cs, tile, prefetch_depth,
                          n_blocks)


_megakernel_budget = megakernel_budget    # back-compat alias


def megakernel_fits(n_words: int, v_pad: int, n_cs: int, tile: int,
                    prefetch_depth: int = 0, n_blocks: int = 1) -> bool:
    """True when the whole-layer megakernel's working set (bitmaps +
    P + colstarts + rows DMA buffers + the in-kernel planning
    vectors) fits the VMEM budget.  `CsrFormat._build_steps` consults
    this at build time and degrades ``pipeline="megakernel"`` to the
    unfused ``fused_gather`` step when it is False — mirroring
    `compact_fits`: large graphs keep traversing (at the unfused
    launch count) instead of failing on the budget check.  Since
    ISSUE 8 the degrade emits a ``serve.degrade.vmem_fallback``
    `obs.metrics.DegradeEvent` naming this budget and the pipeline
    actually built."""
    return megakernel_budget(n_words, v_pad, n_cs, tile,
                             prefetch_depth, n_blocks) \
        <= VMEM_BYTES * _VMEM_HEADROOM


@_scoped("bfs.layer_fused")
def layer_fused(rows, colstarts, frontier, visited, p_init, *,
                n_vertices: int, tile: int = ge.DEFAULT_TILE,
                bottom_up: bool = False, prefetch_depth: int = 0,
                interpret: bool | None = None):
    """Run one whole BFS layer (plan + compact + gather-expand +
    restoration) in ONE Pallas call (kernels/layer_fused.py).
    ``rows`` must already be padded to a tile multiple at build.
    Returns (out, parent, n_active) with restoration APPLIED."""
    if interpret is None:
        interpret = _interpret_default()
    n_blocks = rows.shape[0] // tile
    budget = _megakernel_budget(visited.shape[0], p_init.shape[0],
                                colstarts.shape[0], tile,
                                prefetch_depth, n_blocks)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"layer_fused working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py), reduce the tile or "
            f"prefetch_depth, or run pipeline='fused_gather'")
    _charge_launch()
    return lf.layer_fused(
        rows, colstarts, frontier, visited, p_init,
        n_vertices=n_vertices, tile=tile, bottom_up=bottom_up,
        prefetch_depth=prefetch_depth, interpret=interpret)


@_scoped("bfs.layer_fused_batched")
def layer_fused_batched(rows, colstarts, frontier, visited, p_init, *,
                        n_vertices: int, tile: int = ge.DEFAULT_TILE,
                        bottom_up: bool = False, prefetch_depth: int = 0,
                        interpret: bool | None = None):
    """Batched (leading root-axis) whole-layer megakernel: one launch,
    B restored layers.  The VMEM budget is per-root."""
    if interpret is None:
        interpret = _interpret_default()
    n_blocks = rows.shape[0] // tile
    budget = _megakernel_budget(visited.shape[1], p_init.shape[1],
                                colstarts.shape[0], tile,
                                prefetch_depth, n_blocks)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"layer_fused working set {budget/2**20:.1f} MiB exceeds "
            f"VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py), reduce the tile or "
            f"prefetch_depth, or run pipeline='fused_gather'")
    _charge_launch()
    return lf.layer_fused_batched(
        rows, colstarts, frontier, visited, p_init,
        n_vertices=n_vertices, tile=tile, bottom_up=bottom_up,
        prefetch_depth=prefetch_depth, interpret=interpret)


def sell_megakernel_budget(n_words: int, v_pad: int, n_slabs: int,
                           slabs_per_step: int, prefetch_depth: int = 0
                           ) -> int:
    """Bytes the whole-layer SELL megakernel pins in VMEM — the
    number `sell_megakernel_fits` tests and degrade events report.
    ``n_slabs`` is the raw slab count; step padding and the pipeline
    depth clamp are resolved here (budgets from the resolved spec)."""
    n_steps = -(-int(n_slabs) // int(slabs_per_step))
    n_slabs_p = n_steps * int(slabs_per_step)
    return se.megakernel_vmem_budget(n_words, v_pad, n_slabs_p,
                                     slabs_per_step, prefetch_depth,
                                     n_steps)


def sell_megakernel_fits(n_words: int, v_pad: int, n_slabs: int,
                         slabs_per_step: int,
                         prefetch_depth: int = 0) -> bool:
    """True when the whole-layer SELL megakernel (resident
    ``slab_rows`` + cols DMA buffers + bitmaps/P) fits the VMEM
    budget.  `SellFormat._build_steps` consults this at build time and
    degrades to the unfused ``fused_gather`` steps when False, with a
    metric-counted `DegradeEvent` — the `megakernel_fits` contract."""
    return sell_megakernel_budget(n_words, v_pad, n_slabs,
                                  slabs_per_step, prefetch_depth) \
        <= VMEM_BYTES * _VMEM_HEADROOM


@_scoped("bfs.sell_layer_fused")
def sell_layer_fused(cols, slab_rows, frontier, visited, p_init, *,
                     n_vertices: int, slabs_per_step: int = 1,
                     bottom_up: bool = False, prefetch_depth: int = 0,
                     interpret: bool | None = None):
    """Run one whole SELL layer (in-kernel slab plan + manual cols DMA
    + sweep + restoration) in ONE Pallas call
    (kernels/sell_expand.py `sell_layer_fused`).  Pads the slab axis
    itself.  Returns (out, parent, n_active) with restoration
    APPLIED."""
    if interpret is None:
        interpret = _interpret_default()
    budget = sell_megakernel_budget(visited.shape[0], p_init.shape[0],
                                    cols.shape[0], slabs_per_step,
                                    prefetch_depth)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"sell_layer_fused working set {budget/2**20:.1f} MiB "
            f"exceeds VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py), reduce slabs_per_step or "
            f"prefetch_depth, or run pipeline='fused_gather'")
    cols, slab_rows = _pad_slabs(cols, slab_rows, n_vertices,
                                 slabs_per_step)
    _charge_launch()
    return se.sell_layer_fused(
        cols, slab_rows, frontier, visited, p_init,
        n_vertices=n_vertices, slabs_per_step=slabs_per_step,
        bottom_up=bottom_up, prefetch_depth=prefetch_depth,
        interpret=interpret)


@_scoped("bfs.sell_layer_fused_batched")
def sell_layer_fused_batched(cols, slab_rows, frontier, visited,
                             p_init, *, n_vertices: int,
                             slabs_per_step: int = 1,
                             bottom_up: bool = False,
                             prefetch_depth: int = 0,
                             interpret: bool | None = None):
    """Batched (leading root-axis) whole-layer SELL megakernel: one
    launch, B restored layers.  The VMEM budget is per-root."""
    if interpret is None:
        interpret = _interpret_default()
    budget = sell_megakernel_budget(visited.shape[1], p_init.shape[1],
                                    cols.shape[0], slabs_per_step,
                                    prefetch_depth)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"sell_layer_fused working set {budget/2**20:.1f} MiB "
            f"exceeds VMEM budget; shard the vertex range across chips "
            f"(core/bfs_distributed.py), reduce slabs_per_step or "
            f"prefetch_depth, or run pipeline='fused_gather'")
    cols, slab_rows = _pad_slabs(cols, slab_rows, n_vertices,
                                 slabs_per_step)
    _charge_launch()
    return se.sell_layer_fused_batched(
        cols, slab_rows, frontier, visited, p_init,
        n_vertices=n_vertices, slabs_per_step=slabs_per_step,
        bottom_up=bottom_up, prefetch_depth=prefetch_depth,
        interpret=interpret)


def persistent_budget(n_words: int, v_pad: int, n_cs: int, tile: int,
                      n_batch: int, max_layers: int,
                      prefetch_depth: int = 0,
                      n_blocks: int = 1) -> int:
    """Bytes the CSR whole-traversal persistent kernel pins in VMEM —
    the number `persistent_fits` tests and degrade events report.
    Unlike the per-layer kernels the whole batch's state is resident
    at once, so the budget scales with ``n_batch``."""
    return tf.vmem_budget(n_words, v_pad, n_cs, tile, n_batch,
                          max_layers, prefetch_depth, n_blocks)


def persistent_fits(n_words: int, v_pad: int, n_cs: int, tile: int,
                    n_batch: int, max_layers: int,
                    prefetch_depth: int = 0, n_blocks: int = 1) -> bool:
    """True when the CSR persistent kernel's whole-batch working set
    (state x2 + colstarts + plan vectors + rows DMA buffers + stats)
    fits the VMEM budget.  The engine consults this at trace time and
    degrades ``pipeline="persistent"`` to megakernel (then unfused)
    when False, emitting a metric-counted `DegradeEvent` per the
    ISSUE 8 contract."""
    return persistent_budget(n_words, v_pad, n_cs, tile, n_batch,
                             max_layers, prefetch_depth, n_blocks) \
        <= VMEM_BYTES * _VMEM_HEADROOM


def sell_persistent_budget(n_words: int, v_pad: int, n_slabs: int,
                           slabs_per_step: int, n_batch: int,
                           max_layers: int,
                           prefetch_depth: int = 0) -> int:
    """Bytes the SELL whole-traversal persistent kernel pins in VMEM
    (resident ``slab_rows`` + degrees + cols DMA buffers + the whole
    batch's state)."""
    n_steps = -(-int(n_slabs) // int(slabs_per_step))
    n_slabs_p = n_steps * int(slabs_per_step)
    return tf.sell_vmem_budget(n_words, v_pad, n_slabs_p,
                               slabs_per_step, n_batch, max_layers,
                               prefetch_depth, n_steps)


def sell_persistent_fits(n_words: int, v_pad: int, n_slabs: int,
                         slabs_per_step: int, n_batch: int,
                         max_layers: int,
                         prefetch_depth: int = 0) -> bool:
    """`persistent_fits` for the SELL persistent kernel."""
    return sell_persistent_budget(n_words, v_pad, n_slabs,
                                  slabs_per_step, n_batch, max_layers,
                                  prefetch_depth) \
        <= VMEM_BYTES * _VMEM_HEADROOM


@_scoped("bfs.traversal_fused")
def traversal_fused_batched(rows, colstarts, frontier, visited, p_init,
                            *, n_vertices: int,
                            tile: int = ge.DEFAULT_TILE, policy,
                            max_layers: int = 64,
                            prefetch_depth: int = 0,
                            interpret: bool | None = None):
    """Run the WHOLE multi-root BFS traversal in ONE Pallas call
    (kernels/traversal_fused.py): layer loop, direction decision and
    termination all inside the kernel, state VMEM-resident across
    layers.  ``rows`` must already be padded to a tile multiple.
    Returns (frontier, visited, parent, depths, layers, stats) — the
    engine's whole-traversal contract — and charges exactly ONE launch
    to the trace-time counter."""
    if interpret is None:
        interpret = _interpret_default()
    n_blocks = rows.shape[0] // tile
    budget = persistent_budget(visited.shape[1], p_init.shape[1],
                               colstarts.shape[0], tile,
                               visited.shape[0], max_layers,
                               prefetch_depth, n_blocks)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"traversal_fused working set {budget/2**20:.1f} MiB "
            f"exceeds VMEM budget; reduce the batch width, the tile "
            f"or max_layers, or run pipeline='megakernel'")
    _charge_launch()
    return tf.traversal_fused_batched(
        rows, colstarts, frontier, visited, p_init,
        n_vertices=n_vertices, tile=tile, policy=policy,
        max_layers=max_layers, prefetch_depth=prefetch_depth,
        interpret=interpret)


@_scoped("bfs.sell_traversal_fused")
def sell_traversal_fused_batched(cols, slab_rows, deg, frontier,
                                 visited, p_init, *, n_vertices: int,
                                 slabs_per_step: int = 1, policy,
                                 max_layers: int = 64,
                                 prefetch_depth: int = 0,
                                 interpret: bool | None = None):
    """The whole multi-root SELL traversal in ONE Pallas call.  Pads
    the slab axis itself; ``deg`` is the (V,) degree array (SELL has
    no colstarts for the in-kernel Table 1 counters).  Same contract
    and launch accounting as `traversal_fused_batched`."""
    if interpret is None:
        interpret = _interpret_default()
    budget = sell_persistent_budget(visited.shape[1], p_init.shape[1],
                                    cols.shape[0], slabs_per_step,
                                    visited.shape[0], max_layers,
                                    prefetch_depth)
    if budget > VMEM_BYTES * _VMEM_HEADROOM:
        raise ValueError(
            f"sell_traversal_fused working set {budget/2**20:.1f} MiB "
            f"exceeds VMEM budget; reduce the batch width, "
            f"slabs_per_step or max_layers, or run "
            f"pipeline='megakernel'")
    cols, slab_rows = _pad_slabs(cols, slab_rows, n_vertices,
                                 slabs_per_step)
    _charge_launch()
    return tf.sell_traversal_fused_batched(
        cols, slab_rows, deg, frontier, visited, p_init,
        n_vertices=n_vertices, slabs_per_step=slabs_per_step,
        policy=policy, max_layers=max_layers,
        prefetch_depth=prefetch_depth, interpret=interpret)
