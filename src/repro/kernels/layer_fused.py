"""Pallas TPU kernel: the whole-layer BFS megakernel (ISSUE 6).

One Pallas call per layer.  The three launches the fused pipeline
issues every layer — packed frontier compaction, active-tile planning
(a jnp pass feeding scalar prefetch), and the gather-expand sweep,
plus a fourth for restoration — collapse into a single persistent
kernel whose sequential grid walks the rows-blocks of the CSR:

* **grid step 0 — in-kernel plan + compact.**  The frontier bitmap
  (or its complement, bottom-up) unpacks in-register to a dense
  activity vector; the adjacency ranges of active vertices range-mark
  the rows-blocks with the same +1/-1 difference scatter + prefix sum
  as `engine._mark_blocks`, and a cumsum-rank masked scatter (the
  `compact.py` rank-and-scatter, applied to block marks) compacts the
  covered blocks into a work-list that never leaves the chip: it is
  written to SMEM scratch and read back like a scalar-prefetch
  operand.  No ``jnp.nonzero``, no HBM round trip — the §4 "queue
  generation" runs against block marks inside the sweep kernel
  itself.
* **grid steps t < n_active — gather-expand.**  Because the work-list
  is computed *inside* the kernel, a BlockSpec index map (which binds
  before launch) cannot drive the rows DMA; the kernel instead keeps
  ``rows`` in HBM (ANY memory space) and issues its own
  ``make_async_copy`` per active block through the shared
  `gather_expand._dma_pipeline` — ``prefetch_depth`` tile DMAs in
  flight ahead of the compute tile (depth 0 degrades to a synchronous
  start/wait copy).  The compute body is `_gather_tile` verbatim, so
  the racy expansion semantics (and therefore the bit-exact results)
  are shared with the unfused pipeline.
* **final grid step — in-kernel restoration.**  The §3.3.2 repair of
  racy bitmap drops (negative P marks -> +|V| restore + repacked
  delta OR'd into the output bitmap) runs over the VMEM-resident P
  before the outputs ship, eliminating the separate restoration
  launch.  Because every true discovery carries a negative P mark, the
  restored output bitmap equals the unfused path's ``out | delta``
  bit for bit.

The work-list clamp contract is `engine.compact_worklist`'s: entries
past ``n_active`` repeat the last active block (unchanged DMA source
=> Mosaic elides the copy; a ``pl.when`` guard skips the compute).
The kernel also emits ``n_active`` as a (1,) output so the engine's
bytes-accounting counters stay exact without a second planning pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitmap import BITS_PER_WORD, word_bits
from repro.kernels.gather_expand import (DEFAULT_TILE, _dma_pipeline,
                                         _gather_tile)
from repro.kernels.pallas_compat import CompilerParams


def _plan_in_kernel(n_vertices: int, tile: int, n_blocks: int,
                    bottom_up: bool, words, colstarts):
    """The in-kernel transcription of `engine.plan_active_tiles`'s
    dense arm: packed activity words -> (worklist, n_active), all in
    registers/VMEM.  Scatter-based (difference marks + cumsum ranks);
    no ``jnp.nonzero`` (which has no Mosaic lowering)."""
    if bottom_up:
        words = ~words
    dense = word_bits(words).reshape(-1)[:n_vertices] != 0
    start = colstarts[:-1]
    end = colstarts[1:]
    has = dense & (end > start)
    blk_lo = start // tile
    blk_hi = (end - 1) // tile
    drop = n_blocks + 1
    diff = jnp.zeros((n_blocks + 1,), jnp.int32)
    diff = diff.at[jnp.where(has, blk_lo, drop)].add(1, mode="drop")
    diff = diff.at[jnp.where(has, blk_hi + 1, drop)].add(-1, mode="drop")
    covered = (jnp.cumsum(diff)[:n_blocks] > 0).astype(jnp.int32)
    n_active = covered.sum(dtype=jnp.int32)
    # rank-and-scatter the covered block ids (compact.py idiom on
    # block marks), then clamp the tail to the last active block
    rank = jnp.cumsum(covered) - covered
    idx = jnp.where(covered != 0, rank, n_blocks)
    blocks = jnp.arange(n_blocks, dtype=jnp.int32)
    wl = jnp.zeros((n_blocks,), jnp.int32).at[idx].set(blocks,
                                                       mode="drop")
    last = wl[jnp.clip(n_active - 1, 0, n_blocks - 1)]
    wl = jnp.where(blocks < n_active, wl, last)
    return wl, n_active


def _restore_in_kernel(n_vertices: int, out, p):
    """The in-kernel transcription of `restoration._restoration_kernel`
    over the whole VMEM-resident P: negative marks -> restored P and
    the repaired output bitmap."""
    marked = p < 0
    p_fixed = jnp.where(marked, p + n_vertices, p)
    bits = marked.reshape(-1, BITS_PER_WORD).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(BITS_PER_WORD,
                                          dtype=jnp.uint32)
    delta = (bits * weights).sum(axis=1, dtype=jnp.uint32)
    return out | delta, p_fixed


def _layer_kernel(n_vertices: int, tile: int, n_cs: int,
                  bottom_up: bool, depth: int, n_blocks: int,
                  rows_ref, cs_ref, frontier_ref, vis_ref, p0_ref,
                  out_ref, p_ref, na_out_ref, wl_ref, na_ref, rows_buf,
                  sems):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _plan():
        out_ref[...] = jnp.zeros(out_ref.shape, jnp.uint32)
        p_ref[...] = p0_ref[...]
        words = vis_ref[...] if bottom_up else frontier_ref[...]
        wl, n_active = _plan_in_kernel(n_vertices, tile, n_blocks,
                                       bottom_up, words, cs_ref[...])
        wl_ref[...] = wl
        na_ref[0] = n_active
        na_out_ref[0] = n_active

    def work(rows_blk):
        @pl.when(t < na_ref[0])
        def _work():
            out, p = _gather_tile(n_vertices, tile, n_cs, bottom_up,
                                  wl_ref[t], rows_blk, cs_ref[...],
                                  frontier_ref[...], vis_ref[...],
                                  out_ref[...], p_ref[...])
            out_ref[...] = out
            p_ref[...] = p

    _dma_pipeline(rows_ref, rows_buf, sems, lambda s: wl_ref[s], tile,
                  depth, n_blocks, t, t == 0, work)

    @pl.when(t == n_blocks - 1)
    def _restore():
        out, p = _restore_in_kernel(n_vertices, out_ref[...], p_ref[...])
        out_ref[...] = out
        p_ref[...] = p


def _layer_batched_kernel(n_vertices: int, tile: int, n_cs: int,
                          bottom_up: bool, depth: int, n_blocks: int,
                          rows_ref, cs_ref, frontier_ref, vis_ref,
                          p0_ref, out_ref, p_ref, na_out_ref, wl_ref,
                          na_ref, rows_buf, sems):
    """Batched variant: grid (roots, blocks), both sequential — the
    SMEM work-list scratch is re-planned at each root's first step
    and the DMA pipeline re-warms at root boundaries (exactly the
    batched-DMA gather contract)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _plan():
        out_ref[...] = jnp.zeros(out_ref.shape, jnp.uint32)
        p_ref[...] = p0_ref[...]
        words = vis_ref[0] if bottom_up else frontier_ref[0]
        wl, n_active = _plan_in_kernel(n_vertices, tile, n_blocks,
                                       bottom_up, words, cs_ref[...])
        wl_ref[...] = wl
        na_ref[0] = n_active
        na_out_ref[0] = n_active

    def work(rows_blk):
        @pl.when(t < na_ref[0])
        def _work():
            out, p = _gather_tile(n_vertices, tile, n_cs, bottom_up,
                                  wl_ref[t], rows_blk, cs_ref[...],
                                  frontier_ref[0], vis_ref[0],
                                  out_ref[0], p_ref[0])
            out_ref[...] = out[None]
            p_ref[...] = p[None]

    _dma_pipeline(rows_ref, rows_buf, sems, lambda s: wl_ref[s], tile,
                  depth, n_blocks, t, t == 0, work)

    @pl.when(t == n_blocks - 1)
    def _restore():
        out, p = _restore_in_kernel(n_vertices, out_ref[0], p_ref[0])
        out_ref[...] = out[None]
        p_ref[...] = p[None]


def vmem_budget(n_words: int, v_pad: int, n_cs: int, tile: int,
                prefetch_depth: int = 0, n_blocks: int = 1) -> int:
    """Bytes of VMEM the megakernel pins: bitmaps x3 + P x2 +
    colstarts + the rows DMA buffers, PLUS the planning working set
    (the dense activity vector and the block-mark vectors) that the
    unfused pipeline keeps outside the kernel.

    The buffer count charges the *resolved* pipeline depth — the
    wrappers clamp ``prefetch_depth`` to ``n_blocks``, so the budget
    must too, or a deep affinity-resolved prefetch on a small graph
    double-counts DMA buffers the kernel never allocates (ISSUE 9
    satellite)."""
    n_buf = min(max(int(prefetch_depth), 0),
                max(int(n_blocks), 1)) + 1
    plan = 4 * (v_pad + 3 * (n_blocks + 1))
    return (4 * (3 * n_words + 2 * v_pad + n_cs) + n_buf * 4 * tile
            + plan)


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def layer_fused(rows, colstarts, frontier, visited, p_init, *,
                n_vertices: int, tile: int = DEFAULT_TILE,
                bottom_up: bool = False, prefetch_depth: int = 0,
                interpret: bool = True):
    """One BFS layer in ONE Pallas call: plan + compact + gather-expand
    + restoration (see the module docstring).

    Args:
      rows: (E_tiles,) int32 CSR adjacency, sentinel-padded to a tile
        multiple (pad once at build).  Stays in HBM; the kernel DMAs
        active blocks itself.
      colstarts: (V + 1,) int32, VMEM-resident.
      frontier, visited: (W,) uint32 bitmaps.
      p_init: (V_pad,) int32 predecessor array.
      bottom_up: plan from the unvisited complement and swap the
        gate/discover roles (the hybrid direction).
      prefetch_depth: tile DMAs kept in flight ahead of the compute
        tile (0 = synchronous copy per block).
    Returns:
      (out, parent, n_active): the RESTORED layer outputs — ``out``
      already includes the repair delta, ``parent`` is non-negative —
      plus the (1,) count of active blocks the in-kernel plan found.
    """
    n_slots = rows.shape[0]
    assert n_slots % tile == 0, "pad rows to the tile size at build"
    n_blocks = n_slots // tile
    n_cs = colstarts.shape[0]
    n_words = visited.shape[0]
    v_pad = p_init.shape[0]
    depth = min(max(int(prefetch_depth), 0), n_blocks)

    whole = lambda n: pl.BlockSpec((n,), lambda t: (0,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                  whole(n_cs), whole(n_words), whole(n_words),
                  whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad), whole(1)],
        scratch_shapes=[pltpu.SMEM((n_blocks,), jnp.int32),
                        pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((depth + 1, tile), jnp.int32),
                        pltpu.SemaphoreType.DMA((depth + 1,))],
    )
    out, parent, n_active = pl.pallas_call(
        functools.partial(_layer_kernel, n_vertices, tile, n_cs,
                          bottom_up, depth, n_blocks),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_words,), jnp.uint32),
                   jax.ShapeDtypeStruct((v_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        compiler_params=CompilerParams(
            # scratch work-list + accumulating outputs => sequential
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_layer_fused",
    )(rows, colstarts, frontier, visited, p_init)
    return out, parent, n_active


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def layer_fused_batched(rows, colstarts, frontier, visited, p_init, *,
                        n_vertices: int, tile: int = DEFAULT_TILE,
                        bottom_up: bool = False,
                        prefetch_depth: int = 0,
                        interpret: bool = True):
    """Multi-root megakernel: one launch, B whole layers.

    The adjacency carries no root axis (shared layout); bitmaps/P are
    (B, W) / (B, V_pad).  Grid is (B, n_blocks), fully sequential —
    each root re-plans its own work-list into the SMEM scratch at its
    first step.  Returns (out (B, W), parent (B, V_pad), n_active
    (B,)).
    """
    n_slots = rows.shape[0]
    assert n_slots % tile == 0, "pad rows to the tile size at build"
    n_blocks = n_slots // tile
    n_batch = visited.shape[0]
    n_cs = colstarts.shape[0]
    n_words = visited.shape[1]
    v_pad = p_init.shape[1]
    depth = min(max(int(prefetch_depth), 0), n_blocks)

    flat = lambda n: pl.BlockSpec((n,), lambda b, t: (0,))
    whole = lambda n: pl.BlockSpec((1, n), lambda b, t: (b, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(n_batch, n_blocks),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                  flat(n_cs), whole(n_words), whole(n_words),
                  whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad),
                   pl.BlockSpec((1,), lambda b, t: (b,))],
        scratch_shapes=[pltpu.SMEM((n_blocks,), jnp.int32),
                        pltpu.SMEM((1,), jnp.int32),
                        pltpu.VMEM((depth + 1, tile), jnp.int32),
                        pltpu.SemaphoreType.DMA((depth + 1,))],
    )
    out, parent, n_active = pl.pallas_call(
        functools.partial(_layer_batched_kernel, n_vertices, tile,
                          n_cs, bottom_up, depth, n_blocks),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32),
                   jax.ShapeDtypeStruct((n_batch,), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="bfs_layer_fused_batched",
    )(rows, colstarts, frontier, visited, p_init)
    return out, parent, n_active
