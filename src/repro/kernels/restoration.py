"""Pallas TPU kernel: the restoration process (Alg. 3 lines 15-29).

Repairs the output-queue bitmap after the racy expansion: every vertex
v with ``P[v] < 0`` was discovered this layer (the expansion wrote
``P[v] = u - |V|``); its bit must be present in ``out`` and ``visited``
regardless of which scatter lanes lost their word race.

The paper walks each non-zero 32-bit word and splits it into low/high
16-lane halves to fit the 16-wide VPU.  The TPU formulation instead
tiles the predecessor array into (tile,) blocks, reshapes each block to
(tile/32, 32) and packs bits with a weighted sum — the same
word-halving idea generalized to 8x128 lanes, with no data-dependent
branching at all (the paper's ``if w != 0`` short-circuit is replaced
by unconditional vector math, which on TPU is cheaper than a branch).

Every tile is independent: the grid is embarrassingly parallel
(dimension_semantics = parallel), unlike the expansion kernel.
Output: fixed P tile + a (tile/32,) uint32 bitmap *delta* that the
caller ORs into both ``out`` and ``visited``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitmap import BITS_PER_WORD
from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE = 4096  # vertices per grid step; 128 words out per step


def _restoration_kernel(n_vertices: int, p_ref, p_out_ref, delta_ref):
    p = p_ref[...]
    marked = p < 0
    # P[vertex] = P[vertex] + nodes  (line 25)
    p_out_ref[...] = jnp.where(marked, p + n_vertices, p)
    # out.SetBit(vertex) for each marked vertex (lines 23-24), packed
    bits = marked.reshape(-1, BITS_PER_WORD).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(BITS_PER_WORD, dtype=jnp.uint32)
    delta_ref[...] = (bits * weights).sum(axis=1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "interpret"))
def restoration(parent, *, n_vertices: int, tile: int = DEFAULT_TILE,
                interpret: bool = True):
    """Run the restoration kernel over the whole P array.

    Args:
      parent: (V_pad,) int32, V_pad a multiple of ``tile``;
        negative entries mark this layer's discoveries.
    Returns:
      (parent_fixed, delta) where delta is the (V_pad/32,) uint32
      bitmap of repaired vertices.
    """
    v_pad = parent.shape[0]
    assert v_pad % tile == 0, "V_pad must be a multiple of the tile"
    assert tile % BITS_PER_WORD == 0
    n_tiles = v_pad // tile

    kernel = functools.partial(_restoration_kernel, n_vertices)
    p_fixed, delta = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile,), lambda t: (t,))],
        out_specs=[pl.BlockSpec((tile,), lambda t: (t,)),
                   pl.BlockSpec((tile // BITS_PER_WORD,), lambda t: (t,))],
        out_shape=[
            jax.ShapeDtypeStruct((v_pad,), jnp.int32),
            jax.ShapeDtypeStruct((v_pad // BITS_PER_WORD,), jnp.uint32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="bfs_restoration",
    )(parent)
    return p_fixed, delta
