"""Pallas TPU kernel: vectorized adjacency-list exploration (Listing 1).

The paper's hot loop, re-tiled for the TPU memory hierarchy:

* the **edge stream** (`nbr`, `cand`, `valid` — the apportioned layer
  adjacency) lives in HBM and is DMA'd tile-by-tile into VMEM by the
  Pallas pipeline (BlockSpec over the grid).  Mosaic double-buffers
  these DMAs — the TPU replacement for the paper's software-prefetch
  intrinsics, with the *block size* playing the role of the prefetch
  distance (swept in EXPERIMENTS §Perf);
* the **bitmaps** (visited, output queue) and the **predecessor array**
  are VMEM-resident for the whole kernel — the payoff of the paper's
  32x bitmap compression on TPU: a SCALE-22 graph's bitmaps + P
  (0.5 MB + 0.5 MB + 16 MB... P dominates; see ``vmem_budget``) fit in
  scratchpad, so every irregular gather/scatter hits VMEM instead of
  HBM.  Larger graphs shard vertex ranges across chips first
  (core/bfs_distributed.py) precisely to preserve this property;
* lane masking replaces AVX-512 mask registers; the sentinel-padded
  tail replaces the peel/remainder loops (csr.py).

Per tile (16 AVX lanes -> 8x128 = 1024 TPU lanes):
  1. load `cand` vertex ids                  (paper: _mm512_load_epi32)
  2. word = cand >> 5, bit = cand & 31       (paper: div/rem)
  3. gather visited & out words              (paper: i32gather)
  4. mask = !(test(vis) | test(out))         (paper: ktest/kor/knot)
  5. masked scatter P[cand] = nbr - |V|      (paper: mask i32scatter)
  6. masked racy word scatter out |= bit     (the §3.3.2 race)

The scatter in step 6 loses colliding-word bits exactly like the
paper's non-atomic scatter; the restoration kernel repairs them.
Grid steps are sequential on a TensorCore, so tile t+1 observes tile
t's updates (the contract pinned by kernels/ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitmap import WORD_MASK, WORD_SHIFT
from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE = 1024  # 8 sublanes x 128 lanes of int32


def _expand_tile(n_vertices: int, check_frontier: bool,
                 nbr, cand, valid, frontier, vis, out, p):
    """One tile of the hot loop on loaded VMEM values.

    Shared by the single-root and the batched (leading root-axis)
    kernels.  Returns the updated (out, p) for this tile's writes.
    """
    valid = valid != 0

    # index transformation vertex -> (word, bit)
    word = cand >> WORD_SHIFT
    bit = (cand & WORD_MASK).astype(jnp.uint32)
    bits = jnp.uint32(1) << bit

    w_clip = jnp.clip(word, 0, out.shape[0] - 1)
    vis_words = vis[w_clip]          # i32gather against VMEM bitmap
    out_words = out[w_clip]
    undiscovered = ((vis_words | out_words) & bits) == 0
    mask = valid & undiscovered
    if check_frontier:               # bottom-up direction: test parent
        nw = jnp.clip(nbr >> WORD_SHIFT, 0, frontier.shape[0] - 1)
        nb = (nbr & WORD_MASK).astype(jnp.uint32)
        in_front = (frontier[nw] & (jnp.uint32(1) << nb)) != 0
        mask = mask & in_front

    # masked scatter of P (negative marking) — benign duplicate race
    p_idx = jnp.where(mask, cand, p.shape[0])
    new_p = p.at[p_idx].set(nbr - n_vertices, mode="drop")

    # masked racy word scatter of the output queue (Fig. 6 race)
    new_words = out_words | bits
    w_idx = jnp.where(mask, word, out.shape[0])
    new_out = out.at[w_idx].set(new_words, mode="drop")
    return new_out, new_p


def _expand_kernel(n_vertices: int, check_frontier: bool,
                   nbr_ref, cand_ref, valid_ref, frontier_ref, vis_ref,
                   out0_ref, p0_ref, out_ref, p_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():  # carry initial out/P into the accumulating outputs
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    out, p = _expand_tile(n_vertices, check_frontier,
                          nbr_ref[...], cand_ref[...], valid_ref[...],
                          frontier_ref[...], vis_ref[...],
                          out_ref[...], p_ref[...])
    out_ref[...] = out
    p_ref[...] = p


def _expand_batched_kernel(n_vertices: int, check_frontier: bool,
                           nbr_ref, cand_ref, valid_ref, frontier_ref,
                           vis_ref, out0_ref, p0_ref, out_ref, p_ref):
    """Batched variant: grid (roots, tiles); blocks carry a leading
    size-1 root axis.  Each root's tile sequence accumulates into its
    own out/P rows, so roots are independent ("parallel" axis)."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    out, p = _expand_tile(n_vertices, check_frontier,
                          nbr_ref[0], cand_ref[0], valid_ref[0],
                          frontier_ref[0], vis_ref[0],
                          out_ref[0], p_ref[0])
    out_ref[...] = out[None]
    p_ref[...] = p[None]


def vmem_budget(n_words: int, v_pad: int, tile: int) -> int:
    """Bytes of VMEM the kernel pins (bitmaps x3 + P x2 + stream x3x2)."""
    return 4 * (3 * n_words + 2 * v_pad) + 2 * 3 * 4 * tile


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "check_frontier", "interpret"))
def frontier_expand(nbr, cand, valid, frontier, visited, out_init, p_init,
                    *, n_vertices: int, tile: int = DEFAULT_TILE,
                    check_frontier: bool = False, interpret: bool = True):
    """

    Args:
      nbr, cand, valid: (E_slots,) int32 apportioned edge stream
        (valid as int32 0/1; E_slots must be a multiple of ``tile``).
      frontier, visited, out_init: (W,) uint32 bitmaps.
      p_init: (V_pad,) int32 predecessor array.
      n_vertices: |V| (the paper's ``nodes`` constant).
      check_frontier: False = top-down (Listing 1), True = bottom-up.
      interpret: run the kernel body in interpret mode (CPU validation);
        on a real TPU pass False.
    Returns:
      (out, parent) after the racy expansion (restoration NOT applied).
    """
    n_slots = cand.shape[0]
    assert n_slots % tile == 0, "pad the edge stream to the tile size"
    n_tiles = n_slots // tile
    n_words = visited.shape[0]
    v_pad = p_init.shape[0]

    stream_spec = pl.BlockSpec((tile,), lambda t: (t,))
    whole = lambda n: pl.BlockSpec((n,), lambda t: (0,))

    kernel = functools.partial(_expand_kernel, n_vertices, check_frontier)
    out, parent = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[stream_spec, stream_spec, stream_spec,
                  whole(n_words), whole(n_words), whole(n_words),
                  whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad)],
        out_shape=[jax.ShapeDtypeStruct((n_words,), jnp.uint32),
                   jax.ShapeDtypeStruct((v_pad,), jnp.int32)],
        compiler_params=CompilerParams(
            # accumulating outputs => sequential grid on the core
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_frontier_expand",
    )(nbr, cand, valid, frontier, visited, out_init, p_init)
    return out, parent


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "check_frontier", "interpret"))
def frontier_expand_batched(nbr, cand, valid, frontier, visited,
                            out_init, p_init, *, n_vertices: int,
                            tile: int = DEFAULT_TILE,
                            check_frontier: bool = False,
                            interpret: bool = True):
    """Multi-root expansion: one launch expands B independent searches.

    Args are the single-root ones with a leading root axis:
      nbr, cand, valid: (B, E_slots) int32 apportioned edge streams.
      frontier, visited, out_init: (B, W) uint32 bitmaps.
      p_init: (B, V_pad) int32 predecessor arrays.
    Returns (out, parent) of shapes (B, W) / (B, V_pad), racy
    (restoration NOT applied) — the same contract as `frontier_expand`
    applied independently per root.

    Grid is (B, n_tiles): the root axis is embarrassingly parallel
    (each root accumulates into its own rows); the tile axis stays
    sequential so later tiles observe earlier tiles' updates.
    """
    n_batch, n_slots = cand.shape
    assert n_slots % tile == 0, "pad the edge stream to the tile size"
    n_tiles = n_slots // tile
    n_words = visited.shape[1]
    v_pad = p_init.shape[1]

    stream_spec = pl.BlockSpec((1, tile), lambda b, t: (b, t))
    whole = lambda n: pl.BlockSpec((1, n), lambda b, t: (b, 0))

    kernel = functools.partial(_expand_batched_kernel, n_vertices,
                               check_frontier)
    out, parent = pl.pallas_call(
        kernel,
        grid=(n_batch, n_tiles),
        in_specs=[stream_spec, stream_spec, stream_spec,
                  whole(n_words), whole(n_words), whole(n_words),
                  whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad)],
        out_shape=[jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="bfs_frontier_expand_batched",
    )(nbr, cand, valid, frontier, visited, out_init, p_init)
    return out, parent
