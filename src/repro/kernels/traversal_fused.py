"""Pallas TPU kernel: the whole-traversal persistent BFS kernel (ISSUE 9).

ONE Pallas call per *traversal*.  The PR-6 megakernel collapsed a
layer's launches into one call but left the layer loop in a
``lax.while_loop`` that re-dispatches per layer — small-diameter
graphs pay per-launch overhead L times and the direction decision
bounces through XLA carry state.  This kernel moves the layer loop
*inside* the kernel and keeps the whole search state resident:

* **grid = (1,)** — a single persistent grid step.  Every loop (layer
  x root x rows-block) is a ``lax.fori_loop`` inside the kernel body,
  so interpret mode traces each body once instead of unrolling a
  layers x blocks grid.
* **state lives in the output refs.**  frontier/visited/P copy from
  the inputs once, then every layer mutates them in place — VMEM
  residency across layers is the point: no HBM round trip of the
  bitmaps between layers, no while_loop carry.
* **direction/termination on in-kernel counters.**  The Table 1
  workload counters (frontier popcount, masked degree sums) are
  computed from the VMEM-resident bitmaps each layer and fed to the
  *engine's own policy object* (closed over statically — policies are
  pure jnp, so `policy.decide` traces straight into the kernel).  An
  empty frontier drops the ``live`` flag and the remaining layer
  iterations become no-ops — the in-kernel transcription of the
  engine's while condition.
* **per-layer sweep = the megakernel body.**  Each live layer plans
  its work-list with `layer_fused._plan_in_kernel`, streams the
  active rows-blocks through a manual `make_async_copy` pipeline
  (``prefetch_depth`` tiles in flight), expands with the
  direction/mode-blended `_gather_tile` body and repairs racy drops
  with `layer_fused._restore_in_kernel` before the next layer reads
  the state.

Mode parity with the per-layer engine is exact by construction: SIMD
and bottom-up layers use the accumulating ``vis | out`` undiscovered
test (`frontier_expand._expand_tile` — first tile wins), while
MODE_SCALAR layers test against the pre-layer ``visited`` only, so an
ascending-block sweep reproduces the jnp `expand_candidates` scatter's
global last-write-wins bit for bit.  ``LayerStats.launches`` therefore
charges 1 on layer 0 and 0 elsewhere — one launch per traversal, the
number CI gate 5 pins.

The SELL-C-σ variant (`sell_traversal_fused_batched`) swaps the
rows-block gather for the slab sweep of `sell_expand._sell_tile`,
planned by the in-kernel slab membership pass
(`sell_expand._plan_slabs_in_kernel`) — ``slab_rows`` stays fully
VMEM-resident (the plan reads every slab's lane owners), only the
``cols`` slabs stream through the DMA pipeline.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitmap import WORD_MASK, WORD_SHIFT, word_bits
from repro.kernels.gather_expand import DEFAULT_TILE, _owner_search
from repro.kernels.layer_fused import _plan_in_kernel, _restore_in_kernel
from repro.kernels.pallas_compat import CompilerParams
from repro.kernels.sell_expand import (SLICE_C, W_QUANT,
                                       _plan_slabs_in_kernel)

# Engine mode constants, restated locally: this module sits below
# core/engine.py in the import graph (ops.py wraps these kernels and
# the engine imports ops), so importing the engine here would be a
# cycle.  tests/test_persistent.py pins these against the engine's.
MODE_SCALAR = 0
MODE_SIMD = 1
MODE_BOTTOMUP = 2

_N_ST = 8           # stats buffer columns (engine._N_ST)


class _Workload(NamedTuple):
    """Duck-typed stand-in for `engine.Workload` (same fields, same
    order).  Policies only read attributes, so the engine's frozen
    policy objects decide *inside* the kernel trace without this
    module importing the engine."""
    layer: jax.Array
    frontier_vertices: jax.Array
    frontier_edges: jax.Array
    unvisited_vertices: jax.Array
    unvisited_edges: jax.Array
    n_vertices: int
    bottom_up: jax.Array
    n_roots: int = 1


def _layer_counters(n_vertices: int, words, deg):
    """Per-root Table 1 counters from a packed (B, W) bitmap: set-bit
    counts and masked degree sums — the in-kernel transcription of
    `engine.row_popcounts` + `bitmap.masked_degree_sum`."""
    count_b = jax.lax.population_count(words).astype(jnp.int32) \
        .sum(axis=1)
    n_batch = words.shape[0]
    dense = word_bits(words).reshape(n_batch, -1)[:, :n_vertices]
    edges_b = (dense * deg).sum(axis=1, dtype=jnp.int32)
    return count_b, edges_b


def _decide(policy, layer, f_count_b, f_edges_b, vis, deg,
            n_vertices: int, n_batch: int, bottom_up):
    """The engine's measure+decide phase on in-kernel counters: batch
    sums aggregate in float32 exactly like `engine._traverse_impl`
    (per-root counts are int32-safe; a batch sum may not be)."""
    if policy.needs_unvisited:
        u_words = ~vis
        u_count_b, u_edges_b = _layer_counters(n_vertices, u_words, deg)
        u_count = u_count_b.sum().astype(jnp.float32)
        u_edges = u_edges_b.astype(jnp.float32).sum()
    else:
        u_count = u_edges = jnp.float32(0)
    w = _Workload(layer, f_count_b.astype(jnp.float32).sum(),
                  f_edges_b.astype(jnp.float32).sum(), u_count, u_edges,
                  n_vertices, bottom_up, n_roots=n_batch)
    return policy.decide(w)


def _gather_tile_dyn(n_vertices: int, tile: int, n_cs: int, is_bu,
                     is_scalar, blk, rows_blk, colstarts, frontier, vis,
                     out, p):
    """`gather_expand._gather_tile` with the direction and the
    mode-dependent undiscovered test as *traced* selects — the layer
    loop decides both at run time, so the per-layer kernels' static
    role swap becomes a `jnp.where` blend here.

    The mode select mirrors the megakernel pipeline's step table:
    SIMD/bottom-up layers share `_expand_tile`'s accumulating
    ``vis | out`` test (first tile wins), while MODE_SCALAR layers
    test against the *pre-layer* visited only — a vertex discovered by
    an earlier tile can be re-discovered and its P overwritten, so the
    ascending-block sweep reproduces the jnp `expand_candidates`
    scatter's global last-write-wins exactly."""
    e_idx = blk * tile + jnp.arange(tile, dtype=jnp.int32)
    u = _owner_search(colstarts, e_idx, n_cs)
    v = rows_blk
    valid = (u < n_vertices) & (v < n_vertices)
    nbr = jnp.where(is_bu, v, u)
    cand = jnp.where(is_bu, u, v)

    word = cand >> WORD_SHIFT
    bit = (cand & WORD_MASK).astype(jnp.uint32)
    bits = jnp.uint32(1) << bit
    w_clip = jnp.clip(word, 0, out.shape[0] - 1)
    vis_words = vis[w_clip]
    out_words = out[w_clip]
    undis = jnp.where(is_scalar, (vis_words & bits) == 0,
                      ((vis_words | out_words) & bits) == 0)
    nw = jnp.clip(nbr >> WORD_SHIFT, 0, frontier.shape[0] - 1)
    nb = (nbr & WORD_MASK).astype(jnp.uint32)
    in_front = (frontier[nw] & (jnp.uint32(1) << nb)) != 0
    mask = valid & undis & in_front

    p_idx = jnp.where(mask, cand, p.shape[0])
    new_p = p.at[p_idx].set(nbr - n_vertices, mode="drop")
    new_words = out_words | bits
    w_idx = jnp.where(mask, word, out.shape[0])
    new_out = out.at[w_idx].set(new_words, mode="drop")
    return new_out, new_p


def _sell_tile_dyn(n_vertices: int, is_bu, cols, rows, frontier, vis,
                   out, p):
    """`sell_expand._sell_tile` with the gate/discover role swap as a
    traced select (the persistent layer loop decides direction at run
    time).  SELL maps every engine mode onto this one sweep
    (``algorithm="simd"`` — the format's step table), so there is no
    scalar-mode blend here: the accumulating ``vis | out`` test IS the
    per-layer kernel's semantics for all modes."""
    nbr = cols
    src = jnp.broadcast_to(rows[:, None, :], cols.shape)
    gate = jnp.where(is_bu, nbr, src)
    disc = jnp.where(is_bu, src, nbr)

    sw = jnp.clip(gate >> WORD_SHIFT, 0, frontier.shape[0] - 1)
    sb = (gate & WORD_MASK).astype(jnp.uint32)
    in_front = (frontier[sw] >> sb) & jnp.uint32(1) != 0

    word = disc >> WORD_SHIFT
    bit = (disc & WORD_MASK).astype(jnp.uint32)
    bits = jnp.uint32(1) << bit
    w_clip = jnp.clip(word, 0, out.shape[0] - 1)
    out_words = out[w_clip]
    undiscovered = ((vis[w_clip] | out_words) & bits) == 0
    mask = (in_front & undiscovered
            & (nbr < n_vertices) & (src < n_vertices))

    p_idx = jnp.where(mask, disc, p.shape[0])
    new_p = p.at[p_idx].set(gate - n_vertices, mode="drop")
    new_words = out_words | bits
    w_idx = jnp.where(mask, word, out.shape[0])
    new_out = out.at[w_idx].set(new_words, mode="drop")
    return new_out, new_p


def _persistent_layer_loop(policy, n_vertices: int, n_batch: int,
                           max_layers: int, deg, f_ref, vis_ref, p_ref,
                           depths_ref, layers_ref, stats_ref,
                           sweep_root):
    """The layer x root scaffold shared by the CSR and SELL persistent
    kernels: init outputs from inputs is done by the caller; this runs
    the in-kernel measure -> decide -> sweep -> restore -> stats loop.

    ``sweep_root(is_bu, is_scalar, live, f_b, vis_b, p_b)`` returns the
    un-restored ``(out_b, p_b, n_active)`` for one root's layer sweep.
    """
    def layer_body(l, bottom_up):
        frontier = f_ref[...]
        vis = vis_ref[...]
        f_count_b, f_edges_b = _layer_counters(n_vertices, frontier, deg)
        live = f_count_b.sum() > 0
        mode, new_bu = _decide(policy, l, f_count_b, f_edges_b, vis,
                               deg, n_vertices, n_batch, bottom_up)
        is_bu = mode == jnp.int32(MODE_BOTTOMUP)
        is_scalar = mode == jnp.int32(MODE_SCALAR)

        def root_body(b, na_sum):
            f_b = f_ref[pl.ds(b, 1), :][0]
            vis_b = vis_ref[pl.ds(b, 1), :][0]
            p_b = p_ref[pl.ds(b, 1), :][0]
            out_b, p_new, na = sweep_root(is_bu, is_scalar, live, f_b,
                                          vis_b, p_b)
            out_b, p_new = _restore_in_kernel(n_vertices, out_b, p_new)
            # in-place per-root update is safe: later roots in this
            # layer read only their own rows, and the batch counters
            # above were read before the root loop started
            f_ref[pl.ds(b, 1), :] = out_b[None]
            vis_ref[pl.ds(b, 1), :] = (vis_b | out_b)[None]
            p_ref[pl.ds(b, 1), :] = p_new[None]
            return na_sum + na

        na_sum = jax.lax.fori_loop(0, n_batch, root_body, jnp.int32(0))

        @pl.when(live)
        def _stats():
            discovered = jax.lax.population_count(f_ref[...]) \
                .astype(jnp.int32).sum()
            # launches: ONE Pallas call per traversal, charged to the
            # first layer's row (the stats contract stays per-layer)
            launches = jnp.where(l == 0, jnp.int32(1), jnp.int32(0))
            row = jnp.stack([f_count_b.sum(), f_edges_b.sum(),
                             discovered, mode, jnp.int32(1), na_sum,
                             jnp.int32(0), launches])
            stats_ref[pl.ds(l, 1), :] = row[None]
            depths_ref[...] = depths_ref[...] \
                + (f_count_b > 0).astype(jnp.int32)
            layers_ref[...] = layers_ref[...] + 1

        return jnp.where(live, new_bu, bottom_up)

    jax.lax.fori_loop(0, max_layers, layer_body, jnp.asarray(False))


def _init_state(f0_ref, vis0_ref, p0_ref, f_ref, vis_ref, p_ref,
                depths_ref, layers_ref, stats_ref):
    f_ref[...] = f0_ref[...]
    vis_ref[...] = vis0_ref[...]
    p_ref[...] = p0_ref[...]
    depths_ref[...] = jnp.zeros(depths_ref.shape, jnp.int32)
    layers_ref[...] = jnp.zeros(layers_ref.shape, jnp.int32)
    stats_ref[...] = jnp.zeros(stats_ref.shape, jnp.int32)


def _traversal_kernel(n_vertices: int, tile: int, n_cs: int, depth: int,
                      n_blocks: int, max_layers: int, n_batch: int,
                      policy, rows_ref, cs_ref, f0_ref, vis0_ref,
                      p0_ref, f_ref, vis_ref, p_ref, depths_ref,
                      layers_ref, stats_ref, rows_buf, sems):
    _init_state(f0_ref, vis0_ref, p0_ref, f_ref, vis_ref, p_ref,
                depths_ref, layers_ref, stats_ref)
    cs = cs_ref[...]
    deg = cs[1:] - cs[:-1]
    n_buf = depth + 1

    def sweep_root(is_bu, is_scalar, live, f_b, vis_b, p_b):
        words_b = jnp.where(is_bu, ~vis_b, f_b)
        wl, na = _plan_in_kernel(n_vertices, tile, n_blocks, False,
                                 words_b, cs)
        na = jnp.where(live, na, jnp.int32(0))

        def dma(step):
            slot = jax.lax.rem(step, n_buf)
            return pltpu.make_async_copy(
                rows_ref.at[pl.ds(wl[step] * tile, tile)],
                rows_buf.at[slot], sems.at[slot])

        # the pipeline re-warms per root sweep (the clamped work-list
        # tail makes every source index valid, so warmup DMAs are
        # always legal — `gather_expand._dma_pipeline`'s contract)
        for k in range(min(depth, n_blocks)):
            dma(jnp.int32(k)).start()

        def blk_body(t, op):
            out_b, pp = op

            @pl.when(t + depth < n_blocks)
            def _ahead():
                dma(t + depth).start()

            dma(t).wait()
            rows_blk = rows_buf[jax.lax.rem(t, n_buf)]
            new_out, new_p = _gather_tile_dyn(
                n_vertices, tile, n_cs, is_bu, is_scalar, wl[t],
                rows_blk, cs, f_b, vis_b, out_b, pp)
            # inactive tiles: the DMA ran (balanced start/wait sets)
            # but the compute result is discarded — the value-carry
            # analogue of the grid kernels' `pl.when` guard
            act = t < na
            return (jnp.where(act, new_out, out_b),
                    jnp.where(act, new_p, pp))

        out_b, p_b = jax.lax.fori_loop(
            0, n_blocks, blk_body, (jnp.zeros_like(f_b), p_b))
        return out_b, p_b, na

    _persistent_layer_loop(policy, n_vertices, n_batch, max_layers,
                           deg, f_ref, vis_ref, p_ref, depths_ref,
                           layers_ref, stats_ref, sweep_root)


def _sell_traversal_kernel(n_vertices: int, spp: int, depth: int,
                           n_steps: int, max_layers: int, n_batch: int,
                           policy, cols_ref, rows_ref, deg_ref, f0_ref,
                           vis0_ref, p0_ref, f_ref, vis_ref, p_ref,
                           depths_ref, layers_ref, stats_ref, cols_buf,
                           sems):
    _init_state(f0_ref, vis0_ref, p0_ref, f_ref, vis_ref, p_ref,
                depths_ref, layers_ref, stats_ref)
    slab_rows = rows_ref[...]        # VMEM-resident: the plan reads all
    deg = deg_ref[...]
    n_buf = depth + 1

    def sweep_root(is_bu, is_scalar, live, f_b, vis_b, p_b):
        del is_scalar    # SELL maps every mode onto the one slab sweep
        words_b = jnp.where(is_bu, ~vis_b, f_b)
        wl, na = _plan_slabs_in_kernel(n_vertices, spp, n_steps,
                                       words_b, slab_rows)
        na = jnp.where(live, na, jnp.int32(0))

        def dma(step):
            slot = jax.lax.rem(step, n_buf)
            return pltpu.make_async_copy(
                cols_ref.at[pl.ds(wl[step] * spp, spp)],
                cols_buf.at[slot], sems.at[slot])

        for k in range(min(depth, n_steps)):
            dma(jnp.int32(k)).start()

        def blk_body(t, op):
            out_b, pp = op

            @pl.when(t + depth < n_steps)
            def _ahead():
                dma(t + depth).start()

            dma(t).wait()
            cols_blk = cols_buf[jax.lax.rem(t, n_buf)]
            rows_blk = rows_ref[pl.ds(wl[t] * spp, spp), :]
            new_out, new_p = _sell_tile_dyn(
                n_vertices, is_bu, cols_blk, rows_blk, f_b, vis_b,
                out_b, pp)
            act = t < na
            return (jnp.where(act, new_out, out_b),
                    jnp.where(act, new_p, pp))

        out_b, p_b = jax.lax.fori_loop(
            0, n_steps, blk_body, (jnp.zeros_like(f_b), p_b))
        return out_b, p_b, na

    _persistent_layer_loop(policy, n_vertices, n_batch, max_layers,
                           deg, f_ref, vis_ref, p_ref, depths_ref,
                           layers_ref, stats_ref, sweep_root)


def vmem_budget(n_words: int, v_pad: int, n_cs: int, tile: int,
                n_batch: int = 1, max_layers: int = 64,
                prefetch_depth: int = 0, n_blocks: int = 1) -> int:
    """Bytes of VMEM the CSR persistent kernel pins: the whole batch's
    state x2 (input copies + resident outputs) + colstarts + the
    planning working set + the rows DMA buffers + the stats buffer.
    The DMA depth is clamped to ``n_blocks`` exactly as the kernel
    clamps it (the resolved-spec budget rule of ISSUE 9)."""
    depth = min(max(int(prefetch_depth), 0), max(int(n_blocks), 1))
    state = 2 * 4 * n_batch * (2 * n_words + v_pad)
    plan = 4 * (v_pad + 3 * (n_blocks + 1))
    stats = 4 * (_N_ST * max_layers + n_batch + 1)
    return state + 4 * n_cs + (depth + 1) * 4 * tile + plan + stats


def sell_vmem_budget(n_words: int, v_pad: int, n_slabs: int, spp: int,
                     n_batch: int = 1, max_layers: int = 64,
                     prefetch_depth: int = 0, n_steps: int = 1) -> int:
    """Bytes of VMEM the SELL persistent kernel pins: batch state x2 +
    the fully resident ``slab_rows`` (the in-kernel plan reads every
    slab's lane owners, charged x2 for the membership working set) +
    degrees + the cols slab DMA buffers + the stats buffer."""
    depth = min(max(int(prefetch_depth), 0), max(int(n_steps), 1))
    state = 2 * 4 * n_batch * (2 * n_words + v_pad)
    slab_cols = spp * W_QUANT * SLICE_C * 4
    plan = 2 * 4 * n_slabs * SLICE_C + 4 * 3 * (n_steps + 1)
    stats = 4 * (_N_ST * max_layers + n_batch + 1)
    return state + 4 * v_pad + plan + (depth + 1) * slab_cols + stats


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "policy", "max_layers",
                                             "prefetch_depth",
                                             "interpret"))
def traversal_fused_batched(rows, colstarts, frontier, visited, p_init,
                            *, n_vertices: int, tile: int = DEFAULT_TILE,
                            policy, max_layers: int = 64,
                            prefetch_depth: int = 0,
                            interpret: bool = True):
    """The whole multi-root BFS traversal in ONE Pallas call.

    Args:
      rows: (E_tiles,) int32 CSR adjacency, sentinel-padded to a tile
        multiple (pad once at build).  Stays in HBM; active blocks are
        DMA'd per layer.
      colstarts: (V + 1,) int32, VMEM-resident for the whole search.
      frontier, visited: (B, W) uint32 initial bitmaps (root states).
      p_init: (B, V_pad) int32 predecessor arrays.
      policy: a frozen engine DirectionPolicy — closed over statically;
        `policy.decide` runs on in-kernel counters every layer.
      max_layers: the in-kernel layer cap (the engine's while bound).
    Returns:
      (frontier, visited, parent, depths (B,), layers (1,), stats
      (max_layers, 8)) — the engine's whole-traversal contract, with
      restoration applied every layer and the stats launch column
      charging 1 to layer 0 (one launch per traversal).
    """
    n_slots = rows.shape[0]
    assert n_slots % tile == 0, "pad rows to the tile size at build"
    n_blocks = n_slots // tile
    n_batch, n_words = visited.shape
    n_cs = colstarts.shape[0]
    v_pad = p_init.shape[1]
    depth = min(max(int(prefetch_depth), 0), n_blocks)

    whole = lambda *s: pl.BlockSpec(s, lambda t: (0,) * len(s))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                  whole(n_cs), whole(n_batch, n_words),
                  whole(n_batch, n_words), whole(n_batch, v_pad)],
        out_specs=[whole(n_batch, n_words), whole(n_batch, n_words),
                   whole(n_batch, v_pad), whole(n_batch), whole(1),
                   whole(max_layers, _N_ST)],
        scratch_shapes=[pltpu.VMEM((depth + 1, tile), jnp.int32),
                        pltpu.SemaphoreType.DMA((depth + 1,))],
    )
    return pl.pallas_call(
        functools.partial(_traversal_kernel, n_vertices, tile, n_cs,
                          depth, n_blocks, max_layers, n_batch, policy),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32),
                   jax.ShapeDtypeStruct((n_batch,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((max_layers, _N_ST), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_traversal_fused",
    )(rows, colstarts, frontier, visited, p_init)


@functools.partial(jax.jit, static_argnames=("n_vertices",
                                             "slabs_per_step", "policy",
                                             "max_layers",
                                             "prefetch_depth",
                                             "interpret"))
def sell_traversal_fused_batched(cols, slab_rows, deg, frontier,
                                 visited, p_init, *, n_vertices: int,
                                 slabs_per_step: int = 1, policy,
                                 max_layers: int = 64,
                                 prefetch_depth: int = 0,
                                 interpret: bool = True):
    """The whole multi-root SELL-C-σ traversal in ONE Pallas call.

    Same contract as `traversal_fused_batched`; the adjacency is the
    slab layout (``cols`` (n_slabs, W_QUANT, C) streamed via DMA,
    ``slab_rows`` (n_slabs, C) VMEM-resident for the in-kernel plan)
    plus the explicit ``deg`` (V,) array (SELL has no colstarts to
    derive the Table 1 edge counters from).  ``cols``/``slab_rows``
    must be pre-padded to a ``slabs_per_step`` multiple
    (`ops._pad_slabs`).
    """
    n_slabs = cols.shape[0]
    assert n_slabs % slabs_per_step == 0, \
        "pad the slab count to the step size"
    n_steps = n_slabs // slabs_per_step
    n_batch, n_words = visited.shape
    v_pad = p_init.shape[1]
    n_deg = deg.shape[0]
    depth = min(max(int(prefetch_depth), 0), n_steps)

    whole = lambda *s: pl.BlockSpec(s, lambda t: (0,) * len(s))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
                  whole(n_slabs, SLICE_C), whole(n_deg),
                  whole(n_batch, n_words), whole(n_batch, n_words),
                  whole(n_batch, v_pad)],
        out_specs=[whole(n_batch, n_words), whole(n_batch, n_words),
                   whole(n_batch, v_pad), whole(n_batch), whole(1),
                   whole(max_layers, _N_ST)],
        scratch_shapes=[pltpu.VMEM((depth + 1, slabs_per_step, W_QUANT,
                                    SLICE_C), jnp.int32),
                        pltpu.SemaphoreType.DMA((depth + 1,))],
    )
    return pl.pallas_call(
        functools.partial(_sell_traversal_kernel, n_vertices,
                          slabs_per_step, depth, n_steps, max_layers,
                          n_batch, policy),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32),
                   jax.ShapeDtypeStruct((n_batch,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32),
                   jax.ShapeDtypeStruct((max_layers, _N_ST), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_sell_traversal_fused",
    )(cols, slab_rows, deg, frontier, visited, p_init)
