"""Version-tolerant access to renamed Pallas TPU symbols.

jax has shipped the TPU compiler-params dataclass under two names
across releases (``TPUCompilerParams`` in the 0.4.3x line,
``CompilerParams`` before and after).  Every kernel module resolves it
through here so a jax upgrade/downgrade is a one-line fix.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
