"""Pallas TPU kernel: fused in-kernel CSR gather + active-tile schedule.

The frontier-proportional replacement for the materialized edge stream
(ISSUE 3).  `frontier_expand.py` consumes an apportioned ``(u, v,
valid)`` triple that a jnp pass writes to HBM and the kernel re-reads
— a layer touching 1% of the edges still moves ~3x E_pad words twice.
This kernel eliminates the round trip and makes the HBM traffic scale
with the live frontier:

* **in-kernel gather** — the kernel takes ``colstarts``/``rows``
  directly.  ``rows`` stays in HBM and is DMA'd one aligned
  *tile-sized block* per grid step (the Pallas indirection idiom:
  block-granular gathers through the BlockSpec index map).  The edge
  -> owner mapping that `engine.apportion` materialized is recomputed
  on the fly with a branchless binary search over the VMEM-resident
  ``colstarts`` — log2(V) VMEM gathers instead of an E_pad-word HBM
  stream.
* **scalar-prefetched active-tile scheduling** — a tiny on-device
  planning pass (`engine.plan_active_tiles`) marks which rows-blocks
  intersect the frontier's adjacency and compacts them into a
  *work-list*.  The work-list rides in scalar-prefetch memory: the
  BlockSpec index map reads ``worklist[t]`` to pick the block each
  grid step DMAs, entries past ``n_active`` are clamped to the last
  active block (an unchanged block index => Mosaic elides the repeated
  DMA) and a ``pl.when`` guard skips their compute.  A 1k-edge layer
  on a SCALE-22 graph therefore costs ~1 tile of traffic, not
  E_pad/tile tiles.  This is the TPU analog of the paper's §4
  prefetch-distance tuning: the *tile size* is the prefetch distance,
  the work-list replaces ``_mm_prefetch``.

Direction is a role swap on the same body (`_expand_tile`):

* top-down:  owner u gated by "u in frontier", neighbor v tested
  undiscovered, P[v] = u - |V| (the Listing 1 hot loop);
* bottom-up: the planner marks *unvisited* vertices' blocks, owner u
  tested undiscovered, neighbor v gated by "v in frontier",
  P[u] = v - |V| (the hybrid extension, arXiv:1704.02259).

Races and restoration are exactly the §3.3.2 story of the materialized
kernel: the word scatter may drop colliding bits, the negative P marks
let `restoration.py` repair them.

Since ISSUE 4 the kernel also offers a **manual double-buffered DMA
input pipeline** (``prefetch_depth`` > 0): ``rows`` stays in HBM (ANY
memory space) and the kernel itself issues ``make_async_copy`` for
tile ``t + depth`` while tile ``t`` computes, over ``depth + 1`` VMEM
buffers with per-slot DMA semaphores — the explicit-prefetch-distance
transcription of the paper's ``vprefetch`` tuning, where the
BlockSpec pipeline's automatic double buffering is the fixed
distance-1 special case.  The visited/frontier membership tests and
the output-queue scatter operate on packed uint32 words in VMEM
throughout (in-kernel packed test-and-set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitmap import WORD_MASK, WORD_SHIFT
from repro.kernels.frontier_expand import _expand_tile
from repro.kernels.pallas_compat import CompilerParams

DEFAULT_TILE = 1024  # 8 sublanes x 128 lanes of int32


def _owner_search(colstarts, e_idx, n_entries: int):
    """Largest u with ``colstarts[u] <= e`` — branchless bit-lifting
    binary search (log2(V+1) VMEM gathers, no HBM traffic).

    This is the in-kernel inverse of the apportionment prefix-sum:
    edge position -> owning vertex.  ``colstarts[0] == 0 <= e`` holds
    for every slot, so the greedy bit descent is total; a result of
    ``n_entries - 1`` (== V) marks the sentinel-padded tail of rows.
    """
    u = jnp.zeros(e_idx.shape, jnp.int32)
    step = 1
    while step * 2 < n_entries:
        step *= 2
    while step:
        cand = u + step
        safe = jnp.clip(cand, 0, n_entries - 1)
        ok = (cand < n_entries) & (colstarts[safe] <= e_idx)
        u = jnp.where(ok, cand, u)
        step //= 2
    return u


def _gather_tile(n_vertices: int, tile: int, n_cs: int, bottom_up: bool,
                 blk, rows_blk, colstarts, frontier, vis, out, p):
    """One active tile: gather owners + run the shared hot-loop body."""
    e_idx = blk * tile + jnp.arange(tile, dtype=jnp.int32)
    u = _owner_search(colstarts, e_idx, n_cs)
    v = rows_blk
    valid = (u < n_vertices) & (v < n_vertices)
    # the role swap: the frontier-gated side goes through the
    # check_frontier test, the discovered side through the bitmap test
    nbr, cand = (v, u) if bottom_up else (u, v)
    return _expand_tile(n_vertices, True, nbr, cand, valid, frontier,
                        vis, out, p)


def _gather_kernel(n_vertices: int, tile: int, n_cs: int,
                   bottom_up: bool, wl_ref, na_ref, rows_ref, cs_ref,
                   frontier_ref, vis_ref, out0_ref, p0_ref, out_ref,
                   p_ref):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():  # carry initial out/P into the accumulating outputs
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    @pl.when(t < na_ref[0])
    def _work():  # inactive tiles: no DMA (clamped index), no compute
        out, p = _gather_tile(n_vertices, tile, n_cs, bottom_up,
                              wl_ref[t], rows_ref[...], cs_ref[...],
                              frontier_ref[...], vis_ref[...],
                              out_ref[...], p_ref[...])
        out_ref[...] = out
        p_ref[...] = p


def _gather_batched_kernel(n_vertices: int, tile: int, n_cs: int,
                           bottom_up: bool, wl_ref, na_ref, rows_ref,
                           cs_ref, frontier_ref, vis_ref, out0_ref,
                           p0_ref, out_ref, p_ref):
    """Batched variant: grid (roots, tiles); the adjacency is shared
    (no root axis on rows/colstarts), each root has its own work-list
    and accumulates into its own out/P rows."""
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    @pl.when(t < na_ref[b])
    def _work():
        out, p = _gather_tile(n_vertices, tile, n_cs, bottom_up,
                              wl_ref[b, t], rows_ref[...], cs_ref[...],
                              frontier_ref[0], vis_ref[0],
                              out_ref[0], p_ref[0])
        out_ref[...] = out[None]
        p_ref[...] = p[None]


def vmem_budget(n_words: int, v_pad: int, n_cs: int, tile: int,
                prefetch_depth: int = 0,
                n_blocks: int | None = None) -> int:
    """Bytes of VMEM pinned (bitmaps x3 + P x2 + colstarts + rows
    tile buffers — 2 for the automatic BlockSpec pipeline, the
    resolved ``depth + 1`` for the manual DMA pipeline).  The wrappers
    clamp ``prefetch_depth`` to the block count, so the budget charges
    the clamped depth too (ISSUE 9 satellite: budgets from the
    resolved spec only)."""
    depth = max(int(prefetch_depth), 0)
    if n_blocks is not None:
        depth = min(depth, max(int(n_blocks), 1))
    n_buf = max(2, depth + 1)
    return 4 * (3 * n_words + 2 * v_pad + n_cs) + n_buf * 4 * tile


def _dma_pipeline(rows_hbm, rows_buf, sems, wl, tile: int, depth: int,
                  n_blocks: int, t, warm, work):
    """The manual double-buffered input pipeline shared by the single
    and batched DMA kernels.

    At the first step of a root's tile sequence (``warm``) the DMAs
    for tiles 0..depth are started; at every step the DMA for tile
    ``t + depth`` is started (if it exists) before *waiting* on tile
    ``t``'s — so ``depth`` tiles are always in flight while the
    current tile computes (the §4 ``vprefetch`` distance, DMA-shaped).
    ``depth + 1`` buffer slots make the in-flight set disjoint from
    the compute slot.  The clamped work-list tail re-copies the last
    active block (cheap, and the tail's compute is skipped by the
    caller's ``pl.when`` guard).  ``work`` consumes the current
    tile's VMEM buffer."""
    n_buf = depth + 1

    def dma(step):
        return pltpu.make_async_copy(
            rows_hbm.at[pl.ds(wl(step) * tile, tile)],
            rows_buf.at[jax.lax.rem(step, n_buf)],
            sems.at[jax.lax.rem(step, n_buf)])

    @pl.when(warm)
    def _warmup():
        for k in range(min(depth, n_blocks)):
            dma(jnp.int32(k)).start()

    @pl.when(t + depth < n_blocks)
    def _ahead():
        dma(t + depth).start()

    dma(t).wait()
    work(rows_buf[jax.lax.rem(t, n_buf)])


def _gather_dma_kernel(n_vertices: int, tile: int, n_cs: int,
                       bottom_up: bool, depth: int, n_blocks: int,
                       wl_ref, na_ref, rows_ref, cs_ref, frontier_ref,
                       vis_ref, out0_ref, p0_ref, out_ref, p_ref,
                       rows_buf, sems):
    """`_gather_kernel` with the manual double-buffered input pipeline:
    ``rows`` stays in HBM (ANY memory space) and the kernel itself
    keeps ``depth`` tile DMAs in flight ahead of the compute tile."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    def work(rows_blk):
        @pl.when(t < na_ref[0])
        def _work():
            out, p = _gather_tile(n_vertices, tile, n_cs, bottom_up,
                                  wl_ref[t], rows_blk, cs_ref[...],
                                  frontier_ref[...], vis_ref[...],
                                  out_ref[...], p_ref[...])
            out_ref[...] = out
            p_ref[...] = p

    _dma_pipeline(rows_ref, rows_buf, sems, lambda s: wl_ref[s], tile,
                  depth, n_blocks, t, t == 0, work)


def _gather_dma_batched_kernel(n_vertices: int, tile: int, n_cs: int,
                               bottom_up: bool, depth: int,
                               n_blocks: int, wl_ref, na_ref, rows_ref,
                               cs_ref, frontier_ref, vis_ref, out0_ref,
                               p0_ref, out_ref, p_ref, rows_buf, sems):
    """Batched DMA variant: each root's tile sequence re-warms the
    pipeline at its first grid step (the grid stays sequential, so
    buffer slots hand over cleanly at root boundaries)."""
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = out0_ref[...]
        p_ref[...] = p0_ref[...]

    def work(rows_blk):
        @pl.when(t < na_ref[b])
        def _work():
            out, p = _gather_tile(n_vertices, tile, n_cs, bottom_up,
                                  wl_ref[b, t], rows_blk, cs_ref[...],
                                  frontier_ref[0], vis_ref[0],
                                  out_ref[0], p_ref[0])
            out_ref[...] = out[None]
            p_ref[...] = p[None]

    _dma_pipeline(rows_ref, rows_buf, sems, lambda s: wl_ref[b, s],
                  tile, depth, n_blocks, t, t == 0, work)


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def gather_expand(worklist, n_active, rows, colstarts, frontier,
                  visited, out_init, p_init, *, n_vertices: int,
                  tile: int = DEFAULT_TILE, bottom_up: bool = False,
                  prefetch_depth: int = 0, interpret: bool = True):
    """Fused gather-expand over the active rows-blocks of one layer.

    Args:
      worklist: (n_blocks,) int32 — block id each grid step DMAs.
        Active entries first; the tail must be clamped to the last
        active block (repeated index => the DMA is elided).
      n_active: (1,) int32 — live prefix length of ``worklist``.
      rows: (E_tiles,) int32 CSR adjacency, sentinel-padded, length a
        multiple of ``tile`` (pad once at build, NOT per layer).
      colstarts: (V + 1,) int32 — VMEM-resident for the owner search.
      frontier, visited, out_init: (W,) uint32 bitmaps.
      p_init: (V_pad,) int32 predecessor array.
      bottom_up: False = top-down gather, True = unvisited-adjacency
        sweep testing neighbors against the frontier.
      prefetch_depth: 0 = the BlockSpec pipeline (Mosaic's automatic
        double buffering); > 0 = the manual `make_async_copy` input
        pipeline with ``depth`` tile DMAs in flight ahead of the
        compute tile (``depth + 1`` VMEM buffers) — §4's prefetch
        distance as an explicit knob.
    Returns:
      (out, parent) after the racy expansion (restoration NOT applied)
      — the same contract as `frontier_expand.frontier_expand`.
    """
    n_slots = rows.shape[0]
    assert n_slots % tile == 0, "pad rows to the tile size at build"
    n_blocks = n_slots // tile
    assert worklist.shape[0] == n_blocks
    n_cs = colstarts.shape[0]
    n_words = visited.shape[0]
    v_pad = p_init.shape[0]

    whole = lambda n: pl.BlockSpec((n,), lambda t, wl, na: (0,))
    if prefetch_depth > 0:
        depth = min(int(prefetch_depth), n_blocks)
        rows_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
        scratch = [pltpu.VMEM((depth + 1, tile), jnp.int32),
                   pltpu.SemaphoreType.DMA((depth + 1,))]
        kernel = functools.partial(_gather_dma_kernel, n_vertices, tile,
                                   n_cs, bottom_up, depth, n_blocks)
    else:
        rows_spec = pl.BlockSpec((tile,), lambda t, wl, na: (wl[t],))
        scratch = []
        kernel = functools.partial(_gather_kernel, n_vertices, tile,
                                   n_cs, bottom_up)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks,),
        in_specs=[rows_spec,
                  whole(n_cs), whole(n_words), whole(n_words),
                  whole(n_words), whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad)],
        scratch_shapes=scratch,
    )
    out, parent = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_words,), jnp.uint32),
                   jax.ShapeDtypeStruct((v_pad,), jnp.int32)],
        compiler_params=CompilerParams(
            # accumulating outputs => sequential grid on the core
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="bfs_gather_expand",
    )(worklist, n_active, rows, colstarts, frontier, visited, out_init,
      p_init)
    return out, parent


# ---------------------------------------------------------------------------
# Semiring relaxation (ISSUE 10): the same fused in-kernel gather, but
# the per-edge update is the (min, ⊗) pair of `algorithms/semiring.py`
# instead of the BFS bit test-and-set.  Two structural differences from
# the bitmap kernels above:
#
# * the scatter is a masked **scatter-min of values** — min is
#   commutative and associative, so the §3.3.2 word-collision race of
#   the BFS scatter does not exist here and no restoration pass is
#   needed.  Duplicate relaxations of the same target are benign by
#   algebra.
# * parents are resolved by a **second phase over the same tiles**
#   (grid (B, 2, tiles), phase-major sequential): phase 0 folds every
#   candidate into ``out_vals``; phase 1 re-walks the tiles and takes
#   the minimum source id among edges whose candidate EQUALS the
#   now-final value of an improved target.  The candidate is recomputed
#   from identical inputs with identical ops, so the float equality is
#   bitwise-exact, and "min u among optimal edges" makes the parent
#   tree deterministic without any restoration machinery.
#
# ⊗ arrives as data (``unit`` hop cost + optional synthetic
# ``edge_weight``), which is what lets one kernel serve sssp / cc /
# k-source BFS — see the Semiring table in algorithms/semiring.py.
# ---------------------------------------------------------------------------

#: parent-resolve scatter-min sentinel: larger than any vertex id
P_UNSET = jnp.iinfo(jnp.int32).max


def _relax_edges(n_vertices: int, tile: int, n_cs: int, unit: int,
                 weighted: bool, blk, rows_blk, colstarts, frontier,
                 vals):
    """Shared per-tile edge enumeration: gather owners, gate on the
    frontier, and form each edge's semiring candidate ``vals[u] ⊗ w``.
    Returns (u, v, mask, cand) for the phase-specific scatter."""
    from repro.algorithms.semiring import edge_weight

    e_idx = blk * tile + jnp.arange(tile, dtype=jnp.int32)
    u = _owner_search(colstarts, e_idx, n_cs)
    v = rows_blk
    valid = (u < n_vertices) & (v < n_vertices)
    uw = jnp.clip(u >> WORD_SHIFT, 0, frontier.shape[0] - 1)
    ub = (u & WORD_MASK).astype(jnp.uint32)
    in_front = ((frontier[uw] >> ub) & jnp.uint32(1)) != 0
    mask = valid & in_front
    u_val = vals[jnp.clip(u, 0, vals.shape[0] - 1)]
    if weighted:
        cand = u_val + edge_weight(u, v)
    elif unit:
        cand = u_val + jnp.asarray(unit, vals.dtype)
    else:
        cand = u_val
    return u, v, mask, cand


def _relax_scatter_vals(v_slots: int, u, v, mask, cand, out_vals):
    """Phase 0: fold candidates into the value row (masked scatter-min;
    out-of-mask lanes are dropped on the OOB sentinel index)."""
    idx = jnp.where(mask, v, v_slots)
    return out_vals.at[idx].min(cand, mode="drop")


def _relax_scatter_parents(v_slots: int, u, v, mask, cand, vals,
                           out_vals, p):
    """Phase 1: deterministic parent resolve against the finalized
    values — min source id among edges achieving the optimum, gated on
    strict improvement over the layer-start value."""
    v_clip = jnp.clip(v, 0, v_slots - 1)
    cur = out_vals[v_clip]
    win = mask & (cand == cur) & (cur < vals[v_clip])
    idx = jnp.where(win, v, v_slots)
    return p.at[idx].min(u, mode="drop")


def _relax_batched_kernel(n_vertices: int, tile: int, n_cs: int,
                          unit: int, weighted: bool, wl_ref, na_ref,
                          rows_ref, cs_ref, frontier_ref, vals_ref,
                          out_ref, p_ref):
    b = pl.program_id(0)
    ph = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when((ph == 0) & (t == 0))
    def _init():  # value row starts at the layer-start values
        out_ref[...] = vals_ref[...]
        p_ref[...] = jnp.full(p_ref.shape, P_UNSET, jnp.int32)

    @pl.when(t < na_ref[b])
    def _work():
        u, v, mask, cand = _relax_edges(
            n_vertices, tile, n_cs, unit, weighted, wl_ref[b, t],
            rows_ref[...], cs_ref[...], frontier_ref[0], vals_ref[0])
        v_slots = p_ref.shape[1]

        @pl.when(ph == 0)
        def _vals():
            out_ref[...] = _relax_scatter_vals(
                v_slots, u, v, mask, cand, out_ref[0])[None]

        @pl.when(ph == 1)
        def _parents():
            p_ref[...] = _relax_scatter_parents(
                v_slots, u, v, mask, cand, vals_ref[0], out_ref[0],
                p_ref[0])[None]


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "unit", "weighted",
                                             "interpret"))
def gather_relax_batched(worklist, n_active, rows, colstarts, frontier,
                         vals, *, n_vertices: int,
                         tile: int = DEFAULT_TILE, unit: int = 0,
                         weighted: bool = False,
                         interpret: bool = True):
    """Multi-root semiring relaxation over the active rows-blocks of
    one layer (the (min, ⊗) generalization of `gather_expand_batched`).

    Args:
      worklist, n_active: (B, n_blocks) / (B,) — the same scalar-
        prefetched active-tile schedule as the BFS kernel (entries past
        ``n_active`` clamped, their DMA elided, compute skipped).
      rows, colstarts: the shared CSR adjacency (no root axis).
      frontier: (B, W) uint32 packed frontier bitmaps.
      vals: (B, V_pad) layer-start value rows (int32 or float32).
      unit, weighted: the ⊗ data — candidate along (u, v) is
        ``vals[u] + unit (+ edge_weight(u, v) if weighted)``.
    Returns:
      (out_vals, p_layer): the folded value rows and the per-layer
      parent scatter (``P_UNSET`` where no edge won; the driver merges
      it into the persistent parent array under the improved mask).
      No restoration pass exists or is needed — scatter-min commutes.
    """
    n_slots = rows.shape[0]
    assert n_slots % tile == 0, "pad rows to the tile size at build"
    n_blocks = n_slots // tile
    n_batch = worklist.shape[0]
    assert worklist.shape == (n_batch, n_blocks)
    n_cs = colstarts.shape[0]
    n_words = frontier.shape[1]
    v_pad = vals.shape[1]

    flat = lambda n: pl.BlockSpec((n,), lambda b, ph, t, wl, na: (0,))
    whole = lambda n: pl.BlockSpec((1, n),
                                   lambda b, ph, t, wl, na: (b, 0))
    rows_spec = pl.BlockSpec((tile,),
                             lambda b, ph, t, wl, na: (wl[b, t],))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        # phase-major sequential: every phase-0 tile of a root lands
        # before its phase-1 tiles, so phase 1 reads finalized values
        grid=(n_batch, 2, n_blocks),
        in_specs=[rows_spec, flat(n_cs), whole(n_words), whole(v_pad)],
        out_specs=[whole(v_pad), whole(v_pad)],
    )
    out_vals, p_layer = pl.pallas_call(
        functools.partial(_relax_batched_kernel, n_vertices, tile,
                          n_cs, unit, weighted),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, v_pad), vals.dtype),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
        name="bfs_gather_relax_batched",
    )(worklist, n_active, rows, colstarts, frontier, vals)
    return out_vals, p_layer


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "bottom_up",
                                             "prefetch_depth",
                                             "interpret"))
def gather_expand_batched(worklist, n_active, rows, colstarts, frontier,
                          visited, out_init, p_init, *, n_vertices: int,
                          tile: int = DEFAULT_TILE,
                          bottom_up: bool = False,
                          prefetch_depth: int = 0,
                          interpret: bool = True):
    """Multi-root fused gather-expand: one launch, B searches.

    ``worklist`` is (B, n_blocks) and ``n_active`` (B,) — each root
    schedules its own active tiles (a finished root has n_active == 0
    and costs nothing).  ``rows``/``colstarts`` carry no root axis
    (the layout is shared); bitmaps/P are (B, W) / (B, V_pad).  Grid
    is (B, n_tiles): roots parallel, tiles sequential.
    ``prefetch_depth`` > 0 selects the manual double-buffered DMA
    input pipeline (see `gather_expand`); the grid then stays fully
    sequential so buffer slots hand over cleanly at root boundaries.
    """
    n_slots = rows.shape[0]
    assert n_slots % tile == 0, "pad rows to the tile size at build"
    n_blocks = n_slots // tile
    n_batch = worklist.shape[0]
    assert worklist.shape == (n_batch, n_blocks)
    n_cs = colstarts.shape[0]
    n_words = visited.shape[1]
    v_pad = p_init.shape[1]

    flat = lambda n: pl.BlockSpec((n,), lambda b, t, wl, na: (0,))
    whole = lambda n: pl.BlockSpec((1, n), lambda b, t, wl, na: (b, 0))
    if prefetch_depth > 0:
        depth = min(int(prefetch_depth), n_blocks)
        rows_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
        scratch = [pltpu.VMEM((depth + 1, tile), jnp.int32),
                   pltpu.SemaphoreType.DMA((depth + 1,))]
        kernel = functools.partial(_gather_dma_batched_kernel,
                                   n_vertices, tile, n_cs, bottom_up,
                                   depth, n_blocks)
        semantics = ("arbitrary", "arbitrary")
    else:
        rows_spec = pl.BlockSpec((tile,),
                                 lambda b, t, wl, na: (wl[b, t],))
        scratch = []
        kernel = functools.partial(_gather_batched_kernel, n_vertices,
                                   tile, n_cs, bottom_up)
        semantics = ("parallel", "arbitrary")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_batch, n_blocks),
        in_specs=[rows_spec,
                  flat(n_cs), whole(n_words), whole(n_words),
                  whole(n_words), whole(v_pad)],
        out_specs=[whole(n_words), whole(v_pad)],
        scratch_shapes=scratch,
    )
    out, parent = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_batch, n_words), jnp.uint32),
                   jax.ShapeDtypeStruct((n_batch, v_pad), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=semantics),
        interpret=interpret,
        name="bfs_gather_expand_batched",
    )(worklist, n_active, rows, colstarts, frontier, visited, out_init,
      p_init)
    return out, parent
