"""Pure-jnp oracles for every Pallas kernel.

The kernels' contract is *tile-sequential, racy-within-tile*:

* the edge stream is processed in tiles of ``tile`` slots, strictly in
  order (the TPU grid is sequential on a core);
* within a tile, all bitmap words are read at tile start (stale reads)
  and scattered back with last-lane-wins on duplicate word indices —
  the paper's bit race condition (§3.3.2);
* across tiles, updates accumulate (tile *t+1* observes tile *t*).

The oracles below implement exactly that contract with plain jnp (a
``lax.scan`` over tiles), so interpret-mode kernels must match them
bit-for-bit.  Algorithm-level correctness never depends on the racy
details — the restoration process repairs any interleaving — but the
kernels must do precisely what they claim, and these oracles pin that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitmap import WORD_MASK, WORD_SHIFT


def _gather(words: jax.Array, idx: jax.Array) -> jax.Array:
    return words[jnp.clip(idx, 0, words.shape[0] - 1)]


def expand_tile(nbr, cand, valid, frontier, visited, out, parent,
                n_vertices: int, check_frontier: bool):
    """One tile of the gather-test-mask-scatter pipeline (Listing 1).

    nbr:   (T,) parent-side vertex (u top-down; the neighbor bottom-up)
    cand:  (T,) candidate vertex v to discover
    valid: (T,) int32/bool lane validity (peel/remainder masking)
    Returns (out', parent').
    """
    v_pad = parent.shape[0]
    word = cand >> WORD_SHIFT
    bit = (cand & WORD_MASK).astype(jnp.uint32)
    vis_words = _gather(visited, word)
    out_words = _gather(out, word)
    bits = jnp.uint32(1) << bit
    undiscovered = ((vis_words | out_words) & bits) == 0
    mask = valid.astype(bool) & undiscovered
    if check_frontier:  # bottom-up: is the neighbor in the frontier?
        nw = nbr >> WORD_SHIFT
        nb = (nbr & WORD_MASK).astype(jnp.uint32)
        in_frontier = (_gather(frontier, nw) & (jnp.uint32(1) << nb)) != 0
        mask = mask & in_frontier
    # P[v] = u - nodes (negative marking; benign duplicate-cand race)
    p_idx = jnp.where(mask, cand, v_pad)
    parent = parent.at[p_idx].set(nbr - n_vertices, mode="drop")
    # racy word scatter: stale out_words | own bit, last lane wins
    new_words = out_words | bits
    w_idx = jnp.where(mask, word, out.shape[0])
    out = out.at[w_idx].set(new_words, mode="drop")
    return out, parent


@functools.partial(jax.jit, static_argnames=("n_vertices", "tile",
                                             "check_frontier"))
def frontier_expand_ref(nbr, cand, valid, frontier, visited, out_init,
                        p_init, *, n_vertices: int, tile: int,
                        check_frontier: bool = False):
    """Tile-sequential oracle for the frontier-expansion kernel."""
    n_slots = cand.shape[0]
    assert n_slots % tile == 0
    n_tiles = n_slots // tile

    def step(carry, t):
        out, parent = carry
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, t * tile, tile)
        out, parent = expand_tile(sl(nbr), sl(cand), sl(valid), frontier,
                                  visited, out, parent, n_vertices,
                                  check_frontier)
        return (out, parent), None

    (out, parent), _ = jax.lax.scan(
        step, (out_init, p_init), jnp.arange(n_tiles, dtype=jnp.int32))
    return out, parent


@functools.partial(jax.jit, static_argnames=("n_vertices",))
def restoration_ref(parent, *, n_vertices: int):
    """Oracle for the restoration kernel (Alg. 3 lines 15-29).

    Returns (parent_fixed, delta_bitmap): every vertex with P < 0 gets
    its bit set in delta and its parent incremented by |V|.
    """
    marked = parent < 0
    fixed = jnp.where(marked, parent + n_vertices, parent)
    bits = marked.reshape(-1, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    delta = (bits * weights).sum(axis=1, dtype=jnp.uint32)
    return fixed, delta


@jax.jit
def popcount_ref(words):
    return jax.lax.population_count(words).astype(jnp.int32).sum()
