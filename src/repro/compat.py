"""Version-tolerant access to jax symbols that moved across releases.

Companion to `kernels.pallas_compat` (the Pallas rename) and the
AxisType shim in `launch.mesh`; everything that has to run on both the
jax 0.4.x line and >= 0.5 resolves through here.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` when available (>= 0.5), else the experimental
    entry point (0.4.x) with replication checking off — the older
    tracker lacks rules for some collectives these programs use."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def pcast_varying(x, axis_names):
    """Mark a replicated value as device-varying for while_loop carry
    typing; identity on jax versions without replication tracking."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axis_names, to="varying")
