"""Batched serving engine: continuous batching over the decode step.

A minimal but real production shape: a request pool, a fixed decode
batch with slot reuse (a finished request's slot is refilled from the
queue on the next step — "continuous batching"), ring-buffer KV reuse,
and per-request max_tokens/EOS termination.

The decode batch never changes shape, so the jitted serve_step is
compiled once — the serving analogue of the paper's fixed-size bitmap
frontier.  Slot refill resets that slot's cache entries via masked
state update.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_tokens: int = 16
    generated: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 4,
                 cache_len: int = 256, eos_id: int | None = None,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.slots: list[Request | None] = [None] * batch_slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.states = lm.init_decode_state(params, cfg, batch_slots,
                                           cache_len)
        self._fresh = lm.init_decode_state(params, cfg, batch_slots,
                                           cache_len)
        self.positions = np.zeros(batch_slots, np.int32)
        self.pending = np.zeros(batch_slots, np.int32)  # prompt cursor

        def step(states, tokens, position):
            return lm.decode_step(params, cfg, states, tokens, position)
        self._step = jax.jit(step)

        def reset_slot(states, fresh, slot):
            return jax.tree.map(
                lambda s, f: s.at[:, slot].set(f[:, slot]), states, fresh)
        self._reset = jax.jit(reset_slot, static_argnums=2)

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_slots(self):
        for i, slot in enumerate(self.slots):
            if (slot is None or slot.done) and self.queue:
                self.slots[i] = self.queue.pop(0)
                self.positions[i] = 0
                self.pending[i] = 0
                self.states = self._reset(self.states, self._fresh, i)

    def _next_tokens(self, logits: np.ndarray) -> np.ndarray:
        return np.asarray(logits).argmax(-1).astype(np.int32)

    def step(self):
        """One engine tick: feed prompt tokens or sample, per slot."""
        self._fill_slots()
        tokens = np.zeros(len(self.slots), np.int32)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            cursor = int(self.pending[i])
            if cursor < len(req.prompt):
                tokens[i] = req.prompt[cursor]
            elif req.generated:
                tokens[i] = req.generated[-1]
            else:
                tokens[i] = req.prompt[-1]
        self.states, logits = self._step(
            self.states, jnp.asarray(tokens),
            jnp.asarray(self.positions))
        nxt = self._next_tokens(logits)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            self.positions[i] += 1
            cursor = int(self.pending[i])
            if cursor < len(req.prompt) - 1:
                self.pending[i] = cursor + 1      # still prefilling
                continue
            self.pending[i] = cursor + 1
            tok = int(nxt[i])
            req.generated.append(tok)
            if (self.eos_id is not None and tok == self.eos_id) \
                    or len(req.generated) >= req.max_tokens:
                req.done = True
                self.finished.append(req)

    def run_until_done(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None and not r.done
                                 for r in self.slots)):
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                raise RuntimeError("serving did not converge")
        return ticks
