"""Continuous-batching BFS query service over one resident graph.

The graph analogue of `serve.engine.ServeEngine`: a request pool, a
fixed query batch with slot reuse (a finished query's slot is refilled
from the queue on the next tick — "continuous batching"), and a batch
shape that never changes so the jitted tick compiles exactly once.

One tick == one BFS layer for EVERY active slot, via the plan layer's
single-layer executable (`repro.bfs.plan(...).layer_step`, leading
root axis).  Since ISSUE 3 the ``algorithm="simd"`` tick routes
through the fused gather pipeline: each slot's frontier plans its own
active-tile work-list, so slots whose frontier has emptied flow
through as true no-ops — their work-list is empty (n_active == 0),
costing zero DMA tiles instead of a full sentinel edge stream — until
the host harvests the parent array and refills the slot.  The
per-tick host sync (a (B,) frontier-count readback) is the serving
tick boundary, exactly like ServeEngine's per-token logits readback;
whole-query throughput without any tick sync is what a root-batched
`CompiledTraversal.run_batched` provides.

**Preprocess-on-load** (the formats scenario axis): the engine picks
a graph layout per resident graph at construction —
``graph_format="auto"`` runs the `formats.autotune` decision on the
graph's degree statistics; any registered name forces that layout.
Since ISSUE 5 the remaining configuration is ONE `TraversalSpec`
(``spec=``): the engine stores a `CompiledTraversal` instead of six
loose attributes, and the tick hits that plan's cached executable.
"""
from __future__ import annotations

import collections
import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import engine
from repro.obs import metrics as obs_metrics


@functools.partial(jax.jit, static_argnames=("slot", "n_vertices"))
def _reset_slot(frontier, visited, parent, base_visited, root, *,
                slot: int, n_vertices: int):
    """Re-arm one batch slot for a fresh root (masked row updates).

    Module-level so the jit cache survives across GraphEngine
    instances (compiles once per (batch shape, slot))."""
    f_row, vis_row, p_row = engine.init_root_state(root, base_visited,
                                                   n_vertices)
    return (frontier.at[slot].set(f_row),
            visited.at[slot].set(vis_row),
            parent.at[slot].set(p_row))




@dataclass
class BfsQuery:
    uid: int
    root: int
    parent: np.ndarray | None = None   # Graph500 convention (-1 unreached)
    n_layers: int = 0
    done: bool = False
    truncated: bool = False            # hit the max_layers budget: the
    #                                    parent array is PARTIAL (-1 may
    #                                    mean "not reached yet")
    meta: dict = field(default_factory=dict)


class GraphEngine:
    """Serve many concurrent BFS queries against one device-resident
    graph.

    Args:
      graph: the resident graph — a `Csr` or an already-built
        `formats.GraphFormat` (stays on device for the engine's
        lifetime).
      batch_slots: fixed query-batch width (compiled once).
      graph_format: layout for the tick — "auto" (autotune from graph
        statistics, the default), any registered format name, or None
        to wrap a Csr as-is.  A passed-in built format is kept under
        "auto"/None (the caller already chose); forcing a *different*
        name re-lays it out when the format can recover its CSR
        (`to_csr`) and raises a TypeError otherwise.
      spec: a `repro.bfs.TraversalSpec` — the ONE configuration object
        for the tick (algorithm, pipeline, packed, prefetch_depth,
        tile) and the per-query layer budget (``max_layers``; "auto"
        = 64).  Resolved once at construction; the engine stores the
        resulting `CompiledTraversal` (``self.compiled``), whose
        cached executable every tick hits.
      algorithm/max_layers/pipeline/packed/prefetch_depth: deprecated
        loose-knob form of the same fields (kept for compatibility;
        emits DeprecationWarning).
      registry: a `repro.obs.MetricsRegistry` to record serving
        metrics into (default: the process registry,
        `repro.obs.get_registry()`).  Recorded under ``serve.*``:
        per-query submit→harvest latency (``serve.query_latency_s``
        histogram — p50/p99 in its snapshot), tick duration
        (``serve.tick_s``), queue depth / slot occupancy gauges, and
        tick/query/skip counters.
    """

    def __init__(self, graph, batch_slots: int = 8,
                 algorithm=engine._UNSET, max_layers=engine._UNSET,
                 graph_format: str | None = "auto",
                 pipeline=engine._UNSET, packed=engine._UNSET,
                 prefetch_depth=engine._UNSET, spec=None,
                 registry: obs_metrics.MetricsRegistry | None = None):
        from repro.api.plan import plan as _plan
        from repro.formats import GraphFormat, autotune
        if isinstance(graph, GraphFormat):
            self.csr = None
            self.fmt = (graph if graph_format in (None, "auto",
                                                  graph.name)
                        else autotune.build(graph, graph_format))
        else:
            self.csr = graph
            self.fmt = autotune.build(graph, graph_format or "csr")
        # the tick never evaluates a direction policy; "auto" and the
        # neutral TopDown (object or registered name — what
        # make_spec/legacy knobs pin) pass silently, anything else
        # was a real configuration intent
        if spec is not None \
                and spec.policy not in ("auto", "topdown") \
                and spec.policy != engine.TopDown():
            import warnings
            warnings.warn(
                "GraphEngine: the serve tick is policy-free (one "
                "layer per tick; scalar vs SIMD comes from "
                "spec.algorithm) — spec.policy is ignored",
                UserWarning, stacklevel=2)
        spec = engine._spec_from_knobs(
            "GraphEngine", spec,
            dict(algorithm=algorithm, max_layers=max_layers,
                 pipeline=pipeline, packed=packed,
                 prefetch_depth=prefetch_depth))
        if spec.policy == "auto":
            # pin a concrete policy the tick never reads: skips the
            # autotune measurement and keeps .resolved honest about
            # the direction machinery not running here
            spec = spec.replace(policy="topdown")
        self.compiled = _plan(self.fmt, spec)
        b = batch_slots
        self.n_vertices = self.fmt.n_vertices
        v_pad = self.fmt.n_vertices_padded
        w = v_pad // bm.BITS_PER_WORD
        self.frontier = jnp.zeros((b, w), jnp.uint32)
        self.visited = jnp.zeros((b, w), jnp.uint32)
        self.parent = jnp.full((b, v_pad), self.n_vertices, jnp.int32)
        self._base_visited = self.fmt.init_visited()
        self.slots: list[BfsQuery | None] = [None] * b
        # deque: continuous batching pops from the head every tick —
        # list.pop(0) is O(queue) per slot fill, O(n^2) over a long
        # serving run
        self.queue: collections.deque[BfsQuery] = collections.deque()
        self.finished: list[BfsQuery] = []
        # serving metrics (ISSUE 7): the operational distributions the
        # ROADMAP serve-SLO work will budget against
        self.metrics = (registry if registry is not None
                        else obs_metrics.get_registry())
        self._m_latency = self.metrics.histogram(
            "serve.query_latency_s",
            "submit->harvest wall seconds per query")
        self._m_tick = self.metrics.histogram(
            "serve.tick_s", "wall seconds per engine tick")
        self._m_queue = self.metrics.gauge(
            "serve.queue_depth", "queries waiting for a slot")
        self._m_occupancy = self.metrics.gauge(
            "serve.slot_occupancy", "active slots / batch_slots")
        self._m_ticks = self.metrics.counter(
            "serve.ticks", "engine ticks that dispatched a layer_step")
        self._m_skipped = self.metrics.counter(
            "serve.ticks_skipped",
            "ticks short-circuited with no active slot (no device "
            "dispatch)")
        self._m_submitted = self.metrics.counter(
            "serve.queries_submitted")
        self._m_finished = self.metrics.counter("serve.queries_finished")
        self._m_truncated = self.metrics.counter(
            "serve.queries_truncated",
            "queries harvested PARTIAL at the max_layers budget")

    # -- resolved-spec views (legacy attribute compatibility) -----------
    @property
    def resolved(self):
        """The fully-concrete `TraversalSpec` the tick runs."""
        return self.compiled.resolved

    @property
    def algorithm(self) -> str:
        return self.compiled.resolved.algorithm

    @property
    def pipeline(self) -> str:
        return self.compiled.resolved.pipeline

    @property
    def packed(self) -> bool:
        return self.compiled.resolved.packed

    @property
    def prefetch_depth(self) -> int:
        return self.compiled.resolved.prefetch_depth

    @property
    def max_layers(self) -> int:
        return self.compiled.resolved.max_layers

    def submit(self, query: BfsQuery):
        query.meta.setdefault("submit_t", time.perf_counter())
        self.queue.append(query)
        self._m_submitted.inc()
        self._m_queue.set(len(self.queue))

    def _fill_slots(self):
        for i, q in enumerate(self.slots):
            if (q is None or q.done) and self.queue:
                nxt = self.queue.popleft()
                self.slots[i] = nxt
                self.frontier, self.visited, self.parent = _reset_slot(
                    self.frontier, self.visited, self.parent,
                    self._base_visited, jnp.asarray(nxt.root, jnp.int32),
                    slot=i, n_vertices=self.n_vertices)
        self._m_queue.set(len(self.queue))

    def _active_slots(self) -> int:
        return sum(q is not None and not q.done for q in self.slots)

    def _harvest(self, i: int, q: BfsQuery, truncated: bool = False):
        p = np.asarray(self.parent[i, :self.n_vertices])
        q.parent = np.where(p >= self.n_vertices, -1, p)
        q.truncated = truncated
        q.done = True
        self.finished.append(q)
        self._m_finished.inc()
        if truncated:
            self._m_truncated.inc()
        t0 = q.meta.get("submit_t")
        if t0 is not None:
            q.meta["latency_s"] = time.perf_counter() - t0
            self._m_latency.observe(q.meta["latency_s"])

    def step(self):
        """One engine tick: advance every active query by one layer.

        When every slot is empty/done after the refill (drain tail,
        or ticking an idle engine) the device ``layer_step`` is NOT
        dispatched — the tick is a host no-op counted in
        ``serve.ticks_skipped``.  Before ISSUE 7 every such tick paid
        a full compiled step for zero active queries."""
        with self._m_tick.time():
            self._fill_slots()
            n_active = self._active_slots()
            self._m_occupancy.set(n_active / max(len(self.slots), 1))
            if n_active == 0:
                self._m_skipped.inc()
                return
            self._m_ticks.inc()
            self.frontier, self.visited, self.parent = \
                self.compiled.layer_step(self.frontier, self.visited,
                                         self.parent)
            counts = np.asarray(engine.row_popcounts(self.frontier))
            for i, q in enumerate(self.slots):
                if q is None or q.done:
                    continue
                q.n_layers += 1
                if counts[i] == 0:
                    self._harvest(i, q)
                elif q.n_layers >= self.max_layers:
                    self._harvest(i, q, truncated=True)

    def run_until_done(self, max_ticks: int = 100_000) -> int:
        """Drain the queue; returns the number of ticks taken."""
        ticks = 0
        while (self.queue or any(q is not None and not q.done
                                 for q in self.slots)):
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                slot_layers = {i: q.n_layers
                               for i, q in enumerate(self.slots)
                               if q is not None and not q.done}
                raise RuntimeError(
                    f"graph serving did not converge within "
                    f"{max_ticks} ticks: queue_depth="
                    f"{len(self.queue)}, active_slots="
                    f"{self._active_slots()}/{len(self.slots)}, "
                    f"per-slot n_layers={slot_layers}, "
                    f"max_layers={self.max_layers}")
        return ticks
