"""Continuous-batching BFS query service over one resident graph.

The graph analogue of `serve.engine.ServeEngine`: a request pool, a
fixed query batch with slot reuse (a finished query's slot is refilled
from the queue on the next tick — "continuous batching"), and a batch
shape that never changes so the jitted tick compiles exactly once.

One tick == one BFS layer for EVERY active slot, via the plan layer's
single-layer executable (`repro.bfs.plan(...).layer_step`, leading
root axis).  Since ISSUE 3 the ``algorithm="simd"`` tick routes
through the fused gather pipeline: each slot's frontier plans its own
active-tile work-list, so slots whose frontier has emptied flow
through as true no-ops — their work-list is empty (n_active == 0),
costing zero DMA tiles instead of a full sentinel edge stream — until
the host harvests the parent array and refills the slot.  The
per-tick host sync (a (B,) frontier-count readback) is the serving
tick boundary, exactly like ServeEngine's per-token logits readback;
whole-query throughput without any tick sync is what a root-batched
`CompiledTraversal.run_batched` provides.

**Preprocess-on-load** (the formats scenario axis): the engine picks
a graph layout per resident graph at construction —
``graph_format="auto"`` runs the `formats.autotune` decision on the
graph's degree statistics; any registered name forces that layout.
Since ISSUE 5 the remaining configuration is ONE `TraversalSpec`
(``spec=``): the engine stores a `CompiledTraversal` instead of six
loose attributes, and the tick hits that plan's cached executable.

**Robustness** (ISSUE 8): the queue is *bounded* — `submit` returns a
typed `serve.robust.AdmissionDecision` or raises
`repro.errors.QueueFullError` / `AdmissionRejected` (backpressure
instead of unbounded latency); queries carry optional wall-clock
deadlines (`repro.errors.DeadlineExceeded` attached to the truncated
result) and per-query layer budgets; a failed device tick retries
with capped exponential backoff and, on exhaustion, re-queues every
in-flight query before raising `TickRetriesExhausted` (zero lost
queries); every harvested result passes a sanity check (root
self-parented, ids in range) and a corrupted slot is re-run instead
of delivered; and the ``serve.circuit_state`` gauge exports the
healthy/degraded/shedding breaker position.  Chaos coverage drives a
`serve.robust.ServeFaultInjector` through all of it
(``make chaos-smoke``).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import engine
from repro.errors import (AdmissionRejected, DeadlineExceeded,
                          QueueFullError, TickRetriesExhausted)
from repro.obs import metrics as obs_metrics
from repro.serve import robust


@functools.partial(jax.jit, static_argnames=("slot", "n_vertices"))
def _reset_slot(frontier, visited, parent, base_visited, root, *,
                slot: int, n_vertices: int):
    """Re-arm one batch slot for a fresh root (masked row updates).

    Module-level so the jit cache survives across GraphEngine
    instances (compiles once per (batch shape, slot))."""
    f_row, vis_row, p_row = engine.init_root_state(root, base_visited,
                                                   n_vertices)
    return (frontier.at[slot].set(f_row),
            visited.at[slot].set(vis_row),
            parent.at[slot].set(p_row))




@dataclass
class BfsQuery:
    uid: int
    root: int
    parent: np.ndarray | None = None   # Graph500 convention (-1 unreached)
    n_layers: int = 0
    done: bool = False
    truncated: bool = False            # hit a budget (layers/deadline):
    #                                    the parent array is PARTIAL
    #                                    (-1 may mean "not reached
    #                                    yet") or None (never ran)
    priority: int = 0                  # admission order; shedding floor
    deadline_s: float | None = None    # wall-clock budget from submit
    max_layers: int | None = None      # per-query layer budget override
    #                                    (None = the engine spec's)
    error: Exception | None = None     # typed degradation record —
    #                                    DeadlineExceeded on budget
    #                                    expiry; None on clean finishes
    retries: int = 0                   # times this query was re-run
    #                                    (tick failure / poisoned slot)
    meta: dict = field(default_factory=dict)


class GraphEngine:
    """Serve many concurrent BFS queries against one device-resident
    graph.

    Args:
      graph: the resident graph — a `Csr` or an already-built
        `formats.GraphFormat` (stays on device for the engine's
        lifetime).
      batch_slots: fixed query-batch width (compiled once).
      graph_format: layout for the tick — "auto" (autotune from graph
        statistics, the default), any registered format name, or None
        to wrap a Csr as-is.  A passed-in built format is kept under
        "auto"/None (the caller already chose); forcing a *different*
        name re-lays it out when the format can recover its CSR
        (`to_csr`) and raises a TypeError otherwise.
      spec: a `repro.bfs.TraversalSpec` — the ONE configuration object
        for the tick (algorithm, pipeline, packed, prefetch_depth,
        tile) and the per-query layer budget (``max_layers``; "auto"
        = 64).  Resolved once at construction; the engine stores the
        resulting `CompiledTraversal` (``self.compiled``), whose
        cached executable every tick hits.
      algorithm/max_layers/pipeline/packed/prefetch_depth: deprecated
        loose-knob form of the same fields (kept for compatibility;
        emits DeprecationWarning).
      registry: a `repro.obs.MetricsRegistry` to record serving
        metrics into (default: the process registry,
        `repro.obs.get_registry()`).  Recorded under ``serve.*``:
        per-query submit→harvest latency (``serve.query_latency_s``
        histogram — p50/p99 in its snapshot), tick duration
        (``serve.tick_s``), queue depth / slot occupancy /
        circuit-state gauges, and tick/query/skip/reject/retry
        counters.
      queue_capacity: bounded submit-queue size (default
        ``16 * batch_slots``).  At capacity `submit` raises
        `QueueFullError` — explicit backpressure instead of unbounded
        queueing.  Ignored when ``admission`` is passed.
      admission: a full `serve.robust.AdmissionPolicy` (capacity,
        degraded depth, optional priority-shedding floor); overrides
        ``queue_capacity``.
      injector: a `serve.robust.ServeFaultInjector` — chaos-test hook
        firing failures/stalls/poisoned rows at configured ticks.
      max_tick_retries: device-tick retry budget (capped exponential
        backoff between attempts); on exhaustion every in-flight
        query is re-queued and `TickRetriesExhausted` raises.
      retry_backoff_s: backoff base for `serve.robust.backoff_s`.
    """

    def __init__(self, graph, batch_slots: int = 8,
                 algorithm=engine._UNSET, max_layers=engine._UNSET,
                 graph_format: str | None = "auto",
                 pipeline=engine._UNSET, packed=engine._UNSET,
                 prefetch_depth=engine._UNSET, spec=None,
                 registry: obs_metrics.MetricsRegistry | None = None,
                 queue_capacity: int | None = None,
                 admission: robust.AdmissionPolicy | None = None,
                 injector: robust.ServeFaultInjector | None = None,
                 max_tick_retries: int = 3,
                 retry_backoff_s: float = 0.01):
        from repro.api.plan import plan as _plan
        from repro.core.csr import Csr as _Csr, check_structure
        from repro.formats import GraphFormat, autotune
        # admission-time validation (ISSUE 8): a raw Csr is checked
        # BEFORE autotune re-lays it out — a malformed graph must be
        # a typed construction error, not a wrong resident layout
        if isinstance(graph, _Csr):
            check_structure(graph)
        if isinstance(graph, GraphFormat):
            self.csr = None
            self.fmt = (graph if graph_format in (None, "auto",
                                                  graph.name)
                        else autotune.build(graph, graph_format))
        else:
            self.csr = graph
            self.fmt = autotune.build(graph, graph_format or "csr")
        # the tick never evaluates a direction policy; "auto" and the
        # neutral TopDown (object or registered name — what
        # make_spec/legacy knobs pin) pass silently, anything else
        # was a real configuration intent
        if spec is not None \
                and spec.policy not in ("auto", "topdown") \
                and spec.policy != engine.TopDown():
            import warnings
            warnings.warn(
                "GraphEngine: the serve tick is policy-free (one "
                "layer per tick; scalar vs SIMD comes from "
                "spec.algorithm) — spec.policy is ignored",
                UserWarning, stacklevel=2)
        spec = engine._spec_from_knobs(
            "GraphEngine", spec,
            dict(algorithm=algorithm, max_layers=max_layers,
                 pipeline=pipeline, packed=packed,
                 prefetch_depth=prefetch_depth))
        if spec.policy == "auto":
            # pin a concrete policy the tick never reads: skips the
            # autotune measurement and keeps .resolved honest about
            # the direction machinery not running here
            spec = spec.replace(policy="topdown")
        if spec.is_semiring:
            # the tick contract is one BFS layer per slot; the
            # portfolio driver owns its own value/frontier carry and
            # has no single-layer tick — route those queries through
            # the dedicated methods instead of the resident spec
            raise ValueError(
                f"GraphEngine's tick spec cannot use the semiring "
                f"algorithm {spec.algorithm!r}: the slot machinery "
                f"advances one BFS layer per tick — use "
                f"shortest_paths()/components()/ksource_depths() "
                f"(run-direct portfolio queries), and keep spec."
                f"algorithm a scalar value or 'auto'")
        self.compiled = _plan(self.fmt, spec)
        b = batch_slots
        self.n_vertices = self.fmt.n_vertices
        v_pad = self.fmt.n_vertices_padded
        w = v_pad // bm.BITS_PER_WORD
        self.frontier = jnp.zeros((b, w), jnp.uint32)
        self.visited = jnp.zeros((b, w), jnp.uint32)
        self.parent = jnp.full((b, v_pad), self.n_vertices, jnp.int32)
        self._base_visited = self.fmt.init_visited()
        self.slots: list[BfsQuery | None] = [None] * b
        # bounded priority queue (ISSUE 8): higher priority first,
        # FIFO within a level; at capacity `submit` rejects with a
        # typed error instead of queueing unboundedly
        if admission is None:
            cap = (int(queue_capacity) if queue_capacity is not None
                   else 16 * b)
            admission = robust.AdmissionPolicy(
                queue_capacity=cap, degraded_depth=max(1, cap // 2))
        self.admission = admission
        self.queue = robust.AdmissionQueue(admission.queue_capacity)
        self.injector = injector
        self.max_tick_retries = int(max_tick_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self._tick_no = 0
        self.finished: list[BfsQuery] = []
        # serving metrics (ISSUE 7): the operational distributions the
        # ROADMAP serve-SLO work will budget against
        self.metrics = (registry if registry is not None
                        else obs_metrics.get_registry())
        self._m_latency = self.metrics.histogram(
            "serve.query_latency_s",
            "submit->harvest wall seconds per query")
        self._m_tick = self.metrics.histogram(
            "serve.tick_s", "wall seconds per engine tick")
        self._m_queue = self.metrics.gauge(
            "serve.queue_depth", "queries waiting for a slot")
        self._m_occupancy = self.metrics.gauge(
            "serve.slot_occupancy", "active slots / batch_slots")
        self._m_ticks = self.metrics.counter(
            "serve.ticks", "engine ticks that dispatched a layer_step")
        self._m_skipped = self.metrics.counter(
            "serve.ticks_skipped",
            "ticks short-circuited with no active slot (no device "
            "dispatch)")
        self._m_submitted = self.metrics.counter(
            "serve.queries_submitted")
        self._m_finished = self.metrics.counter("serve.queries_finished")
        self._m_truncated = self.metrics.counter(
            "serve.queries_truncated",
            "queries harvested PARTIAL at a layers/deadline budget")
        # robustness counters (ISSUE 8)
        self._m_rejected = self.metrics.counter(
            "serve.rejected",
            "submits refused by admission control (queue full / "
            "priority shed)")
        self._m_retries = self.metrics.counter(
            "serve.retries", "failed device-tick attempts retried")
        self._m_requeued = self.metrics.counter(
            "serve.requeued",
            "in-flight queries re-queued after tick failure or a "
            "corrupted slot")
        self._m_poisoned = self.metrics.counter(
            "serve.poisoned",
            "corrupted slot results caught by the harvest sanity "
            "check (re-run, never delivered)")
        self._m_deadline = self.metrics.counter(
            "serve.deadline_exceeded",
            "queries harvested with a DeadlineExceeded error")
        self._m_circuit = self.metrics.gauge(
            "serve.circuit_state",
            "admission circuit: 0=healthy 1=degraded 2=shedding")
        # algorithm-portfolio counters (ISSUE 10)
        self._m_portfolio = self.metrics.counter(
            "serve.portfolio_queries",
            "semiring portfolio queries (shortest_paths/components/"
            "ksource_depths) answered run-direct")
        self._semiring_plans: dict[str, object] = {}

    # -- resolved-spec views (legacy attribute compatibility) -----------
    @property
    def resolved(self):
        """The fully-concrete `TraversalSpec` the tick runs."""
        return self.compiled.resolved

    @property
    def algorithm(self) -> str:
        return self.compiled.resolved.algorithm

    @property
    def pipeline(self) -> str:
        return self.compiled.resolved.pipeline

    @property
    def packed(self) -> bool:
        return self.compiled.resolved.packed

    @property
    def prefetch_depth(self) -> int:
        return self.compiled.resolved.prefetch_depth

    @property
    def max_layers(self) -> int:
        return self.compiled.resolved.max_layers

    # -- admission (ISSUE 8) --------------------------------------------
    def circuit_state(self) -> str:
        """Current breaker position (`serve.robust.CIRCUIT_*`)."""
        depth = len(self.queue)
        if self.queue.full:
            return robust.CIRCUIT_SHEDDING
        if (self._active_slots() == len(self.slots)
                and depth >= self.admission.degraded_depth):
            return robust.CIRCUIT_DEGRADED
        return robust.CIRCUIT_HEALTHY

    def _set_circuit_gauge(self, state: str | None = None) -> str:
        state = state if state is not None else self.circuit_state()
        self._m_circuit.set(robust.CIRCUIT_CODES[state])
        return state

    def try_submit(self, query: BfsQuery) -> robust.AdmissionDecision:
        """Admission decision without raising: validates the root
        (typed `GraphValidationError` — malformed input is a client
        bug, not backpressure), then admits or rejects per the
        circuit."""
        from repro.api.plan import check_roots
        check_roots(query.root, self.n_vertices)
        state = self._set_circuit_gauge()
        depth = len(self.queue)
        if state == robust.CIRCUIT_SHEDDING:
            self._m_rejected.inc()
            return robust.AdmissionDecision(
                admitted=False, circuit=state, queue_depth=depth,
                reason=(f"queue at capacity "
                        f"({depth}/{self.queue.capacity})"))
        floor = self.admission.shed_min_priority
        if (state == robust.CIRCUIT_DEGRADED and floor is not None
                and query.priority < floor):
            self._m_rejected.inc()
            return robust.AdmissionDecision(
                admitted=False, circuit=state, queue_depth=depth,
                reason=(f"load shedding: priority {query.priority} < "
                        f"floor {floor} while degraded"))
        query.meta.setdefault("submit_t", time.perf_counter())
        self.queue.push(query, query.priority)
        self._m_submitted.inc()
        self._m_queue.set(len(self.queue))
        self._set_circuit_gauge()
        return robust.AdmissionDecision(
            admitted=True, circuit=state, queue_depth=len(self.queue))

    def submit(self, query: BfsQuery) -> robust.AdmissionDecision:
        """Admit ``query`` or raise the typed rejection
        (`QueueFullError` at capacity, `AdmissionRejected` when
        priority-shed); returns the `AdmissionDecision` on admit."""
        decision = self.try_submit(query)
        if not decision.admitted:
            cls = (QueueFullError
                   if decision.circuit == robust.CIRCUIT_SHEDDING
                   else AdmissionRejected)
            raise cls(f"query uid={query.uid} rejected: "
                      f"{decision.reason}", decision=decision)
        return decision

    def _expire_queued(self) -> None:
        """Harvest queued queries whose deadline passed before they
        ever got a slot (parent=None — they never ran)."""
        now = time.perf_counter()

        def expired(q):
            return (q.deadline_s is not None
                    and now - q.meta.get("submit_t", now) > q.deadline_s)

        for q in self.queue.remove_if(expired):
            elapsed = now - q.meta.get("submit_t", now)
            q.error = DeadlineExceeded(
                f"query uid={q.uid} expired after {elapsed:.3f}s in "
                f"the queue (deadline_s={q.deadline_s}) without ever "
                f"getting a slot", uid=q.uid, elapsed_s=elapsed,
                budget_s=q.deadline_s, where="queued")
            q.parent = None
            q.truncated = True
            q.done = True
            self.finished.append(q)
            self._m_finished.inc()
            self._m_truncated.inc()
            self._m_deadline.inc()
        self._m_queue.set(len(self.queue))

    def _fill_slots(self):
        for i, q in enumerate(self.slots):
            if (q is None or q.done) and self.queue:
                nxt = self.queue.pop()
                self.slots[i] = nxt
                self.frontier, self.visited, self.parent = _reset_slot(
                    self.frontier, self.visited, self.parent,
                    self._base_visited, jnp.asarray(nxt.root, jnp.int32),
                    slot=i, n_vertices=self.n_vertices)
        self._m_queue.set(len(self.queue))

    def _active_slots(self) -> int:
        return sum(q is not None and not q.done for q in self.slots)

    # -- result integrity / recovery (ISSUE 8) --------------------------
    def _result_ok(self, i: int, q: BfsQuery) -> bool:
        """Harvest-time sanity check: the root must be self-parented
        and every entry a legal id (device convention: unreached ==
        sentinel ``n_vertices``).  A violation means the slot's state
        was corrupted (e.g. an injected poisoned result) — the query
        is re-run, never delivered."""
        p = np.asarray(self.parent[i, :self.n_vertices])
        if int(p[q.root]) != q.root:
            return False
        return bool(((p >= 0) & (p <= self.n_vertices)).all())

    def _requeue(self, i: int, q: BfsQuery) -> None:
        """Re-run ``q`` from its root: reset its progress and force it
        back onto the queue (past capacity if need be — the engine's
        own recovery must never lose a query to its own
        backpressure)."""
        q.n_layers = 0
        q.done = False
        q.truncated = False
        q.parent = None
        q.retries += 1
        self.slots[i] = None
        self.queue.push(q, q.priority, force=True)
        self._m_requeued.inc()
        self._m_queue.set(len(self.queue))

    def _requeue_in_flight(self) -> None:
        for i, q in enumerate(self.slots):
            if q is not None and not q.done:
                self._requeue(i, q)

    def _dispatch_with_retry(self, tick_no: int) -> None:
        """Run the device tick, retrying with capped exponential
        backoff.  `CompiledTraversal.layer_step` is functional (new
        arrays out; assignment only on success), so a failed attempt
        cannot corrupt slot state.  On exhaustion every in-flight
        query is re-queued (restart from root) and
        `TickRetriesExhausted` raises — a loud infrastructure error
        with zero lost queries."""
        last: Exception | None = None
        for attempt in range(self.max_tick_retries + 1):
            try:
                if self.injector is not None:
                    stall = self.injector.stall_s(tick_no)
                    if stall > 0:
                        time.sleep(stall)
                    self.injector.check_tick(tick_no)
                self.frontier, self.visited, self.parent = \
                    self.compiled.layer_step(
                        self.frontier, self.visited, self.parent)
                return
            except Exception as exc:    # noqa: BLE001 — retry any
                last = exc              # device-step failure flavour
                self._m_retries.inc()
                if attempt < self.max_tick_retries:
                    time.sleep(robust.backoff_s(
                        attempt, self.retry_backoff_s))
        self._requeue_in_flight()
        raise TickRetriesExhausted(
            f"serve tick {tick_no} failed {self.max_tick_retries + 1} "
            f"times; {self._m_requeued.value:g} in-flight queries "
            f"re-queued (none lost) — last error: {last!r}") from last

    def _harvest(self, i: int, q: BfsQuery, truncated: bool = False,
                 error: Exception | None = None,
                 check: bool = True) -> bool:
        """Deliver slot ``i``'s result; returns False when the sanity
        check caught a corrupted slot (the query was re-queued
        instead)."""
        if check and not self._result_ok(i, q):
            self._m_poisoned.inc()
            self._requeue(i, q)
            return False
        p = np.asarray(self.parent[i, :self.n_vertices])
        q.parent = np.where(p >= self.n_vertices, -1, p)
        q.truncated = truncated
        q.error = error
        q.done = True
        self.finished.append(q)
        self._m_finished.inc()
        if truncated:
            self._m_truncated.inc()
        if isinstance(error, DeadlineExceeded):
            self._m_deadline.inc()
        t0 = q.meta.get("submit_t")
        if t0 is not None:
            q.meta["latency_s"] = time.perf_counter() - t0
            self._m_latency.observe(q.meta["latency_s"])
        return True

    def run_direct(self, roots) -> engine.EngineResult:
        """Whole-traversal fast path: run root(s) to completion
        through the plan's compiled program, bypassing the per-tick
        slot machinery (no per-layer host sync, no admission queue).
        Under ``spec.pipeline="persistent"`` (ISSUE 9) the batch is
        ONE Pallas launch — layer loop, direction decision and
        termination in-kernel.  The tick path (`step`) keeps the
        per-layer steps regardless of pipeline: a tick is by
        definition one layer, so ``"persistent"`` ticks run the
        whole-layer megakernel steps instead."""
        return self.compiled.run(roots)

    # -- algorithm portfolio queries (ISSUE 10) -------------------------
    def _semiring_plan(self, algorithm: str):
        """One lazily-built portfolio plan per algorithm, cached on
        the engine; the executable itself is shared process-wide
        through the plan cache (keyed by geometry + resolved spec),
        so many engines over one graph trace each algorithm once."""
        ct = self._semiring_plans.get(algorithm)
        if ct is None:
            from repro.api.plan import plan as _plan
            from repro.api.spec import TraversalSpec
            # a deep bucket/propagation chain (SSSP on a path graph
            # walks one delta bucket per iteration) needs more
            # iterations than a BFS diameter bound; the while_loop
            # exits early, so the generous ceiling costs nothing
            spec = TraversalSpec(
                algorithm=algorithm, policy="topdown",
                max_layers=max(512, self.max_layers))
            ct = self._semiring_plans[algorithm] = _plan(self.fmt,
                                                         spec)
        return ct

    def shortest_paths(self, roots):
        """Single-source shortest paths (min-plus semiring, the
        synthetic symmetric-hash edge weights in [1, 2)) from one
        root (int) or a root batch.  Returns ``(distances, parent)``
        host arrays over the real vertices: ``distances`` float32
        with ``inf`` for unreached vertices, ``parent`` int32 with
        ``-1`` for unreached (the root is its own parent)."""
        ct = self._semiring_plan("sssp")
        res = ct.run(roots)
        self._m_portfolio.inc()
        dist = np.asarray(res.values)[..., :self.n_vertices]
        p = np.asarray(res.state.parent)[..., :self.n_vertices]
        return dist, np.where(np.isfinite(dist), p, -1)

    def components(self):
        """Connected-component labels (min-label propagation run to
        fixpoint).  Returns ``(labels, n_components)``: ``labels`` is
        an int32 host array mapping every real vertex to the smallest
        vertex id in its component."""
        ct = self._semiring_plan("cc")
        res = ct.run(0)       # root is irrelevant: every vertex seeds
        self._m_portfolio.inc()
        labels = np.asarray(res.values)[:self.n_vertices]
        return labels, int(np.unique(labels).size)

    def ksource_depths(self, roots):
        """Batched k-source BFS: one traversal, one depth row per
        root.  Returns the (k, n_vertices) int32 per-source depth
        matrix with ``-1`` for unreached vertices."""
        from repro.algorithms.semiring import INT_INF
        ct = self._semiring_plan("ksource_bfs")
        roots = np.atleast_1d(np.asarray(roots, np.int32))
        res = ct.run_batched(roots)
        self._m_portfolio.inc()
        depths = np.asarray(res.values)[:, :self.n_vertices]
        return np.where(depths >= INT_INF, -1, depths)

    def step(self):
        """One engine tick: advance every active query by one layer.

        When every slot is empty/done after the refill (drain tail,
        or ticking an idle engine) the device ``layer_step`` is NOT
        dispatched — the tick is a host no-op counted in
        ``serve.ticks_skipped``.  Before ISSUE 7 every such tick paid
        a full compiled step for zero active queries."""
        with self._m_tick.time():
            self._expire_queued()
            self._fill_slots()
            n_active = self._active_slots()
            self._m_occupancy.set(n_active / max(len(self.slots), 1))
            self._set_circuit_gauge()
            if n_active == 0:
                self._m_skipped.inc()
                return
            self._m_ticks.inc()
            tick_no = self._tick_no
            self._tick_no += 1
            self._dispatch_with_retry(tick_no)
            if self.injector is not None:
                for s in self.injector.poison_slots(tick_no):
                    if 0 <= s < len(self.slots) \
                            and self.slots[s] is not None \
                            and not self.slots[s].done:
                        # corrupt the slot's parent row the way a bad
                        # device step would: every entry off-by-one,
                        # so parent[root] != root
                        v_pad = self.parent.shape[1]
                        self.parent = self.parent.at[s].set(
                            (jnp.arange(v_pad, dtype=jnp.int32) + 1)
                            % self.n_vertices)
            counts = np.asarray(engine.row_popcounts(self.frontier))
            now = time.perf_counter()
            for i, q in enumerate(self.slots):
                if q is None or q.done:
                    continue
                q.n_layers += 1
                budget = (q.max_layers if q.max_layers is not None
                          else self.max_layers)
                elapsed = now - q.meta.get("submit_t", now)
                if counts[i] == 0:
                    self._harvest(i, q)
                elif q.deadline_s is not None \
                        and elapsed > q.deadline_s:
                    self._harvest(
                        i, q, truncated=True,
                        error=DeadlineExceeded(
                            f"query uid={q.uid} exceeded its "
                            f"deadline_s={q.deadline_s} after "
                            f"{elapsed:.3f}s / {q.n_layers} layers "
                            f"(partial tree delivered)",
                            uid=q.uid, elapsed_s=elapsed,
                            budget_s=q.deadline_s, where="in_flight"))
                elif q.n_layers >= budget:
                    self._harvest(i, q, truncated=True)

    def _harvest_global_budget(self, budget_s: float,
                               elapsed: float) -> None:
        """`run_until_done` budget expiry: deliver every in-flight
        query as a truncated partial (sanity check still applies) and
        every queued query as never-ran — nothing is lost, everything
        is typed."""
        for i, q in enumerate(self.slots):
            if q is not None and not q.done:
                self._harvest(
                    i, q, truncated=True,
                    error=DeadlineExceeded(
                        f"run_until_done budget_s={budget_s} expired "
                        f"after {elapsed:.3f}s with query uid={q.uid} "
                        f"in flight ({q.n_layers} layers done)",
                        uid=q.uid, elapsed_s=elapsed,
                        budget_s=budget_s, where="global"),
                    check=False)
        while self.queue:
            q = self.queue.pop()
            q.error = DeadlineExceeded(
                f"run_until_done budget_s={budget_s} expired after "
                f"{elapsed:.3f}s with query uid={q.uid} still queued",
                uid=q.uid, elapsed_s=elapsed, budget_s=budget_s,
                where="global")
            q.parent = None
            q.truncated = True
            q.done = True
            self.finished.append(q)
            self._m_finished.inc()
            self._m_truncated.inc()
            self._m_deadline.inc()
        self._m_queue.set(0)

    def run_until_done(self, max_ticks: int = 100_000,
                       budget_s: float | None = None) -> int:
        """Drain the queue; returns the number of ticks taken.

        ``budget_s`` is the global wall-clock budget: when it expires,
        in-flight queries are delivered as truncated partials and
        queued ones as never-ran, each carrying a
        `DeadlineExceeded(where="global")` — graceful degradation
        instead of an open-ended run."""
        ticks = 0
        t0 = time.perf_counter()
        while (self.queue or any(q is not None and not q.done
                                 for q in self.slots)):
            elapsed = time.perf_counter() - t0
            if budget_s is not None and elapsed > budget_s:
                self._harvest_global_budget(budget_s, elapsed)
                break
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                now = time.perf_counter()
                slot_report = {}
                for i, q in enumerate(self.slots):
                    if q is None or q.done:
                        continue
                    left = (None if q.deadline_s is None else round(
                        q.deadline_s
                        - (now - q.meta.get("submit_t", now)), 3))
                    slot_report[i] = {
                        "n_layers": q.n_layers,
                        "deadline_remaining_s": left,
                        "retries": q.retries,
                    }
                raise RuntimeError(
                    f"graph serving did not converge within "
                    f"{max_ticks} ticks: queue_depth="
                    f"{len(self.queue)}, active_slots="
                    f"{self._active_slots()}/{len(self.slots)}, "
                    f"per-slot state={slot_report}, "
                    f"max_layers={self.max_layers}, "
                    f"circuit={self.circuit_state()}")
        return ticks
