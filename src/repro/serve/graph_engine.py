"""Continuous-batching BFS query service over one resident graph.

The graph analogue of `serve.engine.ServeEngine`: a request pool, a
fixed query batch with slot reuse (a finished query's slot is refilled
from the queue on the next tick — "continuous batching"), and a batch
shape that never changes so the jitted tick compiles exactly once.

One tick == one BFS layer for EVERY active slot, via the engine's
batched format-generic `layer_step_format` (leading root axis).
Since ISSUE 3 the ``algorithm="simd"`` tick routes through the fused
gather pipeline: each slot's frontier plans its own active-tile
work-list, so slots whose frontier has emptied flow through as true
no-ops — their work-list is empty (n_active == 0), costing zero DMA
tiles instead of a full sentinel edge stream — until the host
harvests the parent array and refills the slot.  The per-tick host sync (a (B,) frontier-count
readback) is the serving tick boundary, exactly like ServeEngine's
per-token logits readback; whole-query throughput without any tick
sync is what `engine.traverse` with a root batch provides.

**Preprocess-on-load** (the formats scenario axis): the engine picks
a graph layout per resident graph at construction —
``graph_format="auto"`` runs the `formats.autotune` decision on the
graph's degree statistics; any registered name forces that layout.
The jitted tick then runs on the chosen format's step.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import engine
from repro.core.csr import Csr


@functools.partial(jax.jit, static_argnames=("slot", "n_vertices"))
def _reset_slot(frontier, visited, parent, base_visited, root, *,
                slot: int, n_vertices: int):
    """Re-arm one batch slot for a fresh root (masked row updates).

    Module-level so the jit cache survives across GraphEngine
    instances (compiles once per (batch shape, slot))."""
    f_row, vis_row, p_row = engine.init_root_state(root, base_visited,
                                                   n_vertices)
    return (frontier.at[slot].set(f_row),
            visited.at[slot].set(vis_row),
            parent.at[slot].set(p_row))




@dataclass
class BfsQuery:
    uid: int
    root: int
    parent: np.ndarray | None = None   # Graph500 convention (-1 unreached)
    n_layers: int = 0
    done: bool = False
    truncated: bool = False            # hit the max_layers budget: the
    #                                    parent array is PARTIAL (-1 may
    #                                    mean "not reached yet")
    meta: dict = field(default_factory=dict)


class GraphEngine:
    """Serve many concurrent BFS queries against one device-resident
    graph.

    Args:
      graph: the resident graph — a `Csr` or an already-built
        `formats.GraphFormat` (stays on device for the engine's
        lifetime).
      batch_slots: fixed query-batch width (compiled once).
      algorithm: scalar expander flavour for the layer step.
      max_layers: per-query layer budget (safety valve).
      graph_format: layout for the tick — "auto" (autotune from graph
        statistics, the default), any registered format name, or None
        to wrap a Csr as-is.  A passed-in built format is kept under
        "auto"/None (the caller already chose); forcing a *different*
        name re-lays it out when the format can recover its CSR
        (`to_csr`) and raises a TypeError otherwise.
      pipeline: expansion pipeline for the tick — "fused_gather"
        (default: per-slot active-tile work-lists, drained slots cost
        nothing) or "materialized" (legacy full edge stream).
      packed: keep the tick's planning/compaction on packed uint32
        words (the ISSUE 4 native representation; False = the legacy
        dense-mask arm, kept for parity measurement).
      prefetch_depth: input-DMA tiles kept in flight ahead of compute
        inside the expansion kernels (0 = automatic BlockSpec double
        buffering).
    """

    def __init__(self, graph, batch_slots: int = 8,
                 algorithm: str = "simd", max_layers: int = 64,
                 graph_format: str | None = "auto",
                 pipeline: str = "fused_gather", packed: bool = True,
                 prefetch_depth: int = 0):
        from repro.formats import GraphFormat, autotune
        if isinstance(graph, GraphFormat):
            self.csr = None
            self.fmt = (graph if graph_format in (None, "auto",
                                                  graph.name)
                        else autotune.build(graph, graph_format))
        else:
            self.csr = graph
            self.fmt = autotune.build(graph, graph_format or "csr")
        engine.check_pipeline(pipeline)
        self.max_layers = max_layers
        self.algorithm = algorithm
        self.pipeline = pipeline
        self.packed = packed
        self.prefetch_depth = prefetch_depth
        b = batch_slots
        self.n_vertices = self.fmt.n_vertices
        v_pad = self.fmt.n_vertices_padded
        w = v_pad // bm.BITS_PER_WORD
        self.frontier = jnp.zeros((b, w), jnp.uint32)
        self.visited = jnp.zeros((b, w), jnp.uint32)
        self.parent = jnp.full((b, v_pad), self.n_vertices, jnp.int32)
        self._base_visited = self.fmt.init_visited()
        self.slots: list[BfsQuery | None] = [None] * b
        self.queue: list[BfsQuery] = []
        self.finished: list[BfsQuery] = []

    def submit(self, query: BfsQuery):
        self.queue.append(query)

    def _fill_slots(self):
        for i, q in enumerate(self.slots):
            if (q is None or q.done) and self.queue:
                nxt = self.queue.pop(0)
                self.slots[i] = nxt
                self.frontier, self.visited, self.parent = _reset_slot(
                    self.frontier, self.visited, self.parent,
                    self._base_visited, jnp.asarray(nxt.root, jnp.int32),
                    slot=i, n_vertices=self.n_vertices)

    def _harvest(self, i: int, q: BfsQuery, truncated: bool = False):
        p = np.asarray(self.parent[i, :self.n_vertices])
        q.parent = np.where(p >= self.n_vertices, -1, p)
        q.truncated = truncated
        q.done = True
        self.finished.append(q)

    def step(self):
        """One engine tick: advance every active query by one layer."""
        self._fill_slots()
        self.frontier, self.visited, self.parent = \
            engine.layer_step_format(
                self.fmt, self.frontier, self.visited, self.parent,
                algorithm=self.algorithm, pipeline=self.pipeline,
                packed=self.packed,
                prefetch_depth=self.prefetch_depth)
        counts = np.asarray(engine.row_popcounts(self.frontier))
        for i, q in enumerate(self.slots):
            if q is None or q.done:
                continue
            q.n_layers += 1
            if counts[i] == 0:
                self._harvest(i, q)
            elif q.n_layers >= self.max_layers:
                self._harvest(i, q, truncated=True)

    def run_until_done(self, max_ticks: int = 100_000) -> int:
        """Drain the queue; returns the number of ticks taken."""
        ticks = 0
        while (self.queue or any(q is not None and not q.done
                                 for q in self.slots)):
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                raise RuntimeError("graph serving did not converge")
        return ticks
