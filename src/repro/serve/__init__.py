"""Substrate: serve."""
