"""Substrate: serve.

  engine        continuous-batching LM decode engine
  graph_engine  continuous-batching BFS query service (same design)
"""
