"""Serve-tier robustness primitives — admission control, deadlines,
fault injection (ISSUE 8).

The ROADMAP's north star is Graph500-shaped work served "to millions
of users", and a serving engine that melts down under overload — or
silently delivers corrupted trees when a device step fails — is not a
serving engine.  This module holds the pieces `GraphEngine` composes:

* `AdmissionPolicy` / `AdmissionDecision` / `AdmissionQueue` — a
  *bounded* priority queue with an explicit admit/reject decision at
  ``submit`` time.  Backpressure beats buffering: an unbounded queue
  converts overload into unbounded latency (every queued query's
  deadline silently dies), a silently-dropping ``deque(maxlen=...)``
  converts it into lost queries.  The bounded queue rejects loudly
  (`repro.errors.QueueFullError`) so the *client* decides.
* circuit state — the three-position breaker the
  ``serve.circuit_state`` gauge exports: `CIRCUIT_HEALTHY` (slots
  free or queue shallow), `CIRCUIT_DEGRADED` (every slot busy and the
  queue past ``degraded_depth`` — optional priority shedding kicks
  in), `CIRCUIT_SHEDDING` (queue at capacity — every submit
  rejected).
* `ServeFaultInjector` — the serve-path sibling of
  `repro.runtime.fault.FailureInjector`: deterministic, fire-once
  faults at configured *ticks* instead of pipeline steps.  Three
  flavours, matching how devices actually fail: the step raises
  (``fail_ticks``), the step stalls (``slow_ticks``/``slow_s``), the
  step returns garbage (``poison`` — (tick, slot) pairs whose parent
  row is corrupted; the engine's harvest-time sanity check must catch
  and re-run them).  Chaos tests drive traffic through an injector
  and assert ZERO lost or corrupted queries.
* `backoff_s` — capped exponential backoff for the engine's tick
  retry loop.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable

from repro.errors import InjectedFault

# -- circuit breaker states -------------------------------------------------
CIRCUIT_HEALTHY = "healthy"
CIRCUIT_DEGRADED = "degraded"
CIRCUIT_SHEDDING = "shedding"

#: gauge encoding for ``serve.circuit_state`` (metrics are floats;
#: the snapshot stays JSON-scalar)
CIRCUIT_CODES = {CIRCUIT_HEALTHY: 0, CIRCUIT_DEGRADED: 1,
                 CIRCUIT_SHEDDING: 2}


def backoff_s(attempt: int, base: float = 0.01,
              cap: float = 0.25) -> float:
    """Capped exponential backoff: ``min(cap, base * 2**attempt)``."""
    return min(cap, base * (2 ** attempt))


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """When to admit, degrade, and shed.

    Attributes:
      queue_capacity: bounded-queue size.  At capacity the circuit is
        `CIRCUIT_SHEDDING` and every submit raises `QueueFullError`.
      degraded_depth: queue depth at/above which — with every slot
        busy — the circuit reports `CIRCUIT_DEGRADED`.
      shed_min_priority: optional load-shedding floor: while DEGRADED,
        queries with ``priority <`` this are rejected
        (`AdmissionRejected`) to keep room for the important ones.
        ``None`` (default) disables priority shedding — only the hard
        capacity bound rejects.
    """

    queue_capacity: int
    degraded_depth: int
    shed_min_priority: int | None = None

    def __post_init__(self):
        if self.queue_capacity < 1:
            raise ValueError(
                f"queue_capacity must be >= 1, got {self.queue_capacity}")
        if self.degraded_depth < 0:
            raise ValueError(
                f"degraded_depth must be >= 0, got {self.degraded_depth}")


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """The typed record of one submit-time admission decision.

    Rejections carry this on the raised `AdmissionRejected` as
    ``.decision`` so a client's retry policy can read *why* (circuit
    state, queue depth) instead of parsing a message string.
    """

    admitted: bool
    circuit: str            # CIRCUIT_* at decision time
    queue_depth: int        # depth when the decision was made
    reason: str = ""


class AdmissionQueue:
    """Bounded priority queue: higher ``priority`` first, FIFO within
    a priority level (heap key ``(-priority, seq)``).

    ``push`` refuses past ``capacity`` unless ``force=True`` — the
    force path exists for the engine's *requeue* of in-flight queries
    on tick failure, which must never lose a query to its own
    backpressure.  Truthiness and ``len`` mirror the deque this
    replaces (``assert not engine.queue`` keeps working).
    """

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._heap: list = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    @property
    def full(self) -> bool:
        return len(self._heap) >= self.capacity

    def push(self, item, priority: int = 0, *,
             force: bool = False) -> bool:
        """Enqueue; returns False (without enqueuing) when at capacity
        and not ``force``."""
        if self.full and not force:
            return False
        heapq.heappush(self._heap, (-int(priority), self._seq, item))
        self._seq += 1
        return True

    def pop(self):
        """Highest-priority (then oldest) item; raises IndexError when
        empty."""
        return heapq.heappop(self._heap)[2]

    def items(self) -> list:
        """Queued items in pop order (non-destructive)."""
        return [t[2] for t in sorted(self._heap)]

    def remove_if(self, pred: Callable) -> list:
        """Remove and return every queued item matching ``pred``
        (deadline expiry harvests through this)."""
        removed = [t[2] for t in self._heap if pred(t[2])]
        if removed:
            self._heap = [t for t in self._heap if not pred(t[2])]
            heapq.heapify(self._heap)
        return removed


@dataclasses.dataclass
class ServeFaultInjector:
    """Deterministic, fire-once fault schedule for the serve tick.

    The serve-path sibling of `repro.runtime.fault.FailureInjector`
    (same shape: configured trigger points + a ``fired`` set so each
    listed fault raises exactly once — retries then succeed, proving
    the recovery machinery rather than looping forever).

    Attributes:
      fail_ticks: tick numbers whose device dispatch raises
        `repro.errors.InjectedFault` (once each).
      slow_ticks: tick numbers stalled by ``slow_s`` wall seconds
        (once each) — exercises deadline budgets.
      slow_s: the stall duration.
      poison: ``(tick, slot)`` pairs — after the listed tick's
        dispatch succeeds, that slot's parent row is corrupted in
        place (once each).  The engine's harvest-time sanity check
        must detect the corruption and re-run the query; a delivered
        poisoned result is the chaos-test failure mode.
    """

    fail_ticks: tuple = ()
    slow_ticks: tuple = ()
    slow_s: float = 0.0
    poison: tuple = ()      # ((tick, slot), ...)

    def __post_init__(self):
        self.fail_ticks = tuple(int(t) for t in self.fail_ticks)
        self.slow_ticks = tuple(int(t) for t in self.slow_ticks)
        self.poison = tuple((int(t), int(s)) for t, s in self.poison)
        self._fired_fail: set = set()
        self._fired_slow: set = set()
        self._fired_poison: set = set()

    def check_tick(self, tick: int) -> None:
        """Raise `InjectedFault` if ``tick`` is scheduled to fail and
        hasn't fired yet."""
        if tick in self.fail_ticks and tick not in self._fired_fail:
            self._fired_fail.add(tick)
            raise InjectedFault(
                f"injected device-step failure at serve tick {tick} "
                f"(ServeFaultInjector.fail_ticks={self.fail_ticks})")

    def stall_s(self, tick: int) -> float:
        """Seconds to stall ``tick`` (0.0 when not scheduled/already
        fired)."""
        if tick in self.slow_ticks and tick not in self._fired_slow:
            self._fired_slow.add(tick)
            return float(self.slow_s)
        return 0.0

    def poison_slots(self, tick: int) -> tuple:
        """Slots whose parent row to corrupt after ``tick`` (each
        (tick, slot) pair fires once)."""
        out = []
        for t, s in self.poison:
            if t == tick and (t, s) not in self._fired_poison:
                self._fired_poison.add((t, s))
                out.append(s)
        return tuple(out)

    @property
    def faults_remaining(self) -> int:
        """Scheduled faults that have not fired yet (chaos tests
        assert 0 at drain)."""
        return (len(set(self.fail_ticks) - self._fired_fail)
                + len(set(self.slow_ticks) - self._fired_slow)
                + len(set(self.poison) - self._fired_poison))
