"""Semiring — the algebraic view of the vectorized sweep (ISSUE 10).

SlimSell [Besta et al., arXiv:2010.09913] observes that the SELL/CSR
frontier sweep is a semiring SpMV: one layer computes

    vals' = vals ⊕ (A ⊗ vals)        over the (⊕, ⊗) pair,

and BFS is just the (select2nd, min) instance.  Buluç–Madduri
[arXiv:1104.4518] build their whole distributed traversal stack on the
same algebraic view.  This module is the ONE home of the pair: a
frozen, hashable `Semiring` record that `kernels/gather_expand.py`
(`gather_relax*`), `kernels/sell_expand.py` (`sell_relax*`) and the
engine's `expand_candidates` are parameterized over, plus the
registered instances behind the `TraversalSpec.algorithm` values.

Every instance here is a *tropical* (min-⊕) semiring, so the kernels
share one deterministic primitive: a masked **scatter-min** of edge
candidates (min is commutative + associative — unlike the BFS bitmap
scatter there is no §3.3.2 race to restore, duplicate updates are
benign by algebra).  ⊗ is data, not code: a candidate along edge
(u, v) is

    cand = vals[u] + unit + (w(u, v) if weighted else 0)

which covers the whole portfolio (``unit``/``weighted`` per instance):

==============  ======  =====  ========  ===========================
name            dtype   unit   weighted  algorithm
==============  ======  =====  ========  ===========================
bfs             int32   1      no        BFS depths / min-parent tree
ksource_bfs     int32   1      no        batched k-root BFS (the
                                         per-source depth matrix)
sssp            float32 0      yes       min-plus shortest paths
cc              int32   0      no        min-label propagation
==============  ======  =====  ========  ===========================

The "improved" predicate (strict ``cand < old``) doubles as the
frontier generator: a vertex whose value improved this layer is
exactly a member of the next frontier — for BFS that degenerates to
"newly discovered" (values are set once; later candidates are never
smaller), so BFS through this path visits the same vertices in the
same layers as the hard-wired engine.

**Synthetic edge weights** (`edge_weight`): the adjacency layouts
store no weight array, so SSSP draws Graph500-SSSP-style weights from
a deterministic symmetric hash of the endpoints — uniform in [1, 2),
computed on the fly inside the kernels (zero extra HBM streams, zero
bytes-model tax) and mirrored bit-exactly in numpy for the Dijkstra
oracle (`edge_weight_np`).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

#: ⊕-identity == the "unreached" value.  int32 uses a half-range
#: infinity so ``identity + unit`` cannot wrap; float32 uses inf.
INT_INF = np.int32(np.iinfo(np.int32).max // 2)
FLOAT_INF = np.float32(np.inf)

#: the `TraversalSpec.algorithm` values resolved through the semiring
#: engine (the BFS default stays on the hard-wired engine paths and is
#: reachable here as the "bfs" instance for the parity/bytes gates)
SEMIRING_ALGORITHMS = ("sssp", "cc", "ksource_bfs")

#: SSSP delta-stepping bucket width.  Weights live in [1, 2), so
#: delta == the minimum edge weight makes each bucket Dijkstra-like
#: (a settled bucket never reopens a lighter one).
SSSP_DELTA = 1.0


@dataclasses.dataclass(frozen=True)
class Semiring:
    """One (⊕, ⊗) pair.  Frozen + hashable so kernels can take it as
    a jit-static argument; ⊗ is carried as data (``unit``/
    ``weighted``), ⊕ is min for every registered instance.

    Fields:
      name: registry key (== the `TraversalSpec.algorithm` value).
      dtype: value dtype name ("int32" | "float32").
      identity: ⊕-identity — the "unreached" value (`INT_INF`/inf).
      annihilator: ⊗-annihilator (0 for the additive ⊗ family: a
        zero-length self-edge changes nothing) — documented for the
        algebra, the kernels never materialize it.
      unit: constant added along an edge (1 = hop counting, 0 = label
        copy / pure weight).
      weighted: add the synthetic `edge_weight` along each edge.
      all_vertices_frontier: seed the frontier with EVERY real vertex
        instead of the roots (CC's init: each vertex its own label).
    """

    name: str
    dtype: str
    identity: float
    annihilator: float = 0.0
    unit: int = 0
    weighted: bool = False
    all_vertices_frontier: bool = False

    @property
    def jnp_dtype(self):
        return jnp.float32 if self.dtype == "float32" else jnp.int32

    def identity_value(self):
        return jnp.asarray(self.identity, self.jnp_dtype)

    # -- the (⊕, ⊗) pair on jnp values ----------------------------------
    def add(self, a, b):
        """⊕ — min for every registered (tropical) instance."""
        return jnp.minimum(a, b)

    def mul(self, u_val, u, v):
        """⊗ along edge (u, v): the candidate value offered to v."""
        if self.weighted:
            return u_val + edge_weight(u, v)
        if self.unit:
            return u_val + self.jnp_dtype(self.unit)
        return u_val

    def improved(self, old, new):
        """Strict improvement — the frontier-generation predicate AND
        the update gate (values only ever move toward ⊕)."""
        return new < old

    # -- initial state ---------------------------------------------------
    def init_vals(self, roots, n_vertices: int, v_pad: int):
        """(B, V_pad) initial value rows for a (B,) root batch."""
        ids = jnp.arange(v_pad, dtype=jnp.int32)
        if self.all_vertices_frontier:       # CC: own id, padding = INF
            row = jnp.where(ids < n_vertices, ids.astype(self.jnp_dtype),
                            self.identity_value())
            return jnp.broadcast_to(row, (roots.shape[0], v_pad))
        return jnp.full((roots.shape[0], v_pad), self.identity_value(),
                        self.jnp_dtype).at[
            jnp.arange(roots.shape[0]), roots].set(self.jnp_dtype(0))


# -- synthetic edge weights (Graph500-SSSP-style, hash-derived) ---------

_MIX1 = 0x7FEB352D
_MIX2 = 0x846CA68B
_GOLD = 0x9E3779B1


def _mix_u32(x, xp):
    """32-bit avalanche (splitmix-style) in either jnp or numpy."""
    u32 = xp.uint32
    x = (x ^ (x >> u32(16))) * u32(_MIX1)
    x = (x ^ (x >> u32(15))) * u32(_MIX2)
    return x ^ (x >> u32(16))


def _weight_impl(u, v, xp):
    u32, f32 = xp.uint32, xp.float32
    a = xp.minimum(u, v).astype(u32)        # symmetric: w(u,v)==w(v,u)
    b = xp.maximum(u, v).astype(u32)
    h = _mix_u32(a * u32(_GOLD) + b, xp)
    # top 24 hash bits -> uniform [0, 1); weights live in [1, 2)
    return f32(1.0) + (h >> u32(8)).astype(f32) * f32(1.0 / (1 << 24))


def edge_weight(u, v):
    """Deterministic symmetric weight in [1, 2) — jnp, kernel-safe."""
    return _weight_impl(jnp.asarray(u), jnp.asarray(v), jnp)


def edge_weight_np(u, v):
    """The numpy mirror of `edge_weight` (bit-identical) — what the
    serial Dijkstra oracle in tests/test_algorithms.py runs on."""
    with np.errstate(over="ignore"):       # uint32 wraparound is spec
        return _weight_impl(np.asarray(u), np.asarray(v), np)


# -- registry -----------------------------------------------------------

SEMIRINGS: dict[str, Semiring] = {
    "bfs": Semiring("bfs", "int32", int(INT_INF), unit=1),
    "ksource_bfs": Semiring("ksource_bfs", "int32", int(INT_INF),
                            unit=1),
    "sssp": Semiring("sssp", "float32", float(FLOAT_INF),
                     weighted=True),
    "cc": Semiring("cc", "int32", int(INT_INF),
                   all_vertices_frontier=True),
}


def get(name: str) -> Semiring:
    """Look up a registered semiring; KeyError lists what exists."""
    try:
        return SEMIRINGS[name]
    except KeyError:
        raise KeyError(
            f"unknown semiring {name!r}; registered: "
            f"{sorted(SEMIRINGS)}") from None
