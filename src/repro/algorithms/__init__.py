"""Semiring-generic traversal: one engine, a portfolio of algorithms.

`semiring` holds the (⊕, ⊗) abstraction and the registered instances
(bfs / ksource_bfs / sssp / cc); `traversal` is the whole-traversal
driver the plan cache routes `TraversalSpec.algorithm` values in
`SEMIRING_ALGORITHMS` through.  This ``__init__`` re-exports only the
semiring layer — `traversal` imports the kernel stack, and the kernels
import `semiring` back for the synthetic edge weights, so keeping the
package root thin keeps the import graph acyclic.
"""
from repro.algorithms.semiring import (SEMIRING_ALGORITHMS, SEMIRINGS,
                                       Semiring, edge_weight,
                                       edge_weight_np, get)

__all__ = [
    "SEMIRING_ALGORITHMS",
    "SEMIRINGS",
    "Semiring",
    "edge_weight",
    "edge_weight_np",
    "get",
]
