"""plan/compile/run — one cached jit executable behind every BFS.

The ROADMAP north-star (production-scale serving) wants the Graph500
shape of work: configure ONCE per graph, compile ONCE, then run many
roots without re-tracing or re-deciding knobs.  `plan` is that step:

    import repro.bfs as bfs
    ct = bfs.plan(graph, spec=bfs.TraversalSpec(policy="beamer"))
    res = ct.run(17)                    # single root
    res = ct.run_batched([3, 7, 11])    # leading root axis
    ct.resolved                         # the fully-concrete spec

``plan`` resolves the spec's ``"auto"`` fields exactly once
(`TraversalSpec.resolve` — the committed BENCH affinity table feeds
the tile auto, the autotune degree statistics feed the policy auto)
and returns a `CompiledTraversal` whose ``run`` / ``run_batched`` /
``layer_step`` all hit ONE cached jit executable keyed by
``(format class, geometry, resolved spec)``.  Planning the same
geometry + spec again — from any entry point, including every legacy
``traverse*`` shim — reuses the cached executable, so the engine
traces at most once per configuration regardless of how many surfaces
route through it (`_Executable.traces` is the probe the plan-cache
tests and the ``bfs_plan_cache`` micro-benchmark read).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.spec import TraversalSpec, as_format
from repro.core import engine as _engine
from repro.errors import GraphValidationError


def check_roots(roots, n_vertices: int) -> None:
    """Admission-time root validation (ISSUE 8): every root must be an
    integer in ``[0, n_vertices)``.  Raises `GraphValidationError`
    (IS-A ``ValueError``) — an out-of-range root would silently index
    the sentinel/padding region and return a wrong tree.  Tracer-held
    roots (inside a jitted caller) skip the check."""
    try:
        arr = np.asarray(roots)
    except Exception:
        return
    if arr.dtype.kind == "f":
        if np.any(~np.isfinite(arr)) or np.any(arr != np.floor(arr)):
            raise GraphValidationError(
                f"roots must be integers in [0, {n_vertices}), got "
                f"non-integral/NaN values {arr!r}")
    elif arr.dtype.kind not in "iu":
        raise GraphValidationError(
            f"roots must be integers in [0, {n_vertices}), got dtype "
            f"{arr.dtype}")
    if arr.size and (int(arr.min()) < 0
                     or int(arr.max()) >= n_vertices):
        bad = int(arr.min()) if int(arr.min()) < 0 else int(arr.max())
        raise GraphValidationError(
            f"root {bad} is outside [0, n_vertices={n_vertices}); "
            f"roots index real vertices (the sentinel/padding region "
            f"would return a wrong tree, not an error)")


def geometry_key(fmt) -> tuple:
    """Hashable (format class, static aux, leaf shapes/dtypes) key —
    what "same geometry" means for the plan cache.  Works on traced
    leaves too (shape/dtype are trace-time constants)."""
    leaves, aux = fmt.tree_flatten()
    return (type(fmt).__name__, aux,
            tuple((tuple(x.shape), str(x.dtype)) for x in leaves))


class _Executable:
    """The cached compile unit: one jitted whole-search program + one
    jitted single-layer tick for a (geometry, resolved spec) pair.
    ``traces`` counts engine traces (bumped at trace time only) — the
    probe behind the "≤1 trace per N runs" acceptance gate."""

    def __init__(self, spec: TraversalSpec):
        self.spec = spec
        self.traces = 0
        self.layer_traces = 0

        def _run(fmt, roots):
            self.traces += 1          # trace-time side effect only
            if spec.is_semiring:
                from repro.algorithms.traversal import traverse_semiring
                return traverse_semiring(fmt, roots, spec)
            return _engine._traverse_impl(fmt, roots, spec)

        def _layer(fmt, frontier, visited, parent):
            self.layer_traces += 1
            if spec.is_semiring:
                raise NotImplementedError(
                    f"semiring algorithm {spec.algorithm!r} has no "
                    f"single-layer tick: the portfolio driver owns "
                    f"the value/frontier carry — use run()/"
                    f"run_batched() for whole traversals")
            steps = fmt.make_steps(spec)
            mode = (_engine.MODE_SIMD if spec.algorithm == "simd"
                    else _engine.MODE_SCALAR)
            return steps[mode](frontier, visited, parent)[:3]

        self.run_jit = jax.jit(_run)
        self.layer_jit = jax.jit(_layer)


_CACHE: dict[tuple, _Executable] = {}
_STATS = {"hits": 0, "misses": 0}


def _executable(fmt, spec: TraversalSpec) -> _Executable:
    # ``merge`` is only read by the mesh path (which bypasses the
    # executable entirely) — normalize it out of the key so two specs
    # differing only in merge flavour share one single-chip trace
    key = (geometry_key(fmt), spec.replace(merge="auto"))
    ex = _CACHE.get(key)
    if ex is None:
        _STATS["misses"] += 1
        ex = _CACHE[key] = _Executable(spec)
    else:
        _STATS["hits"] += 1
    return ex


def cache_info() -> dict:
    """Plan-cache counters: {size, hits, misses}."""
    return {"size": len(_CACHE), **_STATS}


def clear_cache() -> None:
    """Drop every cached executable (tests / benchmarks)."""
    _CACHE.clear()
    _STATS.update(hits=0, misses=0)


class CompiledTraversal:
    """A graph bound to a fully-resolved `TraversalSpec` and its
    cached executable.

    Attributes:
      resolved: the concrete spec (every ``"auto"`` resolved) — the
        loggable/reproducible record of what runs.
      executable: the shared `_Executable` (identical across plans of
        equal geometry + spec — the cache identity tests assert on
        ``is``).
    """

    def __init__(self, fmt, resolved: TraversalSpec,
                 executable: _Executable | None, *,
                 batch: int | None = None, mesh: Any = None):
        self.fmt = fmt
        self.resolved = resolved
        self.executable = executable      # None iff mesh-bound
        self.batch = batch
        self.mesh = mesh
        self._partition = None            # mesh path: built once, lazily

    # -- execution -------------------------------------------------------
    def run(self, roots) -> _engine.EngineResult:
        """Run for one root (int — unbatched result arrays) or a
        sequence of roots (leading root axis), `engine.traverse`
        semantics.  On a mesh-bound plan, runs the distributed program
        instead and returns its ``(parent, layers)`` pair."""
        if self.mesh is not None:
            check_roots(roots, self.fmt.n_vertices)
            return self._run_distributed(roots)
        single = jnp.ndim(roots) == 0
        res = self.run_batched(
            jnp.atleast_1d(jnp.asarray(roots, jnp.int32)))
        if single:
            st = res.state
            return _engine.EngineResult(
                _engine.BfsState(st.frontier[0], st.visited[0],
                                 st.parent[0], st.layer),
                res.depths[0], res.stats,
                None if res.values is None else res.values[0])
        return res

    def run_batched(self, roots) -> _engine.EngineResult:
        """Run a (B,) root batch in one launch.  A plan built with
        ``batch=N`` pads smaller batches up to N (repeating the last
        root) and slices results back, so every batch size <= N hits
        the same trace.  NB the ``stats`` buffer is summed over the
        *padded* batch on device (the duplicate roots' work included)
        — for exact Table 1 accounting use an exact-width plan
        (``batch=None``)."""
        if self.mesh is not None:
            raise NotImplementedError(
                "mesh-bound plans run one root per launch via .run(); "
                "batched multi-root distributed search is not wired up")
        check_roots(roots, self.fmt.n_vertices)
        roots = jnp.atleast_1d(jnp.asarray(roots, jnp.int32))
        n = int(roots.shape[0])
        if n == 0:
            raise ValueError("run_batched needs at least one root")
        if self.batch is not None and n > self.batch:
            raise ValueError(
                f"root batch of {n} exceeds this plan's fixed "
                f"batch={self.batch}; chunk the roots or plan with a "
                f"larger batch (the fixed width is what guarantees "
                f"one trace)")
        if self.batch is not None and n < self.batch:
            pad = jnp.full((self.batch - n,), roots[-1], jnp.int32)
            res = self.executable.run_jit(
                self.fmt, jnp.concatenate([roots, pad]))
            st = res.state
            return _engine.EngineResult(
                _engine.BfsState(st.frontier[:n], st.visited[:n],
                                 st.parent[:n], st.layer),
                res.depths[:n], res.stats,
                None if res.values is None else res.values[:n])
        return self.executable.run_jit(self.fmt, roots)

    def layer_step(self, state, visited=None, parent=None):
        """Advance every root by exactly one layer (the serve tick).

        Accepts an `engine.BfsState` (returns a BfsState with layer+1)
        or the bare ``(frontier, visited, parent)`` triple (returns
        the updated triple)."""
        if self.mesh is not None:
            raise NotImplementedError(
                "mesh-bound plans have no single-layer tick; the "
                "distributed program runs whole searches via .run()")
        if visited is None:
            f, v, p = state.frontier, state.visited, state.parent
            nf, nv, np_ = self.executable.layer_jit(self.fmt, f, v, p)
            return _engine.BfsState(nf, nv, np_, state.layer + 1)
        return self.executable.layer_jit(self.fmt, state, visited,
                                         parent)

    def trace_run(self, roots, *, tracer=None, sync: bool = True,
                  profile_logdir: str | None = None):
        """Instrumented traversal: host-steps this plan's compiled
        ``layer_step`` recording per-layer wall-clock spans — the
        opt-in timing mode (`repro.obs.trace.trace_run`); the fused
        ``run`` fast path is untouched.  Returns a
        `repro.obs.trace.TraceRun`."""
        from repro.obs.trace import trace_run as _trace_run
        return _trace_run(self, roots, tracer=tracer, sync=sync,
                          profile_logdir=profile_logdir)

    def _run_distributed(self, root):
        from repro.core import bfs_distributed as dist
        if jnp.ndim(root) != 0:
            raise ValueError("the distributed program runs one root "
                             "per launch; pass a scalar root")
        if self._partition is None:
            to_csr = getattr(self.fmt, "to_csr", None)
            if to_csr is None:
                raise TypeError(
                    f"mesh-bound plans need a CSR-recoverable format; "
                    f"{type(self.fmt).__name__} has no to_csr()")
            # partition ONCE at first run — the host-side O(E) split
            # is the mesh path's "compile" step; subsequent roots
            # reuse the sharded arrays (plan-once/run-many)
            csr = to_csr()
            axis_names = tuple(self.mesh.axis_names)
            n_devices = int(np.prod([self.mesh.shape[a]
                                     for a in axis_names]))
            rows_sh, colstarts_sh = dist.partition_csr(csr, n_devices)
            self._partition = (csr.n_vertices, axis_names, rows_sh,
                               colstarts_sh)
        n_vertices, axis_names, rows_sh, colstarts_sh = self._partition
        parent, layers = dist._run(
            self.mesh, axis_names, n_vertices,
            self.resolved.max_layers, self.resolved.merge, rows_sh,
            colstarts_sh, jnp.asarray(root, jnp.int32))
        return parent[:n_vertices], layers

    # -- introspection ---------------------------------------------------
    @property
    def traces(self) -> int:
        """Engine traces this plan's executable has paid so far (0 on
        mesh-bound plans — the distributed program jits separately)."""
        return self.executable.traces if self.executable else 0

    def lower(self, roots=None):
        """``jax.jit(...).lower`` of the whole-search program — the
        dry-run/AOT hook.  ``roots`` defaults to a zero batch of the
        plan's ``batch`` width (or 1)."""
        if self.mesh is not None:
            raise NotImplementedError(
                "mesh-bound plans lower through launch/dryrun.py's "
                "shard_map path, not the single-chip executable")
        if roots is None:
            roots = jnp.zeros((self.batch or 1,), jnp.int32)
        roots = jnp.atleast_1d(jnp.asarray(roots, jnp.int32))
        return self.executable.run_jit.lower(self.fmt, roots)

    def stats(self, result) -> list[_engine.LayerStats]:
        """Decode a result's on-device stats buffer (Table 1 rows)."""
        return _engine.layer_stats(result)

    def direction_log(self, result) -> list[str]:
        """Per-layer direction strings from a result's stats buffer."""
        return _engine.direction_log(result)

    def __repr__(self) -> str:
        return (f"CompiledTraversal({self.fmt!r}, traces="
                f"{self.traces}, spec={self.resolved})")


def plan(graph, spec: TraversalSpec | None = None, *,
         batch: int | None = None, mesh: Any = None) -> CompiledTraversal:
    """Resolve a spec against a graph and bind the cached executable.

    Args:
      graph: a `Csr`, `EdgeList` or built `formats.GraphFormat` (Csr/
        EdgeList are viewed through `CsrFormat`; pick another layout
        with `formats.autotune.build` first).
      spec: a `TraversalSpec` (default: all-``"auto"``).  Resolved
        exactly once, here.
      batch: optional fixed batch width — `run_batched` pads smaller
        root batches up to it so varying query counts reuse one trace
        (the serving shape).
      mesh: optional jax mesh — ``run`` then executes the distributed
        per-chip program derived from the same resolved spec
        (``merge``/``max_layers``).
    """
    # admission-time structural validation (ISSUE 8): raw Csr inputs
    # are checked BEFORE as_format wraps them (CsrFormat's int() ctor
    # would turn NaN geometry into an untyped ValueError), built
    # formats through their own validate_structure hook
    from repro.core.csr import Csr as _Csr, check_structure
    if isinstance(graph, _Csr):
        check_structure(graph)
    fmt = as_format(graph)
    fmt.validate_structure()
    spec = spec if spec is not None else TraversalSpec()
    if mesh is not None:
        # same contract as run_bfs_distributed(spec=): flag
        # explicitly-set fields the fixed per-chip program cannot
        # honor, and skip the autotune policy measurement it would
        # never read
        from repro.api.spec import warn_mesh_ignored_fields
        warn_mesh_ignored_fields(spec, "mesh-bound plan")
        if spec.policy == "auto":
            spec = spec.replace(policy="topdown")
    resolved = spec.resolve(fmt)
    # mesh-bound plans never run the single-chip executable (their
    # run() is the shard_map program) — don't pollute the cache
    ex = None if mesh is not None else _executable(fmt, resolved)
    return CompiledTraversal(fmt, resolved, ex, batch=batch, mesh=mesh)
