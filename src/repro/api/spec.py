"""TraversalSpec — ONE declarative configuration object for every BFS.

The paper's whole point is that a single traversal algorithm has many
orthogonal tuning axes that must be co-selected per graph: the §4.1
layer-adaptive direction decision, the §4.2 aligned tile unit, the §4
vprefetch distance, the §3.3.1 packed-word representation.  Beamer et
al. [2012] and the Buluç–Madduri survey frame the same set as a single
*traversal configuration* chosen once per graph.  After PRs 1–4 this
repo exposed those axes as seven loose keyword knobs copy-threaded
through every entry point, each with its own ``static_argnames`` list
and drifting defaults; `TraversalSpec` is the one frozen object that
replaces the knob pile.

Every field also accepts ``"auto"``; autos are resolved exactly ONCE,
at plan time (`TraversalSpec.resolve`), against the graph's format —
the tile auto consults the committed ``BENCH_bfs.json`` affinity sweep
(`engine.default_tile_csr`), the policy auto consults the
`formats.autotune` degree statistics — so ``CompiledTraversal.resolved``
is always a fully-concrete, loggable, hashable record of what actually
ran.

Field → paper-knob map (the §-references are to the source paper):

* ``policy``          — the §4.1 layer-adaptive direction decision
  (which expansion flavour each layer runs).  A policy *object*
  (`engine.TopDown` / `ThresholdSimd` / `PaperLiteralLayers` /
  `BeamerHybrid`), a registered name string, or ``"auto"`` (degree
  skew >= `autotune.SKEW_THRESHOLD` picks the Beamer hybrid, else the
  edge-threshold SIMD switch).
* ``algorithm``       — which scalar expander backs MODE_SCALAR
  layers: ``"simd"`` (Algorithm 3: bitmaps + racy scatter +
  restoration §3.3.2) or ``"nonsimd"`` (Algorithm 2: exact dense
  updates).  Auto: ``"simd"``.
* ``pipeline``        — the expansion gather pipeline:
  ``"fused_gather"`` (in-kernel CSR gather + active-tile scheduling,
  HBM traffic proportional to the frontier) or ``"materialized"``
  (the legacy full-E edge stream; the ablation baseline).  Auto:
  ``"fused_gather"``.
* ``packed``          — §3.3.1's bitmap compression as the engine's
  native per-layer representation (SIMD compaction kernel, V/8 mask
  bytes per layer) vs the legacy dense-mask arm.  Auto: ``True``.
* ``tile``            — §4.2's aligned unit: the fused pipeline's DMA
  block and therefore its prefetch distance (format-defined units:
  CSR rows-slots, SELL slabs per grid step).  Auto: the format's
  `resolve_tile(None)` — for CSR the ``REPRO_BFS_TILE`` env override,
  else the committed BENCH affinity-sweep argmin, else 1024.
* ``prefetch_depth``  — §4's ``vprefetch0/vprefetch1`` distance as an
  explicit knob: input-DMA tiles kept in flight ahead of the compute
  tile in the gather kernels (0 = the BlockSpec pipeline's automatic
  double buffering).  Auto: ``0``.  Invalid on the bitmap format,
  which streams no edge tiles.
* ``max_layers``      — static layer budget of the fused
  ``lax.while_loop`` (and the serve engine's per-query safety valve).
  Auto: ``64``.
* ``merge``           — the distributed per-layer exchange:
  ``"allreduce"`` (dense per-layer pmin), ``"owner"``
  (owner-computes all_to_all; parent output is the LOCAL slice) or
  ``"packed"`` (V/8-byte discovered-word all-gather + one post-loop
  pmin — bit-identical tree to allreduce).  Auto: ``"packed"``, the
  wire-optimal full-tree merge.  Ignored off-mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core import engine as _engine

AUTO = "auto"

_ALGORITHMS = ("simd", "nonsimd")
_MERGES = ("allreduce", "owner", "packed")

#: registered policy names <-> engine policy classes
POLICIES = {
    "topdown": _engine.TopDown,
    "threshold_simd": _engine.ThresholdSimd,
    "paper_layers": _engine.PaperLiteralLayers,
    "beamer": _engine.BeamerHybrid,
}
_POLICY_NAMES = {cls: name for name, cls in POLICIES.items()}


def _is_policy(obj: Any) -> bool:
    """Duck-typed DirectionPolicy: decides a mode from a Workload."""
    return callable(getattr(obj, "decide", None)) \
        and hasattr(obj, "modes")


def as_format(graph):
    """View whatever the caller holds as a built `GraphFormat`.

    Csr and EdgeList are wrapped as `CsrFormat` (no silent re-layout —
    picking a different layout is `formats.autotune.build`'s job);
    built formats pass through.
    """
    from repro.core.csr import Csr, from_edges
    from repro.core.rmat import EdgeList
    from repro.formats.base import GraphFormat
    from repro.formats.csr_format import CsrFormat
    if isinstance(graph, GraphFormat):
        return graph
    if isinstance(graph, Csr):
        return CsrFormat.from_csr(graph)
    if isinstance(graph, EdgeList):
        return CsrFormat.from_csr(from_edges(graph))
    raise TypeError(
        f"cannot plan a traversal over {type(graph).__name__}; expected "
        f"a Csr, EdgeList or built GraphFormat")


#: spec fields the distributed per-chip program (a fixed top-down
#: rowsweep) cannot honor — it consumes only merge/max_layers
MESH_IGNORED_FIELDS = ("policy", "algorithm", "pipeline", "packed",
                       "tile", "prefetch_depth")


def warn_mesh_ignored_fields(spec: "TraversalSpec", entry: str) -> None:
    """The ONE mesh-path contract warning (shared by
    `run_bfs_distributed` and mesh-bound `plan`): flag explicitly-set
    fields the fixed per-chip program ignores.  A fully-resolved spec
    passes silently — its concrete fields are resolution artifacts,
    not user intent."""
    if spec.is_resolved:
        return
    ignored = [f for f in MESH_IGNORED_FIELDS
               if getattr(spec, f) != AUTO]
    if ignored:
        import warnings
        warnings.warn(
            f"{entry}: the distributed per-chip program is a fixed "
            f"top-down rowsweep; spec fields {ignored} are ignored "
            f"(only merge/max_layers apply)",
            UserWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class TraversalSpec:
    """Frozen, hashable traversal configuration (see module docstring
    for the field → paper-knob map).  Every field accepts ``"auto"``;
    `resolve` turns autos into concrete values exactly once, and
    `validate` rejects invalid values/combinations in ONE place with
    actionable messages."""

    policy: Any = AUTO
    algorithm: str = AUTO
    pipeline: str = AUTO
    packed: Any = AUTO            # bool | "auto"
    tile: Any = AUTO              # positive int | "auto"
    prefetch_depth: Any = AUTO    # int >= 0 | "auto"
    max_layers: Any = AUTO        # int >= 1 | "auto"
    merge: str = AUTO

    # -- introspection ---------------------------------------------------
    @classmethod
    def field_names(cls) -> tuple[str, ...]:
        return tuple(f.name for f in dataclasses.fields(cls))

    @property
    def is_resolved(self) -> bool:
        """True iff no field is ``"auto"`` and policy is an object."""
        return (not any(getattr(self, f) == AUTO
                        for f in self.field_names())
                and _is_policy(self.policy))

    def replace(self, **changes) -> "TraversalSpec":
        return dataclasses.replace(self, **changes)

    # -- validation (the ONE home of combination checks) -----------------
    def validate(self, fmt=None) -> "TraversalSpec":
        """Reject invalid values and invalid (spec, format) combos.

        Called standalone it checks every non-``"auto"`` field value;
        with ``fmt`` it additionally rejects combinations the format
        cannot honor (e.g. ``prefetch_depth > 0`` on the bitmap
        layout).  Returns self so call sites can chain."""
        p = self.policy
        if not (_is_policy(p) or p == AUTO or
                (isinstance(p, str) and p in POLICIES)):
            raise ValueError(
                f"unknown policy {p!r}; expected a DirectionPolicy "
                f"object, one of {sorted(POLICIES)}, or 'auto'")
        if self.algorithm != AUTO and self.algorithm not in _ALGORITHMS:
            raise ValueError(
                f"unknown scalar algorithm {self.algorithm!r}; expected "
                f"one of {_ALGORITHMS} or 'auto'")
        if self.pipeline != AUTO:
            _engine.check_pipeline(self.pipeline)
        if self.merge != AUTO and self.merge not in _MERGES:
            raise ValueError(
                f"unknown merge {self.merge!r}; expected one of "
                f"{_MERGES} or 'auto' (merge only matters with a mesh)")
        if self.packed != AUTO and not isinstance(self.packed, bool):
            raise ValueError(
                f"packed must be True, False or 'auto', got "
                f"{self.packed!r}")
        if self.tile != AUTO and (not isinstance(self.tile, int)
                                  or isinstance(self.tile, bool)
                                  or self.tile < 1):
            raise ValueError(
                f"tile must be a positive int or 'auto', got "
                f"{self.tile!r}")
        if self.prefetch_depth != AUTO and (
                not isinstance(self.prefetch_depth, int)
                or isinstance(self.prefetch_depth, bool)
                or self.prefetch_depth < 0):
            raise ValueError(
                f"prefetch_depth must be an int >= 0 or 'auto', got "
                f"{self.prefetch_depth!r}")
        if self.max_layers != AUTO and (
                not isinstance(self.max_layers, int)
                or isinstance(self.max_layers, bool)
                or self.max_layers < 1):
            raise ValueError(
                f"max_layers must be an int >= 1 or 'auto', got "
                f"{self.max_layers!r}")
        if fmt is not None:
            depth = self.prefetch_depth
            if isinstance(depth, int) and depth > 0 \
                    and not getattr(fmt, "supports_prefetch", True):
                raise ValueError(
                    f"prefetch_depth={depth} is invalid for the "
                    f"{getattr(fmt, 'name', type(fmt).__name__)!r} "
                    f"format: it streams no edge tiles to prefetch "
                    f"(supports_prefetch=False) — use prefetch_depth=0 "
                    f"(or 'auto'), or pick a streamed layout like "
                    f"'csr'/'sell'")
        return self

    # -- auto resolution (exactly once, at plan time) --------------------
    def resolve(self, graph) -> "TraversalSpec":
        """Resolve every ``"auto"`` against the graph's format.

        Deterministic given the graph and the committed
        ``BENCH_bfs.json`` (the tile affinity table).  The returned
        spec `is_resolved` and has been validated against the format.
        Requires a concrete graph when ``policy="auto"`` (the degree
        statistics must be readable); every other auto resolves from
        static geometry alone, so tracer-held formats (e.g. inside a
        jitted legacy shim) resolve fine with a concrete policy.
        """
        self.validate()
        fmt = as_format(graph)
        policy = self.policy
        if policy == AUTO:
            from repro.formats import autotune
            s = autotune.measure(fmt)
            policy = (_engine.BeamerHybrid()
                      if s.degree_skew >= autotune.SKEW_THRESHOLD
                      else _engine.ThresholdSimd())
        elif isinstance(policy, str):
            policy = POLICIES[policy]()
        tile = fmt.resolve_tile(None if self.tile == AUTO else self.tile)
        resolved = self.replace(
            policy=policy,
            algorithm="simd" if self.algorithm == AUTO else self.algorithm,
            pipeline=("fused_gather" if self.pipeline == AUTO
                      else self.pipeline),
            packed=True if self.packed == AUTO else self.packed,
            tile=int(tile),
            prefetch_depth=(0 if self.prefetch_depth == AUTO
                            else self.prefetch_depth),
            max_layers=64 if self.max_layers == AUTO else self.max_layers,
            merge="packed" if self.merge == AUTO else self.merge)
        return resolved.validate(fmt)

    # -- serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        """JSON-ready dict; policy objects serialize as
        ``{"name": ..., "params": {...}}`` (tuples become lists)."""
        d = {f: getattr(self, f) for f in self.field_names()}
        p = self.policy
        if _is_policy(p):
            cls = type(p)
            if cls not in _POLICY_NAMES:
                raise ValueError(
                    f"cannot serialize unregistered policy class "
                    f"{cls.__name__}; register it in spec.POLICIES")
            params = {k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in dataclasses.asdict(p).items()}
            d["policy"] = {"name": _POLICY_NAMES[cls], "params": params}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TraversalSpec":
        """Inverse of `to_dict` (round-trips to an equal spec)."""
        unknown = set(d) - set(cls.field_names())
        if unknown:
            raise ValueError(
                f"unknown TraversalSpec fields {sorted(unknown)}; "
                f"expected a subset of {cls.field_names()}")
        kw = dict(d)
        p = kw.get("policy")
        if isinstance(p, dict):
            pol_cls = POLICIES[p["name"]]
            params = {k: (tuple(v) if isinstance(v, list) else v)
                      for k, v in p.get("params", {}).items()}
            kw["policy"] = pol_cls(**params)
        return cls(**kw).validate()
