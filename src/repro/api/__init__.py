"""Declarative traversal API: `TraversalSpec` + plan/compile/run.

The public facade is `repro.bfs`; this package holds the pieces:
`repro.api.spec` (the frozen configuration object + auto resolution +
the ONE validation home) and `repro.api.plan` (the geometry+spec-keyed
executable cache behind every entry point).

Only the submodules are re-exported here — rebinding the ``plan``
*function* onto the package would shadow the ``repro.api.plan``
module attribute (import either the submodule or `repro.bfs`).
"""
from repro.api import plan as plan      # noqa: F401  (submodule)
from repro.api import spec as spec      # noqa: F401  (submodule)

__all__ = ["plan", "spec"]
