"""repro: BFS vectorization (Xeon Phi, 2016) as a multi-pod JAX framework."""
__version__ = "1.0.0"
