"""Process-local metrics registry: counters, gauges, histograms.

The serve tier's operational truth lives here — per-query
submit→harvest latency, tick duration, queue depth, slot occupancy —
and every benchmark ``emit`` mirrors its value in, so one snapshot
shows TEPS/bytes next to the serving distributions they explain.

Deliberately dependency-free and synchronous (this is a single-process
engine; the registry is the in-process end of the pipe a real
deployment would scrape).  Two export forms:

* `MetricsRegistry.snapshot()` — a JSON-ready dict that round-trips
  through ``json.dumps``/``loads`` unchanged (the obs-smoke contract);
* `MetricsRegistry.to_prometheus()` — Prometheus-style text
  exposition (counters/gauges as samples, histograms as summaries
  with p50/p90/p99 quantile samples plus ``_count``/``_sum``).

Histograms keep a bounded reservoir of the most recent
``RESERVOIR_SIZE`` observations for quantiles (exact until the cap,
sliding-window after) while ``count``/``sum``/``min``/``max`` stay
exact over the full stream.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import logging
import math
import threading
import time
from typing import Iterator

RESERVOIR_SIZE = 4096

#: quantiles exported by snapshots and the text exposition
QUANTILES = (0.5, 0.9, 0.99)


class Counter:
    """Monotonically increasing counter."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(
                f"counter {self.name!r} cannot decrease (inc by "
                f"{amount}); use a Gauge for values that go down")
        self._value += amount

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, value: float) -> None:
        self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self._value -= amount

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Streaming distribution with exact count/sum/min/max and
    reservoir-backed quantiles (`QUANTILES`)."""

    def __init__(self, name: str, help: str = "",
                 reservoir: int = RESERVOIR_SIZE):
        self.name = name
        self.help = help
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._window: collections.deque = collections.deque(
            maxlen=reservoir)

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.sum += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        self._window.append(value)

    def time(self) -> "_Timer":
        """``with hist.time(): ...`` observes the block's wall
        seconds."""
        return _Timer(self)

    def percentile(self, p: float) -> float:
        """p in [0, 1]; nearest-rank over the reservoir window (NaN
        when nothing has been observed)."""
        if not self._window:
            return math.nan
        xs = sorted(self._window)
        idx = min(len(xs) - 1, max(0, math.ceil(p * len(xs)) - 1))
        return xs[idx]

    def summary(self) -> dict:
        d = {"count": self.count,
             "sum": self.sum,
             "min": self.min if self.count else None,
             "max": self.max if self.count else None}
        for q in QUANTILES:
            v = self.percentile(q)
            d[f"p{int(q * 100)}"] = None if math.isnan(v) else v
        return d


class _Timer:
    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe(time.perf_counter() - self._t0)
        return False


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Names are free-form dotted strings (``serve.tick_s``,
    ``bench.bfs_packed.path_teps``); re-requesting a name returns the
    existing metric, and requesting it as a different type raises
    (one name, one meaning)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kwargs)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested as {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  reservoir: int = RESERVOIR_SIZE) -> Histogram:
        return self._get(Histogram, name, help, reservoir=reservoir)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self) -> Iterator[tuple[str, object]]:
        return iter(sorted(self._metrics.items()))

    def clear(self) -> None:
        """Drop every metric (tests)."""
        with self._lock:
            self._metrics.clear()

    # -- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready state: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: {count, sum, min, max, p50, p90, p99}}}``.
        Round-trips through ``json.dumps``/``loads`` unchanged."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in self:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary()
        # the round-trip contract, enforced at the source: every value
        # must be JSON-representable (inf/nan would survive dumps but
        # not strict parsers)
        return json.loads(json.dumps(out))

    def to_prometheus(self) -> str:
        """Prometheus text exposition (histograms as summaries)."""
        lines: list[str] = []
        for name, m in self:
            pname = name.replace(".", "_").replace("-", "_")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value:g}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {m.value:g}")
            else:
                lines.append(f"# TYPE {pname} summary")
                for q in QUANTILES:
                    v = m.percentile(q)
                    if not math.isnan(v):
                        lines.append(
                            f'{pname}{{quantile="{q:g}"}} {v:g}')
                lines.append(f"{pname}_count {m.count}")
                lines.append(f"{pname}_sum {m.sum:g}")
        return "\n".join(lines) + ("\n" if lines else "")


#: the process-default registry — what the serve tier and benchmark
#: `emit` record into unless handed an explicit one
_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY


# ---------------------------------------------------------------------------
# Degradation events (ISSUE 8)
# ---------------------------------------------------------------------------
# Before this tier, every capacity-driven fallback in the kernel/plan
# path was SILENT: `ops.megakernel_fits` quietly rebuilt the layer as
# unfused steps, `ops.compact_fits` quietly took the dense planner,
# and `spec.resolve` quietly downgraded an auto-selected megakernel on
# formats without one.  Each of those is the right *behavior* (a
# working set past the VMEM budget must still traverse) but the wrong
# *observability*: an operator watching a latency regression had no
# signal that the engine was running a slower pipeline than the spec
# asked for.  `record_degrade` is the one emission point: every
# fallback site now produces a `DegradeEvent` — counted under
# ``serve.degrade.<site>``, appended to a bounded in-process log, and
# warn-once logged with the budget that failed and the pipeline that
# actually runs.

_LOG = logging.getLogger("repro.serve")

#: bounded ring of recent events — the post-mortem view `degrade_log`
#: exposes (counters aggregate; this keeps the *reasons*)
_DEGRADE_LOG_SIZE = 256


@dataclasses.dataclass(frozen=True)
class DegradeEvent:
    """One observable step down the degradation ladder.

    Attributes:
      site: stable counter key (``serve.degrade.<site>``) — e.g.
        ``"vmem_fallback"`` (a VMEM budget rejected the working set)
        or ``"pipeline_unsupported"`` (the format lacks the
        auto-selected pipeline).
      reason: which budget/capability failed, with numbers.
      fallback: what actually runs instead (the honest record an
        operator needs next to a latency regression).
      detail: optional free-form context (geometry, shapes).
    """

    site: str
    reason: str
    fallback: str
    detail: str = ""


_degrade_events: collections.deque = collections.deque(
    maxlen=_DEGRADE_LOG_SIZE)
_degrade_warned: set = set()
_degrade_lock = threading.Lock()


def record_degrade(site: str, reason: str, fallback: str,
                   detail: str = "",
                   registry: MetricsRegistry | None = None
                   ) -> DegradeEvent:
    """Emit a `DegradeEvent`: count + log-once + append to the ring.

    Called from trace/build time code paths (the fallback decisions
    are host booleans), so it is a pure host side effect — safe inside
    ``jax.jit`` tracing and ``jax.eval_shape``.  The warn-once key is
    ``(site, reason)``: the first occurrence logs at WARNING, repeats
    only count (a serving loop re-tracing per geometry must not spam).
    """
    ev = DegradeEvent(site=site, reason=reason, fallback=fallback,
                      detail=detail)
    reg = registry if registry is not None else get_registry()
    reg.counter(
        f"serve.degrade.{site}",
        "observable degradation events (see obs.metrics.DegradeEvent)"
    ).inc()
    with _degrade_lock:
        _degrade_events.append(ev)
        key = (site, reason)
        first = key not in _degrade_warned
        if first:
            _degrade_warned.add(key)
    if first:
        _LOG.warning("degrade[%s]: %s -> running %s%s", site, reason,
                     fallback, f" ({detail})" if detail else "")
    return ev


def degrade_log() -> tuple:
    """Snapshot of the most recent `DegradeEvent`\\ s (newest last)."""
    with _degrade_lock:
        return tuple(_degrade_events)


def clear_degrade_log() -> None:
    """Drop the event ring and re-arm every warn-once (tests)."""
    with _degrade_lock:
        _degrade_events.clear()
        _degrade_warned.clear()
