"""Observability subsystem — spans, metrics, and cost-model drift.

The paper's results are measurement all the way down: Table 1's
per-layer vertex/edge counters, §5's per-run TEPS methodology, the
hyperthreading/affinity studies — and the hybrid follow-up
(arXiv:1704.02259) shows the direction switch is only *tunable* when
per-layer behavior is visible.  The engine has captured on-device
counters since PR 1 (`LayerStats`, `direction_log`) and an analytic
bytes model gated in CI since PR 3; this package adds the axis none of
those record: **time**, plus the check that the hand-derived bytes
model still matches what XLA actually compiles.

Three modules, one concern each:

* `obs.trace`      — span tracer (traversal → layer → step nesting,
  wall clock + optional device sync) exporting Chrome trace-event
  JSON viewable in Perfetto, plus the host-stepped instrumented
  traversal (`trace_run`) that reuses the plan cache's compiled
  `layer_step` so timing never perturbs the fused ``lax.while_loop``
  fast path.
* `obs.metrics`    — process-local counters/gauges/histograms with a
  JSON snapshot and Prometheus-style text exposition; the serve tier
  records submit→harvest latency (p50/p99), tick duration, queue
  depth and slot occupancy through it, and every benchmark `emit`
  lands here too.
* `obs.cost_drift` — the analytic `layer_bytes`/`traversal_bytes`
  models compared against what the compiled program reports
  (``jax.jit(...).lower().compile().cost_analysis()`` and the
  trip-count-aware `roofline.hlo_analyze`), per (format, pipeline) —
  wired as a CI gate so the PR-3/4/6 bytes gates can never silently
  diverge from the compiled program.
"""
from repro.obs.cost_drift import Drift, drift_rows, measure_drift
from repro.obs.metrics import (Counter, DegradeEvent, Gauge, Histogram,
                               MetricsRegistry, clear_degrade_log,
                               degrade_log, get_registry,
                               record_degrade)
from repro.obs.trace import SpanTracer, TraceRun, trace_run, xla_profiler

__all__ = [
    "Counter",
    "DegradeEvent",
    "Drift",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SpanTracer",
    "TraceRun",
    "clear_degrade_log",
    "degrade_log",
    "drift_rows",
    "get_registry",
    "measure_drift",
    "record_degrade",
    "trace_run",
    "xla_profiler",
]
