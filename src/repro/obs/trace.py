"""Span tracer + instrumented host-stepped traversal.

The engine's fused ``lax.while_loop`` (PR 1) deliberately has no host
synchronization inside the layer loop — which is exactly why nothing
can time its layers.  This module adds the *time* axis without
touching that fast path:

* `SpanTracer` — a context-manager span recorder (nesting:
  traversal → layer → step) that exports Chrome trace-event JSON;
  open ``chrome://tracing`` or https://ui.perfetto.dev and load the
  file.  Spans are wall-clock (``time.perf_counter``); callers pass
  device arrays to `SpanTracer.device_sync` so a span's close waits
  for the device work it timed (otherwise JAX's async dispatch would
  attribute everything to the first sync).
* `trace_run` — the instrumented traversal: a host Python layer loop
  over the plan cache's compiled single-layer tick
  (`CompiledTraversal.layer_step`, the same executable the serve tier
  ticks), so per-layer wall times attach to the familiar `LayerStats`
  rows.  The fused whole-search program is never modified — tracing
  is a *mode you opt into*, not overhead the fast path pays.
* `xla_profiler` — gated pass-through to ``jax.profiler.start_trace``
  for full XLA/TensorBoard profiles; combined with the
  ``jax.named_scope`` annotations on every Pallas wrapper in
  `kernels/ops.py`, device time shows up attributed to named BFS
  phases (``bfs.gather_expand``, ``bfs.frontier_compact``, ...).

The host-stepped loop pays one device sync per layer — that is the
price of per-layer timing, and the reason `trace_run` is a separate
entry point instead of a flag that silently de-fuses ``run``.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmap as bm
from repro.core import engine as _engine

#: span names — the obs-smoke gate greps for these
TRAVERSAL_SPAN = "bfs.traversal"
LAYER_SPAN = "bfs.layer"
STEP_SPAN = "bfs.layer_step"
#: the whole-traversal persistent pipeline (ISSUE 9) is ONE Pallas
#: launch — there is no per-layer host boundary to time, so trace_run
#: records ONE span of this name and recovers per-layer counters from
#: the kernel's on-device stats buffer instead of host recomputation
PERSISTENT_SPAN = "bfs.traversal.persistent"
#: the semiring portfolio (ISSUE 10: sssp/cc/ksource_bfs) runs the
#: whole traversal through the portfolio driver's fused while_loop —
#: like the persistent pipeline there is no host layer boundary, so
#: trace_run records ONE span of this name and recovers per-layer
#: counters from the driver's on-device stats buffer
SEMIRING_SPAN = "bfs.traversal.semiring"


@dataclass
class Span:
    """One closed span: microsecond offset + duration relative to the
    tracer's origin, plus free-form ``args`` shown in the trace UI."""
    name: str
    ts_us: float = 0.0
    dur_us: float = 0.0
    tid: int = 1
    args: dict = field(default_factory=dict)


class SpanTracer:
    """Records nested wall-clock spans; exports Chrome trace events.

    Usage::

        tr = SpanTracer()
        with tr.span("bfs.traversal", n_roots=4):
            with tr.span("bfs.layer", layer=0):
                ...work...
        tr.export("obs_trace.json")      # load in Perfetto

    ``sync=True`` (default) makes `device_sync` call
    ``jax.block_until_ready`` so spans measure finished device work,
    not dispatch latency; ``sync=False`` turns every `device_sync`
    into a no-op (time the async dispatch itself).
    """

    def __init__(self, sync: bool = True):
        self.sync = sync
        self.spans: list[Span] = []
        self._origin = time.perf_counter()
        self._stack: list[Span] = []

    def _now_us(self) -> float:
        return (time.perf_counter() - self._origin) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **args: Any) -> Iterator[Span]:
        """Open a span; closes (records duration) on exit.  Extra
        kwargs become the trace event's ``args`` and may be amended on
        the yielded `Span` before exit."""
        s = Span(name, args=dict(args))
        self._stack.append(s)
        s.ts_us = self._now_us()
        try:
            yield s
        finally:
            s.dur_us = self._now_us() - s.ts_us
            self._stack.pop()
            self.spans.append(s)

    def device_sync(self, *arrays) -> None:
        """Wait for device work (``jax.block_until_ready``) so the
        enclosing span's close time is honest.  No-op when the tracer
        was built with ``sync=False``."""
        if self.sync:
            jax.block_until_ready(arrays)

    # -- export ----------------------------------------------------------
    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object (the ``traceEvents`` array
        of complete "X" events).  Nesting is implied by time
        containment on the shared tid — exactly how Perfetto draws
        flame stacks."""
        pid = os.getpid()
        events: list[dict] = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": "repro.bfs"},
        }]
        for s in sorted(self.spans, key=lambda s: s.ts_us):
            events.append({
                "name": s.name, "cat": "bfs", "ph": "X",
                "ts": round(s.ts_us, 3), "dur": round(s.dur_us, 3),
                "pid": pid, "tid": s.tid, "args": s.args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON to ``path``; returns the path."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, indent=1)
        return path

    def __len__(self) -> int:
        return len(self.spans)


@contextlib.contextmanager
def xla_profiler(logdir: str | None):
    """``jax.profiler.start_trace``/``stop_trace`` around a block when
    the installed jax exposes it AND ``logdir`` is set; a silent no-op
    otherwise (CPU wheels without profiler support, logdir=None).
    Combined with the `kernels.ops` ``jax.named_scope`` annotations,
    the resulting TensorBoard/Perfetto profile attributes device time
    to named BFS phases."""
    if logdir is None or not hasattr(jax.profiler, "start_trace"):
        yield None
        return
    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()


class TraceRun(NamedTuple):
    """What `trace_run` returns: the usual engine outputs plus timing.

    ``stats[i]`` and ``layer_seconds[i]`` describe the same layer —
    the per-layer timing "attached to the LayerStats row".  ``state``
    and ``depths`` match `EngineResult` semantics (unbatched when a
    scalar root was passed)."""
    state: _engine.BfsState
    depths: jax.Array                     # (B,) or scalar int32
    stats: list[_engine.LayerStats]
    layer_seconds: list[float]
    tracer: SpanTracer


def trace_run(graph, roots, *, spec=None, tracer: SpanTracer | None = None,
              sync: bool = True, profile_logdir: str | None = None
              ) -> TraceRun:
    """Instrumented traversal: per-layer wall-clock spans + counters.

    Runs a host Python layer loop over the *plan cache's* compiled
    single-layer tick — the same `layer_jit` executable
    `CompiledTraversal.layer_step` and the serve tier use — so this
    mode adds zero new compiles beyond the layer tick and never
    perturbs the fused ``lax.while_loop`` program.  Each layer pays
    one ``block_until_ready`` sync (that is what buys honest
    timings); per-layer Table 1 counters (frontier vertices, edges
    examined, discovered) are recomputed host-side from word popcounts
    and the word-aligned degree matrix, identical to the fused
    engine's on-device accounting.

    Args:
      graph: a `Csr`/`EdgeList`/`GraphFormat` (planned here) or an
        existing `repro.bfs.CompiledTraversal` (reused — zero extra
        traces when it has already run).
      roots: int (unbatched result) or sequence (leading root axis).
      spec: optional `TraversalSpec` when ``graph`` is not already a
        plan.  The layer tick runs the spec's fixed SIMD/scalar step
        (``algorithm``); direction *policies* decide inside the fused
        program and do not apply to the host-stepped mode.
      tracer: record into an existing `SpanTracer` (default: fresh
        one with ``sync=``).
      sync: block on device work at span close (see `SpanTracer`).
      profile_logdir: also wrap the loop in `xla_profiler`.

    Returns a `TraceRun`; ``len(stats) == len(layer_seconds)`` == the
    number of layer spans recorded (the obs-smoke acceptance gate).
    """
    from repro.api.plan import CompiledTraversal, plan as _plan
    ct = (graph if isinstance(graph, CompiledTraversal)
          else _plan(graph, spec))
    if ct.mesh is not None:
        raise NotImplementedError(
            "trace_run hosts the single-chip layer tick; mesh-bound "
            "plans have no per-layer step to instrument")
    tracer = tracer if tracer is not None else SpanTracer(sync=sync)
    fmt, rspec = ct.fmt, ct.resolved
    n_vertices, v_pad = fmt.n_vertices, fmt.n_vertices_padded

    single = jnp.ndim(roots) == 0
    roots_b = jnp.atleast_1d(jnp.asarray(roots, jnp.int32))
    n_roots = int(roots_b.shape[0])

    if rspec.is_semiring:
        # ONE run, ONE span: the portfolio driver owns the
        # value/frontier carry inside a fused while_loop, so (like
        # the persistent pipeline) there is no per-layer host
        # boundary; Table 1-equivalent counters come back from the
        # driver's stats buffer and the per-layer seconds are the
        # span amortized over the recovered layers.
        with xla_profiler(profile_logdir), \
             tracer.span(SEMIRING_SPAN, n_roots=n_roots,
                         format=type(fmt).__name__,
                         pipeline=rspec.pipeline,
                         algorithm=rspec.algorithm,
                         n_vertices=n_vertices) as top:
            res = ct.run_batched(roots_b)
            tracer.device_sync(res.state.frontier, res.state.parent,
                               res.values, res.stats)
            stats = _engine.layer_stats(res)
            top.args["n_layers"] = len(stats)
            top.args["launches"] = sum(s.launches for s in stats)
            top.args["relaxations"] = sum(s.edges_examined
                                          for s in stats)
        per_layer_s = (top.dur_us / 1e6) / max(len(stats), 1)
        layer_seconds = [per_layer_s] * len(stats)
        state, depths_j = res.state, res.depths
        if single:
            state = _engine.BfsState(state.frontier[0],
                                     state.visited[0],
                                     state.parent[0], state.layer)
            depths_j = depths_j[0]
        return TraceRun(state, depths_j, stats, layer_seconds, tracer)

    if rspec.pipeline == "persistent":
        # ONE launch, ONE span: the layer loop runs inside the kernel
        # (ISSUE 9), so there is no per-layer host boundary to time.
        # Per-layer Table 1 counters come back from the kernel's
        # on-device stats buffer (`engine.layer_stats`); the per-layer
        # seconds are the single span's duration amortized over the
        # recovered layers — the honest figure when layers cannot be
        # individually observed (len(stats) == len(layer_seconds)
        # still holds for every consumer).
        with xla_profiler(profile_logdir), \
             tracer.span(PERSISTENT_SPAN, n_roots=n_roots,
                         format=type(fmt).__name__,
                         pipeline=rspec.pipeline,
                         algorithm=rspec.algorithm,
                         n_vertices=n_vertices) as top:
            res = ct.run_batched(roots_b)
            tracer.device_sync(res.state.frontier, res.state.visited,
                               res.state.parent, res.stats)
            stats = _engine.layer_stats(res)
            top.args["n_layers"] = len(stats)
            top.args["launches"] = sum(s.launches for s in stats)
            top.args["layers"] = [
                {"frontier_vertices": s.frontier_vertices,
                 "edges_examined": s.edges_examined,
                 "discovered": s.discovered} for s in stats]
        per_layer_s = (top.dur_us / 1e6) / max(len(stats), 1)
        layer_seconds = [per_layer_s] * len(stats)
        state, depths_j = res.state, res.depths
        if single:
            state = _engine.BfsState(state.frontier[0], state.visited[0],
                                     state.parent[0], state.layer)
            depths_j = depths_j[0]
        return TraceRun(state, depths_j, stats, layer_seconds, tracer)

    deg_mat = bm.degree_matrix(fmt.degrees(), v_pad)

    stats: list[_engine.LayerStats] = []
    layer_seconds: list[float] = []
    depths = np.zeros((n_roots,), np.int32)

    with xla_profiler(profile_logdir), \
         tracer.span(TRAVERSAL_SPAN, n_roots=n_roots,
                     format=type(fmt).__name__, pipeline=rspec.pipeline,
                     algorithm=rspec.algorithm, n_vertices=n_vertices
                     ) as top:
        with tracer.span("bfs.init"):
            frontier, visited, parent = _engine._init_batched(
                roots_b, n_vertices, v_pad)
            tracer.device_sync(frontier, visited, parent)
        layer = 0
        while layer < rspec.max_layers:
            f_count_b = np.asarray(_engine.row_popcounts(frontier))
            f_count = int(f_count_b.sum())
            if f_count == 0:
                break
            f_edges = int(np.asarray(jax.vmap(
                lambda w: bm.masked_degree_sum(w, deg_mat))(frontier)
            ).sum())
            with tracer.span(LAYER_SPAN, layer=layer,
                             frontier_vertices=f_count,
                             edges_examined=f_edges) as lsp:
                with tracer.span(STEP_SPAN, layer=layer):
                    frontier, visited, parent = ct.layer_step(
                        frontier, visited, parent)
                    tracer.device_sync(frontier, visited, parent)
                discovered = int(_engine.row_popcounts(frontier).sum())
                lsp.args["discovered"] = discovered
            stats.append(_engine.LayerStats(
                layer=layer, frontier_vertices=f_count,
                edges_examined=f_edges, discovered=discovered))
            layer_seconds.append(lsp.dur_us / 1e6)
            depths += (f_count_b > 0).astype(np.int32)
            layer += 1
        top.args["n_layers"] = layer

    state = _engine.BfsState(frontier, visited, parent, jnp.int32(layer))
    depths_j = jnp.asarray(depths)
    if single:
        state = _engine.BfsState(state.frontier[0], state.visited[0],
                                 state.parent[0], state.layer)
        depths_j = depths_j[0]
    return TraceRun(state, depths_j, stats, layer_seconds, tracer)
