"""Analytic bytes model vs what XLA actually compiled.

The repo's CI gates (PR 3/4/6) compare *analytic* per-layer byte
models (`formats.base.layer_bytes` / `tile_bytes` / `plan_bytes`)
against each other — fused vs materialized, packed vs dense.  Nothing
checks the models against the *compiled program*: if a format's
`layer_bytes` drifts from what its kernels really stream (a refactor
changes the stream layout, a new XLA version fuses differently), every
downstream gate keeps passing while measuring fiction.

This module closes that loop.  For each (format, pipeline) it compiles
the plan cache's single-layer tick — the exact executable `run`,
`layer_step` and the serve tier share — and reads two independent
compiled-side byte counts:

* ``jax.jit(...).lower().compile().cost_analysis()`` — XLA's own
  "bytes accessed" estimate;
* `roofline.hlo_analyze.analyze` over the optimized HLO text — our
  trip-count-aware analyzer (tighter fusion model).

against the analytic *full-sweep* per-layer model (the compiled
program is data-independent — it contains the code for every tile, so
the comparable analytic figure is all-tiles-active + the planning
pass, not a measured thin-frontier layer).

The ratio ``compiled / analytic`` is NOT expected to be 1.0 — the
compiled program also moves state bitmaps, work-lists, and whatever
XLA materializes between fusions (interpret-mode Pallas adds its own
overhead).  What the CI gate pins is the ratio's *stability*: the
measured ratio must stay within tolerance of the committed
BENCH_bfs.json baseline, so either side drifting (model edit, kernel
rewrite, XLA upgrade) fails loudly instead of silently skewing the
PR-3/4/6 gates.  See ``benchmarks/check_bytes_regression.py`` gate 4.
"""
from __future__ import annotations

from typing import NamedTuple

from repro.core import engine as _engine


class Drift(NamedTuple):
    """One (format, pipeline) comparison row."""
    format: str
    pipeline: str
    analytic_bytes: int        # full-sweep per-layer model
    compiled_bytes: float      # XLA cost_analysis "bytes accessed"
    hlo_bytes: float           # roofline.hlo_analyze over the HLO text
    tile: int

    @property
    def ratio(self) -> float:
        """compiled / analytic — the drift figure the CI gate pins."""
        return (self.compiled_bytes / self.analytic_bytes
                if self.analytic_bytes else float("nan"))

    @property
    def hlo_ratio(self) -> float:
        return (self.hlo_bytes / self.analytic_bytes
                if self.analytic_bytes else float("nan"))


def cost_analysis_bytes(compiled) -> float:
    """'bytes accessed' out of ``compiled.cost_analysis()`` across the
    jax versions in play (dict, or a one-element list of dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("bytes accessed", 0.0))


def analytic_layer_bytes(fmt, *, pipeline: str, tile: int,
                         packed: bool = True) -> int:
    """The model's bytes for one FULL-SWEEP layer — the figure
    comparable to a compiled (data-independent) layer program.

    ``materialized`` streams the whole apportioned edge stream
    (`layer_bytes`); the fused pipelines stream every tile plus the
    planning pass (`tile_bytes * n_blocks + plan_bytes`) — the
    all-tiles-active ceiling `formats.base.traversal_bytes` charges a
    dense layer."""
    _engine.check_pipeline(pipeline)
    if pipeline == "materialized":
        return fmt.layer_bytes()
    n_blocks = -(-fmt.edge_slots // max(tile, 1))
    return fmt.tile_bytes(tile) * n_blocks + fmt.plan_bytes(tile, packed)


def measure_drift(graph, spec=None, *,
                  pipelines=("fused_gather", "materialized"),
                  batch: int = 1) -> list[Drift]:
    """Compile the single-layer tick per pipeline and compare byte
    counts.  Reuses the plan cache (`repro.bfs.plan`), so a pipeline
    already compiled by tests/benchmarks costs only the ``lower``/
    ``compile`` replay, not a new trace.

    Args:
      graph: Csr/EdgeList/GraphFormat (same contract as ``plan``).
      spec: base `TraversalSpec`; its ``pipeline`` field is overridden
        per entry of ``pipelines``.
      pipelines: which pipeline flavours to compile (the caller skips
        flavours the format rejects, e.g. megakernel on SELL).
      batch: root-batch width of the compiled tick (1 = the analytic
        model's single-root accounting).
    """
    import jax.numpy as jnp

    from repro.api.plan import plan as _plan
    from repro.api.spec import TraversalSpec
    from repro.roofline import hlo_analyze

    spec = spec if spec is not None else TraversalSpec()
    out: list[Drift] = []
    for pipeline in pipelines:
        ct = _plan(graph, spec.replace(pipeline=pipeline))
        fmt, rspec = ct.fmt, ct.resolved
        roots = jnp.zeros((batch,), jnp.int32)
        f, v, p = _engine._init_batched(roots, fmt.n_vertices,
                                        fmt.n_vertices_padded)
        lowered = ct.executable.layer_jit.lower(fmt, f, v, p)
        compiled = lowered.compile()
        out.append(Drift(
            format=type(fmt).name,
            pipeline=pipeline,
            analytic_bytes=analytic_layer_bytes(
                fmt, pipeline=pipeline, tile=rspec.tile,
                packed=rspec.packed),
            compiled_bytes=cost_analysis_bytes(compiled),
            hlo_bytes=float(hlo_analyze.analyze(compiled.as_text())
                            .bytes),
            tile=rspec.tile))
    return out


def drift_rows(drifts: list[Drift], prefix: str = "obs.cost_drift"
               ) -> dict:
    """BENCH_bfs.json rows: ``{prefix}.{format}.{pipeline}`` ->
    {analytic_bytes, compiled_bytes, hlo_bytes, ratio, hlo_ratio,
    tile}.  The ``ratio`` value is what gate 4 of
    ``check_bytes_regression`` pins against the committed baseline."""
    rows = {}
    for d in drifts:
        rows[f"{prefix}.{d.format}.{d.pipeline}"] = {
            "analytic_bytes": d.analytic_bytes,
            "compiled_bytes": d.compiled_bytes,
            "hlo_bytes": d.hlo_bytes,
            "ratio": d.ratio,
            "hlo_ratio": d.hlo_ratio,
            "tile": d.tile,
        }
    return rows
