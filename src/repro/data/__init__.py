"""Substrate: data."""
