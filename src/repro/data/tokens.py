"""Synthetic token data pipeline.

Deterministic, host-sharded, restart-safe: batch ``i`` on host ``h`` is
a pure function of (seed, step, host), so a restarted job regenerates
exactly the stream it would have seen — the data-side half of
fault-tolerant training (runtime/fault.py) and the straggler story
(no host ever waits on a data feed).

The "corpus" is a Zipf-like mixture with Markov structure so losses
actually decrease during the example runs (pure uniform tokens have
no learnable signal).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    batch_size: int = 8          # per-host batch
    seq_len: int = 128
    n_hosts: int = 1
    host_id: int = 0


def _zipf_logits(vocab: int) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -1.1 * jnp.log(ranks)


def batch_at(cfg: ModelConfig, dcfg: DataConfig, step: int) -> dict:
    """The (host, step)-indexed batch. Pure function — restart safe."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step),
        dcfg.host_id)
    k1, k2, k3 = jax.random.split(key, 3)
    b, t = dcfg.batch_size, dcfg.seq_len
    base = jax.random.categorical(
        k1, _zipf_logits(cfg.vocab_size), shape=(b, t + 1))
    # Markov-ish structure: with p=0.5 the next token is a fixed
    # function of the previous one (learnable bigram signal)
    follow = (base * 31 + 7) % cfg.vocab_size
    coin = jax.random.bernoulli(k2, 0.5, (b, t + 1))
    toks = jnp.where(coin, jnp.roll(follow, 1, axis=1), base)
    toks = toks.astype(jnp.int32)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.prefix_len:
        out["prefix"] = 0.02 * jax.random.normal(
            k3, (b, cfg.prefix_len, cfg.d_model))
    if cfg.encoder_layers:
        out["src_embeddings"] = 0.02 * jax.random.normal(
            k3, (b, max(t // 4, 8), cfg.d_model))
    return out


def stream(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0):
    """Infinite restartable iterator of (step, batch)."""
    step = start_step
    while True:
        yield step, batch_at(cfg, dcfg, step)
        step += 1
