PY ?= python
export PYTHONPATH := src

.PHONY: test test-quick obs-smoke chaos-smoke bench bench-quick bench-formats bench-affinity bench-gate

test:            ## full tier-1 suite (ROADMAP verify command)
	$(PY) -m pytest -x -q

test-quick:      ## BFS substrate + engine + formats + API (fast inner loop)
	$(PY) -m pytest -x -q tests/test_bitmap.py tests/test_kernels.py \
	    tests/test_bfs_correctness.py tests/test_engine.py \
	    tests/test_formats.py tests/test_gather_pipeline.py \
	    tests/test_packed_engine.py tests/test_plan_api.py \
	    tests/test_api_surface.py tests/test_megakernel.py \
	    tests/test_persistent.py tests/test_obs.py \
	    tests/test_serve_robust.py tests/test_graph_validation.py
	$(MAKE) obs-smoke
	$(MAKE) chaos-smoke

obs-smoke:       ## end-to-end obs contract (trace JSON + serve metrics)
	$(PY) -m benchmarks.obs_smoke

chaos-smoke:     ## serve robustness under fault injection (zero lost queries)
	$(PY) -m benchmarks.chaos_smoke

bench:           ## full benchmark harness
	$(PY) -m benchmarks.run

bench-quick:     ## batched + formats + layer/bytes + packed + plan-cache probes (updates BENCH_bfs.json)
	$(PY) -m benchmarks.run --quick --only bfs_batched
	$(PY) -m benchmarks.run --quick --only bfs_formats
	$(PY) -m benchmarks.run --quick --only bfs_layers
	$(PY) -m benchmarks.run --quick --only bfs_packed
	$(PY) -m benchmarks.run --quick --only bfs_plan_cache
	$(PY) -m benchmarks.run --quick --only bfs_megakernel
	$(PY) -m benchmarks.run --quick --only bfs_persistent
	$(PY) -m benchmarks.run --quick --only bfs_algorithms

bench-formats:   ## the graph-format sweep (TEPS + bytes per layout)
	$(PY) -m benchmarks.run --only bfs_formats

bench-affinity:  ## regenerate the geometry-keyed autotune table rows
	$(PY) -m benchmarks.run --only affinity

bench-gate:      ## CI: fused bytes-moved vs committed BENCH_bfs.json
	$(PY) -m benchmarks.check_bytes_regression
