PY ?= python
export PYTHONPATH := src

.PHONY: test test-quick bench bench-quick

test:            ## full tier-1 suite (ROADMAP verify command)
	$(PY) -m pytest -x -q

test-quick:      ## BFS substrate + engine only (fast inner loop)
	$(PY) -m pytest -x -q tests/test_bitmap.py tests/test_kernels.py \
	    tests/test_bfs_correctness.py tests/test_engine.py

bench:           ## full benchmark harness
	$(PY) -m benchmarks.run

bench-quick:     ## the batched-BFS benchmark at CI scale
	$(PY) -m benchmarks.run --quick --only bfs_batched
