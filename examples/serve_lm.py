"""Batched serving example: continuous batching over a small model.

Submits a mixed burst of requests with different prompt/output lengths
and serves them through fixed decode slots with slot reuse, printing
per-request completions and aggregate throughput.

    PYTHONPATH=src python examples/serve_lm.py [--arch rwkv6]
"""
import argparse
import sys
import time

import jax

from repro.configs import registry
from repro.models import lm
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    args = ap.parse_args()

    cfg = registry.get(args.arch, reduced=True).with_(dtype="float32")
    print(f"== serving {cfg.name} ({cfg.family}) with "
          f"{args.slots} decode slots")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=args.slots,
                      cache_len=128)

    rng = jax.random.PRNGKey(1)
    for uid in range(args.requests):
        rng, k = jax.random.split(rng)
        plen = 2 + uid % 5
        prompt = [int(t) for t in jax.random.randint(
            k, (plen,), 0, cfg.vocab_size)]
        eng.submit(Request(uid=uid, prompt=prompt,
                           max_tokens=4 + uid % 8))

    t0 = time.perf_counter()
    ticks = eng.run_until_done()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in eng.finished)
    print(f"== {len(eng.finished)} requests, {total_tokens} tokens in "
          f"{ticks} engine ticks, {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s)")
    for r in sorted(eng.finished, key=lambda r: r.uid)[:5]:
        print(f"   req {r.uid}: prompt={r.prompt} -> {r.generated}")
    assert len(eng.finished) == args.requests
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
