"""End-to-end training driver: train a ~100M-param LM with the full
production stack — data pipeline, AdamW, checkpointing, fault-tolerant
loop — on CPU.

Default is a quick demonstration (~20M params, 30 steps).  The full
assignment setting is reproduced with:

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

--arch accepts any assigned architecture id; the reduced config of
that family is scaled to the preset size.
"""
import argparse
import sys
import time

import jax

from repro.checkpoint.ckpt import CheckpointManager
from repro.configs import registry
from repro.data.tokens import DataConfig, stream
from repro.models import lm
from repro.models.config import param_count
from repro.runtime.fault import FailureInjector, train_loop
from repro.train import optimizer as opt
from repro.train.train_step import TrainConfig, make_train_step

PRESETS = {
    # name: (n_layers, d_model, n_heads, kv, d_ff, vocab)
    "tiny": (2, 128, 4, 2, 512, 2048),
    "20m": (6, 384, 6, 2, 1536, 8192),
    "100m": (12, 768, 12, 4, 3072, 32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3")
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--inject-failure", type=int, default=None,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    L, d, h, kv, ff, v = PRESETS[args.preset]
    cfg = registry.get(args.arch, reduced=True).with_(
        name=f"{args.arch}-{args.preset}", dtype="float32",
        n_layers=L, d_model=d, n_heads=h,
        n_kv_heads=min(kv, h), head_dim=d // h, d_ff=ff, vocab_size=v,
        vocab_chunk=1024)
    print(f"== {cfg.name}: ~{param_count(cfg)/1e6:.0f}M params "
          f"({cfg.family} family)")

    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(adamw=opt.AdamWConfig(
        lr=args.lr, warmup_steps=min(20, args.steps // 5),
        total_steps=args.steps))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(batch_size=args.batch, seq_len=args.seq)
    ckpt = CheckpointManager(args.ckpt_dir, every=max(args.steps // 5, 1))
    injector = (FailureInjector(at_steps=(args.inject_failure,))
                if args.inject_failure else None)

    t0 = time.perf_counter()
    last_print = [0]

    class PrintingStream:
        def __call__(self, start):
            for step, batch in stream(cfg, dcfg, start):
                yield step, batch

    def data_fn(start):
        return stream(cfg, dcfg, start)

    stats = train_loop(
        train_step=step_fn, params=params, opt_state=opt.init(params),
        data_stream_fn=data_fn, ckpt=ckpt, total_steps=args.steps,
        injector=injector)

    dt = time.perf_counter() - t0
    first = sum(stats.losses[:3]) / max(len(stats.losses[:3]), 1)
    last = sum(stats.losses[-3:]) / max(len(stats.losses[-3:]), 1)
    tok_s = stats.steps * args.batch * args.seq / dt
    print(f"== done: {stats.steps} steps in {dt:.1f}s "
          f"({tok_s:,.0f} tok/s)")
    print(f"   loss {first:.3f} -> {last:.3f}  "
          f"restarts={stats.restarts} stragglers={stats.stragglers}")
    assert last < first, "loss did not decrease"
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
