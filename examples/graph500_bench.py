"""Graph500-style benchmark run — the paper's §5 experimental design.

64 BFS executions from random start vertices on an RMAT graph,
harmonic-mean TEPS, with the Graph500 soft validation on each run —
the end-to-end driver for the paper's kind of system (throughput
benchmark), mirroring Fig. 10.

    PYTHONPATH=src python examples/graph500_bench.py --scale 16 --roots 64
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_parallel import run_bfs
from repro.core.bfs_serial import bfs_serial
from repro.core.bfs_vectorized import run_bfs_vectorized
from repro.core.stats import run_harness


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--roots", type=int, default=64)
    ap.add_argument("--validate", action="store_true")
    ap.add_argument("--algorithm", default="vectorized",
                    choices=["vectorized", "simd", "nonsimd"])
    args = ap.parse_args()

    print(f"== Graph500 kernel 1: SCALE={args.scale} "
          f"edgefactor={args.edgefactor}")
    t0 = time.perf_counter()
    g = csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(2), args.scale,
                      args.edgefactor))
    print(f"   construction: {time.perf_counter()-t0:.1f}s  "
          f"|V|={g.n_vertices:,} |E|={g.n_edges:,}")

    fn = {"vectorized": run_bfs_vectorized,
          "simd": lambda c, r: run_bfs(c, r, algorithm="simd"),
          "nonsimd": lambda c, r: run_bfs(c, r, algorithm="nonsimd"),
          }[args.algorithm]

    ref_fn = None
    if args.validate:
        rows = np.asarray(g.rows)
        cs = np.asarray(g.colstarts)
        ref_fn = lambda root: bfs_serial(rows, cs, g.n_vertices,
                                         root)[1]

    print(f"== Graph500 kernel 2: {args.roots} BFS runs "
          f"({args.algorithm})")
    res = run_harness(g, fn, jax.random.PRNGKey(11),
                      n_roots=args.roots,
                      validate_runs=args.validate,
                      reference_depths_fn=ref_fn)
    if args.validate:
        bad = [r for r in res.runs if r.valid is False]
        assert not bad, f"validation failures: {bad}"
        print("   all runs validated")
    print(f"   {res.summary()}")
    print(f"   harmonic_mean_TEPS {res.hmean_teps:.3e}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
