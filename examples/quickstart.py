"""Quickstart: the paper's pipeline end to end on a laptop-size graph.

Generates a Graph500 RMAT graph, then drives everything through the
declarative API (`repro.bfs`): each paper variant (serial oracle
aside) is ONE `TraversalSpec`, planned once (`bfs.plan` — autos
resolved against the graph, one cached jit executable) and run for
many roots.  Validates every tree and prints the TEPS comparison
table the paper's Fig. 9/10 are built from.

    PYTHONPATH=src python examples/quickstart.py [--scale 14]
"""
import argparse
import sys
import time

import jax
import numpy as np

import repro.bfs as bfs
from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.stats import run_harness
from repro.core.validate import validate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--roots", type=int, default=8)
    args = ap.parse_args()

    print(f"== Graph500 RMAT: SCALE={args.scale} "
          f"edgefactor={args.edgefactor}")
    t0 = time.perf_counter()
    edges = rmat.generate(jax.random.PRNGKey(42), args.scale,
                          args.edgefactor)
    g = csr_mod.from_edges(edges)
    print(f"   |V|={g.n_vertices:,} |E|={g.n_edges:,} "
          f"(built in {time.perf_counter()-t0:.1f}s)")

    root = 1
    while int(g.out_degree(root)) == 0:
        root += 1

    print(f"== serial oracle (Algorithm 1), root={root}")
    p_ref, d_ref = bfs_serial(np.asarray(g.rows), np.asarray(g.colstarts),
                              g.n_vertices, root)
    print(f"   reached {int((d_ref >= 0).sum()):,} vertices, "
          f"depth {int(d_ref.max())}")

    # each paper variant is one declarative spec; plan once, run many
    specs = {
        "nonsimd (Alg. 2)": bfs.TraversalSpec(policy="topdown",
                                              algorithm="nonsimd"),
        "bitmap+restoration (Alg. 3)": bfs.TraversalSpec(
            policy="topdown"),
        "vectorized kernels (§4)": bfs.TraversalSpec(
            policy="threshold_simd"),
        "hybrid (beyond paper)": bfs.TraversalSpec(policy="beamer"),
    }
    plans = {name: bfs.plan(g, spec) for name, spec in specs.items()}
    for name, ct in plans.items():
        state = ct.run(root).state
        p = parents_graph500(state, g.n_vertices)
        res = validate(g, p, root, reference_depth=d_ref)
        assert res.ok, f"{name}: validation failed: {res}"
        print(f"   [valid] {name}")

    auto = bfs.plan(g)          # every field "auto", resolved once
    print(f"== auto plan resolves to: {auto.resolved.to_dict()}")

    print(f"== TEPS harness ({args.roots} random roots, harmonic mean)")
    for name, ct in plans.items():
        h = run_harness(g, lambda c, r, ct=ct: ct.run(r).state,
                        jax.random.PRNGKey(7), n_roots=args.roots)
        print(f"   {name:32s} {h.summary()}")
    print(f"   plan cache: {bfs.plan_cache_info()} — every harness "
          f"root reused its plan's one trace")

    print("== graph formats (§4.2's layout axis, repro/formats)")
    from repro.formats import autotune, registry
    fmts = {name: registry.get(name).from_graph(g)
            for name in ("csr", "sell")}
    base = fmts["csr"].footprint().total_bytes
    fmt_spec = bfs.TraversalSpec(policy="threshold_simd")
    for name, fmt in fmts.items():
        fp = fmt.footprint()
        extra = (f" fill={fmt.fill_ratio:.2f} slices_of_128"
                 if name == "sell" else "")
        print(f"   {fp.summary()}  ({fp.total_bytes/base:.2f}x csr)"
              f"{extra}")
        state = bfs.plan(fmt, fmt_spec).run(root).state
        res = validate(g, parents_graph500(state, g.n_vertices), root,
                       reference_depth=d_ref)
        assert res.ok, f"format {name}: validation failed: {res}"
    choice = autotune.choose(g)
    print(f"   autotuner picks [{choice.format}]: {choice.reason}")

    print(f"== batched multi-root engine ({args.roots} roots, 1 launch)")
    roots = [root + i for i in range(args.roots)]
    ct = plans["bitmap+restoration (Alg. 3)"]
    t0 = time.perf_counter()
    res = ct.run_batched(roots)
    jax.block_until_ready(res.state.parent)
    dt = time.perf_counter() - t0
    # depths counts active layers (= eccentricity + 1 from the root)
    print(f"   {args.roots} searches in {dt:.2f}s "
          f"({args.roots/dt:.1f} roots/s), max tree depth "
          f"{(np.asarray(res.depths) - 1).tolist()}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
