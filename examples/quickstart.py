"""Quickstart: the paper's pipeline end to end on a laptop-size graph.

Generates a Graph500 RMAT graph, runs all four BFS variants (serial
oracle, Algorithm 2, Algorithm 3 + restoration, §4 vectorized with
Pallas kernels, hybrid), validates every tree, and prints the TEPS
comparison table the paper's Fig. 9/10 are built from.

    PYTHONPATH=src python examples/quickstart.py [--scale 14]
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_hybrid import run_bfs_hybrid
from repro.core.bfs_parallel import parents_graph500, run_bfs
from repro.core.bfs_serial import bfs_serial
from repro.core.bfs_vectorized import run_bfs_vectorized
from repro.core.stats import run_harness
from repro.core.validate import validate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--edgefactor", type=int, default=16)
    ap.add_argument("--roots", type=int, default=8)
    args = ap.parse_args()

    print(f"== Graph500 RMAT: SCALE={args.scale} "
          f"edgefactor={args.edgefactor}")
    t0 = time.perf_counter()
    edges = rmat.generate(jax.random.PRNGKey(42), args.scale,
                          args.edgefactor)
    g = csr_mod.from_edges(edges)
    print(f"   |V|={g.n_vertices:,} |E|={g.n_edges:,} "
          f"(built in {time.perf_counter()-t0:.1f}s)")

    root = 1
    while int(g.out_degree(root)) == 0:
        root += 1

    print(f"== serial oracle (Algorithm 1), root={root}")
    p_ref, d_ref = bfs_serial(np.asarray(g.rows), np.asarray(g.colstarts),
                              g.n_vertices, root)
    print(f"   reached {int((d_ref >= 0).sum()):,} vertices, "
          f"depth {int(d_ref.max())}")

    variants = {
        "nonsimd (Alg. 2)": lambda c, r: run_bfs(c, r,
                                                 algorithm="nonsimd"),
        "bitmap+restoration (Alg. 3)": lambda c, r: run_bfs(
            c, r, algorithm="simd"),
        "vectorized kernels (§4)": run_bfs_vectorized,
        "hybrid (beyond paper)": run_bfs_hybrid,
    }
    for name, fn in variants.items():
        state = fn(g, root)
        p = parents_graph500(state, g.n_vertices)
        res = validate(g, p, root, reference_depth=d_ref)
        assert res.ok, f"{name}: validation failed: {res}"
        print(f"   [valid] {name}")

    print(f"== TEPS harness ({args.roots} random roots, harmonic mean)")
    for name, fn in variants.items():
        h = run_harness(g, fn, jax.random.PRNGKey(7),
                        n_roots=args.roots)
        print(f"   {name:32s} {h.summary()}")

    print("== graph formats (§4.2's layout axis, repro/formats)")
    from repro.core import engine
    from repro.formats import autotune, registry
    fmts = {name: registry.get(name).from_graph(g)
            for name in ("csr", "sell")}
    base = fmts["csr"].footprint().total_bytes
    for name, fmt in fmts.items():
        fp = fmt.footprint()
        extra = (f" fill={fmt.fill_ratio:.2f} slices_of_128"
                 if name == "sell" else "")
        print(f"   {fp.summary()}  ({fp.total_bytes/base:.2f}x csr)"
              f"{extra}")
        state = engine.traverse(fmt, root).state
        res = validate(g, parents_graph500(state, g.n_vertices), root,
                       reference_depth=d_ref)
        assert res.ok, f"format {name}: validation failed: {res}"
    choice = autotune.choose(g)
    print(f"   autotuner picks [{choice.format}]: {choice.reason}")

    print(f"== batched multi-root engine ({args.roots} roots, 1 launch)")
    roots = [root + i for i in range(args.roots)]
    t0 = time.perf_counter()
    res = engine.traverse(g, roots, policy=engine.TopDown())
    jax.block_until_ready(res.state.parent)
    dt = time.perf_counter() - t0
    # depths counts active layers (= eccentricity + 1 from the root)
    print(f"   {args.roots} searches in {dt:.2f}s "
          f"({args.roots/dt:.1f} roots/s), max tree depth "
          f"{(np.asarray(res.depths) - 1).tolist()}")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
