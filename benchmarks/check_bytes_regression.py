"""CI gate: the fused pipeline's analytic bytes-moved must not regress.

Recomputes the high-diameter probe (`bfs_layers.path_probe`: path
graph SCALE-10, SIMD forced, fixed tile) with the *current* code and
compares against the committed baseline in ``BENCH_bfs.json``.  The
number is analytic — per-layer active tiles x tile bytes + planning —
so the gate is deterministic and immune to CI timing noise, yet any
structural regression (a step that stops scheduling work-lists, a
planner that marks everything active, a kernel that re-materializes
the stream) inflates it immediately.

Run BEFORE ``make bench-quick`` in CI: the bench run merge-updates
BENCH_bfs.json, and the gate must read the committed baseline.

Two checks, because the baseline can be (legitimately) refreshed by
committing a new BENCH_bfs.json — which would otherwise let a
regression ratchet itself in:

1. relative — current fused bytes vs the committed baseline (>10%
   worse fails);
2. absolute — the fused-vs-materialized ratio must stay >= MIN_RATIO
   (the ISSUE 3 acceptance floor).  This one cannot be ratcheted
   away: a planner that marks everything active fails it no matter
   what baseline is committed.

    PYTHONPATH=src python -m benchmarks.check_bytes_regression
"""
from __future__ import annotations

import json
import sys

TOLERANCE = 1.10   # fail if current bytes exceed baseline by >10%
MIN_RATIO = 5.0    # acceptance floor: fused >= 5x less than stream
BASELINE_KEY = "bfs_layers.path_bytes_fused"


def main() -> int:
    from benchmarks.bfs_layers import path_probe
    from benchmarks.common import BENCH_JSON

    if not BENCH_JSON.exists():
        print(f"no {BENCH_JSON.name} baseline committed yet — run "
              f"`make bench-quick` and commit the file")
        return 1
    data = json.loads(BENCH_JSON.read_text())
    if BASELINE_KEY not in data or "value" not in data[BASELINE_KEY]:
        print(f"{BENCH_JSON.name} has no {BASELINE_KEY!r} value — run "
              f"`make bench-quick` and commit the update")
        return 1
    baseline = float(data[BASELINE_KEY]["value"])

    probe = path_probe(quiet=True)
    current = float(probe["bytes_fused"])
    ratio = current / baseline
    print(f"{BASELINE_KEY}: baseline={baseline:.0f} B "
          f"current={current:.0f} B ({ratio:.3f}x, "
          f"fused-vs-materialized {probe['ratio']:.1f}x)")
    if current > baseline * TOLERANCE:
        print(f"FAIL: analytic bytes-moved regressed >"
              f"{(TOLERANCE - 1) * 100:.0f}% — the fused pipeline "
              f"stopped being frontier-proportional")
        return 1
    if probe["ratio"] < MIN_RATIO:
        print(f"FAIL: fused-vs-materialized ratio "
              f"{probe['ratio']:.1f}x fell below the {MIN_RATIO:.0f}x "
              f"acceptance floor (baseline-independent check)")
        return 1
    if current < baseline / TOLERANCE:
        print("note: improved beyond tolerance — commit the new "
              "baseline via `make bench-quick`")
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
