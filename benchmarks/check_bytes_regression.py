"""CI gate: the fused pipeline's analytic bytes-moved must not regress
— AND the packed engine must stay fast and 32x-compressed (two-gate
check, ISSUE 4).

**Gate 1 — analytic bytes (deterministic).**  Recomputes the
high-diameter probe (`bfs_layers.path_probe`: path graph SCALE-10,
SIMD forced, fixed tile) with the *current* code and compares against
the committed baseline in ``BENCH_bfs.json``.  The number is analytic
— per-layer active tiles x tile bytes + planning — so the gate is
immune to CI timing noise, yet any structural regression (a step that
stops scheduling work-lists, a planner that marks everything active, a
kernel that re-materializes the stream) inflates it immediately.  Two
sub-checks, because the baseline can be (legitimately) refreshed by
committing a new BENCH_bfs.json — which would otherwise let a
regression ratchet itself in:

1. relative — current fused bytes vs the committed baseline (>10%
   worse fails);
2. absolute — the fused-vs-materialized ratio must stay >= MIN_RATIO
   (the ISSUE 3 acceptance floor).  This one cannot be ratcheted
   away: a planner that marks everything active fails it no matter
   what baseline is committed.

**Gate 2 — packed engine (ISSUE 4).**  Recomputes the packed-vs-
unpacked probe (`bfs_packed.path_packed_probe`):

3. representation — the traversal's LIVE state arrays must actually
   be packed uint32 words: the measured ``frontier``/``visited``
   device bytes vs the dense int32-mask equivalent (4 B/vertex) must
   stay >= MIN_MASK_RATIO (the acceptance floor; packed words are
   32x).  Measured from the result arrays, not the analytic model —
   a change that silently reverts the state to dense masks fails
   here no matter what model constants say.
4. TEPS floor — two sub-checks on the packed path traversal's
   interpret-mode wall clock.  (a) *relative*, machine-independent:
   packed TEPS vs the co-measured unpacked-arm TEPS on the same
   machine must stay >= REL_TEPS_FLOOR (runner speed cancels out —
   this is the structural check).  Sub-parity here is EXPECTED and
   acceptable: in this CPU interpret harness every extra Pallas call
   costs fixed Python-interpreter time per layer, and the packed
   arm's compaction kernel is one such call on each of the probe's
   1024 thin layers (measured ~0.6-0.8x; compiled on TPU the same
   kernel replaces an O(V) dense nonzero and the packed arm is the
   fast one).  The floor is set midway between that steady state and
   collapse, so it trips on a structural slowdown (an extra host
   sync, a quadratic pass), not on the known interpret overhead.
   (b) *absolute*, catastrophic backstop: >= TEPS_FLOOR_FRACTION of
   the committed ``bfs_packed.path_teps`` baseline, with enough
   headroom that only order-of-magnitude regressions trip it, not
   runner-class differences.

**Gate 3 — megakernel launch count (ISSUE 6, deterministic).**
Recomputes the path probe under ``pipeline="megakernel"`` and reads
the per-layer launch counter (`ops.count_launches`, measured at trace
time — the ground truth of how many Pallas calls each layer issues):

5. every SIMD layer must issue EXACTLY 1 Pallas call — the fused
   whole-layer kernel.  A change that silently splits the plan,
   compaction or gather back out into its own launch (or routes the
   probe through the VMEM-degrade arm) reads >= 2 and fails
   immediately; like gate 1 this is counter-based, immune to timing
   noise, and cannot be ratcheted by committing a new baseline.

**Gate 4 — cost-model drift (ISSUE 7, deterministic).**  Recomputes
the analytic-vs-compiled bytes ratio (`repro.obs.cost_drift` on the
``benchmarks.cost_drift`` probe graph) for CSR fused_gather and
compares against the committed ``obs.cost_drift.csr.fused_gather``
baseline:

6. the ratio must stay within DRIFT_TOLERANCE of the baseline in
   BOTH directions — the analytic `layer_bytes`/`tile_bytes`/
   `plan_bytes` models and what XLA actually compiles may not drift
   apart (or together) silently.  Either side moving (a model edit, a
   kernel rewrite, an XLA upgrade) fails until the new ratio is
   deliberately committed via ``make bench-quick`` — which also
   re-stamps ``_meta``, so the baseline's provenance is on record.

**Gate 5 — persistent launch count (ISSUE 9, deterministic).**
Recomputes the path probe under ``pipeline="persistent"`` and sums
the per-layer launch counter over the WHOLE traversal:

7. the persistent traversal must issue EXACTLY 1 Pallas call total —
   the in-kernel layer loop.  A change that silently re-opens the
   per-layer dispatch (or routes the probe through the VMEM-degrade
   arm back to the megakernel) reads ~n_layers and fails; the
   co-measured megakernel arm must still read >= 2 total so the
   counter is proven live.  A TEPS backstop vs the committed
   ``bfs_persistent.path_teps_persistent`` baseline catches
   order-of-magnitude wall-clock collapse (the in-kernel loop going
   quadratic) without tripping on runner-class differences.

**Gate 6 — semiring zero-tax (ISSUE 10, deterministic).**  Recomputes
the path probe with BFS running AS a semiring instance
(`benchmarks.bfs_algorithms.semiring_path_probe`: ``ksource_bfs``,
one root, same geometry/tile):

8. the semiring traversal's analytic bytes must EQUAL the committed
   ``bfs_layers.path_bytes_fused`` baseline — the portfolio
   abstraction may not move one byte more than the hard-wired BFS
   engine (equality, not a tolerance: both numbers are deterministic
   functions of the same active-tile planner).

Run BEFORE ``make bench-quick`` in CI: the bench run merge-updates
BENCH_bfs.json, and the gate must read the committed baseline.  On
any failure the committed baseline's ``_meta`` record (git sha,
timestamp, jax version, device kind, interpret flag — stamped by the
bench harness) is printed so load-noise or environment-skew
re-measurements are attributable.

    PYTHONPATH=src python -m benchmarks.check_bytes_regression
"""
from __future__ import annotations

import json
import sys

TOLERANCE = 1.10   # fail if current bytes exceed baseline by >10%
MIN_RATIO = 5.0    # acceptance floor: fused >= 5x less than stream
MIN_MASK_RATIO = 8.0   # ISSUE 4 floor: packed state >= 8x smaller
REL_TEPS_FLOOR = 0.3   # packed >= 0.3x the co-measured unpacked arm
#                        (steady state ~0.6-0.8x in interpret — see
#                        gate 2 sub-check 4a in the module docstring)
TEPS_FLOOR_FRACTION = 0.15  # absolute backstop vs committed baseline
DRIFT_TOLERANCE = 1.25  # cost-drift ratio may move <=25% vs baseline
#                         (both directions: the ratio is deterministic
#                         for a fixed code + jax version; the headroom
#                         absorbs minor XLA point-release deltas)
BASELINE_KEY = "bfs_layers.path_bytes_fused"
TEPS_KEY = "bfs_packed.path_teps"
DRIFT_KEY = "obs.cost_drift.csr.fused_gather"
PERSISTENT_TEPS_KEY = "bfs_persistent.path_teps_persistent"


def _bytes_gate(data) -> int:
    from benchmarks.bfs_layers import path_probe

    if BASELINE_KEY not in data or "value" not in data[BASELINE_KEY]:
        print(f"no {BASELINE_KEY!r} value committed — run "
              f"`make bench-quick` and commit the update")
        return 1
    baseline = float(data[BASELINE_KEY]["value"])

    probe = path_probe(quiet=True)
    current = float(probe["bytes_fused"])
    ratio = current / baseline
    print(f"{BASELINE_KEY}: baseline={baseline:.0f} B "
          f"current={current:.0f} B ({ratio:.3f}x, "
          f"fused-vs-materialized {probe['ratio']:.1f}x)")
    if current > baseline * TOLERANCE:
        print(f"FAIL: analytic bytes-moved regressed >"
              f"{(TOLERANCE - 1) * 100:.0f}% — the fused pipeline "
              f"stopped being frontier-proportional")
        return 1
    if probe["ratio"] < MIN_RATIO:
        print(f"FAIL: fused-vs-materialized ratio "
              f"{probe['ratio']:.1f}x fell below the {MIN_RATIO:.0f}x "
              f"acceptance floor (baseline-independent check)")
        return 1
    if current < baseline / TOLERANCE:
        print("note: improved beyond tolerance — commit the new "
              "baseline via `make bench-quick`")
    return 0


def _live_state_ratio() -> float:
    """Measured packed-state compression from a real traversal: the
    dense int32-mask equivalent over the ACTUAL state array bytes."""
    import jax.numpy as jnp
    from repro.core import engine

    from benchmarks.bfs_layers import build_path_graph
    g = build_path_graph(256)
    res = engine.traverse(g, 0, spec=engine.make_spec(
        policy=engine.ThresholdSimd(0), max_layers=8))
    frontier = res.state.frontier
    visited = res.state.visited
    assert frontier.dtype == jnp.uint32, frontier.dtype
    state_bytes = (frontier.size * frontier.dtype.itemsize
                   + visited.size * visited.dtype.itemsize)
    dense_bytes = 2 * 4 * g.n_vertices_padded
    return dense_bytes / max(state_bytes, 1)


def _packed_gate(data) -> int:
    from benchmarks.bfs_packed import path_packed_probe

    if TEPS_KEY not in data or "value" not in data[TEPS_KEY]:
        print(f"no {TEPS_KEY!r} value committed — run "
              f"`make bench-quick` and commit the update")
        return 1
    teps_baseline = float(data[TEPS_KEY]["value"])

    live_ratio = _live_state_ratio()
    print(f"live packed-state compression: {live_ratio:.1f}x vs "
          f"dense int32 masks")
    if live_ratio < MIN_MASK_RATIO:
        print(f"FAIL: measured state compression {live_ratio:.1f}x "
              f"fell below the {MIN_MASK_RATIO:.0f}x acceptance floor "
              f"— the engine state is no longer packed words")
        return 1

    probe = path_packed_probe(time_reps=2)
    print(f"model membership bytes: {probe['mask_bytes_packed']} B "
          f"packed vs {probe['mask_bytes_unpacked']} B dense "
          f"({probe['mask_ratio']:.1f}x)")
    rel = probe["teps_packed"] / max(probe["teps_unpacked"], 1e-9)
    print(f"packed-vs-unpacked TEPS (co-measured): {rel:.2f}x "
          f"(floor {REL_TEPS_FLOOR:.2f}x)")
    if rel < REL_TEPS_FLOOR:
        print("FAIL: the packed arm fell far behind the unpacked arm "
              "on the same machine — a structural slowdown, not "
              "runner speed")
        return 1
    floor = teps_baseline * TEPS_FLOOR_FRACTION
    print(f"{TEPS_KEY}: baseline={teps_baseline:.3e} "
          f"current={probe['teps_packed']:.3e} "
          f"(floor {floor:.3e})")
    if probe["teps_packed"] < floor:
        print(f"FAIL: packed path-probe TEPS fell below "
              f"{TEPS_FLOOR_FRACTION:.2f}x of the committed baseline")
        return 1
    return 0


def _launch_gate(data) -> int:
    """Gate 3: megakernel = EXACTLY one Pallas call per SIMD layer on
    the path probe (baseline-independent, counter-based)."""
    from benchmarks.bfs_megakernel import (PATH_SCALE,
                                           path_launch_probe)

    probe = path_launch_probe(time_reps=1)
    mega = probe["megakernel"]["launches_per_layer"]
    unfused = probe["fused_gather"]["launches_per_layer"]
    print(f"launches/layer (path s={PATH_SCALE}): megakernel={mega:.2f} "
          f"unfused={unfused:.2f}")
    if mega != 1.0:
        print("FAIL: the megakernel no longer runs each SIMD layer as "
              "ONE Pallas call — a stage split back out into its own "
              "launch, or the probe degraded to the unfused pipeline")
        return 1
    if unfused < 2.0:
        print("FAIL: the unfused launch counter reads < 2 calls/layer "
              "— the counter itself broke (it must see plan + compact "
              "+ gather), so the megakernel check above proves nothing")
        return 1
    return 0


def _drift_gate(data) -> int:
    """Gate 4: the analytic-vs-compiled bytes ratio for CSR
    fused_gather must match the committed baseline within
    DRIFT_TOLERANCE (both directions — see module docstring)."""
    from benchmarks.cost_drift import drift_probe

    if DRIFT_KEY not in data or "value" not in data[DRIFT_KEY]:
        print(f"no {DRIFT_KEY!r} value committed — run "
              f"`make bench-quick` and commit the update")
        return 1
    baseline = float(data[DRIFT_KEY]["value"])

    row = drift_probe(pipelines=("fused_gather",), quiet=True)
    d = row["fused_gather"]["drift"]
    rel = d.ratio / baseline
    print(f"{DRIFT_KEY}: baseline={baseline:.3f} current="
          f"{d.ratio:.3f} ({rel:.3f}x; analytic={d.analytic_bytes} B "
          f"compiled={d.compiled_bytes:.0f} B)")
    if not (1 / DRIFT_TOLERANCE <= rel <= DRIFT_TOLERANCE):
        print(f"FAIL: the analytic-vs-compiled bytes ratio drifted "
              f">{(DRIFT_TOLERANCE - 1) * 100:.0f}% from the committed "
              f"baseline — the hand-derived bytes model and the "
              f"compiled program no longer agree; if the change is "
              f"deliberate, re-commit via `make bench-quick`")
        return 1
    return 0


def _persistent_gate(data) -> int:
    """Gate 5: persistent = EXACTLY one Pallas call per TRAVERSAL on
    the path probe (counter-based), plus a TEPS backstop vs the
    committed baseline."""
    from benchmarks.bfs_persistent import (PATH_SCALE,
                                           path_persistent_probe)

    if (PERSISTENT_TEPS_KEY not in data
            or "value" not in data[PERSISTENT_TEPS_KEY]):
        print(f"no {PERSISTENT_TEPS_KEY!r} value committed — run "
              f"`make bench-quick` and commit the update")
        return 1
    teps_baseline = float(data[PERSISTENT_TEPS_KEY]["value"])

    probe = path_persistent_probe(
        time_reps=1, pipelines=("megakernel", "persistent"))
    pers = probe["persistent"]["launches_per_traversal"]
    mega = probe["megakernel"]["launches_per_traversal"]
    print(f"launches/traversal (path s={PATH_SCALE}): "
          f"persistent={pers} megakernel={mega}")
    if pers != 1:
        print("FAIL: the persistent traversal no longer runs as ONE "
              "Pallas call — per-layer dispatch re-opened, or the "
              "probe degraded to the megakernel arm")
        return 1
    if mega < 2:
        print("FAIL: the megakernel launch counter reads < 2 calls "
              "for a ~1k-layer traversal — the counter itself broke, "
              "so the persistent check above proves nothing")
        return 1
    teps = probe["persistent"]["edges"] / probe["persistent"]["sec"]
    floor = teps_baseline * TEPS_FLOOR_FRACTION
    print(f"{PERSISTENT_TEPS_KEY}: baseline={teps_baseline:.3e} "
          f"current={teps:.3e} (floor {floor:.3e})")
    if teps < floor:
        print(f"FAIL: persistent path-probe TEPS fell below "
              f"{TEPS_FLOOR_FRACTION:.2f}x of the committed baseline "
              f"— the in-kernel layer loop got structurally slower")
        return 1
    return 0


def _semiring_gate(data) -> int:
    """Gate 6 (ISSUE 10): zero abstraction tax.  BFS run AS a
    semiring instance (ksource_bfs, one root) on the path-probe
    geometry must plan EXACTLY the committed BFS baseline's analytic
    bytes — the generic relax schedule may not move one byte more
    than the hard-wired engine (equality, not a tolerance: both
    numbers are deterministic functions of the same planner)."""
    from benchmarks.bfs_algorithms import semiring_path_probe

    if BASELINE_KEY not in data or "value" not in data[BASELINE_KEY]:
        print(f"no {BASELINE_KEY!r} value committed — run "
              f"`make bench-quick` and commit the update")
        return 1
    baseline = int(float(data[BASELINE_KEY]["value"]))

    probe = semiring_path_probe(quiet=True)
    current = int(probe["bytes_semiring"])
    print(f"semiring-BFS analytic bytes: {current} B vs committed "
          f"BFS baseline {baseline} B over {probe['layers']} layers")
    if current != baseline:
        print("FAIL: BFS-via-semiring plans different bytes than the "
              "hard-wired BFS engine — the portfolio abstraction "
              "grew a byte tax (or the relax schedule stopped being "
              "frontier-proportional)")
        return 1
    return 0


def _print_meta(data) -> None:
    """Surface the committed baseline's provenance on a gate failure
    (the ``_meta`` record `benchmarks.common.save_results` stamps)."""
    meta = data.get("_meta")
    if not meta:
        print("baseline _meta: none recorded (baseline predates the "
              "meta stamp — re-commit via `make bench-quick`)")
        return
    fields = ", ".join(f"{k}={meta[k]}" for k in sorted(meta))
    print(f"baseline _meta: {fields}")


def main() -> int:
    from benchmarks.common import BENCH_JSON

    if not BENCH_JSON.exists():
        print(f"no {BENCH_JSON.name} baseline committed yet — run "
              f"`make bench-quick` and commit the file")
        return 1
    data = json.loads(BENCH_JSON.read_text())

    rc = _bytes_gate(data)
    rc = _packed_gate(data) or rc
    rc = _launch_gate(data) or rc
    rc = _drift_gate(data) or rc
    rc = _persistent_gate(data) or rc
    rc = _semiring_gate(data) or rc
    if rc:
        _print_meta(data)
    print("OK" if rc == 0 else "GATE FAILED")
    return rc


if __name__ == "__main__":
    sys.exit(main())
