"""Roofline summary benchmark: one line per dry-run cell.

Reads results/dryrun artifacts (produced by repro.launch.dryrun) and
emits the three roofline terms + bottleneck for every (arch x shape x
mesh) cell — the harness-level table behind EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit


def main(results_dir: str = "results/dryrun"):
    d = Path(results_dir)
    files = sorted(d.glob("*.json"))
    if not files:
        print("# no dry-run artifacts; run: python -m repro.launch.dryrun")
        return
    n_ok = 0
    for f in files:
        r = json.loads(f.read_text())
        tag = f"{r['arch']}.{r['shape']}.{r['mesh']}"
        if r["status"] != "ok":
            emit(f"lm_roofline.{tag}", 0.0,
                 "skip" if r["status"].startswith("skip") else "FAILED")
            continue
        n_ok += 1
        ro = r["roofline"]
        t_bound = max(ro["t_compute_s"], ro["t_memory_s"],
                      ro["t_collective_s"])
        emit(f"lm_roofline.{tag}", t_bound * 1e6,
             f"{ro['bottleneck']}_mfu{ro['mfu_bound']*100:.1f}%")
    print(f"# {n_ok} ok cells")


if __name__ == "__main__":
    main()
