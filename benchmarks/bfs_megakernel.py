"""Whole-layer megakernel vs the 3-launch unfused pipeline (ISSUE 6).

The §4 pipeline ran each SIMD layer as three Pallas calls —
plan_active_tiles, frontier_compact, gather_expand — each paying one
dispatch and bouncing its intermediate (the active-tile worklist, the
compacted frontier) through HBM.  ``pipeline="megakernel"``
(kernels/layer_fused.py) fuses them into ONE call whose plan and
worklist never leave VMEM/SMEM.  This benchmark pins the two
acceptance numbers:

* **launches/layer** — counted at trace time by `ops.count_launches`
  (the same counter `engine.layer_stats` reports per layer): exactly
  1 for the megakernel, 3 for fused_gather.  On the high-diameter
  path probe (1 vertex/layer, ~1k layers) dispatch overhead is the
  whole cost, so this is also where fusion pays most.  The CI gate
  (`benchmarks.check_bytes_regression`) pins the path-probe
  megakernel at exactly 1.0 calls/layer.
* **TEPS** — wall-clock of bit-identical traversals (parity suite in
  tests/test_megakernel.py) under both pipelines, on the path probe
  and the RMAT workload.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, graph
from repro.api import plan as plan_mod
from repro.api import spec as spec_mod
from repro.core import engine
from repro.core.csr import traversed_edges
from repro.formats.csr_format import CsrFormat

PATH_SCALE = 10    # fixed: the CI launch-gate probe, not --quick'd
PATH_TILE = 128


def _time(fn, reps: int = 3) -> float:
    fn()                                   # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)                         # least-noise estimator


def _launches_per_simd_layer(res) -> float:
    """Mean Pallas calls per SIMD/bottom-up layer from the stats
    buffer (scalar layers launch nothing in either pipeline)."""
    buf = np.asarray(res.stats)
    simd = [int(buf[i, engine._ST_LAUNCH])
            for i in range(buf.shape[0])
            if buf[i, engine._ST_ACTIVE]
            and int(buf[i, engine._ST_MODE]) != engine.MODE_SCALAR]
    return float(np.mean(simd)) if simd else 0.0


def path_launch_probe(scale: int = PATH_SCALE,
                      tile: int = PATH_TILE, time_reps: int = 3) -> dict:
    """The s10 path probe: launches/layer + TEPS, both pipelines."""
    from benchmarks.bfs_layers import build_path_graph
    n = 1 << scale
    g = build_path_graph(n)
    fmt = CsrFormat.from_csr(g)
    out = {}
    for pipe in ("fused_gather", "megakernel"):
        spec = spec_mod.TraversalSpec(
            policy=engine.ThresholdSimd(0), tile=tile,
            max_layers=n + 2, pipeline=pipe)
        ct = plan_mod.plan(fmt, spec)
        res = ct.run(0)
        out[pipe] = {
            "launches_per_layer": _launches_per_simd_layer(res),
            "layers": len(engine.layer_stats(res)),
            "edges": int(traversed_edges(
                g, np.asarray(res.state.parent)[:n] < n)),
            "sec": _time(lambda: jax.block_until_ready(
                ct.run(0).state.parent), time_reps),
        }
    return out


def main(scale: int = 12) -> None:
    probe = path_launch_probe()
    for pipe, p in probe.items():
        tag = "mega" if pipe == "megakernel" else "unfused"
        emit(f"bfs_megakernel.path_launches_per_layer_{tag}", 0.0,
             f"scale={PATH_SCALE};layers={p['layers']}",
             value=p["launches_per_layer"])
        emit(f"bfs_megakernel.path_teps_{tag}", p["sec"] * 1e6,
             f"teps={p['edges'] / p['sec']:.3e}",
             value=p["edges"] / p["sec"])
    mega, unf = probe["megakernel"], probe["fused_gather"]
    print(f"# path s={PATH_SCALE}: {mega['launches_per_layer']:.1f} "
          f"calls/layer fused vs {unf['launches_per_layer']:.1f} "
          f"unfused; speedup {unf['sec'] / mega['sec']:.2f}x")

    # RMAT workload: same comparison on the paper's skewed graph
    g = graph(scale)
    fmt = CsrFormat.from_csr(g)
    rng = np.random.default_rng(7)
    deg = np.asarray(g.degrees())
    root = int(rng.choice(np.where(deg > 0)[0]))
    for pipe in ("fused_gather", "megakernel"):
        ct = plan_mod.plan(fmt, spec_mod.TraversalSpec(
            policy=engine.ThresholdSimd(0), pipeline=pipe))
        res = ct.run(root)
        reached = np.asarray(
            res.state.parent)[:g.n_vertices] < g.n_vertices
        edges = int(traversed_edges(g, reached))
        t = _time(lambda: jax.block_until_ready(
            ct.run(root).state.parent))
        tag = "mega" if pipe == "megakernel" else "unfused"
        emit(f"bfs_megakernel.rmat_s{scale}_{tag}", t * 1e6,
             f"teps={edges / t:.3e};"
             f"lpl={_launches_per_simd_layer(res):.1f}",
             value=edges / t)


if __name__ == "__main__":
    main()
