"""Whole-traversal persistent kernel vs per-layer pipelines (ISSUE 9).

The launch-count ladder this repo climbs:

* ``fused_gather`` — 3 Pallas calls per SIMD layer (§4 pipeline),
* ``megakernel``   — 1 call per layer (ISSUE 6, layer_fused.py),
* ``persistent``   — 1 call per TRAVERSAL (traversal_fused.py): the
  layer loop, direction policy and termination all run in-kernel on
  SMEM counters, so host dispatch leaves the critical path entirely.

This benchmark pins the two acceptance numbers on the same probes
bfs_megakernel.py uses:

* **launches/traversal** — summed from the per-layer stats buffer
  (`engine._ST_LAUNCH`); exactly 1 for persistent, ``n_layers`` for
  the megakernel, ``3*n_simd_layers`` unfused.  The high-diameter
  path probe (1 vertex/layer, ~1k layers) is where the ladder shows
  up as wall clock: dispatch overhead IS the cost there.  Gate 5 of
  ``benchmarks.check_bytes_regression`` pins the persistent probe at
  exactly 1.0 launches/traversal.
* **TEPS** — wall-clock of bit-identical traversals (parity suite in
  tests/test_persistent.py) under all three pipelines, on the path
  probe and the RMAT workload.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, graph
from repro.api import plan as plan_mod
from repro.api import spec as spec_mod
from repro.core import engine
from repro.core.csr import traversed_edges
from repro.formats.csr_format import CsrFormat

PATH_SCALE = 10    # fixed: the CI gate-5 probe, not --quick'd
PATH_TILE = 128
PIPELINES = ("fused_gather", "megakernel", "persistent")
_TAG = {"fused_gather": "unfused", "megakernel": "mega",
        "persistent": "persistent"}


def _time(fn, reps: int = 3) -> float:
    fn()                                   # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)                         # least-noise estimator


def _launches_per_traversal(res) -> int:
    """Total Pallas calls for the whole traversal from the stats
    buffer.  Persistent charges its single launch to layer 0 and
    zeros the rest of the column, so the sum is the ladder metric."""
    buf = np.asarray(res.stats)
    return int(buf[:, engine._ST_LAUNCH].sum())


def path_persistent_probe(scale: int = PATH_SCALE,
                          tile: int = PATH_TILE,
                          time_reps: int = 3,
                          pipelines=PIPELINES) -> dict:
    """The s10 path probe: launches/traversal + TEPS, all pipelines."""
    from benchmarks.bfs_layers import build_path_graph
    n = 1 << scale
    g = build_path_graph(n)
    fmt = CsrFormat.from_csr(g)
    out = {}
    for pipe in pipelines:
        spec = spec_mod.TraversalSpec(
            policy=engine.ThresholdSimd(0), tile=tile,
            max_layers=n + 2, pipeline=pipe)
        ct = plan_mod.plan(fmt, spec)
        res = ct.run(0)
        out[pipe] = {
            "launches_per_traversal": _launches_per_traversal(res),
            "layers": len(engine.layer_stats(res)),
            "edges": int(traversed_edges(
                g, np.asarray(res.state.parent)[:n] < n)),
            "sec": _time(lambda: jax.block_until_ready(
                ct.run(0).state.parent), time_reps),
        }
    return out


def main(scale: int = 12) -> None:
    probe = path_persistent_probe()
    for pipe, p in probe.items():
        tag = _TAG[pipe]
        emit(f"bfs_persistent.path_launches_per_traversal_{tag}", 0.0,
             f"scale={PATH_SCALE};layers={p['layers']}",
             value=p["launches_per_traversal"])
        emit(f"bfs_persistent.path_teps_{tag}", p["sec"] * 1e6,
             f"teps={p['edges'] / p['sec']:.3e}",
             value=p["edges"] / p["sec"])
    pers, mega = probe["persistent"], probe["megakernel"]
    print(f"# path s={PATH_SCALE}: {pers['launches_per_traversal']} "
          f"call/traversal persistent vs "
          f"{mega['launches_per_traversal']} megakernel; speedup "
          f"{mega['sec'] / pers['sec']:.2f}x")

    # RMAT workload: same ladder on the paper's skewed graph (few
    # layers, fat frontiers — the regime where per-layer dispatch
    # matters least, so this bounds the ladder's floor)
    g = graph(scale)
    fmt = CsrFormat.from_csr(g)
    rng = np.random.default_rng(7)
    deg = np.asarray(g.degrees())
    root = int(rng.choice(np.where(deg > 0)[0]))
    for pipe in PIPELINES:
        ct = plan_mod.plan(fmt, spec_mod.TraversalSpec(
            policy=engine.ThresholdSimd(0), pipeline=pipe))
        res = ct.run(root)
        reached = np.asarray(
            res.state.parent)[:g.n_vertices] < g.n_vertices
        edges = int(traversed_edges(g, reached))
        t = _time(lambda: jax.block_until_ready(
            ct.run(root).state.parent))
        emit(f"bfs_persistent.rmat_s{scale}_{_TAG[pipe]}", t * 1e6,
             f"teps={edges / t:.3e};"
             f"lpt={_launches_per_traversal(res)}",
             value=edges / t)


if __name__ == "__main__":
    main()
