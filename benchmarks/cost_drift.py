"""Analytic bytes model vs the compiled program (obs.cost_drift).

Compiles the plan cache's single-layer tick per (format, pipeline) on
the deterministic RMAT graph ``common.graph(DRIFT_SCALE)`` and records
the ``compiled / analytic`` bytes ratio (`repro.obs.cost_drift`).  The
ratio's absolute magnitude reflects everything the model deliberately
excludes (state bitmaps, interpret-mode Pallas expansion, XLA's own
materializations); its *stability* is the contract — gate 4 of
``check_bytes_regression`` recomputes it and fails on movement beyond
tolerance, so neither the hand-derived model nor the compiled program
can drift silently.

    PYTHONPATH=src python -m benchmarks.cost_drift
"""
from __future__ import annotations

import time

from benchmarks import common

DRIFT_SCALE = 10
#: pipelines compiled for the drift table (CSR supports all four;
#: "persistent" compiles the serve-tier per-layer tick, which by
#: contract is the megakernel step — the drift row pins that routing)
PIPELINES = ("fused_gather", "materialized", "megakernel", "persistent")


def drift_probe(scale: int = DRIFT_SCALE, pipelines=PIPELINES,
                quiet: bool = False) -> dict:
    """-> {pipeline: {"drift": obs.cost_drift.Drift, "us": float}} on
    the deterministic probe graph (what gate 4 recomputes)."""
    from repro.obs.cost_drift import measure_drift

    csr = common.graph(scale)
    out: dict = {}
    for pipeline in pipelines:
        t0 = time.perf_counter()
        (d,) = measure_drift(csr, pipelines=(pipeline,))
        us = (time.perf_counter() - t0) * 1e6
        out[pipeline] = {"drift": d, "us": us}
        if not quiet:
            print(f"# {d.format}/{pipeline}: analytic="
                  f"{d.analytic_bytes} B compiled="
                  f"{d.compiled_bytes:.0f} B ratio={d.ratio:.3f} "
                  f"hlo_ratio={d.hlo_ratio:.3f} tile={d.tile}")
    return out


def main(scale: int = DRIFT_SCALE) -> None:
    rows = drift_probe(scale)
    for pipeline, row in rows.items():
        d = row["drift"]
        common.emit(
            f"obs.cost_drift.{d.format}.{pipeline}", row["us"],
            f"s={scale} analytic={d.analytic_bytes}B "
            f"compiled={d.compiled_bytes:.0f}B "
            f"hlo_ratio={d.hlo_ratio:.2f} tile={d.tile}",
            value=d.ratio)


if __name__ == "__main__":
    main()
