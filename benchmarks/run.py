"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Emits ``name,us_per_call,derived`` CSV lines per benchmark plus the
raw tables each figure needs.  Scales are CPU-container-sized by
default; pass --paper-scale to use the paper's SCALE=20 (slow).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest scales (CI)")
    ap.add_argument("--paper-scale", action="store_true",
                    help="the paper's SCALE=20 sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark by name")
    args = ap.parse_args()

    from benchmarks import (affinity, bfs_algorithms, bfs_batched,
                            bfs_formats, bfs_layers, bfs_megakernel,
                            bfs_opt_ablation, bfs_packed,
                            bfs_persistent, bfs_plan_cache,
                            bfs_scaling, cost_drift, lm_roofline)

    # one provenance stamp per harness run (BENCH_bfs.json _meta)
    started = time.strftime("%Y-%m-%dT%H:%M:%S%z")

    layer_scale = 20 if args.paper_scale else (12 if args.quick else 16)
    abl_scale = 13 if not args.quick else 11
    scales = (11, 12) if args.quick else (12, 13, 14)

    benches = {
        "bfs_layers": lambda: bfs_layers.main(scale=layer_scale),
        "bfs_opt_ablation": lambda: bfs_opt_ablation.main(
            scale=abl_scale, n_roots=2 if args.quick else 3),
        "bfs_scaling": lambda: bfs_scaling.main(
            scales=scales, n_roots=2 if args.quick else 4),
        "bfs_batched": lambda: bfs_batched.main(
            scale=11 if args.quick else 12),
        "bfs_formats": lambda: bfs_formats.main(
            scale=10 if args.quick else 12),
        "bfs_packed": lambda: bfs_packed.main(
            scale=10 if args.quick else 11),
        "bfs_plan_cache": lambda: bfs_plan_cache.main(
            scale=9 if args.quick else 10),
        "bfs_megakernel": lambda: bfs_megakernel.main(
            scale=10 if args.quick else 12),
        "bfs_persistent": lambda: bfs_persistent.main(
            scale=10 if args.quick else 12),
        "bfs_algorithms": lambda: bfs_algorithms.main(
            scale=10 if args.quick else 12),
        "affinity": lambda: affinity.main(scale=abl_scale),
        "cost_drift": lambda: cost_drift.main(),
        "lm_roofline": lambda: lm_roofline.main(),
    }
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"\n=== {name} ===", flush=True)
        t0 = time.perf_counter()
        try:
            fn()
            print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    # persist whatever succeeded: BENCH_bfs.json tracks the perf
    # trajectory (TEPS, analytic bytes-moved, active-tile counts)
    # across PRs; merge-update keeps other benchmarks' entries
    from benchmarks import common
    if common.RESULTS:
        common.save_results(meta=common.build_meta(timestamp=started))
        print(f"# wrote {len(common.RESULTS)} metrics (+_meta) to "
              f"{common.BENCH_JSON.name}")
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        sys.exit(1)
    print("\nALL BENCHMARKS OK")


if __name__ == "__main__":
    main()
