"""Chaos smoke check (``make chaos-smoke``, ISSUE 8).

End-to-end assertion of the serve-tier robustness contract: a 48-query
mixed trace (normal, high-priority, tight-deadline) driven through a
`GraphEngine` while a `ServeFaultInjector` fails device ticks, stalls
ticks and poisons slot results, against a bounded queue small enough
that the burst trips admission control.  The contract:

1. ZERO lost queries — every admitted query is delivered exactly once
   (clients whose submits are rejected see a typed `QueueFullError`
   and retry after draining; every query eventually lands).
2. ZERO corrupted results — every completed (non-truncated) query's
   parent array passes the Graph500 soft validator
   (`repro.core.validate.validate`); poisoned slots were caught by
   the harvest sanity check and re-run, never delivered.
3. Deadline queries degrade observably — truncated with a typed
   `DeadlineExceeded` attached, never silently dropped.
4. The operational counters are live: nonzero ``serve.retries``,
   ``serve.rejected``, ``serve.poisoned``, ``serve.requeued`` and
   ``serve.degrade.*`` (the VMEM-fallback ladder exercised through
   the real trace-time decision via ``jax.eval_shape``).

Exit code 0 = all assertions hold.

    PYTHONPATH=src python -m benchmarks.chaos_smoke
"""
from __future__ import annotations

import sys

SMOKE_SCALE = 8
N_QUERIES = 48
N_TIGHT_DEADLINE = 4


def main() -> int:
    import jax

    from benchmarks import common
    from repro.core import bitmap as bm
    from repro.core import engine as core_engine
    from repro.core.validate import validate
    from repro.errors import DeadlineExceeded, QueueFullError
    from repro.obs.metrics import (clear_degrade_log, degrade_log,
                                   get_registry)
    from repro.serve.graph_engine import BfsQuery, GraphEngine
    from repro.serve.robust import ServeFaultInjector

    csr = common.graph(SMOKE_SCALE)
    reg = get_registry()
    reg.clear()
    clear_degrade_log()

    injector = ServeFaultInjector(
        fail_ticks=(1, 4, 9),
        slow_ticks=(2,), slow_s=0.005,
        poison=((0, 1), (3, 2), (6, 0)))
    eng = GraphEngine(csr, batch_slots=4, registry=reg,
                      queue_capacity=12, injector=injector,
                      retry_backoff_s=0.001)

    # -- mixed 48-query trace against a 12-deep bounded queue ------------
    queries = []
    for i in range(N_QUERIES):
        q = BfsQuery(uid=i, root=(i * 7) % csr.n_vertices,
                     priority=(3 if i % 5 == 0 else 0))
        if i % (N_QUERIES // N_TIGHT_DEADLINE) == 1:
            q.deadline_s = 0.0        # expires before it can finish
        queries.append(q)

    client_retries = 0
    for q in queries:
        while True:
            try:
                eng.submit(q)
                break
            except QueueFullError:
                # typed backpressure: the client drains and retries
                client_retries += 1
                eng.step()
    eng.run_until_done()
    assert injector.faults_remaining == 0, (
        f"{injector.faults_remaining} scheduled faults never fired — "
        f"the trace was too short to exercise the injector")

    # -- 1: zero lost, exactly-once --------------------------------------
    uids = sorted(q.uid for q in eng.finished)
    assert uids == list(range(N_QUERIES)), (
        f"lost/duplicated queries: got {len(uids)} results, "
        f"{len(set(uids))} unique")
    assert not eng.queue and eng._active_slots() == 0

    # -- 2: zero corrupted — Graph500-validate every complete result -----
    complete = [q for q in eng.finished if not q.truncated]
    truncated = [q for q in eng.finished if q.truncated]
    for q in complete:
        check = validate(csr, q.parent, q.root)
        assert check.ok, (f"query uid={q.uid} root={q.root} delivered "
                          f"an INVALID tree: {check}")

    # -- 3: deadline queries degrade observably, never vanish ------------
    assert len(truncated) >= N_TIGHT_DEADLINE
    for q in truncated:
        assert isinstance(q.error, DeadlineExceeded), (
            f"truncated uid={q.uid} carries no typed error")
        assert q.error.where in ("queued", "in_flight")

    # -- 4: the robustness counters are live -----------------------------
    # exercise the real VMEM-fallback decision (trace-time, no giant
    # allocation) so serve.degrade.* is nonzero in the same snapshot
    v_pad, n_batch = 131072, 128
    jax.eval_shape(
        lambda cs, aw: core_engine.plan_active_tiles_batched(
            cs, aw, v_pad, tile=1024, n_blocks=8, packed=True),
        jax.ShapeDtypeStruct((v_pad + 1,), "int32"),
        jax.ShapeDtypeStruct((n_batch, v_pad // bm.BITS_PER_WORD),
                             "uint32"))

    snap = reg.snapshot()
    c = snap["counters"]
    for name in ("serve.retries", "serve.rejected", "serve.poisoned",
                 "serve.requeued", "serve.degrade.vmem_fallback"):
        assert c.get(name, 0) > 0, (
            f"counter {name} is zero — that failure mode was not "
            f"exercised: {c}")
    assert "serve.circuit_state" in snap["gauges"]
    assert degrade_log(), "no DegradeEvent in the ring"

    n_retried = sum(1 for q in eng.finished if q.retries > 0)
    print(f"chaos: {N_QUERIES} queries ({len(complete)} complete + "
          f"{len(truncated)} deadline-truncated) under "
          f"{int(c['serve.retries'])} tick retries, "
          f"{int(c['serve.poisoned'])} poisoned slots caught, "
          f"{int(c['serve.rejected'])} typed rejections "
          f"({client_retries} client retries); {n_retried} queries "
          f"re-run; every complete tree Graph500-valid; "
          f"degrade events={len(degrade_log())}")
    print("CHAOS SMOKE OK")
    clear_degrade_log()
    return 0


if __name__ == "__main__":
    sys.exit(main())
