"""Fused-engine and batched multi-root BFS benchmark.

Three measurements:

1. **Layer-loop overhead removed** — the same single-root top-down
   search via the legacy host layer loop (per-layer ``int(count)``
   device sync + pow2 bucket dispatch) vs the fused engine (one
   ``lax.while_loop`` launch).  The delta is the per-layer host
   round-trip cost the unified engine eliminates.  NB on the CPU
   container the fused path pays full-``E`` padding per layer in
   interpret mode, which can outweigh the sync saving; on TPU the
   sync dominates — the benchmark reports the signed delta either way.
2. **Multi-root throughput** — ``batch`` roots traversed in ONE fused
   launch (leading root axis through the batched expansion kernel);
   reported as roots/s next to the single-root time.
3. **Serve throughput** — the continuous-batching `GraphEngine`
   draining 2x``batch`` queries with slot reuse.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, graph
from repro.configs.bfs_graph500 import SERVE
from repro.core import engine
from repro.serve.graph_engine import BfsQuery, GraphEngine


def _time(fn, reps: int = 3) -> float:
    fn()                                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def main(scale: int = 12, batch: int | None = None,
         policy=None) -> None:
    batch = batch or SERVE.batch_slots
    g = graph(scale)
    rng = np.random.default_rng(7)
    deg = np.asarray(g.degrees())
    connected = np.where(deg > 0)[0]
    roots = [int(r) for r in rng.choice(connected, size=batch,
                                        replace=False)]
    policy = policy or engine.TopDown()

    # 1. single root: host layer loop vs fused while_loop
    r0 = roots[0]
    t_host = _time(lambda: jax.block_until_ready(
        engine.traverse_hostloop(g, r0, policy=policy)[0].parent))
    t_fused = _time(lambda: jax.block_until_ready(
        engine.traverse(g, r0,
                        spec=engine.make_spec(policy=policy))
        .state.parent))
    removed = (t_host - t_fused) * 1e6
    emit(f"bfs_single_hostloop_s{scale}", t_host * 1e6, "per_layer_sync")
    emit(f"bfs_single_fused_s{scale}", t_fused * 1e6,
         f"hostloop_minus_fused_us={removed:.1f}")

    # 2. multi-root: one launch, leading root axis
    t_batch = _time(lambda: jax.block_until_ready(
        engine.traverse(g, roots,
                        spec=engine.make_spec(policy=policy))
        .state.parent))
    emit(f"bfs_batched{batch}_s{scale}", t_batch * 1e6,
         f"roots_per_s={batch / t_batch:.1f};"
         f"speedup_vs_serial_fused={batch * t_fused / t_batch:.2f}x")

    # 3. serve engine: continuous batching, 2x oversubscribed queue
    def serve_once():
        eng = GraphEngine(g, batch_slots=batch,
                          spec=engine.make_spec(
                              algorithm=SERVE.algorithm,
                              max_layers=SERVE.max_layers))
        for uid, r in enumerate(roots * 2):
            eng.submit(BfsQuery(uid=uid, root=int(r)))
        eng.run_until_done()
        return eng
    serve_once()                            # warmup/compile
    t0 = time.perf_counter()
    eng = serve_once()
    t_serve = time.perf_counter() - t0
    n_q = len(eng.finished)
    emit(f"bfs_serve{batch}_s{scale}", t_serve / n_q * 1e6,
         f"queries_per_s={n_q / t_serve:.1f}")


if __name__ == "__main__":
    main()
