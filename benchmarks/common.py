"""Shared benchmark utilities: graph cache, timing, CSV emission.

Every `emit` is also recorded in the in-process ``RESULTS`` registry;
`benchmarks.run` persists the registry to ``BENCH_bfs.json`` at the
repo root after each run (merge-update, so partial ``--only`` runs
refresh just their keys) — the cross-PR perf trajectory file the CI
bytes-moved gate reads."""
from __future__ import annotations

import json
import pathlib
import time

import jax

from repro.core import csr as csr_mod
from repro.core import rmat

_GRAPH_CACHE: dict = {}

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_bfs.json"

#: name -> {"us_per_call": float, "derived": str, "value": float?}
RESULTS: dict[str, dict] = {}


def graph(scale: int, edgefactor: int = 16, seed: int = 2):
    key = (scale, edgefactor, seed)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = csr_mod.from_edges(
            rmat.generate(jax.random.PRNGKey(seed), scale, edgefactor))
    return _GRAPH_CACHE[key]


def time_bfs(fn, csr, roots, warmup_root=None) -> float:
    """Mean seconds per BFS over the given roots (after warmup)."""
    jax.block_until_ready(
        fn(csr, int(warmup_root if warmup_root is not None
                    else roots[0])).parent)
    t0 = time.perf_counter()
    for r in roots:
        jax.block_until_ready(fn(csr, int(r)).parent)
    return (time.perf_counter() - t0) / len(roots)


def emit(name: str, us_per_call: float, derived: str,
         value: float | None = None):
    """The run.py contract: ``name,us_per_call,derived`` CSV.

    ``value`` optionally attaches a machine-readable number (TEPS,
    analytic bytes, tile counts) to the ``RESULTS``/BENCH_bfs.json
    record — what regression gates compare instead of parsing the
    derived string."""
    print(f"{name},{us_per_call:.1f},{derived}")
    rec = {"us_per_call": round(us_per_call, 1), "derived": derived}
    if value is not None:
        rec["value"] = float(value)
    RESULTS[name] = rec


def save_results() -> None:
    """Merge ``RESULTS`` into BENCH_bfs.json (sorted, stable diffs)."""
    data = {}
    if BENCH_JSON.exists():
        data = json.loads(BENCH_JSON.read_text())
    data.update(RESULTS)
    BENCH_JSON.write_text(json.dumps(data, indent=1, sort_keys=True)
                          + "\n")
