"""Shared benchmark utilities: graph cache, timing, CSV emission."""
from __future__ import annotations

import time

import jax

from repro.core import csr as csr_mod
from repro.core import rmat

_GRAPH_CACHE: dict = {}


def graph(scale: int, edgefactor: int = 16, seed: int = 2):
    key = (scale, edgefactor, seed)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = csr_mod.from_edges(
            rmat.generate(jax.random.PRNGKey(seed), scale, edgefactor))
    return _GRAPH_CACHE[key]


def time_bfs(fn, csr, roots, warmup_root=None) -> float:
    """Mean seconds per BFS over the given roots (after warmup)."""
    jax.block_until_ready(
        fn(csr, int(warmup_root if warmup_root is not None
                    else roots[0])).parent)
    t0 = time.perf_counter()
    for r in roots:
        jax.block_until_ready(fn(csr, int(r)).parent)
    return (time.perf_counter() - t0) / len(roots)


def emit(name: str, us_per_call: float, derived: str):
    """The run.py contract: ``name,us_per_call,derived`` CSV."""
    print(f"{name},{us_per_call:.1f},{derived}")
