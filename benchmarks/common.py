"""Shared benchmark utilities: graph cache, timing, CSV emission.

Every `emit` is also recorded in the in-process ``RESULTS`` registry
AND mirrored into the `repro.obs` metrics registry (gauge
``bench.<name>``), so one metrics snapshot shows benchmark TEPS/bytes
next to the serve-tier distributions; `benchmarks.run` persists the
registry to ``BENCH_bfs.json`` at the repo root after each run
(merge-update, so partial ``--only`` runs refresh just their keys) —
the cross-PR perf trajectory file the CI bytes-moved gate reads.
Since ISSUE 7 the file also carries a ``_meta`` record (git sha,
harness timestamp, jax version, device kind, interpret flag) so a
baseline's provenance is attributable when a gate fails — the PR-5
load-noise incident, made diagnosable."""
from __future__ import annotations

import json
import pathlib
import subprocess
import time

import jax

from repro.core import csr as csr_mod
from repro.core import rmat

_GRAPH_CACHE: dict = {}

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_bfs.json"

#: name -> {"us_per_call": float, "derived": str, "value": float?}
RESULTS: dict[str, dict] = {}


def graph(scale: int, edgefactor: int = 16, seed: int = 2):
    key = (scale, edgefactor, seed)
    if key not in _GRAPH_CACHE:
        _GRAPH_CACHE[key] = csr_mod.from_edges(
            rmat.generate(jax.random.PRNGKey(seed), scale, edgefactor))
    return _GRAPH_CACHE[key]


def time_bfs(fn, csr, roots, warmup_root=None) -> float:
    """Mean seconds per BFS over the given roots (after warmup)."""
    jax.block_until_ready(
        fn(csr, int(warmup_root if warmup_root is not None
                    else roots[0])).parent)
    t0 = time.perf_counter()
    for r in roots:
        jax.block_until_ready(fn(csr, int(r)).parent)
    return (time.perf_counter() - t0) / len(roots)


def emit(name: str, us_per_call: float, derived: str,
         value: float | None = None):
    """The run.py contract: ``name,us_per_call,derived`` CSV.

    ``value`` optionally attaches a machine-readable number (TEPS,
    analytic bytes, tile counts) to the ``RESULTS``/BENCH_bfs.json
    record — what regression gates compare instead of parsing the
    derived string.  Every emit is mirrored into the process metrics
    registry as gauges ``bench.<name>`` (the value, when given) and
    ``bench.<name>.us_per_call``."""
    print(f"{name},{us_per_call:.1f},{derived}")
    rec = {"us_per_call": round(us_per_call, 1), "derived": derived}
    if value is not None:
        rec["value"] = float(value)
    RESULTS[name] = rec
    from repro.obs import get_registry
    reg = get_registry()
    reg.gauge(f"bench.{name}.us_per_call").set(us_per_call)
    if value is not None:
        reg.gauge(f"bench.{name}").set(float(value))


def build_meta(timestamp: str | None = None) -> dict:
    """The ``_meta`` provenance record stamped into BENCH_bfs.json.

    ``timestamp`` is passed in by the harness (one stamp per run, not
    one per call).  Git metadata degrades to "unknown" outside a work
    tree so benchmarks stay runnable from an export."""
    def _git(*args: str) -> str:
        try:
            return subprocess.run(
                ["git", *args], capture_output=True, text=True,
                cwd=pathlib.Path(__file__).resolve().parent,
                timeout=10).stdout.strip() or "unknown"
        except (OSError, subprocess.SubprocessError):
            return "unknown"

    return {
        "git_sha": _git("rev-parse", "--short", "HEAD"),
        "git_dirty": bool(_git("status", "--porcelain")
                          not in ("", "unknown")),
        "timestamp": timestamp or "unknown",
        "jax_version": jax.__version__,
        "device_kind": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
    }


def save_results(meta: dict | None = None) -> None:
    """Merge ``RESULTS`` into BENCH_bfs.json (sorted, stable diffs).
    ``meta`` (see `build_meta`) replaces the file's ``_meta`` record —
    the underscore prefix keeps it clear of every benchmark key
    namespace (gates and `formats.affinity` look up specific
    prefixes)."""
    data = {}
    if BENCH_JSON.exists():
        data = json.loads(BENCH_JSON.read_text())
    data.update(RESULTS)
    if meta is not None:
        data["_meta"] = meta
    BENCH_JSON.write_text(json.dumps(data, indent=1, sort_keys=True)
                          + "\n")
