"""Paper Fig. 9: SIMD optimization ablation.

The paper compares three builds of the SIMD path on SCALE-20:
  (1) SIMD - no opt
  (2) SIMD + alignment + masks
  (3) SIMD + prefetching
TPU analogues (DESIGN.md §2):
  (1) kernel path forced on every layer with minimal tiles (no
      layer-adaptive switch §4.1, no DMA depth) — vector-unit overhead
      exposed on skinny layers;
  (2) + layer-adaptive switch + lane-aligned tiles (the padded CSR and
      mask machinery is structural and always on — alignment here
      selects the hardware tile);
  (3) + deep edge-stream tiles = Mosaic double-buffering distance, the
      software-prefetch analogue.

Plus the ISSUE 3 **pipeline axis** through the fused engine:
``fused_gather`` (in-kernel CSR gather + active-tile work-list) vs
``materialized`` (the legacy full-E (u, v, valid) HBM round trip) at
the same policy/tile — timed, and with the analytic bytes-moved of
each pipeline emitted (the number that transfers to TPU; interpret
wall time does not, the fused kernel's in-kernel owner search is pure
Python overhead there).

Numbers on this container come from interpret-mode kernels on CPU, so
ONLY the relative ordering is meaningful; the structure (which knob
buys what) is what transfers to TPU.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph, time_bfs


def main(scale: int = 13, n_roots: int = 3):
    g = graph(scale)
    rng = np.random.default_rng(1)
    deg = np.asarray(g.degrees())
    roots = rng.choice(np.nonzero(deg > 0)[0], size=n_roots,
                       replace=False)

    # tile-differentiated variants run through the hostloop driver,
    # which honors the requested tile exactly against bucketed layer
    # sizes (the fused engine clamps small tiles in interpret mode)
    from repro.core import engine
    variants = {
        "simd_no_opt": dict(policy=engine.ThresholdSimd(0), tile=128),
        "simd_align_mask": dict(policy=engine.ThresholdSimd(16_384),
                                tile=1024),
        "simd_prefetch": dict(policy=engine.ThresholdSimd(16_384),
                              tile=None),
    }
    print(f"# Fig. 9 analog: SCALE={scale}, {n_roots} roots")
    results = {}
    for name, kw in variants.items():
        sec = time_bfs(
            lambda c, r, kw=kw: engine.traverse_hostloop(c, r, **kw)[0],
            g, roots)
        results[name] = sec
        teps = g.n_edges / 2 / sec
        emit(f"bfs_opt_ablation.{name}", sec * 1e6,
             f"{teps:.3e}_teps")
    # layer-adaptive switch should beat always-on minimal-tile SIMD
    # (Fig. 9 shape); 1.3x slack absorbs shared-CPU timing noise
    assert results["simd_align_mask"] <= 1.3 * results["simd_no_opt"], \
        "layer-adaptive switch regressed vs always-on SIMD"

    # pipeline ablation (ISSUE 3, spec-swept since ISSUE 5): fused
    # in-kernel gather vs the legacy materialized stream — each axis
    # point is ONE declarative TraversalSpec planned through
    # repro.bfs.plan (one cached executable per resolved spec), SIMD
    # kernel forced on so the pipelines actually diverge
    import repro.bfs as bfs
    from repro.formats.base import traversal_bytes
    from repro.formats.csr_format import CsrFormat
    fmt = CsrFormat.from_csr(g)
    sweep = {f"pipeline_{p}": bfs.TraversalSpec(
                 policy=engine.ThresholdSimd(0), pipeline=p)
             for p in engine.PIPELINES}
    for name, spec in sweep.items():
        ct = bfs.plan(g, spec)
        res = ct.run(int(roots[0]))
        stats = ct.stats(res)
        mb = traversal_bytes(fmt, stats, tile=ct.resolved.tile,
                             pipeline=ct.resolved.pipeline,
                             packed=ct.resolved.packed) / 2**20
        sec = time_bfs(lambda c, r, ct=ct: ct.run(r).state, g, roots)
        results[name] = sec
        teps = g.n_edges / 2 / sec
        emit(f"bfs_opt_ablation.{name}", sec * 1e6,
             f"{teps:.3e}_teps;layers={len(stats)};mb_moved={mb:.2f}",
             value=mb)
        assert ct.traces == 1, "spec sweep must reuse one trace/axis"
    return results


if __name__ == "__main__":
    main()
