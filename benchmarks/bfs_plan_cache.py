"""Plan-cache micro-benchmark (ISSUE 5).

Measures what the plan/compile/run layer buys the serving scenario:
ONE engine trace per (geometry, resolved spec) no matter how many
roots run — versus re-deciding/re-tracing knobs per call.  Emits:

* ``bfs_plan_cache.traces_per_10_runs`` — engine traces 10 ``.run()``
  calls of one plan cost (value; MUST be 1 — the CI-facing number).
* ``bfs_plan_cache.plan_us`` — cost of a cache-hit ``plan()`` call
  (spec resolution + cache lookup; the per-query overhead a serving
  layer would pay if it re-planned every request).
* ``bfs_plan_cache.cached_run`` — steady-state per-root wall time
  through the cached executable (the serving hot path).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, graph


def main(scale: int = 10, n_runs: int = 10):
    import repro.api.plan as api_plan
    import repro.bfs as bfs

    g = graph(scale)
    api_plan.clear_cache()
    spec = bfs.TraversalSpec(policy="topdown")

    ct = bfs.plan(g, spec)
    rng = np.random.default_rng(7)
    deg = np.asarray(g.degrees())
    roots = rng.choice(np.nonzero(deg > 0)[0], size=n_runs,
                       replace=False)

    t0 = time.perf_counter()
    for r in roots:
        jax.block_until_ready(ct.run(int(r)).state.parent)
    sec_all = time.perf_counter() - t0
    traces = ct.traces
    emit(f"bfs_plan_cache.traces_per_{n_runs}_runs",
         sec_all * 1e6 / n_runs,
         f"traces={traces};scale={scale}", value=traces)
    assert traces <= 1, (
        f"plan cache re-traced: {traces} traces / {n_runs} runs")

    # cache-hit plan() cost: what re-planning per request would add
    n_plan = 200
    t0 = time.perf_counter()
    for _ in range(n_plan):
        ct2 = bfs.plan(g, spec)
    plan_us = (time.perf_counter() - t0) * 1e6 / n_plan
    assert ct2.executable is ct.executable
    emit("bfs_plan_cache.plan_us", plan_us,
         f"cache_hits={api_plan.cache_info()['hits']}", value=plan_us)

    # steady-state cached run (serving hot path)
    t0 = time.perf_counter()
    for r in roots:
        jax.block_until_ready(ct.run(int(r)).state.parent)
    sec_warm = (time.perf_counter() - t0) / n_runs
    teps = g.n_edges / 2 / sec_warm
    emit("bfs_plan_cache.cached_run", sec_warm * 1e6,
         f"{teps:.3e}_teps", value=teps)
    return {"traces": traces, "plan_us": plan_us}


if __name__ == "__main__":
    main()
