"""Paper Table 2: thread-affinity / resource-sharing analogue.

The paper's experiment: 48 threads packed onto 48/24/16/12 cores —
packing threads divides per-thread cache and bandwidth, 1T/core wins
by 3.3x.  TPU has no SMT; the corresponding resource-sharing axes are:

  (a) edge-shards per chip (distributed BFS): fewer chips = more edges
      per chip sharing one HBM pipe — we report the partition's
      per-chip edge load and skew across device counts (the bandwidth-
      sharing curve), plus

  (b) VMEM population: kernel tile size vs working-set pressure —
      more in-flight tiles share VMEM exactly like more threads share
      L2.  Measured via the vectorized path's tile sweep.

Output mirrors Table 2's shape: population factor -> throughput.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph, time_bfs
from repro.core.bfs_distributed import partition_csr
from repro.kernels.frontier_expand import vmem_budget


def main(scale: int = 13):
    g = graph(scale)
    print(f"# Table 2 analog (a): edge-shard load per chip, SCALE={scale}")
    print("chips,mean_edges_per_chip,max_edges_per_chip,skew")
    for chips in (4, 16, 64, 256):
        if g.n_vertices < chips * 128:
            continue
        rows_sh, cs_sh = partition_csr(g, chips)
        per = np.asarray(cs_sh)[:, -1]
        skew = per.max() / max(per.mean(), 1)
        print(f"{chips},{per.mean():.0f},{per.max()},{skew:.2f}")
        emit(f"affinity.shard_skew.chips{chips}", 0.0, f"{skew:.3f}")

    print(f"# Table 2 analog (b): VMEM population (tile sweep)")
    # the hostloop driver honors the requested tile exactly against the
    # bucketed layer sizes (the fused engine clamps small tiles in
    # interpret mode to bound trace-time grid unrolling)
    from repro.core import engine
    policy = engine.ThresholdSimd(16_384)
    rng = np.random.default_rng(3)
    deg = np.asarray(g.degrees())
    roots = rng.choice(np.nonzero(deg > 0)[0], size=2, replace=False)
    v_pad = g.n_vertices_padded
    w = v_pad // 32
    for tile in (512, 1024, 4096, 16384):
        sec = time_bfs(
            lambda c, r, t=tile: engine.traverse_hostloop(
                c, r, policy=policy, tile=t)[0],
            g, roots)
        vmem = vmem_budget(w, v_pad, tile)
        teps = g.n_edges / 2 / sec
        emit(f"affinity.tile{tile}", sec * 1e6,
             f"{teps:.3e}_teps_vmem{vmem//1024}KiB")


if __name__ == "__main__":
    main()
