"""Paper Table 2: thread-affinity / resource-sharing analogue — now
the autotune harness behind every ``"auto"`` spec knob (ISSUE 6).

The paper's experiment: 48 threads packed onto 48/24/16/12 cores —
packing threads divides per-thread cache and bandwidth, 1T/core wins
by 3.3x.  TPU has no SMT; the corresponding resource-sharing axes are:

  (a) edge-shards per chip (distributed BFS): fewer chips = more edges
      per chip sharing one HBM pipe — we report the partition's
      per-chip edge load and skew across device counts (the bandwidth-
      sharing curve), plus

  (b) VMEM population: the per-(format, geometry-class) knob sweeps —
      tile size, DMA prefetch depth, pipeline (unfused 3-launch layer
      vs the whole-layer megakernel) and the SELL σ sort window.  More
      in-flight tiles share VMEM exactly like more threads share L2.

Every sweep row is emitted through `formats.affinity.key_for`, the
writer-side twin of the `formats.affinity.resolve` lookup every auto
knob reads — committing this run's BENCH_bfs.json IS the autotable:

    affinity.{format}.{geometry}.{knob}{value}   e.g.
    affinity.csr.skew16.tile4096
    affinity.csr.skew16.pipeline_megakernel
    affinity.sell.skew16.sigma1024

Within one (format, geometry, knob) group the lowest ``us_per_call``
wins at lookup time; sweeping a second geometry class (the uniform
2-D mesh vs the skewed RMAT) adds rows instead of overwriting.  The
PR-4 flat ``affinity.tile<N>`` rows are no longer emitted (committed
old ones keep working as the back-compat tier-3 read path).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph, time_bfs
from repro.core.bfs_distributed import partition_csr
from repro.kernels.frontier_expand import vmem_budget

# per-knob sweep grids (format -> knob -> values)
CSR_TILES = (512, 1024, 4096, 16384)
CSR_PREFETCH = (0, 1, 2)
CSR_PIPELINES = ("fused_gather", "megakernel", "persistent")
SELL_SIGMAS = (256, 1024, 4096)
SELL_PIPELINES = ("fused_gather", "megakernel", "persistent")
# crossed axis (ISSUE 10 satellite): the whole-traversal persistent
# kernel (ISSUE 9) carries the §4 manual prefetch distance *into* the
# in-kernel layer loop, so depth tunes differently there than under
# the per-layer pipelines — sweep the cross explicitly and commit
# `affinity.{fmt}.{geom}.persistent_prefetch{d}` rows per geometry
PERSISTENT_PREFETCH = (0, 1, 2)


def _mesh(side: int):
    """A uniform 4-regular 2-D torus — the skew1 geometry class, so
    the table learns different tunings for RMAT skew vs flat meshes."""
    from repro.core import csr as csr_mod
    from repro.core.rmat import EdgeList
    v = side * side
    idx = np.arange(v, dtype=np.int32)
    x, y = idx % side, idx // side
    right = ((x + 1) % side) + y * side
    down = x + ((y + 1) % side) * side
    src = np.concatenate([idx, idx])
    dst = np.concatenate([right, down])
    # symmetrize (from_edges builds the directed adjacency as-is)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    import jax.numpy as jnp
    return csr_mod.from_edges(EdgeList(
        src=jnp.asarray(src), dst=jnp.asarray(dst), n_vertices=v))


def _sweep_csr(g, label: str):
    """Tile / prefetch / pipeline sweeps for one geometry class."""
    import jax
    from repro.api import plan as plan_mod
    from repro.api import spec as spec_mod
    from repro.formats import affinity
    from repro.formats.csr_format import CsrFormat

    fmt = CsrFormat.from_csr(g)
    geom = affinity.geometry_class(fmt)
    print(f"# Table 2 analog (b): {label} -> affinity.csr.{geom}.*")
    rng = np.random.default_rng(3)
    deg = np.asarray(g.degrees())
    roots = rng.choice(np.nonzero(deg > 0)[0], size=2, replace=False)
    v_pad = g.n_vertices_padded
    w = v_pad // 32

    def run(spec):
        ct = plan_mod.plan(fmt, spec)
        return time_bfs(lambda c, r: ct.run(r).state, g, roots)

    for tile in CSR_TILES:
        sec = run(spec_mod.TraversalSpec(tile=tile))
        vmem = vmem_budget(w, v_pad, tile)
        teps = g.n_edges / 2 / sec
        emit(affinity.key_for("csr", geom, "tile", tile), sec * 1e6,
             f"{teps:.3e}_teps_vmem{vmem // 1024}KiB", value=teps)
    for depth in CSR_PREFETCH:
        sec = run(spec_mod.TraversalSpec(prefetch_depth=depth))
        teps = g.n_edges / 2 / sec
        emit(affinity.key_for("csr", geom, "prefetch_depth", depth),
             sec * 1e6, f"{teps:.3e}_teps", value=teps)
    for pipe in CSR_PIPELINES:
        sec = run(spec_mod.TraversalSpec(pipeline=pipe))
        teps = g.n_edges / 2 / sec
        emit(affinity.key_for("csr", geom, "pipeline", pipe),
             sec * 1e6, f"{teps:.3e}_teps", value=teps)
    for depth in PERSISTENT_PREFETCH:
        sec = run(spec_mod.TraversalSpec(pipeline="persistent",
                                         prefetch_depth=depth))
        teps = g.n_edges / 2 / sec
        emit(affinity.key_for("csr", geom, "persistent_prefetch",
                              depth),
             sec * 1e6, f"{teps:.3e}_teps", value=teps)


def _sweep_sell(g, label: str):
    """σ sort-window + pipeline sweeps (SELL's resource-sharing knobs).

    Since ISSUE 9 SELL fuses (megakernel) and runs whole traversals in
    one launch (persistent), so the pipeline knob is swept here too —
    ``affinity.sell.{geom}.pipeline_persistent`` rows let ``"auto"``
    resolve the launch-count ladder per geometry class.
    """
    from repro.api import plan as plan_mod
    from repro.api import spec as spec_mod
    from repro.formats import affinity
    from repro.formats.sell import SellFormat

    geom = affinity.geometry_class(g)
    print(f"# Table 2 analog (b): {label} -> affinity.sell.{geom}.*")
    rng = np.random.default_rng(3)
    deg = np.asarray(g.degrees())
    roots = rng.choice(np.nonzero(deg > 0)[0], size=2, replace=False)
    for sigma in SELL_SIGMAS:
        fmt = SellFormat.from_csr(g, sigma=sigma)
        ct = plan_mod.plan(fmt, spec_mod.TraversalSpec())
        sec = time_bfs(lambda c, r: ct.run(r).state, g, roots)
        teps = g.n_edges / 2 / sec
        emit(affinity.key_for("sell", geom, "sigma", sigma),
             sec * 1e6,
             f"{teps:.3e}_teps_slots{fmt.nnz_stored}", value=teps)
    fmt = SellFormat.from_csr(g)
    for pipe in SELL_PIPELINES:
        ct = plan_mod.plan(fmt, spec_mod.TraversalSpec(pipeline=pipe))
        sec = time_bfs(lambda c, r: ct.run(r).state, g, roots)
        teps = g.n_edges / 2 / sec
        emit(affinity.key_for("sell", geom, "pipeline", pipe),
             sec * 1e6, f"{teps:.3e}_teps", value=teps)
    for depth in PERSISTENT_PREFETCH:
        ct = plan_mod.plan(fmt, spec_mod.TraversalSpec(
            pipeline="persistent", prefetch_depth=depth))
        sec = time_bfs(lambda c, r: ct.run(r).state, g, roots)
        teps = g.n_edges / 2 / sec
        emit(affinity.key_for("sell", geom, "persistent_prefetch",
                              depth),
             sec * 1e6, f"{teps:.3e}_teps", value=teps)


def main(scale: int = 13):
    g = graph(scale)
    print(f"# Table 2 analog (a): edge-shard load per chip, SCALE={scale}")
    print("chips,mean_edges_per_chip,max_edges_per_chip,skew")
    for chips in (4, 16, 64, 256):
        if g.n_vertices < chips * 128:
            continue
        rows_sh, cs_sh = partition_csr(g, chips)
        per = np.asarray(cs_sh)[:, -1]
        skew = per.max() / max(per.mean(), 1)
        print(f"{chips},{per.mean():.0f},{per.max()},{skew:.2f}")
        emit(f"affinity.shard_skew.chips{chips}", 0.0, f"{skew:.3f}")

    # (b) the knob sweeps, one geometry class per graph family: the
    # RMAT graph lands in a skew bucket, the torus in skew1 — two
    # table rows per knob value, resolved independently at lookup
    _sweep_csr(g, f"RMAT SCALE={scale}")
    _sweep_csr(_mesh(64), "64x64 torus")
    _sweep_sell(g, f"RMAT SCALE={scale}")


if __name__ == "__main__":
    main()
