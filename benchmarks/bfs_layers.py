"""Paper Table 1: traversed vertices/edges per BFS layer.

Reproduces the layer-profile measurement that justifies §4.1's
layer-adaptive vectorization: the fat middle layers carry ~95% of the
edge traffic.  Run at the paper's SCALE=20 with --scale 20 (needs
~4 GB); default 16 for CPU-friendliness.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph
from repro.core.bfs_parallel import run_bfs


def main(scale: int = 16, root_seed: int = 0):
    g = graph(scale)
    rng = np.random.default_rng(root_seed)
    deg = np.asarray(g.degrees())
    root = int(rng.choice(np.nonzero(deg > 0)[0]))
    _, stats = run_bfs(g, root, algorithm="simd", collect_stats=True)

    print(f"# Table 1 analog: SCALE={scale} edgefactor=16 root={root}")
    print("layer,vertices,edges,traversed")
    total_e = sum(s.edges_examined for s in stats)
    fat = 0
    for s in stats:
        print(f"{s.layer},{s.frontier_vertices},{s.edges_examined},"
              f"{s.discovered}")
    top2 = sorted(s.edges_examined for s in stats)[-2:]
    fat_frac = sum(top2) / max(total_e, 1)
    emit("bfs_layers.fat2_edge_fraction", 0.0, f"{fat_frac:.3f}")
    emit("bfs_layers.diameter", 0.0, str(len(stats)))
    return fat_frac


if __name__ == "__main__":
    main()
