"""Paper Table 1: traversed vertices/edges per BFS layer — plus the
ISSUE 3 active-tile / bytes-moved instrumentation.

Reproduces the layer-profile measurement that justifies §4.1's
layer-adaptive vectorization: the fat middle layers carry ~95% of the
edge traffic.  Run at the paper's SCALE=20 with --scale 20 (needs
~4 GB); default 16 for CPU-friendliness.

The layer table now carries the fused pipeline's per-layer
``active_tiles`` counter (how many rows-blocks the layer's work-list
actually scheduled) — the analytic evidence that per-layer HBM
traffic is frontier-proportional, visible even in interpret mode.

`path_probe` is the high-diameter acceptance probe: a path graph
(SCALE >= 10, one vertex per layer — the materialized pipeline's
worst case, every thin layer re-streams the full padded E) traversed
with the SIMD kernel forced on.  It reports analytic bytes-moved for
both pipelines; the fused number is the baseline the CI regression
gate (`benchmarks.check_bytes_regression`) compares against.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, graph
from repro.core import csr as csr_mod, engine
from repro.core.bfs_parallel import run_bfs
from repro.core.rmat import EdgeList
from repro.formats.base import traversal_bytes
from repro.formats.csr_format import CsrFormat

PATH_SCALE = 10    # fixed: the probe is the CI baseline, not --quick'd
PATH_TILE = 128    # one lane set — the probe's prefetch distance


def build_path_graph(n: int):
    """Symmetrized chain 0-1-...-(n-1): one vertex per layer."""
    i = jnp.arange(n - 1, dtype=jnp.int32)
    return csr_mod.from_edges(
        EdgeList(src=jnp.concatenate([i, i + 1]),
                 dst=jnp.concatenate([i + 1, i]),
                 n_vertices=n))


def path_probe(scale: int = PATH_SCALE, tile: int = PATH_TILE,
               quiet: bool = False) -> dict:
    """Analytic bytes-moved for a high-diameter traversal, per
    pipeline.  Deterministic (no timing) — safe as a CI gate."""
    n = 1 << scale
    g = build_path_graph(n)
    fmt = CsrFormat.from_csr(g)
    t = fmt.resolve_tile(tile)
    res = engine.traverse(g, 0, spec=engine.make_spec(
        policy=engine.ThresholdSimd(0), tile=tile, max_layers=n + 2,
        pipeline="fused_gather"))
    stats = engine.layer_stats(res)
    fused = traversal_bytes(fmt, stats, tile=t,
                            pipeline="fused_gather")
    mat = traversal_bytes(fmt, stats, tile=t, pipeline="materialized")
    out = {
        "layers": len(stats),
        "tile": t,
        "bytes_fused": fused,
        "bytes_materialized": mat,
        "ratio": mat / max(fused, 1),
        "max_layer_tiles": max(s.active_tiles for s in stats),
    }
    if not quiet:
        emit("bfs_layers.path_bytes_fused", 0.0,
             f"scale={scale};tile={t};bytes={fused}", value=fused)
        emit("bfs_layers.path_bytes_materialized", 0.0,
             f"scale={scale};tile={t};bytes={mat}", value=mat)
        emit("bfs_layers.path_bytes_ratio", 0.0,
             f"{out['ratio']:.1f}x", value=out["ratio"])
        emit("bfs_layers.path_max_layer_tiles", 0.0,
             str(out["max_layer_tiles"]),
             value=out["max_layer_tiles"])
    return out


def main(scale: int = 16, root_seed: int = 0):
    g = graph(scale)
    rng = np.random.default_rng(root_seed)
    deg = np.asarray(g.degrees())
    root = int(rng.choice(np.nonzero(deg > 0)[0]))
    _, stats = run_bfs(g, root, algorithm="simd", collect_stats=True,
                       policy=engine.ThresholdSimd(0))

    print(f"# Table 1 analog: SCALE={scale} edgefactor=16 root={root}")
    print("layer,vertices,edges,traversed,active_tiles")
    total_e = sum(s.edges_examined for s in stats)
    for s in stats:
        print(f"{s.layer},{s.frontier_vertices},{s.edges_examined},"
              f"{s.discovered},{s.active_tiles}")
    top2 = sorted(s.edges_examined for s in stats)[-2:]
    fat_frac = sum(top2) / max(total_e, 1)
    emit("bfs_layers.fat2_edge_fraction", 0.0, f"{fat_frac:.3f}",
         value=fat_frac)
    emit("bfs_layers.diameter", 0.0, str(len(stats)),
         value=len(stats))
    total_tiles = sum(s.active_tiles for s in stats)
    emit("bfs_layers.total_active_tiles", 0.0, str(total_tiles),
         value=total_tiles)

    # the high-diameter probe: the paper's prefetch lesson, measured
    # as frontier-proportional bytes.  Fixed scale/tile — this is the
    # committed baseline the CI bytes-moved gate compares against.
    probe = path_probe()
    print(f"# path probe s={PATH_SCALE}: fused "
          f"{probe['bytes_fused']/2**20:.2f} MiB vs materialized "
          f"{probe['bytes_materialized']/2**20:.2f} MiB "
          f"({probe['ratio']:.1f}x), max {probe['max_layer_tiles']} "
          f"tile(s)/layer")
    return fat_frac


if __name__ == "__main__":
    main()
