"""Semiring algorithm portfolio benchmark (ISSUE 10).

One engine, a portfolio of graph algorithms: SSSP (min-plus over the
synthetic hash weights), connected components (min-label propagation)
and k-source BFS all run through the same relax kernels and plan
cache as BFS.  Two row families per (algorithm, layout):

* ``bfs_algorithms.{alg}.{fmt}.teps`` — TEPS-equivalent throughput
  (edge relaxations per second, from the driver's on-device stats
  buffer over interpret-mode wall clock);
* ``bfs_algorithms.{alg}.{fmt}.bytes`` — analytic HBM bytes-moved for
  the traversal (`formats.base.traversal_bytes` over the measured
  active tiles — the frontier-proportionality evidence).

`semiring_path_probe` is the zero-abstraction-tax probe: BFS run AS a
semiring instance (``ksource_bfs``, one root) on the exact
`bfs_layers.path_probe` geometry (path graph SCALE-10, fixed tile).
Its analytic bytes must EQUAL the committed
``bfs_layers.path_bytes_fused`` baseline — the generic relax schedule
plans the same active tiles as the hard-wired BFS engine, so the
abstraction costs zero bytes.  `benchmarks.check_bytes_regression`
gates on it.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph, time_bfs
from repro.core import engine

#: generous iteration ceiling: SSSP drains one delta bucket per driver
#: iteration (more iterations than BFS diameter); while_loop exits
#: early so the headroom is free
MAX_LAYERS = 512
ALGORITHMS = ("sssp", "cc", "ksource_bfs")
FORMATS = ("csr", "sell")
KSOURCE_ROOTS = 4


def semiring_path_probe(quiet: bool = False) -> dict:
    """Analytic bytes for BFS-as-a-semiring on the CI path-probe
    geometry.  Deterministic (no timing) — safe as a CI gate."""
    from benchmarks.bfs_layers import (PATH_SCALE, PATH_TILE,
                                       build_path_graph)
    from repro.api.plan import plan
    from repro.api.spec import TraversalSpec
    from repro.formats.base import traversal_bytes
    from repro.formats.csr_format import CsrFormat

    n = 1 << PATH_SCALE
    fmt = CsrFormat.from_csr(build_path_graph(n))
    t = fmt.resolve_tile(PATH_TILE)
    ct = plan(fmt, TraversalSpec(algorithm="ksource_bfs",
                                 policy="topdown", tile=PATH_TILE,
                                 max_layers=n + 2))
    res = ct.run(0)
    stats = engine.layer_stats(res)
    out = {
        "layers": len(stats),
        "tile": t,
        "bytes_semiring": traversal_bytes(fmt, stats, tile=t,
                                          pipeline="fused_gather"),
        "max_layer_tiles": max(s.active_tiles for s in stats),
    }
    if not quiet:
        emit("bfs_algorithms.path_bytes_semiring", 0.0,
             f"scale={PATH_SCALE};tile={t};"
             f"bytes={out['bytes_semiring']}",
             value=out["bytes_semiring"])
    return out


def main(scale: int = 12, root_seed: int = 0):
    from repro.api.plan import plan
    from repro.api.spec import TraversalSpec
    from repro.formats import registry
    from repro.formats.base import traversal_bytes

    g = graph(scale)
    rng = np.random.default_rng(root_seed)
    deg = np.asarray(g.degrees())
    roots = rng.choice(np.nonzero(deg > 0)[0], size=KSOURCE_ROOTS,
                       replace=False).astype(np.int32)

    print(f"# algorithm portfolio: SCALE={scale} edgefactor=16 "
          f"roots={roots.tolist()}")
    print("algorithm,format,layers,relaxations,teps_equiv,bytes")
    for fmt_name in FORMATS:
        fmt = registry.get(fmt_name).from_graph(g)
        for alg in ALGORITHMS:
            ct = plan(fmt, TraversalSpec(algorithm=alg,
                                         policy="topdown",
                                         max_layers=MAX_LAYERS))
            if alg == "ksource_bfs":
                # the k-source contract: ONE traversal, k depth rows
                sec = time_bfs(
                    lambda c, r: ct.run_batched(roots).state, g,
                    roots[:1])
                res = ct.run_batched(roots)
            else:
                sec = time_bfs(lambda c, r: ct.run(r).state, g,
                               roots[:2])
                res = ct.run(int(roots[0]))
            stats = engine.layer_stats(res)
            relaxations = sum(s.edges_examined for s in stats)
            teps = relaxations / sec
            nbytes = traversal_bytes(fmt, stats,
                                     tile=ct.resolved.tile,
                                     pipeline="fused_gather")
            print(f"{alg},{fmt_name},{len(stats)},{relaxations},"
                  f"{teps:.3e},{nbytes}")
            emit(f"bfs_algorithms.{alg}.{fmt_name}.teps", sec * 1e6,
                 f"{teps:.3e}_relax_per_s", value=teps)
            emit(f"bfs_algorithms.{alg}.{fmt_name}.bytes", 0.0,
                 f"scale={scale};tile={ct.resolved.tile};"
                 f"bytes={nbytes}", value=nbytes)

    # the zero-abstraction-tax probe: BFS via the semiring machinery
    # must plan the same bytes as the hard-wired engine (the CI gate
    # compares against the committed bfs_layers baseline)
    probe = semiring_path_probe()
    print(f"# path probe: semiring BFS "
          f"{probe['bytes_semiring'] / 2**20:.2f} MiB over "
          f"{probe['layers']} layers, max "
          f"{probe['max_layer_tiles']} tile(s)/layer")


if __name__ == "__main__":
    main()
