"""Paper Fig. 10 (a-c): simd vs non-simd TEPS across graph scales.

Measures harmonic-mean TEPS for the non-simd (Alg. 2) and simd
(Alg. 3 + kernels) builds across SCALE factors, the §6.1 comparison.
The paper's x-axis (thread count) has no CPU-container analogue, so
the measured section sweeps SCALE, and the *distributed* scaling curve
(the multi-chip analogue of more threads) is projected from the
dry-run roofline artifacts of the distributed BFS (collective term vs
edge-stream term per chip count), printed when artifacts exist.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from benchmarks.common import emit, graph
from repro.core.bfs_parallel import run_bfs
from repro.core.bfs_vectorized import run_bfs_vectorized
from repro.core.stats import run_harness
import jax


def main(scales=(12, 13, 14), n_roots: int = 4):
    print(f"# Fig. 10 analog: scales={scales}")
    out = {}
    for scale in scales:
        g = graph(scale)
        for name, fn in [
            ("nonsimd", lambda c, r: run_bfs(c, r, algorithm="nonsimd")),
            ("simd", lambda c, r: run_bfs(c, r, algorithm="simd")),
            ("vectorized", run_bfs_vectorized),
        ]:
            h = run_harness(g, fn, jax.random.PRNGKey(scale),
                            n_roots=n_roots)
            out[(scale, name)] = h.hmean_teps
            emit(f"bfs_scaling.scale{scale}.{name}",
                 h.mean_seconds * 1e6, f"{h.hmean_teps:.3e}_hmean_teps")

    # distributed projection from dry-run roofline (if available)
    for gname in ("rmat-24", "rmat-27"):
        for mesh, chips in (("single", 256), ("multi", 512)):
            p = Path(f"results/dryrun/bfs-{gname}__graph500__{mesh}.json")
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] != "ok":
                continue
            ro = r["roofline"]
            t_layer = max(ro["t_memory_s"], ro["t_collective_s"],
                          ro["t_compute_s"])
            scale = int(gname.split("-")[1])
            edges = (1 << scale) * 16
            # while-loop bound uses max_layers; real diameter ~7
            teps = edges / (t_layer / 64 * 7)
            emit(f"bfs_scaling.projected.{gname}.{mesh}", 0.0,
                 f"{teps:.3e}_teps_bound_{ro['bottleneck']}")
    return out


if __name__ == "__main__":
    main()
