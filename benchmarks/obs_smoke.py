"""Observability smoke check (``make obs-smoke``, ISSUE 7).

End-to-end assertion of the obs contract on a small graph:

1. `repro.bfs.trace_run` produces a Chrome trace-event JSON
   (``artifacts/obs_trace.json`` — CI uploads the ``artifacts/`` dir
   as a workflow artifact; it is never committed) that PARSES,
   contains >= 1 ``bfs.traversal`` span, and
   whose ``bfs.layer`` span count equals ``len(stats)`` — the
   per-layer timing really is attached to the LayerStats rows.
2. A `GraphEngine` run records serve metrics: the snapshot reports
   submit->harvest latency p50/p99, round-trips through
   ``json.dumps``/``loads`` unchanged, and the Prometheus text
   exposition is non-empty.

Exit code 0 = all assertions hold.

    PYTHONPATH=src python -m benchmarks.obs_smoke [out.json]
"""
from __future__ import annotations

import json
import pathlib
import sys

#: run outputs live under the git-ignored artifacts dir, never at the
#: repo root (a committed trace JSON churns every CI run)
ARTIFACTS_DIR = pathlib.Path(__file__).resolve().parent.parent \
    / "artifacts"
TRACE_JSON = ARTIFACTS_DIR / "obs_trace.json"
SMOKE_SCALE = 8


def main(out_path: str | pathlib.Path = TRACE_JSON) -> int:
    pathlib.Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    import repro.bfs as bfs
    from benchmarks import common
    from repro.obs import MetricsRegistry
    from repro.obs.trace import LAYER_SPAN, STEP_SPAN, TRAVERSAL_SPAN
    from repro.serve.graph_engine import BfsQuery, GraphEngine

    csr = common.graph(SMOKE_SCALE)

    # -- 1: span tracer -> Chrome trace JSON -----------------------------
    tr = bfs.trace_run(csr, [0, 1])
    path = tr.tracer.export(str(out_path))
    loaded = json.loads(pathlib.Path(path).read_text())   # must parse
    names = [e["name"] for e in loaded["traceEvents"]]
    n_trav = names.count(TRAVERSAL_SPAN)
    n_layer = names.count(LAYER_SPAN)
    n_step = names.count(STEP_SPAN)
    assert n_trav >= 1, f"no {TRAVERSAL_SPAN} span in {path}"
    assert n_layer == len(tr.stats), (
        f"{n_layer} layer spans != {len(tr.stats)} LayerStats rows")
    assert n_step == len(tr.stats), (
        f"{n_step} step spans != {len(tr.stats)} layers")
    assert len(tr.layer_seconds) == len(tr.stats)
    assert all(s >= 0 for s in tr.layer_seconds)
    print(f"trace: {path} parses; {n_trav} traversal span, "
          f"{n_layer} layer spans == {len(tr.stats)} LayerStats rows")

    # -- 2: serve metrics snapshot ---------------------------------------
    reg = MetricsRegistry()
    eng = GraphEngine(csr, batch_slots=4, registry=reg)
    for i in range(6):
        eng.submit(BfsQuery(uid=i, root=(i * 7) % csr.n_vertices))
    eng.run_until_done()
    eng.step()                       # idle tick -> counted as skipped
    snap = reg.snapshot()
    lat = snap["histograms"]["serve.query_latency_s"]
    assert lat["count"] == 6, lat
    assert lat["p50"] is not None and lat["p99"] is not None, lat
    assert snap["counters"]["serve.ticks_skipped"] >= 1
    assert snap["histograms"]["serve.tick_s"]["count"] >= 1
    roundtrip = json.loads(json.dumps(snap))
    assert roundtrip == snap, "metrics snapshot does not round-trip"
    prom = reg.to_prometheus()
    assert "serve_query_latency_s" in prom and prom.strip()
    print(f"metrics: serve p50={lat['p50']*1e3:.2f}ms "
          f"p99={lat['p99']*1e3:.2f}ms over {lat['count']} queries; "
          f"snapshot round-trips; prometheus {len(prom)} chars")
    print("OBS SMOKE OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(*(sys.argv[1:2] or [TRACE_JSON])))
