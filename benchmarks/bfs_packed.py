"""Packed-vs-unpacked traversal engine: TEPS + membership bytes
(ISSUE 4).

Since ISSUE 4 packed uint32 words are the engine's *native*
frontier/visited representation through the whole layer pipeline —
SIMD compaction kernel (kernels/compact.py), word-matrix workload
counters, packed planning.  This benchmark pins the two acceptance
numbers:

* **membership bytes** — the analytic frontier/visited/next + planning
  mask traffic per representation (`formats.membership_bytes`): packed
  words cost V/8 per bitmap per layer where the legacy dense masks
  cost 4V — a 32x model, gated at >= 8x in CI
  (`benchmarks.check_bytes_regression`).
* **TEPS** — wall-clock of the same traversal under ``packed=True``
  vs the legacy ``packed=False`` arm, on the high-diameter path probe
  (per-layer overheads dominate: 1 vertex/layer, ~1k layers) and on
  the RMAT workload.  The packed path TEPS is also the CI TEPS-floor
  baseline.

Both pipelines produce bit-identical parents (the parity suite in
tests/test_packed_engine.py); only representation cost differs.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, graph
from repro.core import engine
from repro.core.csr import traversed_edges
from repro.formats.base import membership_bytes
from repro.formats.csr_format import CsrFormat

PATH_SCALE = 10    # fixed: the CI TEPS-floor baseline, not --quick'd
PATH_TILE = 128


def _time(fn, reps: int = 3) -> float:
    fn()                                   # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)                         # least-noise estimator


def path_packed_probe(scale: int = PATH_SCALE, tile: int = PATH_TILE,
                      time_reps: int = 3) -> dict:
    """The s10 path-graph probe, packed vs unpacked: analytic
    membership bytes (deterministic) + interpret-mode TEPS."""
    from benchmarks.bfs_layers import build_path_graph
    n = 1 << scale
    g = build_path_graph(n)
    fmt = CsrFormat.from_csr(g)
    pol = engine.ThresholdSimd(0)

    def run(packed):
        return engine.traverse(g, 0, spec=engine.make_spec(
            policy=pol, tile=tile, max_layers=n + 2, packed=packed))

    res = run(True)
    stats = engine.layer_stats(res)
    mb_packed = membership_bytes(fmt, stats, packed=True)
    mb_unpacked = membership_bytes(fmt, stats, packed=False)
    edges = int(traversed_edges(
        g, np.asarray(res.state.parent)[:n] < n))

    t_packed = _time(lambda: jax.block_until_ready(
        run(True).state.parent), time_reps)
    t_unpacked = _time(lambda: jax.block_until_ready(
        run(False).state.parent), time_reps)
    return {
        "layers": len(stats),
        "edges": edges,
        "mask_bytes_packed": mb_packed,
        "mask_bytes_unpacked": mb_unpacked,
        "mask_ratio": mb_unpacked / max(mb_packed, 1),
        "teps_packed": edges / t_packed,
        "teps_unpacked": edges / t_unpacked,
        "t_packed": t_packed,
        "t_unpacked": t_unpacked,
    }


def main(scale: int = 10) -> None:
    probe = path_packed_probe()
    emit("bfs_packed.path_mask_bytes_packed", 0.0,
         f"scale={PATH_SCALE};bytes={probe['mask_bytes_packed']}",
         value=probe["mask_bytes_packed"])
    emit("bfs_packed.path_mask_bytes_unpacked", 0.0,
         f"scale={PATH_SCALE};bytes={probe['mask_bytes_unpacked']}",
         value=probe["mask_bytes_unpacked"])
    emit("bfs_packed.path_mask_bytes_ratio", 0.0,
         f"{probe['mask_ratio']:.1f}x", value=probe["mask_ratio"])
    emit("bfs_packed.path_teps", probe["t_packed"] * 1e6,
         f"teps={probe['teps_packed']:.3e};layers={probe['layers']}",
         value=probe["teps_packed"])
    emit("bfs_packed.path_teps_unpacked", probe["t_unpacked"] * 1e6,
         f"teps={probe['teps_unpacked']:.3e}",
         value=probe["teps_unpacked"])
    print(f"# path s={PATH_SCALE}: membership bytes "
          f"{probe['mask_bytes_packed']/2**20:.2f} MiB packed vs "
          f"{probe['mask_bytes_unpacked']/2**20:.2f} MiB unpacked "
          f"({probe['mask_ratio']:.1f}x)")

    # RMAT workload: same comparison on the paper's skewed graph
    g = graph(scale)
    fmt = CsrFormat.from_csr(g)
    rng = np.random.default_rng(7)
    deg = np.asarray(g.degrees())
    root = int(rng.choice(np.where(deg > 0)[0]))
    pol = engine.ThresholdSimd(0)

    res = engine.traverse(g, root, spec=engine.make_spec(policy=pol))
    stats = engine.layer_stats(res)
    reached = np.asarray(res.state.parent)[:g.n_vertices] < g.n_vertices
    edges = int(traversed_edges(g, reached))
    for packed in (True, False):
        t = _time(lambda p=packed: jax.block_until_ready(
            engine.traverse(g, root, spec=engine.make_spec(
                policy=pol, packed=p)).state.parent))
        tag = "packed" if packed else "unpacked"
        mb = membership_bytes(fmt, stats, packed=packed)
        emit(f"bfs_packed.rmat_s{scale}_{tag}", t * 1e6,
             f"teps={edges / t:.3e};mask_kib={mb/2**10:.1f}",
             value=edges / t)


if __name__ == "__main__":
    main()
