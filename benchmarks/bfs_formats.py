"""Graph-format sweep: traversal TEPS + bytes-moved per format x policy.

The experiment the formats subsystem exists for (ISSUE 2): on the
paper's skewed-degree RMAT workload, compare every registered layout
(`repro.formats`) under a representative direction-policy subset.

Reported per (format, policy):

* ``us_per_call``  — fused single-root traversal wall time;
* ``teps``         — Graph500 traversed edges / second (undirected,
  from the reached set's degrees — layout-independent, so rows are
  directly comparable);
* ``mb_moved``     — analytic bytes the expansion steps streamed
  under the *fused_gather* pipeline the traversal actually ran
  (measured per-layer active tiles x the layout's tile bytes +
  planning; `formats.traversal_bytes`), with ``mb_mat`` the
  materialized full-stream counterfactual alongside;
* ``fp_mb``        — device footprint of the built layout.

Plus one build-time line per format (preprocess-on-load cost,
Graph500 kernel-2 territory) and a headline ``sell_vs_csr`` speedup
line.  The acceptance expectation is SELL-C-σ at or around CSR parity
on this skewed workload; interpret-mode CPU timing jitters ~0.8-1.3x
run to run, so the hard failure (`SELL_VS_CSR_FLOOR`) only triggers
on structural regressions (e.g. padding blow-up), not noise.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, graph
from repro.configs.bfs_graph500 import FORMAT_SWEEP
from repro.core import engine
from repro.core.csr import traversed_edges
from repro.formats import autotune, registry, traversal_bytes


SELL_VS_CSR_FLOOR = 0.5   # hard-fail ratio; see module docstring


def _policies(cfg):
    table = {
        "topdown": engine.TopDown(),
        "threshold": engine.ThresholdSimd(cfg.simd_threshold),
        "hybrid": engine.BeamerHybrid(),
    }
    return {name: table[name] for name in cfg.policies}


def _time(fn, reps: int = 5) -> float:
    fn()                                   # warmup / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts)                         # least-noise estimator


def main(scale: int = 12, cfg=FORMAT_SWEEP) -> None:
    g = graph(scale)
    choice = autotune.choose(g)
    emit(f"bfs_fmt_autotune_s{scale}", 0.0,
         f"choice={choice.format};skew={choice.stats.degree_skew:.1f};"
         f"density={choice.stats.density:.4f}")

    rng = np.random.default_rng(7)
    deg = np.asarray(g.degrees())
    root = int(rng.choice(np.where(deg > 0)[0]))

    best: dict[str, float] = {}
    for name in cfg.formats:
        t0 = time.perf_counter()
        fmt = registry.get(name).from_graph(g)
        jax.block_until_ready(jax.tree_util.tree_leaves(fmt))
        t_build = time.perf_counter() - t0
        fp = fmt.footprint()
        emit(f"bfs_fmt_{name}_build_s{scale}", t_build * 1e6,
             f"fp_mb={fp.total_bytes/2**20:.2f}")

        for pname, policy in _policies(cfg).items():
            res = engine.traverse(
                fmt, root, spec=engine.make_spec(policy=policy))
            p = res.state.parent[:g.n_vertices]
            reached = np.asarray(p) < g.n_vertices
            n_layers = int(res.state.layer)
            edges = int(traversed_edges(g, reached))
            stats = engine.layer_stats(res)
            tile = fmt.resolve_tile(None)
            mb = traversal_bytes(fmt, stats, tile=tile,
                                 pipeline="fused_gather") / 2**20
            mb_mat = traversal_bytes(fmt, stats, tile=tile,
                                     pipeline="materialized") / 2**20
            t = _time(lambda f=fmt, pol=policy: jax.block_until_ready(
                engine.traverse(
                    f, root,
                    spec=engine.make_spec(policy=pol)).state.parent))
            best[name] = min(best.get(name, np.inf), t)
            emit(f"bfs_fmt_{name}_{pname}_s{scale}", t * 1e6,
                 f"teps={edges / t:.3e};layers={n_layers};"
                 f"mb_moved={mb:.2f};mb_mat={mb_mat:.2f};"
                 f"fp_mb={fp.total_bytes/2**20:.2f}",
                 value=edges / t)

    if "csr" in best and "sell" in best:
        speedup = best["csr"] / best["sell"]
        emit(f"bfs_fmt_sell_vs_csr_s{scale}", best["sell"] * 1e6,
             f"speedup={speedup:.2f}x")
        # regression floor: CPU interpret-mode timing jitters around
        # parity (~0.8-1.3x run to run), but a structural regression
        # (e.g. losing row splitting re-inflates the padding 10x) drops
        # the ratio far below it — fail the harness there.
        if speedup < SELL_VS_CSR_FLOOR:
            raise RuntimeError(
                f"SELL-C-σ fell to {speedup:.2f}x of CSR (< floor "
                f"{SELL_VS_CSR_FLOOR}) — layout or sweep regression")


if __name__ == "__main__":
    main()
