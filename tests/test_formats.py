"""Oracle-equivalence + unit tests for the graph-format subsystem.

Every registered format must produce parents/levels identical to the
serial oracle (validated through `core/validate.py`) on all four graph
families — RMAT, star, path, disconnected — for every direction
policy, including the batched multi-root path; plus autotuner,
registry, footprint and kernel/jnp-sweep parity checks.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import engine, rmat
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.rmat import EdgeList
from repro.core.validate import validate
from repro.formats import SellFormat, autotune, registry
from repro.kernels import ops
from repro.serve.graph_engine import BfsQuery, GraphEngine

POLICIES = [
    engine.TopDown(),
    engine.ThresholdSimd(1024),
    engine.PaperLiteralLayers((1, 2)),
    engine.BeamerHybrid(),
]


def _csr_from_pairs(pairs, n):
    src = jnp.asarray([a for a, b in pairs] + [b for a, b in pairs],
                      jnp.int32)
    dst = jnp.asarray([b for a, b in pairs] + [a for a, b in pairs],
                      jnp.int32)
    return csr_mod.from_edges(EdgeList(src, dst, n))


def star_graph(n=128):
    """Hub 0 <-> 1..n-1: maximal degree skew — the SELL row-splitting
    case (the hub becomes many virtual rows) and the Fig. 6 race."""
    return _csr_from_pairs([(0, i) for i in range(1, n)], n)


def path_graph(n=64):
    """A chain: one vertex per layer — maximal layer count, zero skew."""
    return _csr_from_pairs([(i, i + 1) for i in range(n - 1)], n)


def disconnected_graph(n=128):
    """Two components: a star [0, n/2) and a path [n/2, n)."""
    half = n // 2
    pairs = [(0, i) for i in range(1, half)]
    pairs += [(i, i + 1) for i in range(half, n - 1)]
    return _csr_from_pairs(pairs, n)


GRAPHS = {
    "rmat9": lambda: csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=9, edgefactor=16)),
    "star": star_graph,
    "path": path_graph,
    "disconnected": disconnected_graph,
}
ROOTS = {"rmat9": 17, "star": 0, "path": 0, "disconnected": 0}
FORMATS = ("csr", "sell", "bitmap")


@pytest.fixture(scope="module")
def graphs():
    return {k: v() for k, v in GRAPHS.items()}


@pytest.fixture(scope="module")
def formats(graphs):
    return {(gname, fname): registry.get(fname).from_graph(g)
            for gname, g in graphs.items() for fname in FORMATS}


def check_oracle(csr, parent_g500, root):
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, parent_g500, root, reference_depth=ref_depth)
    assert res.ok, res


# ---------------------------------------------------------------------------
# Oracle equivalence: every format x graph family x direction policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_every_format_matches_oracle(graphs, formats, graph_name,
                                     fmt_name, policy):
    g = graphs[graph_name]
    fmt = formats[(graph_name, fmt_name)]
    res = engine.traverse(fmt, ROOTS[graph_name], policy=policy,
                          max_layers=128)
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)),
                 ROOTS[graph_name])


@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
@pytest.mark.parametrize("fmt_name", FORMATS)
def test_batched_multiroot_matches_oracle(graphs, formats, fmt_name,
                                          policy):
    g = graphs["rmat9"]
    fmt = formats[("rmat9", fmt_name)]
    roots = [3, 7, 100, 42, 42]          # dup roots are legal
    res = engine.traverse(fmt, roots, policy=policy)
    assert res.state.parent.shape[0] == len(roots)
    for b, root in enumerate(roots):
        st = engine.BfsState(res.state.frontier[b], res.state.visited[b],
                             res.state.parent[b], res.state.layer)
        check_oracle(g, np.asarray(parents_graph500(st, g.n_vertices)),
                     root)


@pytest.mark.parametrize("fmt_name", ("sell", "bitmap"))
def test_format_agrees_with_csr_depths(graphs, formats, fmt_name):
    g = graphs["disconnected"]
    ref = engine.traverse(formats[("disconnected", "csr")], 0)
    res = engine.traverse(formats[("disconnected", fmt_name)], 0)
    p1 = np.asarray(parents_graph500(ref.state, g.n_vertices))
    p2 = np.asarray(parents_graph500(res.state, g.n_vertices))
    np.testing.assert_array_equal(p1 >= 0, p2 >= 0)
    assert (p2[64:] == -1).all(), "other component must stay unreached"


def test_nonsimd_algorithm_exact_updates(graphs, formats):
    """Algorithm-2 semantics survive the format dispatch."""
    g = graphs["star"]
    for fmt_name in FORMATS:
        res = engine.traverse(formats[("star", fmt_name)], 0,
                              algorithm="nonsimd")
        check_oracle(g, np.asarray(parents_graph500(res.state,
                                                    g.n_vertices)), 0)


# ---------------------------------------------------------------------------
# SELL-C-σ specifics
# ---------------------------------------------------------------------------

def test_sell_kernel_matches_jnp_sweep(graphs, formats):
    """The Pallas slab sweep and the pure-jnp reference sweep discover
    the same layer (after restoration repairs the Fig. 6 race)."""
    g = graphs["star"]
    fmt = formats[("star", "sell")]
    v_pad = g.n_vertices_padded
    frontier, visited, parent = engine.init_root_state(
        jnp.int32(0), fmt.init_visited(), g.n_vertices)
    out_k, p_k = ops.sell(fmt.cols, fmt.slab_rows, frontier, visited,
                          jnp.zeros_like(frontier), parent,
                          n_vertices=g.n_vertices, slabs_per_step=1)
    p_k, delta = ops.restore(p_k, n_vertices=g.n_vertices)
    out_k = out_k | delta
    out_j, _, p_j = fmt._sweep_jnp(frontier, visited, parent, "simd")
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_j))
    np.testing.assert_array_equal(np.asarray(p_k) >= 0,
                                  np.asarray(p_j) >= 0)


def test_sell_row_splitting_bounds_padding(graphs):
    """Row splitting bounds the slice width by the chunk size instead
    of the hub degree: on the maximally skewed star graph the split
    layout stores strictly fewer slots than the unsplit one, and every
    real edge exactly once."""
    g = graphs["star"]
    split = SellFormat.from_csr(g, max_width=32)
    unsplit = SellFormat.from_csr(g, max_width=128)  # >= hub degree
    assert split.edge_slots < unsplit.edge_slots
    for fmt in (split, unsplit):
        cols = np.asarray(fmt.cols).reshape(-1)
        assert (cols < g.n_vertices).sum() == g.n_edges
    # on the skewed RMAT family the quantized padding stays small
    rmat_fmt = SellFormat.from_csr(graphs["rmat9"])
    assert rmat_fmt.fill_ratio >= 0.5


def test_sell_slab_geometry(graphs, formats):
    fmt = formats[("rmat9", "sell")]
    from repro.kernels.sell_expand import SLICE_C, W_QUANT
    assert fmt.cols.shape[1:] == (W_QUANT, SLICE_C)
    assert fmt.slab_rows.shape == (fmt.cols.shape[0], SLICE_C)
    assert 0 < fmt.fill_ratio <= 1.0


def test_sell_resolve_tile_owns_grid(graphs, formats):
    """The format owns tile selection: auto stays within the interpret
    unroll budget, explicit tiles are honored (clamped up only)."""
    fmt = formats[("rmat9", "sell")]
    auto = fmt.resolve_tile(None)
    assert -(-fmt.n_slabs // auto) <= 32
    assert fmt.resolve_tile(fmt.n_slabs) == fmt.n_slabs


# ---------------------------------------------------------------------------
# Registry / autotuner / footprint
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert set(FORMATS) <= set(registry.available())
    with pytest.raises(KeyError):
        registry.get("no-such-format")


def test_autotuner_choices(graphs):
    assert autotune.choose(graphs["star"]).format == "sell"   # skew
    assert autotune.choose(graphs["path"]).format == "csr"    # uniform
    clique = _csr_from_pairs([(i, j) for i in range(32)
                              for j in range(i + 1, 32)], 32)
    assert autotune.choose(clique).format == "bitmap"         # dense


def test_autotune_build_passthrough(graphs, formats):
    fmt = formats[("rmat9", "sell")]
    assert autotune.build(fmt) is fmt
    built = autotune.build(graphs["path"])
    assert built.name == "csr"


def test_format_relayout_via_to_csr(graphs, formats):
    """A built CsrFormat can be re-laid-out (it recovers its Csr); a
    layout without `to_csr` raises a clear TypeError."""
    csr_fmt = formats[("rmat9", "csr")]
    relaid = registry.get("sell").from_graph(csr_fmt)
    assert relaid.name == "sell" and relaid.n_edges == csr_fmt.n_edges
    with pytest.raises(TypeError, match="re-lay-out"):
        registry.get("csr").from_graph(formats[("rmat9", "sell")])


def test_footprint_reports(graphs, formats):
    for fmt_name in FORMATS:
        fp = formats[("rmat9", fmt_name)].footprint()
        assert fp.total_bytes > 0 and fp.format == fmt_name
        assert fmt_name in fp.summary()


def test_traverse_tile_argument_still_works(graphs):
    """The `tile=` A/B knob keeps working through the format layer for
    both the fused engine and the hostloop driver."""
    g = graphs["rmat9"]
    res = engine.traverse(g, 17, tile=512)
    state, _, _ = engine.traverse_hostloop(g, 17, tile=512)
    p1 = np.asarray(parents_graph500(res.state, g.n_vertices))
    p2 = np.asarray(parents_graph500(state, g.n_vertices))
    np.testing.assert_array_equal(p1 >= 0, p2 >= 0)


# ---------------------------------------------------------------------------
# Serve layer: preprocess-on-load format choice
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("graph_format", ("auto", "csr", "sell",
                                          "bitmap"))
def test_serve_engine_with_formats(graphs, graph_format):
    g = graphs["rmat9"]
    eng = GraphEngine(g, batch_slots=2, graph_format=graph_format)
    if graph_format != "auto":
        assert eng.fmt.name == graph_format
    roots = [3, 7, 100]
    for uid, r in enumerate(roots):
        eng.submit(BfsQuery(uid=uid, root=r))
    eng.run_until_done()
    assert len(eng.finished) == len(roots)
    for q in sorted(eng.finished, key=lambda q: q.uid):
        check_oracle(g, q.parent, roots[q.uid])
