"""Optimizer, train_step, data pipeline, checkpoint, fault tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager, restore, save
from repro.configs import registry
from repro.data.tokens import DataConfig, batch_at, stream
from repro.models import lm
from repro.models.config import ModelConfig
from repro.runtime.fault import (FailureInjector, SimulatedFailure,
                                 train_loop)
from repro.train import optimizer as opt
from repro.train.train_step import TrainConfig, make_train_step


@pytest.fixture(scope="module")
def tiny():
    cfg = registry.get("qwen3", reduced=True).with_(
        dtype="float32", n_layers=2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_adamw_converges_quadratic():
    """AdamW drives a quadratic to its minimum."""
    acfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                           total_steps=200)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = jax.grad(lambda p: jnp.sum((p["x"] - 1.0) ** 2))(params)
        params, state, _ = opt.update(acfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["x"]), [1.0, 1.0],
                               atol=0.05)


def test_schedule_shapes():
    acfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                           min_lr_frac=0.1)
    lrs = [float(opt.schedule(acfg, jnp.int32(s)))
           for s in [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert lrs[3] < 1.0                       # decaying
    assert abs(lrs[4] - 0.1) < 1e-6          # floor


def test_grad_clip_applies():
    acfg = opt.AdamWConfig(lr=1e-3, clip_norm=1.0, weight_decay=0.0)
    params = {"x": jnp.zeros(4)}
    state = opt.init(params)
    _, _, stats = opt.update(acfg, params, {"x": jnp.full(4, 100.0)},
                             state)
    assert float(stats["grad_norm"]) > 1.0   # raw norm reported


def test_train_step_descends(tiny):
    cfg, params = tiny
    tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=1e-2, warmup_steps=0))
    step = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(batch_size=4, seq_len=64)
    state = opt.init(params)
    losses = []
    for i in range(8):
        params, state, m = step(params, state, batch_at(cfg, dcfg, i % 2))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses    # learns the bigram signal


def test_grad_accum_matches_full_batch(tiny):
    cfg, params = tiny
    dcfg = DataConfig(batch_size=8, seq_len=32)
    batch = batch_at(cfg, dcfg, 0)
    acfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0)
    s1 = make_train_step(cfg, TrainConfig(adamw=acfg, accum_steps=1))
    s2 = make_train_step(cfg, TrainConfig(adamw=acfg, accum_steps=4))
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    # same data, same total gradient (up to fp accumulation order)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_gradient_compression_close(tiny):
    cfg, params = tiny
    dcfg = DataConfig(batch_size=4, seq_len=32)
    batch = batch_at(cfg, dcfg, 0)
    acfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0)
    s1 = make_train_step(cfg, TrainConfig(adamw=acfg))
    s2 = make_train_step(cfg, TrainConfig(adamw=acfg,
                                          compress_grads="bf16"))
    p1, _, _ = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, _ = jax.jit(s2)(params, opt.init(params), batch)
    rel = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()
                           / (jnp.abs(a).max() + 1e-9)), p1, p2)
    assert max(jax.tree.leaves(rel)) < 0.1


def test_data_pipeline_deterministic_and_host_sharded(tiny):
    cfg, _ = tiny
    d0 = DataConfig(seed=1, batch_size=2, seq_len=16, host_id=0)
    d1 = DataConfig(seed=1, batch_size=2, seq_len=16, host_id=1)
    a = batch_at(cfg, d0, step=5)
    b = batch_at(cfg, d0, step=5)
    c = batch_at(cfg, d1, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # restartable
    assert not np.array_equal(a["tokens"], c["tokens"])      # host-unique
    s = stream(cfg, d0, start_step=5)
    step, batch = next(s)
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], a["tokens"])


def test_checkpoint_roundtrip(tmp_path, tiny):
    cfg, params = tiny
    state = opt.init(params)
    save(tmp_path, 7, {"params": params, "opt": state},
         metadata={"loss": 1.25})
    restored, meta, step = restore(tmp_path,
                                   {"params": params, "opt": state})
    assert step == 7 and meta["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        save(tmp_path, s, tree, keep_n=2)
    dirs = sorted(p.name for p in tmp_path.glob("step_*"))
    assert dirs == ["step_000000003", "step_000000004"]
    _, _, step = restore(tmp_path, tree)
    assert step == 4


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save(tmp_path, 1, {"x": jnp.zeros((4,))})
    with pytest.raises(AssertionError, match="leaf 0"):
        restore(tmp_path, {"x": jnp.zeros((5,))})


def test_fault_tolerant_loop_restarts(tmp_path, tiny):
    """Kill the job twice mid-run; the loop must finish all steps and
    the post-restart losses must continue from the checkpoint."""
    cfg, params = tiny
    tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(batch_size=2, seq_len=32)
    ckpt = CheckpointManager(tmp_path, every=2, keep_n=2)
    injector = FailureInjector(at_steps=(3, 7))
    stats = train_loop(
        train_step=step_fn, params=params, opt_state=opt.init(params),
        data_stream_fn=lambda s: stream(cfg, dcfg, s),
        ckpt=ckpt, total_steps=10, injector=injector)
    assert stats.restarts == 2
    assert stats.steps >= 10                 # replayed work counts
    assert all(np.isfinite(stats.losses))


def test_fault_loop_gives_up_after_max_restarts(tmp_path, tiny):
    cfg, params = tiny
    tcfg = TrainConfig(adamw=opt.AdamWConfig(lr=1e-3, warmup_steps=0))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(batch_size=2, seq_len=32)
    ckpt = CheckpointManager(tmp_path, every=100)
    injector = FailureInjector(at_steps=(1,))
    injector.fired = set()                   # refire forever

    class AlwaysFail(FailureInjector):
        def check(self, step):
            if step == 1:
                raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        train_loop(train_step=step_fn, params=params,
                   opt_state=opt.init(params),
                   data_stream_fn=lambda s: stream(cfg, dcfg, s),
                   ckpt=ckpt, total_steps=5, injector=AlwaysFail(),
                   max_restarts=2)
