"""The Graph500 validator must CATCH every corruption class — a
validator that always says yes validates nothing (§5.3)."""
import jax
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_parallel import parents_graph500, run_bfs
from repro.core.validate import validate


@pytest.fixture(scope="module")
def setup():
    g = csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(4), scale=10, edgefactor=16))
    root = 1
    while int(g.out_degree(root)) == 0:
        root += 1
    state = run_bfs(g, root, algorithm="simd")
    p = np.asarray(parents_graph500(state, g.n_vertices)).copy()
    assert validate(g, p, root).ok
    return g, root, p


def _reached(p):
    return np.nonzero(p >= 0)[0]


def test_catches_wrong_root(setup):
    g, root, p = setup
    bad = p.copy()
    bad[root] = (root + 1) % g.n_vertices
    assert not validate(g, bad, root).root_ok


def test_catches_cycle(setup):
    g, root, p = setup
    bad = p.copy()
    reached = [v for v in _reached(p) if v != root]
    a = reached[0]
    # make a's parent chain loop through itself
    bad[a] = a
    res = validate(g, bad, root)
    assert not res.no_cycles or not res.depths_consistent


def test_catches_nonexistent_tree_edge(setup):
    g, root, p = setup
    rows = np.asarray(g.rows)
    cs = np.asarray(g.colstarts)
    bad = p.copy()
    # find a reached vertex and assign a parent that is NOT a neighbor
    for v in _reached(p):
        if v == root:
            continue
        neighbors = set(rows[cs[v]:cs[v + 1]].tolist())
        for cand in _reached(p):
            if cand not in neighbors and cand != v:
                bad[v] = cand
                break
        else:
            continue
        break
    res = validate(g, bad, root)
    assert not (res.tree_edges_exist and res.depths_consistent
                and res.edge_levels_ok)


def test_catches_component_leak(setup):
    """Marking an unreachable vertex as reached must fail closure or
    tree-edge checks."""
    g, root, p = setup
    unreached = np.nonzero(p < 0)[0]
    if len(unreached) == 0:
        pytest.skip("graph fully connected at this seed")
    bad = p.copy()
    bad[unreached[0]] = root        # fake parent
    res = validate(g, bad, root)
    assert not res.ok


def test_catches_unmarking_reached(setup):
    """Dropping a reached vertex violates component closure (an edge
    now crosses reached -> 'unreached')."""
    g, root, p = setup
    bad = p.copy()
    victims = [v for v in _reached(p) if v != root]
    bad[victims[len(victims) // 2]] = -1
    res = validate(g, bad, root)
    assert not res.ok


def test_catches_depth_skip(setup):
    """Reparenting a depth-3 vertex onto the root breaks depth
    consistency against the reference."""
    from repro.core.bfs_serial import bfs_serial
    g, root, p = setup
    _, ref_depth = bfs_serial(np.asarray(g.rows),
                              np.asarray(g.colstarts), g.n_vertices,
                              root)
    deep = np.nonzero(ref_depth >= 3)[0]
    if len(deep) == 0:
        pytest.skip("graph too shallow")
    bad = p.copy()
    bad[deep[0]] = root             # depth-3 vertex claims depth 1
    res = validate(g, bad, root, reference_depth=ref_depth)
    assert not res.ok
