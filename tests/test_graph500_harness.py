"""Graph500 harness edge cases (core/stats.py): zero-TEPS runs,
``n_zero_runs`` bookkeeping, validate wiring, explicit-root override.

Complements test_stats_harness.py (which exercises the random-root
path on an RMAT graph) with a hand-built path graph + isolated vertex
so the paper's unfiltered-root artifact (§5.3) is deterministic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core.bfs_parallel import run_bfs
from repro.core.rmat import EdgeList
from repro.core.stats import HarnessResult, RunResult, choose_roots, \
    run_harness

N = 8          # vertices 0..6 form a path; vertex 7 is isolated
ISOLATED = 7


@pytest.fixture(scope="module")
def path_graph():
    """0-1-2-3-4-5-6 path (both directions) + degree-0 vertex 7."""
    src = [i for i in range(N - 2)] + [i + 1 for i in range(N - 2)]
    dst = [i + 1 for i in range(N - 2)] + [i for i in range(N - 2)]
    return csr_mod.from_edges(EdgeList(
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        n_vertices=N))


def _bfs(c, r):
    return run_bfs(c, r)


def _path_depths(root: int) -> np.ndarray:
    d = np.full(N, -1, np.int64)
    d[:N - 1] = np.abs(np.arange(N - 1) - root)
    return d


def test_roots_override(path_graph):
    res = run_harness(path_graph, _bfs, jax.random.PRNGKey(0),
                      roots=[0, 3, 6])
    assert [r.root for r in res.runs] == [0, 3, 6]
    # every root reaches the whole 7-vertex path, never the isolate
    assert all(r.reached == N - 1 for r in res.runs)
    assert all(r.edges == N - 2 for r in res.runs)  # 6 undirected edges


def test_disconnected_root_is_zero_run(path_graph):
    res = run_harness(path_graph, _bfs, jax.random.PRNGKey(0),
                      roots=[ISOLATED])
    (run,) = res.runs
    assert run.reached == 1          # only the root itself
    assert run.edges == 0 and run.teps == 0.0
    assert res.n_zero_runs == 1
    # no connected run -> harmonic mean degenerates to 0, not a crash
    assert res.hmean_teps == 0.0
    assert res.max_teps == 0.0
    assert "zero_runs=1" in res.summary()


def test_mixed_roots_filtered_hmean(path_graph):
    res = run_harness(path_graph, _bfs, jax.random.PRNGKey(0),
                      roots=[0, ISOLATED, 3])
    assert len(res.runs) == 3 and res.n_zero_runs == 1
    # hmean is over the two connected runs only (documented deviation)
    ts = [r.teps for r in res.runs if r.teps > 0]
    assert len(ts) == 2
    assert res.hmean_teps == pytest.approx(2 / sum(1 / t for t in ts))


def test_validate_wiring(path_graph):
    calls = []

    def ref(root):
        calls.append(root)
        return _path_depths(root)

    res = run_harness(path_graph, _bfs, jax.random.PRNGKey(0),
                      roots=[0, 4], validate_runs=True,
                      reference_depths_fn=ref)
    assert calls == [0, 4]           # reference fn called per run
    assert all(r.valid is True for r in res.runs)
    # without validate_runs the field stays None
    res2 = run_harness(path_graph, _bfs, jax.random.PRNGKey(0),
                       roots=[0])
    assert res2.runs[0].valid is None


def test_validate_accepts_isolated_root(path_graph):
    res = run_harness(path_graph, _bfs, jax.random.PRNGKey(0),
                      roots=[ISOLATED], validate_runs=True)
    assert res.runs[0].valid is True


def test_hmean_zero_on_empty_result():
    res = HarnessResult()
    assert res.hmean_teps == 0.0 and res.max_teps == 0.0
    res.runs.append(RunResult(root=0, seconds=0.0, edges=0, teps=0.0,
                              reached=1))
    assert res.n_zero_runs == 1 and res.hmean_teps == 0.0


def test_choose_roots_connected_filter(path_graph):
    deg = np.asarray(path_graph.degrees())
    roots = choose_roots(jax.random.PRNGKey(3), N, n_roots=16,
                         degrees=deg, require_connected=True)
    assert ISOLATED not in roots
    # unfiltered draw keeps whatever the PRNG lands on
    unfiltered = choose_roots(jax.random.PRNGKey(3), N, n_roots=16)
    assert len(unfiltered) == 16
