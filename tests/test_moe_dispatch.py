"""Sort-based MoE dispatch must match the einsum (GShard) baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm, moe


@pytest.mark.parametrize("arch", ["llama4", "arctic"])
def test_sort_matches_einsum(arch):
    cfg_e = registry.get(arch, reduced=True).with_(
        dtype="float32", moe_dispatch="einsum", capacity_factor=8.0)
    cfg_s = cfg_e.with_(moe_dispatch="sort")
    params = moe.init(jax.random.PRNGKey(0), cfg_e)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
    out_e, aux_e = moe.apply(params, cfg_e, x)
    out_s, aux_s = moe.apply(params, cfg_s, x)
    # generous capacity => no drops => identical token routing
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_s),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_e["z_loss"]),
                               float(aux_s["z_loss"]), rtol=1e-5)


@pytest.mark.parametrize("arch", ["llama4"])
def test_sort_drops_same_overflow(arch):
    """With tight capacity both modes drop by intra-group token order."""
    cfg_e = registry.get(arch, reduced=True).with_(
        dtype="float32", moe_dispatch="einsum", capacity_factor=0.5)
    cfg_s = cfg_e.with_(moe_dispatch="sort")
    params = moe.init(jax.random.PRNGKey(2), cfg_e)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (2, 64, 64))
    out_e, _ = moe.apply(params, cfg_e, x)
    out_s, _ = moe.apply(params, cfg_s, x)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_s),
                               rtol=2e-4, atol=2e-5)


def test_sort_trains(arch="llama4"):
    cfg = registry.get(arch, reduced=True).with_(
        dtype="float32", moe_dispatch="sort")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "labels": jnp.ones((2, 32), jnp.int32)}
    (loss, _), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g)))
               for g in jax.tree.leaves(grads))
