"""BFS correctness: Algorithms 2/3 vs the serial oracle (Algorithm 1).

Every parallel variant may return a *different* valid spanning tree
(benign race, §3.2) — so equality is checked on the depth array, which
all valid BFS trees share, plus the Graph500 soft validator.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_parallel import (parents_graph500, run_bfs, run_bfs_jit)
from repro.core.bfs_serial import bfs_serial
from repro.core.validate import validate


def build(scale, key=0, edgefactor=16):
    edges = rmat.generate(jax.random.PRNGKey(key), scale=scale,
                          edgefactor=edgefactor)
    return csr_mod.from_edges(edges)


@pytest.fixture(scope="module")
def g12():
    return build(12)


def check_against_oracle(csr, state, root):
    p = parents_graph500(state, csr.n_vertices)
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, p, root, reference_depth=ref_depth)
    assert res.root_ok, "root must parent itself"
    assert res.no_cycles, "parent pointers must be acyclic"
    assert res.tree_edges_exist, "tree edges must exist in graph"
    assert res.edge_levels_ok, "graph edges must span <=1 level"
    assert res.component_closed, "must reach exactly the component"
    assert res.depths_consistent, "depths must match the serial oracle"
    assert res.ok


@pytest.mark.parametrize("algorithm", ["simd", "nonsimd"])
@pytest.mark.parametrize("root_seed", [0, 1, 2])
def test_bucketed_driver_matches_oracle(g12, algorithm, root_seed):
    rng = np.random.default_rng(root_seed)
    root = int(rng.integers(0, g12.n_vertices))
    state = run_bfs(g12, root, algorithm=algorithm)
    check_against_oracle(g12, state, root)


@pytest.mark.parametrize("algorithm", ["simd", "nonsimd"])
def test_jit_while_loop_driver_matches_oracle(algorithm):
    csr = build(9)
    root = 5
    state = run_bfs_jit(csr.colstarts, csr.rows, root, csr.n_vertices,
                        algorithm)
    check_against_oracle(csr, state, root)


def test_drivers_agree_on_reachability(g12):
    s1 = run_bfs(g12, 17, algorithm="simd")
    s2 = run_bfs_jit(g12.colstarts, g12.rows, 17, g12.n_vertices, "simd")
    p1 = np.asarray(parents_graph500(s1, g12.n_vertices))
    p2 = np.asarray(parents_graph500(s2, g12.n_vertices))
    np.testing.assert_array_equal(p1 >= 0, p2 >= 0)


def test_isolated_root():
    """A degree-0 start vertex terminates immediately (zero-TEPS run)."""
    csr = build(8)
    deg = np.asarray(csr.degrees())
    isolated = np.where(deg == 0)[0]
    if len(isolated) == 0:
        pytest.skip("no isolated vertex at this seed")
    root = int(isolated[0])
    state = run_bfs(csr, root, algorithm="simd")
    p = np.asarray(parents_graph500(state, csr.n_vertices))
    assert p[root] == root
    assert (p[np.arange(csr.n_vertices) != root] == -1).all()


def test_layer_stats_shape(g12):
    """Per-layer stats reproduce the paper's Table 1 structure."""
    state, stats = run_bfs(g12, 3, algorithm="simd", collect_stats=True)
    assert len(stats) >= 2
    # frontier sizes rise then fall (small-world, §4.1)
    sizes = [s.frontier_vertices for s in stats]
    peak = sizes.index(max(sizes))
    assert all(a <= b for a, b in zip(sizes[:peak], sizes[1:peak + 1]))
    assert all(a >= b for a, b in zip(sizes[peak:], sizes[peak + 1:]))
    # discovered vertices in layer k == frontier of layer k+1
    for a, b in zip(stats[:-1], stats[1:]):
        assert a.discovered == b.frontier_vertices


def test_restoration_repairs_all_races():
    """Adversarial graph: a hub whose neighbors share bitmap words.

    Star graph: vertex 0 connected to 1..127 — all discoveries happen
    in one layer and collide heavily within 4 words.  The racy scatter
    alone WILL drop bits; the restoration must repair every one.
    """
    import jax.numpy as jnp
    from repro.core.rmat import EdgeList
    n = 128
    src = jnp.asarray([0] * (n - 1) + list(range(1, n)), jnp.int32)
    dst = jnp.asarray(list(range(1, n)) + [0] * (n - 1), jnp.int32)
    csr = csr_mod.from_edges(EdgeList(src, dst, n))
    state = run_bfs(csr, 0, algorithm="simd")
    p = np.asarray(parents_graph500(state, csr.n_vertices))
    assert (p[1:] == 0).all() and p[0] == 0
