"""ISSUE 6: the whole-layer megakernel vs the unfused fused_gather
pipeline and the serial oracle.

Covers the acceptance matrix:

* bit-parity megakernel vs fused_gather vs the numpy BFS oracle across
  every graph family x direction policy x packed/unpacked x
  single/batched root — the two pipelines must agree on the reached
  set and produce oracle-valid parents;
* launch accounting: each megakernel SIMD/bottom-up layer issues
  EXACTLY one Pallas call where the unfused pipeline issues >= 3
  (plan + compact + gather), measured by the trace-time
  `ops.count_launches` counter the stats buffer reports;
* the VMEM-budget degrade: a working set `ops.megakernel_fits`
  rejects silently falls back to the unfused steps (mirroring the
  `ops.compact_fits` pattern) and still traverses correctly;
* the capability gate: ``pipeline="megakernel"`` is rejected by
  `spec.validate` on formats without `supports_megakernel` (SELL,
  bitmap) — keyed on the classvar, not the format name.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import engine, rmat
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.rmat import EdgeList
from repro.core.validate import validate
from repro.formats.csr_format import CsrFormat
from repro.kernels import ops

POLICIES = [
    engine.TopDown(),
    engine.ThresholdSimd(0),          # SIMD forced: every layer fused
    engine.PaperLiteralLayers((1, 2)),
    engine.BeamerHybrid(),
]


def _csr_from_pairs(pairs, n):
    src = jnp.asarray([a for a, b in pairs] + [b for a, b in pairs],
                      jnp.int32)
    dst = jnp.asarray([b for a, b in pairs] + [a for a, b in pairs],
                      jnp.int32)
    return csr_mod.from_edges(EdgeList(src, dst, n))


GRAPHS = {
    "rmat10": lambda: csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=10, edgefactor=16)),
    "star": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 128)], 128),
    "path": lambda: _csr_from_pairs(
        [(i, i + 1) for i in range(95)], 96),
    "disconnected": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 64)]
        + [(i, i + 1) for i in range(64, 127)], 128),
}
ROOTS = {"rmat10": 17, "star": 0, "path": 0, "disconnected": 0}


@pytest.fixture(scope="module")
def graphs():
    return {k: v() for k, v in GRAPHS.items()}


def check_oracle(csr, parent_g500, root):
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, parent_g500, root, reference_depth=ref_depth)
    assert res.ok, res


def _reached(res, n_vertices):
    return np.asarray(res.state.parent)[..., :n_vertices] < n_vertices


def _simd_launches(res):
    """Per-layer launch counts of the non-scalar layers."""
    buf = np.asarray(res.stats)
    return [int(buf[i, engine._ST_LAUNCH])
            for i in range(buf.shape[0])
            if buf[i, engine._ST_ACTIVE]
            and int(buf[i, engine._ST_MODE]) != engine.MODE_SCALAR]


# ---------------------------------------------------------------------------
# Oracle equivalence: megakernel vs fused_gather, every family x policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False],
                         ids=["packed", "unpacked"])
@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_megakernel_matches_fused(graphs, graph_name, policy, packed):
    g = graphs[graph_name]
    root = ROOTS[graph_name]
    mega = engine.traverse(g, root, policy=policy, max_layers=128,
                           pipeline="megakernel", packed=packed)
    fused = engine.traverse(g, root, policy=policy, max_layers=128,
                            pipeline="fused_gather", packed=packed)
    np.testing.assert_array_equal(_reached(mega, g.n_vertices),
                                  _reached(fused, g.n_vertices))
    assert int(mega.state.layer) == int(fused.state.layer)
    check_oracle(g, np.asarray(parents_graph500(mega.state,
                                                g.n_vertices)), root)


@pytest.mark.parametrize("packed", [True, False],
                         ids=["packed", "unpacked"])
def test_megakernel_batched_multiroot(graphs, packed):
    g = graphs["disconnected"]
    # both components + an isolated-ish tail: slot 64's search dies at
    # a different layer than slot 0's, exercising n_active == 0 rows
    roots = [0, 64, 1, 127]
    mega = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel", packed=packed)
    fused = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                            pipeline="fused_gather", packed=packed)
    np.testing.assert_array_equal(_reached(mega, g.n_vertices),
                                  _reached(fused, g.n_vertices))
    for b, root in enumerate(roots):
        st = engine.BfsState(mega.state.frontier[b],
                             mega.state.visited[b],
                             mega.state.parent[b], mega.state.layer)
        check_oracle(g, np.asarray(parents_graph500(st, g.n_vertices)),
                     root)


def test_megakernel_batched_rmat_prefetch(graphs):
    """Batched skewed workload with the DMA pipeline running ahead."""
    g = graphs["rmat10"]
    roots = [17, 200, 5]
    mega = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel", prefetch_depth=2)
    fused = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                            pipeline="fused_gather")
    np.testing.assert_array_equal(_reached(mega, g.n_vertices),
                                  _reached(fused, g.n_vertices))


# ---------------------------------------------------------------------------
# Launch accounting (satellite 1): 1 call/layer fused, >= 3 unfused
# ---------------------------------------------------------------------------

def test_megakernel_single_launch_per_layer(graphs):
    g = graphs["rmat10"]
    mega = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel")
    fused = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                            pipeline="fused_gather")
    lm, lf = _simd_launches(mega), _simd_launches(fused)
    assert lm and lf          # the probe must actually hit SIMD layers
    assert all(n == 1 for n in lm), lm
    assert all(n >= 3 for n in lf), lf


def test_launch_counter_counts_traced_calls():
    """The counter is trace-time ground truth, not a declaration."""
    with ops.count_launches() as c:
        ops.popcount(jnp.zeros((8,), jnp.uint32))
        ops.popcount(jnp.zeros((8,), jnp.uint32))
    assert c.count == 2
    with ops.count_launches() as c2:
        pass
    assert c2.count == 0


# ---------------------------------------------------------------------------
# VMEM-budget degrade (mirrors ops.compact_fits)
# ---------------------------------------------------------------------------

def test_megakernel_fits_budget():
    assert ops.megakernel_fits(36, 1152, 1025, 1024)
    # a 2^22-vertex working set blows the 16 MiB VMEM budget
    assert not ops.megakernel_fits(1 << 17, 1 << 22, (1 << 22) + 1,
                                   1024)
    # deep prefetch on a huge tile also overflows (enough blocks that
    # the resolved pipeline depth really is 3)
    assert not ops.megakernel_fits(36, 1152, 1025, 1 << 20,
                                   prefetch_depth=3, n_blocks=8)
    # ISSUE 9 satellite regression: the budget charges the RESOLVED
    # depth, not the requested one — a single-block graph clamps the
    # pipeline to one in-flight buffer, so the same deep-prefetch
    # request fits (the kernel never allocates the extra buffers)
    assert ops.megakernel_fits(36, 1152, 1025, 1 << 20,
                               prefetch_depth=3, n_blocks=1)


def test_megakernel_vmem_fallback(graphs, monkeypatch):
    """Past the VMEM budget the megakernel arm must degrade to the
    unfused steps — same results, honest (>= 3) launch counter."""
    from repro.api import plan as api_plan
    g = graphs["rmat10"]
    api_plan.clear_cache()     # force a re-trace under the patch
    monkeypatch.setattr(ops, "megakernel_fits",
                        lambda *a, **k: False)
    try:
        res = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                              pipeline="megakernel")
        launches = _simd_launches(res)
    finally:
        monkeypatch.undo()
        api_plan.clear_cache()  # drop the degraded executable
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)), 17)
    assert launches and all(n >= 3 for n in launches), launches


# ---------------------------------------------------------------------------
# Validation matrix (satellite 6): capability classvar, not name
# ---------------------------------------------------------------------------

def test_megakernel_rejected_on_unsupporting_formats(graphs):
    from repro.api.spec import TraversalSpec
    from repro.formats import build
    g = graphs["rmat10"]
    spec = TraversalSpec(pipeline="megakernel")
    spec.validate(build(g, "csr"))               # supported: no raise
    # SELL fuses since ISSUE 9 (manual cols DMA) — also no raise
    spec.validate(build(g, "sell"))
    for fmt_name in ("bitmap",):
        fmt = build(g, fmt_name)
        assert not fmt.supports_megakernel
        with pytest.raises(ValueError, match="megakernel"):
            spec.validate(fmt)
        with pytest.raises(ValueError, match="megakernel"):
            engine.traverse(fmt, 17, spec=spec)


def test_megakernel_gate_is_capability_keyed(graphs):
    """The rejection reads `supports_megakernel`, NOT the format name:
    flipping the classvar on a throwaway CSR subclass flips the
    verdict with no name-keyed table to update."""
    from repro.api.spec import TraversalSpec
    g = graphs["rmat10"]
    spec = TraversalSpec(pipeline="megakernel")

    class NoMegaCsr(CsrFormat):
        supports_megakernel = False

    fmt = NoMegaCsr.from_csr(g)
    with pytest.raises(ValueError, match="supports_megakernel"):
        spec.validate(fmt)
    # auto pipeline must also defensively degrade, never crash
    resolved = TraversalSpec().resolve(fmt)
    assert resolved.pipeline != "megakernel"
