"""Oracle-equivalence tests for the unified traversal engine.

Every direction policy and the batched multi-root path must produce a
valid BFS tree with depths equal to the serial oracle (Algorithm 1) —
on an RMAT graph and on adversarial shapes (star: maximal §3.3.2 word
collisions; path: maximal layer count; disconnected: unreachable
component) — plus the serve engine and fused/hostloop agreement.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import engine, rmat
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.rmat import EdgeList
from repro.core.validate import validate
from repro.serve.graph_engine import BfsQuery, GraphEngine

POLICIES = [
    engine.TopDown(),
    engine.ThresholdSimd(2048),
    engine.PaperLiteralLayers((1, 2)),
    engine.BeamerHybrid(),
]


def _csr_from_pairs(pairs, n):
    src = jnp.asarray([a for a, b in pairs] + [b for a, b in pairs],
                      jnp.int32)
    dst = jnp.asarray([b for a, b in pairs] + [a for a, b in pairs],
                      jnp.int32)
    return csr_mod.from_edges(EdgeList(src, dst, n))


def star_graph(n=128):
    """Hub 0 <-> 1..n-1: every discovery lands in one layer and
    collides inside 4 bitmap words (the Fig. 6 race, maximized)."""
    return _csr_from_pairs([(0, i) for i in range(1, n)], n)


def path_graph(n=96):
    """A chain: one vertex per layer — maximal layer count."""
    return _csr_from_pairs([(i, i + 1) for i in range(n - 1)], n)


def disconnected_graph(n=128):
    """Two components: a clique-ish star [0, n/2) and a path [n/2, n)."""
    half = n // 2
    pairs = [(0, i) for i in range(1, half)]
    pairs += [(i, i + 1) for i in range(half, n - 1)]
    return _csr_from_pairs(pairs, n)


GRAPHS = {
    "rmat10": lambda: csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=10, edgefactor=16)),
    "star": star_graph,
    "path": path_graph,
    "disconnected": disconnected_graph,
}


@pytest.fixture(scope="module")
def graphs():
    return {k: v() for k, v in GRAPHS.items()}


def check_oracle(csr, parent_g500, root):
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, parent_g500, root, reference_depth=ref_depth)
    assert res.ok, res


@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_every_policy_matches_oracle(graphs, graph_name, policy):
    g = graphs[graph_name]
    root = 0 if graph_name != "rmat10" else 17
    res = engine.traverse(g, root, policy=policy, max_layers=128)
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)), root)


def test_path_graph_runs_one_layer_per_vertex(graphs):
    g = graphs["path"]
    res = engine.traverse(g, 0, max_layers=128)
    # 96 expansions: one per frontier {0}..{95}, the last discovers
    # nothing and empties the frontier
    assert int(res.state.layer) == 96
    assert int(res.depths) == 96


def test_disconnected_component_unreached(graphs):
    g = graphs["disconnected"]
    res = engine.traverse(g, 0)
    p = np.asarray(parents_graph500(res.state, g.n_vertices))
    assert (p[64:] == -1).all(), "other component must stay unreached"
    check_oracle(g, p, 0)


@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
def test_batched_multiroot_matches_oracle(graphs, policy):
    g = graphs["rmat10"]
    roots = [3, 7, 11, 100, 511, 900, 42, 42]   # dup roots are legal
    res = engine.traverse(g, roots, policy=policy)
    assert res.state.parent.shape[0] == len(roots)
    for b, root in enumerate(roots):
        st = engine.BfsState(res.state.frontier[b], res.state.visited[b],
                             res.state.parent[b], res.state.layer)
        check_oracle(g, np.asarray(parents_graph500(st, g.n_vertices)),
                     root)


def test_batched_multiroot_adversarial(graphs):
    g = graphs["disconnected"]
    roots = [0, 64, 1, 127]          # both components, both directions
    res = engine.traverse(g, roots, policy=engine.ThresholdSimd(64))
    for b, root in enumerate(roots):
        st = engine.BfsState(res.state.frontier[b], res.state.visited[b],
                             res.state.parent[b], res.state.layer)
        check_oracle(g, np.asarray(parents_graph500(st, g.n_vertices)),
                     root)


def test_batched_depths_match_singles(graphs):
    g = graphs["rmat10"]
    roots = [3, 7, 900]
    res = engine.traverse(g, roots)
    for b, root in enumerate(roots):
        single = engine.traverse(g, root)
        assert int(res.depths[b]) == int(single.depths)


def test_fused_matches_hostloop(graphs):
    g = graphs["rmat10"]
    fused = engine.traverse(g, 17, policy=engine.BeamerHybrid())
    host_state, _, host_log = engine.traverse_hostloop(
        g, 17, policy=engine.BeamerHybrid())
    p1 = np.asarray(parents_graph500(fused.state, g.n_vertices))
    p2 = np.asarray(parents_graph500(host_state, g.n_vertices))
    np.testing.assert_array_equal(p1 >= 0, p2 >= 0)
    assert engine.direction_log(fused) == host_log


def test_stats_buffer_matches_hostloop_counters(graphs):
    g = graphs["rmat10"]
    res = engine.traverse(g, 17)
    fused_stats = engine.layer_stats(res)
    _, host_stats, _ = engine.traverse_hostloop(g, 17,
                                                collect_stats=True)
    # the Table 1 counters must agree exactly; the tile accounting
    # legitimately differs (the fused engine streams the full padded
    # E, the hostloop its pow2 buckets)
    assert [s[:4] for s in fused_stats] == [s[:4] for s in host_stats]


def test_hybrid_policy_switches_on_rmat(graphs):
    g = graphs["rmat10"]
    res = engine.traverse(g, 17, policy=engine.BeamerHybrid())
    log = engine.direction_log(res)
    assert log[0] == "topdown" and "bottomup" in log
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)), 17)


def test_serve_engine_drains_queue(graphs):
    g = graphs["rmat10"]
    eng = GraphEngine(g, batch_slots=4)
    roots = [3, 7, 11, 100, 511, 900]
    for uid, r in enumerate(roots):
        eng.submit(BfsQuery(uid=uid, root=r))
    eng.run_until_done()
    assert len(eng.finished) == len(roots)
    for q in sorted(eng.finished, key=lambda q: q.uid):
        check_oracle(g, q.parent, roots[q.uid])


def test_serve_engine_flags_truncated_queries(graphs):
    """A query that hits the layer budget must be marked partial."""
    g = graphs["path"]
    eng = GraphEngine(g, batch_slots=1, max_layers=8)
    eng.submit(BfsQuery(uid=0, root=0))
    eng.run_until_done()
    q = eng.finished[0]
    assert q.truncated and q.n_layers == 8
    assert (q.parent[:8] >= 0).all()      # prefix reached...
    assert q.parent[50] == -1             # ...deep vertices not yet


def test_serve_engine_reuses_slots(graphs):
    """More queries than slots forces continuous-batching refills."""
    g = graphs["star"]
    eng = GraphEngine(g, batch_slots=2)
    for uid in range(5):
        eng.submit(BfsQuery(uid=uid, root=uid))
    ticks = eng.run_until_done()
    assert len(eng.finished) == 5
    assert ticks >= 3                 # at least ceil(5/2) waves
    for q in eng.finished:
        assert q.parent[q.root] == q.root
