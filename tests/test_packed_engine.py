"""ISSUE 4: packed-word engine — parity, compaction kernel oracle,
and bitmap round-trip properties.

Covers the acceptance matrix:

* **parity** — packed (native) vs unpacked (legacy dense-mask)
  traversal produces bit-identical parents/visited for every format x
  direction policy, both pipelines, batched multi-root, and the
  distributed program at shard counts 1 and 2 (2 via a forced
  host-device subprocess);
* **compaction kernel** — `kernels.compact.frontier_compact[_batched]`
  against a numpy popcount/nonzero oracle, including truncation,
  empty/full bitmaps and non-tile-multiple word counts;
* **round-trip properties** — packed words survive
  pack_bool/unpack_bool/compact/frontier_compact round trips for
  arbitrary bit sets (hypothesis, with the deterministic fallback
  sampler);
* **double-buffered DMA** — prefetch_depth > 0 kernels equal the
  BlockSpec-pipelined kernels exactly;
* **distributed packed merge** — `merge="packed"` returns the same
  deterministic min-parent tree as the per-layer ``pmin`` baseline.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import bitmap as bm
from repro.core import csr as csr_mod
from repro.core import engine, rmat
from repro.core.rmat import EdgeList
from repro.formats.bitmap_format import BitmapCompressedFormat
from repro.formats.csr_format import CsrFormat
from repro.formats.sell import SellFormat
from repro.kernels import compact as ck

POLICIES = {
    "topdown": engine.TopDown(),
    "simd_forced": engine.ThresholdSimd(0),
    "paper_layers": engine.PaperLiteralLayers((1, 2)),
    "hybrid": engine.BeamerHybrid(),
}
FORMATS = {
    "csr": CsrFormat,
    "sell": SellFormat,
    "bitmap": BitmapCompressedFormat,
}


def _csr_from_pairs(pairs, n):
    src = jnp.asarray([a for a, b in pairs] + [b for a, b in pairs],
                      jnp.int32)
    dst = jnp.asarray([b for a, b in pairs] + [a for a, b in pairs],
                      jnp.int32)
    return csr_mod.from_edges(EdgeList(src, dst, n))


@pytest.fixture(scope="module")
def g9():
    return csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=9, edgefactor=8))


@pytest.fixture(scope="module")
def built(g9):
    return {name: cls.from_csr(g9) for name, cls in FORMATS.items()}


def _state_tuple(res):
    return (np.asarray(res.state.parent), np.asarray(res.state.visited),
            np.asarray(res.state.frontier))


# ---------------------------------------------------------------------------
# Packed vs unpacked parity: formats x policies x pipelines x batched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pol_name", list(POLICIES))
@pytest.mark.parametrize("fmt_name", list(FORMATS))
def test_packed_parity_formats_policies(built, fmt_name, pol_name):
    fmt = built[fmt_name]
    kw = dict(policy=POLICIES[pol_name])
    a = engine.traverse(fmt, 17, packed=True, **kw)
    b = engine.traverse(fmt, 17, packed=False, **kw)
    for x, y in zip(_state_tuple(a), _state_tuple(b)):
        np.testing.assert_array_equal(x, y)
    # workload stats are representation-independent; the launch-count
    # column is NOT (the packed arm's compaction kernel is one extra
    # Pallas call per layer — an honest cost difference, not a parity
    # break), so compare everything except _ST_LAUNCH
    sa, sb = np.asarray(a.stats), np.asarray(b.stats)
    keep = [i for i in range(engine._N_ST) if i != engine._ST_LAUNCH]
    np.testing.assert_array_equal(sa[:, keep], sb[:, keep])


@pytest.mark.parametrize("pipeline", engine.PIPELINES)
def test_packed_parity_pipelines(g9, pipeline):
    pol = engine.ThresholdSimd(0)
    a = engine.traverse(g9, 17, policy=pol, pipeline=pipeline,
                        packed=True)
    b = engine.traverse(g9, 17, policy=pol, pipeline=pipeline,
                        packed=False)
    for x, y in zip(_state_tuple(a), _state_tuple(b)):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("fmt_name", list(FORMATS))
def test_packed_parity_batched_multiroot(built, fmt_name):
    fmt = built[fmt_name]
    roots = [3, 7, 17, 100]
    a = engine.traverse(fmt, roots, policy=engine.ThresholdSimd(0),
                        packed=True)
    b = engine.traverse(fmt, roots, policy=engine.ThresholdSimd(0),
                        packed=False)
    np.testing.assert_array_equal(np.asarray(a.state.parent),
                                  np.asarray(b.state.parent))
    np.testing.assert_array_equal(np.asarray(a.depths),
                                  np.asarray(b.depths))


def test_packed_parity_hostpath_edge_graphs():
    """Star (hub frontier) and path (1-vertex layers) corner shapes."""
    star = _csr_from_pairs([(0, i) for i in range(1, 128)], 128)
    path = _csr_from_pairs([(i, i + 1) for i in range(95)], 96)
    for g, root in ((star, 0), (path, 0)):
        a = engine.traverse(g, root, policy=engine.ThresholdSimd(0),
                            packed=True, max_layers=128)
        b = engine.traverse(g, root, policy=engine.ThresholdSimd(0),
                            packed=False, max_layers=128)
        np.testing.assert_array_equal(np.asarray(a.state.parent),
                                      np.asarray(b.state.parent))


def test_prefetch_depth_matches_blockspec_pipeline(built):
    """The manual double-buffered DMA input pipeline is a pure
    performance transform: results equal the BlockSpec kernels."""
    for fmt_name in ("csr", "sell"):
        fmt = built[fmt_name]
        base = engine.traverse(fmt, 17, policy=engine.ThresholdSimd(0))
        for depth in (1, 3):
            res = engine.traverse(fmt, 17,
                                  policy=engine.ThresholdSimd(0),
                                  prefetch_depth=depth)
            np.testing.assert_array_equal(np.asarray(res.state.parent),
                                          np.asarray(base.state.parent))


def test_serve_engine_packed_knobs(g9):
    from repro.serve.graph_engine import BfsQuery, GraphEngine
    results = {}
    for packed in (True, False):
        eng = GraphEngine(g9, batch_slots=2, graph_format="csr",
                          packed=packed, prefetch_depth=1 if packed
                          else 0)
        for uid, r in enumerate([3, 7, 17]):
            eng.submit(BfsQuery(uid=uid, root=r))
        eng.run_until_done()
        results[packed] = {q.uid: q.parent for q in eng.finished}
    for uid in results[True]:
        np.testing.assert_array_equal(results[True][uid],
                                      results[False][uid])


# ---------------------------------------------------------------------------
# Distributed: packed merge + shard count 1/2 parity
# ---------------------------------------------------------------------------

def test_distributed_packed_merge_single_shard(g9):
    from repro.core.bfs_distributed import run_bfs_distributed
    mesh = jax.make_mesh((1,), ("x",))
    p_packed, l1 = run_bfs_distributed(g9, 11, mesh, merge="packed")
    p_base, l2 = run_bfs_distributed(g9, 11, mesh, merge="allreduce")
    np.testing.assert_array_equal(np.asarray(p_packed),
                                  np.asarray(p_base))
    assert int(l1) == int(l2)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    import numpy as np
    from repro.core import csr as csr_mod, rmat
    from repro.core.bfs_distributed import run_bfs_distributed

    assert len(jax.devices()) == 2
    g = csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=9, edgefactor=8))
    mesh = jax.make_mesh((2,), ("x",))
    p_packed, lp = run_bfs_distributed(g, 11, mesh, merge="packed")
    p_base, lb = run_bfs_distributed(g, 11, mesh, merge="allreduce")
    np.testing.assert_array_equal(np.asarray(p_packed),
                                  np.asarray(p_base))
    assert int(lp) == int(lb)
    print("PACKED2_OK")
""")


def test_distributed_packed_merge_two_shards_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PACKED2_OK" in out.stdout, out.stderr[-3000:]


# ---------------------------------------------------------------------------
# Compaction kernel vs numpy oracle
# ---------------------------------------------------------------------------

def _np_compact(words, size, fill):
    dense = np.unpackbits(
        np.asarray(words, np.uint32).view(np.uint8), bitorder="little")
    ids = np.nonzero(dense)[0]
    out = np.full((size,), fill, np.int32)
    take = min(len(ids), size)
    out[:take] = ids[:take]
    return out, len(ids)


@pytest.mark.parametrize("n_words,size", [(4, 128), (36, 1152),
                                          (40, 64), (257, 8224)])
def test_compact_kernel_vs_numpy(n_words, size):
    rng = np.random.default_rng(n_words)
    words = jnp.asarray(rng.integers(0, 2**32, size=n_words,
                                     dtype=np.uint32))
    q, n = ck.frontier_compact(words, size=size, fill=n_words * 32)
    ref_q, ref_n = _np_compact(words, size, n_words * 32)
    np.testing.assert_array_equal(np.asarray(q), ref_q)
    assert int(n) == ref_n


def test_compact_kernel_batched_vs_numpy():
    rng = np.random.default_rng(0)
    words = jnp.asarray(rng.integers(0, 2**32, size=(5, 36),
                                     dtype=np.uint32))
    q, n = ck.frontier_compact_batched(words, size=1152, fill=1152)
    for b in range(5):
        ref_q, ref_n = _np_compact(words[b], 1152, 1152)
        np.testing.assert_array_equal(np.asarray(q[b]), ref_q)
        assert int(n[b]) == ref_n


def test_compact_kernel_empty_and_full():
    z = jnp.zeros((8,), jnp.uint32)
    q, n = ck.frontier_compact(z, size=16, fill=256)
    assert int(n) == 0 and (np.asarray(q) == 256).all()
    f = jnp.full((8,), 0xFFFFFFFF, jnp.uint32)
    q, n = ck.frontier_compact(f, size=256, fill=256)
    np.testing.assert_array_equal(np.asarray(q), np.arange(256))
    assert int(n) == 256


def test_compact_kernel_truncates_like_bitmap_compact():
    rng = np.random.default_rng(7)
    words = jnp.asarray(rng.integers(0, 2**32, size=16,
                                     dtype=np.uint32))
    q, _ = ck.frontier_compact(words, size=10, fill=512)
    ref = bm.compact(words, 10, 512)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(ref))


# ---------------------------------------------------------------------------
# Round-trip properties (packed words <-> bits <-> queues)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=511), min_size=0,
                max_size=80))
def test_packed_roundtrip_property(vertices):
    """set_bits -> unpack -> pack -> compact -> kernel compact all
    agree for arbitrary bit sets (the core/bitmap.py helpers the
    packed engine is built from)."""
    v_pad = 512
    ids = jnp.asarray(sorted(set(vertices)), jnp.int32)
    words = bm.set_bits_exact(bm.zeros(v_pad), ids)
    # word <-> dense round trip
    np.testing.assert_array_equal(
        np.asarray(bm.pack_bool(bm.unpack_bool(words))),
        np.asarray(words))
    # popcount == cardinality
    assert int(bm.popcount(words)) == len(set(vertices))
    # jnp compact == kernel compact == the sorted id list
    lst = np.asarray(bm.compact(words, v_pad, v_pad))
    q, n = ck.frontier_compact(words, size=v_pad, fill=v_pad)
    np.testing.assert_array_equal(np.asarray(q), lst)
    assert int(n) == len(set(vertices))
    np.testing.assert_array_equal(
        lst[:len(set(vertices))], np.asarray(ids, np.int64))


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=255), min_size=1,
                max_size=40))
def test_masked_degree_sum_property(vertices):
    """The packed Table-1 edge counter equals the dense reference."""
    v = 256
    rng = np.random.default_rng(len(vertices))
    deg = jnp.asarray(rng.integers(0, 50, size=v), jnp.int32)
    ids = jnp.asarray(sorted(set(vertices)), jnp.int32)
    words = bm.set_bits_exact(bm.zeros(v), ids)
    deg_mat = bm.degree_matrix(deg, v)
    packed_sum = int(bm.masked_degree_sum(words, deg_mat))
    dense = np.asarray(bm.unpack_bool(words))[:v]
    assert packed_sum == int(np.asarray(deg)[dense].sum())


# ---------------------------------------------------------------------------
# Planning parity: packed planner == dense planner
# ---------------------------------------------------------------------------

def test_edge_stream_packed_parity(g9):
    """The single-root materialized stream is bit-identical whether
    the frontier list comes from the compaction kernel or the dense
    unpack/nonzero pass."""
    rng = np.random.default_rng(3)
    ids = jnp.asarray(
        np.unique(rng.integers(0, g9.n_vertices, size=50)), jnp.int32)
    words = bm.set_bits_exact(bm.zeros(g9.n_vertices_padded), ids)
    a = engine.edge_stream(g9.colstarts, g9.rows, words,
                           g9.n_vertices_padded, g9.n_vertices,
                           g9.n_edges_padded, packed=True)
    b = engine.edge_stream(g9.colstarts, g9.rows, words,
                           g9.n_vertices_padded, g9.n_vertices,
                           g9.n_edges_padded, packed=False)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_plan_active_tiles_packed_matches_dense(g9):
    fmt = CsrFormat.from_csr(g9)
    tile = fmt.resolve_tile(None)
    rows_t = engine._pad_rows_to_tile(g9.rows, g9.n_vertices, tile)
    n_blocks = int(rows_t.shape[0]) // tile
    rng = np.random.default_rng(1)
    ids = jnp.asarray(
        np.unique(rng.integers(0, g9.n_vertices, size=37)), jnp.int32)
    words = bm.set_bits_exact(bm.zeros(g9.n_vertices_padded), ids)
    wl_p, na_p = engine.plan_active_tiles(
        g9.colstarts, words, g9.n_vertices, tile, n_blocks, packed=True)
    wl_d, na_d = engine.plan_active_tiles(
        g9.colstarts, words, g9.n_vertices, tile, n_blocks,
        packed=False)
    assert int(na_p) == int(na_d)
    np.testing.assert_array_equal(np.asarray(wl_p), np.asarray(wl_d))


def test_compact_fits_budget_fallback():
    """Oversized batch x V_pad working sets must route the packed
    planning arms to the dense fallback instead of failing the
    compaction kernel's VMEM budget (large graphs keep traversing
    exactly as they did before the packed default)."""
    from repro.kernels import ops
    assert ops.compact_fits(1, 1152)
    assert ops.compact_fits(8, 1 << 14)
    assert not ops.compact_fits(8, 1 << 22)   # 128 MiB of queues
    assert not ops.compact_fits(1, 1 << 25)


def test_tile_env_override(monkeypatch):
    monkeypatch.setenv(engine._TILE_ENV, "2048")
    assert engine.default_tile_csr() == 2048
    monkeypatch.delenv(engine._TILE_ENV)
    # without the env the committed BENCH table (or the 1024 fallback)
    # decides; either way the resolved tile respects the floor
    t = engine._resolve_tile_csr(None, 1 << 16)
    assert t >= 128
