"""Tests for the RMAT generator and CSR builder (paper §3.3.1, §5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import rmat


@pytest.fixture(scope="module")
def small_graph():
    edges = rmat.generate(jax.random.PRNGKey(7), scale=10, edgefactor=16)
    return edges, csr_mod.from_edges(edges)


def test_rmat_shapes_and_ranges(small_graph):
    edges, _ = small_graph
    v = 1 << 10
    assert edges.n_vertices == v
    # symmetrized: 2 * V * edgefactor directed edges (paper §5.2)
    assert edges.src.shape[0] == 2 * v * 16
    assert int(edges.src.min()) >= 0 and int(edges.src.max()) < v
    assert int(edges.dst.min()) >= 0 and int(edges.dst.max()) < v


def test_rmat_symmetry(small_graph):
    edges, _ = small_graph
    s, d = np.asarray(edges.src), np.asarray(edges.dst)
    fwd = set(zip(s.tolist(), d.tolist()))
    assert all((b, a) in fwd for a, b in list(fwd)[:2000])


def test_rmat_determinism():
    e1 = rmat.generate(jax.random.PRNGKey(3), scale=8)
    e2 = rmat.generate(jax.random.PRNGKey(3), scale=8)
    assert np.array_equal(np.asarray(e1.src), np.asarray(e2.src))


def test_rmat_skew(small_graph):
    """R-MAT graphs are skewed: max degree >> mean degree (§4.1)."""
    _, csr = small_graph
    deg = np.asarray(csr.degrees())
    assert deg.max() > 8 * deg.mean()


def test_csr_roundtrip(small_graph):
    edges, csr = small_graph
    s, d = np.asarray(edges.src), np.asarray(edges.dst)
    cs = np.asarray(csr.colstarts)
    rows = np.asarray(csr.rows)
    assert csr.n_edges == len(s)
    assert cs[0] == 0 and cs[-1] == csr.n_edges
    # spot-check a few vertices: CSR adjacency == multiset of dsts
    rng = np.random.default_rng(0)
    for u in rng.integers(0, csr.n_vertices, size=20):
        want = np.sort(d[s == u])
        got = rows[cs[u]:cs[u + 1]]
        np.testing.assert_array_equal(got, want)
        assert (np.diff(got) >= 0).all()  # sorted adjacency


def test_csr_padding_and_sentinel(small_graph):
    _, csr = small_graph
    assert csr.rows.shape[0] % csr_mod.LANES == 0
    pad = np.asarray(csr.rows[csr.n_edges:])
    assert (pad == csr.sentinel).all()
    assert csr.n_vertices_padded % csr_mod.LANES == 0
    assert csr.n_vertices_padded > csr.n_vertices


def test_init_visited_marks_padding(small_graph):
    from repro.core import bitmap as bm
    _, csr = small_graph
    vis = csr_mod.init_visited(csr)
    pad_ids = jnp.arange(csr.n_vertices, csr.n_vertices_padded)
    assert bool(bm.test_bits(vis, pad_ids).all())
    real = jnp.arange(0, csr.n_vertices)
    assert not bool(bm.test_bits(vis, real).any())


def test_traversed_edges_counts_undirected(small_graph):
    _, csr = small_graph
    reached = jnp.ones((csr.n_vertices,), bool)
    assert int(csr_mod.traversed_edges(csr, reached)) == csr.n_edges // 2
