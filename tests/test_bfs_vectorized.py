"""End-to-end correctness of the §4 vectorized BFS and the hybrid BFS."""
import jax
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_hybrid import run_bfs_hybrid
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.bfs_vectorized import run_bfs_vectorized
from repro.core.validate import validate


@pytest.fixture(scope="module")
def g11():
    return csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(5), scale=11, edgefactor=16))


def check(csr, state, root):
    p = parents_graph500(state, csr.n_vertices)
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, p, root, reference_depth=ref_depth)
    assert res.ok, res


@pytest.mark.parametrize("root", [0, 101, 999])
def test_vectorized_matches_oracle(g11, root):
    state = run_bfs_vectorized(g11, root)
    check(g11, state, root)


def test_vectorized_all_layers_simd(g11):
    """threshold 0 => kernel path on every layer, still correct."""
    state = run_bfs_vectorized(g11, 42, simd_threshold=0)
    check(g11, state, 42)


def test_vectorized_paper_literal_policy(g11):
    """The paper's 'vectorize the fat layers only' (§4.1)."""
    state, stats = run_bfs_vectorized(g11, 7, simd_layers=(2, 3),
                                      collect_stats=True)
    check(g11, state, 7)
    assert len(stats) >= 4


def test_vectorized_agrees_with_scalar(g11):
    from repro.core.bfs_parallel import run_bfs
    s_vec = run_bfs_vectorized(g11, 13)
    s_ref = run_bfs(g11, 13, algorithm="simd")
    p1 = np.asarray(parents_graph500(s_vec, g11.n_vertices))
    p2 = np.asarray(parents_graph500(s_ref, g11.n_vertices))
    np.testing.assert_array_equal(p1 >= 0, p2 >= 0)


@pytest.mark.parametrize("root", [3, 512])
def test_hybrid_matches_oracle(g11, root):
    state = run_bfs_hybrid(g11, root)
    check(g11, state, root)


def test_hybrid_actually_switches_direction(g11):
    deg = np.asarray(g11.degrees())
    root = int(np.where(deg > 0)[0][0])  # a connected start vertex
    state, directions = run_bfs_hybrid(g11, root, collect_stats=True)
    assert "bottomup" in directions, directions
    assert directions[0] == "topdown"
    check(g11, state, root)


def test_hybrid_aggressive_switching(g11):
    """alpha tiny => switches immediately; still correct."""
    state = run_bfs_hybrid(g11, 9, alpha=1.0, beta=2.0)
    check(g11, state, 9)
