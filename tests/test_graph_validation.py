"""Admission-time graph validation (ISSUE 8).

Property: EVERY corruption of a valid CSR — out-of-range neighbor
ids, non-monotone ``colstarts``, mismatched edge counts, NaN/negative
geometry, out-of-range roots — raises a *typed*
`repro.errors.GraphValidationError` (which IS-A ``ValueError``) from
every admission surface: ``bfs.plan``, the legacy ``traverse`` shim,
and `GraphEngine` construction/submit.  A wrong tree delivered
silently is the failure mode these checks exist to kill.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.bfs as bfs
from repro.core.csr import Csr, check_structure, from_edges
from repro.core.rmat import generate
from repro.errors import GraphValidationError, ReproError
from repro.serve.graph_engine import BfsQuery, GraphEngine

from _hypothesis_compat import given, settings, st

CSR = from_edges(generate(jax.random.PRNGKey(7), scale=6, edgefactor=4))
V = CSR.n_vertices
E = CSR.n_edges


def _with(rows=None, colstarts=None, n_vertices=None, n_edges=None):
    return Csr(
        rows=CSR.rows if rows is None else rows,
        colstarts=CSR.colstarts if colstarts is None else colstarts,
        n_vertices=CSR.n_vertices if n_vertices is None else n_vertices,
        n_edges=CSR.n_edges if n_edges is None else n_edges)


def test_valid_csr_passes_and_chains():
    assert check_structure(CSR) is CSR
    bfs.plan(CSR)   # no raise


def test_typed_error_is_a_value_error():
    assert issubclass(GraphValidationError, ValueError)
    assert issubclass(GraphValidationError, ReproError)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=E - 1),
       st.integers(min_value=1, max_value=2**30))
def test_fuzz_out_of_range_neighbor(idx, offset):
    """Any real adjacency entry pushed outside [0, V) is rejected."""
    bad_rows = CSR.rows.at[idx].set(V + (offset % 1000))
    with pytest.raises(GraphValidationError, match="neighbor id"):
        check_structure(_with(rows=bad_rows))
    neg_rows = CSR.rows.at[idx].set(-1 - (offset % 7))
    with pytest.raises(GraphValidationError, match="neighbor id"):
        bfs.plan(_with(rows=neg_rows))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=V - 1),
       st.integers(min_value=1, max_value=1000))
def test_fuzz_non_monotone_colstarts(pos, bump):
    """A colstarts entry raised above its successor is rejected."""
    cs = np.asarray(CSR.colstarts).copy()
    cs[pos] = int(cs[pos + 1]) + bump
    with pytest.raises(GraphValidationError, match="non-decreasing"):
        bfs.plan(_with(colstarts=jnp.asarray(cs)))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=2**20))
def test_fuzz_edge_count_mismatch(delta):
    with pytest.raises(GraphValidationError, match="n_edges"):
        check_structure(_with(n_edges=E + delta))


@pytest.mark.parametrize("n_vertices", [float("nan"), float("inf"),
                                        -float("inf"), 3.5, -1, None,
                                        "64", True])
def test_nan_shaped_geometry(n_vertices):
    bad = Csr(rows=CSR.rows, colstarts=CSR.colstarts,
              n_vertices=n_vertices, n_edges=CSR.n_edges)
    with pytest.raises(GraphValidationError):
        bfs.plan(bad)


def test_zero_vertices_rejected():
    with pytest.raises(GraphValidationError, match="at least a root"):
        check_structure(Csr(rows=jnp.zeros((0,), jnp.int32),
                            colstarts=jnp.zeros((1,), jnp.int32),
                            n_vertices=0, n_edges=0))


def test_wrong_dtype_rejected():
    with pytest.raises(GraphValidationError, match="integer dtype"):
        check_structure(_with(rows=CSR.rows.astype(jnp.float32)))


def test_colstarts_shape_rejected():
    with pytest.raises(GraphValidationError, match="n_vertices"):
        check_structure(_with(colstarts=CSR.colstarts[:-2]))


def test_truncated_rows_rejected():
    with pytest.raises(GraphValidationError, match="truncated"):
        check_structure(_with(rows=CSR.rows[:E // 2],
                              n_edges=E))


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=2**30))
def test_fuzz_root_out_of_range(r):
    ct = bfs.plan(CSR)
    with pytest.raises(GraphValidationError, match="outside"):
        ct.run(V + (r % 1000))
    with pytest.raises(GraphValidationError, match="outside"):
        ct.run_batched([0, -(1 + r % 50)])


def test_traverse_shim_raises_typed():
    bad_rows = CSR.rows.at[0].set(V + 3)
    with pytest.raises(GraphValidationError):
        bfs.traverse(_with(rows=bad_rows), 0)
    # old-style callers that guard with `except ValueError` still work
    with pytest.raises(ValueError):
        bfs.traverse(_with(rows=bad_rows), 0)


def test_graph_engine_ctor_and_submit_raise_typed():
    bad_rows = CSR.rows.at[0].set(-9)
    with pytest.raises(GraphValidationError):
        GraphEngine(_with(rows=bad_rows), batch_slots=2)
    eng = GraphEngine(CSR, batch_slots=2)
    with pytest.raises(GraphValidationError):
        eng.submit(BfsQuery(uid=0, root=V + 1))
    with pytest.raises(GraphValidationError):
        eng.submit(BfsQuery(uid=1, root=-1))


def test_format_validate_structure_memoized():
    from repro.formats.csr_format import CsrFormat
    fmt = CsrFormat.from_csr(CSR)
    assert fmt.validate_structure() is fmt
    assert fmt._structure_ok
    # second call is the memoized no-op path
    assert fmt.validate_structure() is fmt
