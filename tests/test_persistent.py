"""ISSUE 9: the whole-traversal persistent kernel vs the per-layer
megakernel and the serial oracle.

Covers the acceptance matrix:

* bit-parity persistent vs megakernel across every graph family x
  direction policy x packed/unpacked x single/batched root — parents
  must be IDENTICAL (both pipelines run the same racy first-tile-wins
  parent selection over the same resolved tile partition), and
  oracle-valid;
* launch accounting: a persistent traversal issues EXACTLY one Pallas
  call total (charged to layer 0 of the stats buffer) where the
  megakernel issues one per layer, measured by the trace-time
  `ops.count_launches` counter;
* the VMEM-budget degrade: a whole-batch working set
  `fmt.persistent_fits` rejects falls back to the per-layer megakernel
  steps via an observable ``serve.degrade.vmem_fallback``
  `DegradeEvent` — and still traverses correctly;
* SELL joins both fused tiers (ISSUE 9 lifts
  ``supports_megakernel=False`` via the manual cols-DMA rebuild):
  megakernel and persistent parity on the sorted-slab layout;
* the capability gate: ``pipeline="persistent"`` is rejected by
  `spec.validate` on formats without `supports_persistent`, and the
  `persistent_algorithms` honor check rejects scalar algorithms the
  in-kernel layer loop cannot run — both keyed on classvars, not
  format names.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import engine, rmat
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.rmat import EdgeList
from repro.core.validate import validate
from repro.formats.csr_format import CsrFormat
from repro.formats.sell import SellFormat
from repro.kernels import ops
from repro.kernels import traversal_fused

POLICIES = [
    engine.TopDown(),
    engine.ThresholdSimd(0),          # SIMD forced: every layer fused
    engine.PaperLiteralLayers((1, 2)),
    engine.BeamerHybrid(),
]


def _csr_from_pairs(pairs, n):
    src = jnp.asarray([a for a, b in pairs] + [b for a, b in pairs],
                      jnp.int32)
    dst = jnp.asarray([b for a, b in pairs] + [a for a, b in pairs],
                      jnp.int32)
    return csr_mod.from_edges(EdgeList(src, dst, n))


GRAPHS = {
    "rmat10": lambda: csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=10, edgefactor=16)),
    "star": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 128)], 128),
    "path": lambda: _csr_from_pairs(
        [(i, i + 1) for i in range(95)], 96),
    "disconnected": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 64)]
        + [(i, i + 1) for i in range(64, 127)], 128),
}
ROOTS = {"rmat10": 17, "star": 0, "path": 0, "disconnected": 0}


@pytest.fixture(scope="module")
def graphs():
    return {k: v() for k, v in GRAPHS.items()}


def check_oracle(csr, parent_g500, root):
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, parent_g500, root, reference_depth=ref_depth)
    assert res.ok, res


def _reached(res, n_vertices):
    return np.asarray(res.state.parent)[..., :n_vertices] < n_vertices


def _launch_col(res):
    return np.asarray(res.stats)[:, engine._ST_LAUNCH]


def _total_launches(res) -> int:
    return int(_launch_col(res).sum())


# ---------------------------------------------------------------------------
# Bit-parity: persistent vs megakernel, every family x policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False],
                         ids=["packed", "unpacked"])
@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_persistent_matches_megakernel(graphs, graph_name, policy,
                                       packed):
    g = graphs[graph_name]
    root = ROOTS[graph_name]
    pers = engine.traverse(g, root, policy=policy, max_layers=128,
                           pipeline="persistent", packed=packed)
    mega = engine.traverse(g, root, policy=policy, max_layers=128,
                           pipeline="megakernel", packed=packed)
    # same resolved tile -> same racy tiebreak -> IDENTICAL parents
    np.testing.assert_array_equal(np.asarray(pers.state.parent),
                                  np.asarray(mega.state.parent))
    assert int(pers.state.layer) == int(mega.state.layer)
    assert int(pers.depths) == int(mega.depths)
    check_oracle(g, np.asarray(parents_graph500(pers.state,
                                                g.n_vertices)), root)


@pytest.mark.parametrize("packed", [True, False],
                         ids=["packed", "unpacked"])
def test_persistent_batched_multiroot(graphs, packed):
    g = graphs["disconnected"]
    # both components + an isolated-ish tail: slot 64's search dies at
    # a different layer than slot 0's, exercising the per-root layer
    # loop running past a finished slot inside the single launch
    roots = [0, 64, 1, 127]
    pers = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                           pipeline="persistent", packed=packed)
    mega = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel", packed=packed)
    np.testing.assert_array_equal(np.asarray(pers.state.parent),
                                  np.asarray(mega.state.parent))
    np.testing.assert_array_equal(np.asarray(pers.depths),
                                  np.asarray(mega.depths))
    for b, root in enumerate(roots):
        st = engine.BfsState(pers.state.frontier[b],
                             pers.state.visited[b],
                             pers.state.parent[b], pers.state.layer)
        check_oracle(g, np.asarray(parents_graph500(st, g.n_vertices)),
                     root)


def test_persistent_batched_rmat_prefetch(graphs):
    """Batched skewed workload with the DMA pipeline running ahead
    inside the single launch."""
    g = graphs["rmat10"]
    roots = [17, 200, 5]
    pers = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                           pipeline="persistent", prefetch_depth=2)
    mega = engine.traverse(g, roots, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel", prefetch_depth=2)
    np.testing.assert_array_equal(np.asarray(pers.state.parent),
                                  np.asarray(mega.state.parent))
    np.testing.assert_array_equal(np.asarray(pers.depths),
                                  np.asarray(mega.depths))


# ---------------------------------------------------------------------------
# Launch accounting: 1 call/TRAVERSAL vs 1 call/layer
# ---------------------------------------------------------------------------

def test_persistent_single_launch_per_traversal(graphs):
    g = graphs["rmat10"]
    pers = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                           pipeline="persistent")
    mega = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel")
    assert _total_launches(pers) == 1
    # ...charged to layer 0; every later row reads 0 (the launch
    # column is the ladder metric, so the shape matters, not just
    # the sum)
    col = _launch_col(pers)
    assert col[0] == 1 and not col[1:].any(), col
    n_layers = len(engine.layer_stats(mega))
    assert n_layers >= 2
    assert _total_launches(mega) == n_layers


def test_persistent_stats_match_megakernel(graphs):
    """Cols 0-4 (active/frontier/edges/discovered/mode) of the stats
    buffer are recovered from in-kernel counters and must agree with
    the per-layer pipeline's accounting exactly."""
    g = graphs["rmat10"]
    pers = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                           pipeline="persistent")
    mega = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel")
    np.testing.assert_array_equal(np.asarray(pers.stats)[:, :5],
                                  np.asarray(mega.stats)[:, :5])


def test_mode_constants_pinned():
    """The persistent kernel restates the engine's MODE encoding for
    its in-kernel policy arm — the two must never drift apart."""
    assert traversal_fused.MODE_SCALAR == engine.MODE_SCALAR
    assert traversal_fused.MODE_SIMD == engine.MODE_SIMD
    assert traversal_fused.MODE_BOTTOMUP == engine.MODE_BOTTOMUP


# ---------------------------------------------------------------------------
# VMEM-budget degrade: persistent -> megakernel, observable
# ---------------------------------------------------------------------------

def test_persistent_fits_budget():
    assert ops.persistent_fits(36, 1152, 1025, 1024, 1, 64)
    # a 2^22-vertex whole-batch working set blows the VMEM budget
    assert not ops.persistent_fits(1 << 17, 1 << 22, (1 << 22) + 1,
                                   1024, 8, 64)


def test_persistent_vmem_fallback(graphs, monkeypatch):
    """Past the VMEM budget the persistent arm must degrade to the
    per-layer megakernel steps — same results, honest (1/layer)
    launch counter, and an observable DegradeEvent."""
    from repro.api import plan as api_plan
    from repro.obs.metrics import (clear_degrade_log, degrade_log,
                                   get_registry)
    g = graphs["rmat10"]
    clear_degrade_log()
    reg = get_registry()
    before = reg.counter("serve.degrade.vmem_fallback").value
    api_plan.clear_cache()     # force a re-trace under the patch
    monkeypatch.setattr(ops, "persistent_fits",
                        lambda *a, **k: False)
    try:
        res = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                              pipeline="persistent")
    finally:
        monkeypatch.undo()
        api_plan.clear_cache()  # drop the degraded executable
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)), 17)
    # fell back to the megakernel: one launch per layer, not one total
    n_layers = len(engine.layer_stats(res))
    assert _total_launches(res) == n_layers >= 2
    assert reg.counter("serve.degrade.vmem_fallback").value \
        == before + 1
    events = [e for e in degrade_log() if e.site == "vmem_fallback"]
    assert events, "no DegradeEvent recorded"
    assert "persistent" in events[-1].reason
    assert "megakernel" in events[-1].fallback
    clear_degrade_log()


# ---------------------------------------------------------------------------
# SELL: both fused tiers on the sorted-slab layout (ISSUE 9)
# ---------------------------------------------------------------------------

def test_sell_megakernel_matches_unfused(graphs):
    """The lifted capability: SELL's whole-layer fused kernel (manual
    cols DMA) agrees with its own unfused slab pipeline."""
    g = graphs["rmat10"]
    fmt = SellFormat.from_csr(g)
    assert fmt.supports_megakernel
    mega = engine.traverse(fmt, 17, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel")
    fused = engine.traverse(fmt, 17, policy=engine.ThresholdSimd(0),
                            pipeline="fused_gather")
    np.testing.assert_array_equal(_reached(mega, g.n_vertices),
                                  _reached(fused, g.n_vertices))
    check_oracle(g, np.asarray(parents_graph500(mega.state,
                                                g.n_vertices)), 17)
    buf = np.asarray(mega.stats)
    simd = [int(buf[i, engine._ST_LAUNCH])
            for i in range(buf.shape[0])
            if buf[i, engine._ST_ACTIVE]
            and int(buf[i, engine._ST_MODE]) != engine.MODE_SCALAR]
    assert simd and all(n == 1 for n in simd), simd


def test_sell_persistent_matches_megakernel(graphs):
    g = graphs["rmat10"]
    fmt = SellFormat.from_csr(g)
    assert fmt.supports_persistent
    pers = engine.traverse(fmt, 17, policy=engine.ThresholdSimd(0),
                           pipeline="persistent")
    mega = engine.traverse(fmt, 17, policy=engine.ThresholdSimd(0),
                           pipeline="megakernel")
    np.testing.assert_array_equal(np.asarray(pers.state.parent),
                                  np.asarray(mega.state.parent))
    assert _total_launches(pers) == 1
    assert _total_launches(mega) == len(engine.layer_stats(mega))
    check_oracle(g, np.asarray(parents_graph500(pers.state,
                                                g.n_vertices)), 17)


# ---------------------------------------------------------------------------
# Validation matrix: capability classvars, not format names
# ---------------------------------------------------------------------------

def test_persistent_rejected_on_unsupporting_formats(graphs):
    from repro.api.spec import TraversalSpec
    from repro.formats import build
    g = graphs["rmat10"]
    spec = TraversalSpec(pipeline="persistent")
    spec.validate(build(g, "csr"))               # supported: no raise
    spec.validate(build(g, "sell"))
    fmt = build(g, "bitmap")
    assert not fmt.supports_persistent
    with pytest.raises(ValueError, match="supports_persistent"):
        spec.validate(fmt)
    with pytest.raises(ValueError, match="supports_persistent"):
        engine.traverse(fmt, 17, spec=spec)


def test_persistent_algorithm_honor(graphs):
    """SELL's persistent kernel is SIMD-only (`persistent_algorithms`)
    — asking for the nonsimd scalar expander must raise, not silently
    run a different algorithm."""
    from repro.api.spec import TraversalSpec
    g = graphs["rmat10"]
    fmt = SellFormat.from_csr(g)
    assert fmt.persistent_algorithms == ("simd",)
    spec = TraversalSpec(pipeline="persistent", algorithm="nonsimd")
    with pytest.raises(ValueError, match="persistent_algorithms|"
                                         "honors algorithm"):
        spec.validate(fmt)
    # CSR's in-kernel loop carries both scalar arms — no raise
    spec.validate(CsrFormat.from_csr(g))


def test_persistent_gate_is_capability_keyed(graphs):
    """The rejection reads `supports_persistent`, NOT the format name:
    flipping the classvar on a throwaway CSR subclass flips the
    verdict with no name-keyed table to update."""
    from repro.api.spec import TraversalSpec
    g = graphs["rmat10"]

    class NoPersistCsr(CsrFormat):
        supports_persistent = False

    fmt = NoPersistCsr.from_csr(g)
    with pytest.raises(ValueError, match="supports_persistent"):
        TraversalSpec(pipeline="persistent").validate(fmt)
    # auto pipeline must also defensively degrade, never crash
    resolved = TraversalSpec().resolve(fmt)
    assert resolved.pipeline != "persistent"
