"""Serve-tier robustness (ISSUE 8): admission control, deadlines,
fault injection, recovery.

The chaos contract: under injected device failures, stalls and
poisoned results, the engine delivers every submitted query exactly
once, never delivers a corrupted tree, and every degraded outcome is
typed (`QueueFullError`, `AdmissionRejected`, `DeadlineExceeded`,
`TickRetriesExhausted`) and counted (``serve.retries``,
``serve.requeued``, ``serve.poisoned``, ``serve.rejected``,
``serve.deadline_exceeded``, ``serve.circuit_state``).
"""
import time

import jax
import numpy as np
import pytest

import repro.bfs as bfs
from repro.core.csr import from_edges
from repro.core.rmat import generate
from repro.core.validate import validate
from repro.errors import (AdmissionRejected, DeadlineExceeded,
                          InjectedFault, QueueFullError,
                          TickRetriesExhausted)
from repro.obs.metrics import MetricsRegistry
from repro.serve import robust
from repro.serve.graph_engine import BfsQuery, GraphEngine

CSR = from_edges(generate(jax.random.PRNGKey(3), scale=7, edgefactor=6))
V = CSR.n_vertices


def _path_csr(n=64):
    """0-1-2-...-(n-1): one layer per tick, n-1 layers from root 0 —
    the deterministic long-running query for deadline tests."""
    import jax.numpy as jnp
    from repro.core.rmat import EdgeList
    src = jnp.asarray(list(range(n - 1)) + list(range(1, n)), jnp.int32)
    dst = jnp.asarray(list(range(1, n)) + list(range(n - 1)), jnp.int32)
    return from_edges(EdgeList(src=src, dst=dst, n_vertices=n))


def _engine(**kw):
    kw.setdefault("registry", MetricsRegistry())
    kw.setdefault("batch_slots", 4)
    kw.setdefault("retry_backoff_s", 0.001)
    graph = kw.pop("graph", CSR)
    return GraphEngine(graph, **kw)


# -- robust primitives ------------------------------------------------------
def test_backoff_is_capped_exponential():
    assert robust.backoff_s(0, base=0.01, cap=1.0) == 0.01
    assert robust.backoff_s(3, base=0.01, cap=1.0) == 0.08
    assert robust.backoff_s(30, base=0.01, cap=0.25) == 0.25


def test_admission_queue_priority_then_fifo():
    q = robust.AdmissionQueue(capacity=8)
    assert not q and len(q) == 0
    q.push("a", 0)
    q.push("b", 5)
    q.push("c", 0)
    q.push("d", 5)
    assert [q.pop() for _ in range(4)] == ["b", "d", "a", "c"]


def test_admission_queue_capacity_and_force():
    q = robust.AdmissionQueue(capacity=2)
    assert q.push(1) and q.push(2)
    assert q.full
    assert not q.push(3)          # refused, not enqueued
    assert len(q) == 2
    assert q.push(4, force=True)  # recovery path bypasses the bound
    assert len(q) == 3


def test_admission_queue_remove_if():
    q = robust.AdmissionQueue(capacity=8)
    for i in range(6):
        q.push(i, priority=i % 2)
    evens = q.remove_if(lambda x: x % 2 == 0)
    assert sorted(evens) == [0, 2, 4]
    assert sorted(q.items()) == [1, 3, 5]


def test_admission_policy_validates():
    with pytest.raises(ValueError):
        robust.AdmissionPolicy(queue_capacity=0, degraded_depth=1)
    with pytest.raises(ValueError):
        robust.AdmissionPolicy(queue_capacity=4, degraded_depth=-1)


def test_injector_fires_once_per_trigger():
    inj = robust.ServeFaultInjector(fail_ticks=(2,), slow_ticks=(1,),
                                    slow_s=0.5, poison=((3, 0),))
    assert inj.faults_remaining == 3
    inj.check_tick(0)                      # not scheduled: no raise
    assert inj.stall_s(1) == 0.5
    assert inj.stall_s(1) == 0.0           # fired
    with pytest.raises(InjectedFault):
        inj.check_tick(2)
    inj.check_tick(2)                      # fired: no raise
    assert inj.poison_slots(3) == (0,)
    assert inj.poison_slots(3) == ()
    assert inj.faults_remaining == 0


# -- admission control ------------------------------------------------------
def test_bounded_queue_rejects_typed():
    reg = MetricsRegistry()
    eng = _engine(batch_slots=2, queue_capacity=3, registry=reg)
    admitted = 0
    for i in range(9):
        try:
            d = eng.submit(BfsQuery(uid=i, root=i))
            assert d.admitted
            admitted += 1
        except QueueFullError as e:
            assert isinstance(e, AdmissionRejected)
            assert e.decision is not None
            assert e.decision.circuit == robust.CIRCUIT_SHEDDING
            assert "capacity" in e.decision.reason
    assert admitted == 3
    snap = reg.snapshot()
    assert snap["counters"]["serve.rejected"] == 6
    assert snap["gauges"]["serve.circuit_state"] \
        == robust.CIRCUIT_CODES[robust.CIRCUIT_SHEDDING]
    eng.run_until_done()
    assert len(eng.finished) == 3
    assert eng.metrics.gauge("serve.circuit_state").value \
        == robust.CIRCUIT_CODES[robust.CIRCUIT_HEALTHY]


def test_priority_shedding_when_degraded():
    pol = robust.AdmissionPolicy(queue_capacity=64, degraded_depth=2,
                                 shed_min_priority=5)
    eng = _engine(batch_slots=1, admission=pol)
    # saturate: 1 slot + queue past degraded_depth
    for i in range(4):
        eng.submit(BfsQuery(uid=i, root=i))
    eng.step()   # fills the slot -> occupancy 1.0, queue depth 3
    assert eng.circuit_state() == robust.CIRCUIT_DEGRADED
    with pytest.raises(AdmissionRejected) as ei:
        eng.submit(BfsQuery(uid=90, root=1, priority=0))
    assert not isinstance(ei.value, QueueFullError)
    assert "shedding" in ei.value.decision.reason
    # important traffic still gets through
    d = eng.submit(BfsQuery(uid=91, root=2, priority=9))
    assert d.admitted
    eng.run_until_done()
    assert {q.uid for q in eng.finished} == {0, 1, 2, 3, 91}


def test_priority_order_drains_high_first():
    eng = _engine(batch_slots=1)
    eng.submit(BfsQuery(uid=0, root=0))          # fills the slot
    eng.step()
    lo = BfsQuery(uid=1, root=1, priority=0)
    hi = BfsQuery(uid=2, root=2, priority=3)
    eng.submit(lo)
    eng.submit(hi)
    eng.run_until_done()
    uids = [q.uid for q in eng.finished]
    assert uids.index(2) < uids.index(1)


# -- deadlines --------------------------------------------------------------
def test_queued_deadline_expires_without_running():
    eng = _engine(batch_slots=1)
    eng.submit(BfsQuery(uid=0, root=0))
    q = BfsQuery(uid=1, root=1, deadline_s=0.0)
    eng.submit(q)
    time.sleep(0.005)
    eng.run_until_done()
    assert q.done and q.truncated and q.parent is None
    assert isinstance(q.error, DeadlineExceeded)
    assert q.error.where == "queued"
    assert q.error.uid == 1


def test_in_flight_deadline_returns_partial():
    eng = _engine(batch_slots=1, graph=_path_csr(64),
                  spec=bfs.TraversalSpec(max_layers=200))
    # warm the jit cache first so the deadline isn't eaten by compile
    warm = BfsQuery(uid=99, root=0)
    eng.submit(warm)
    eng.run_until_done()
    q = BfsQuery(uid=0, root=0, deadline_s=0.05)
    eng.submit(q)
    eng.step()   # fills the slot, runs layer 1 (well under deadline)
    assert not q.done
    time.sleep(0.06)
    eng.step()   # deadline tripped mid-traversal
    assert q.done and q.truncated
    assert isinstance(q.error, DeadlineExceeded)
    assert q.error.where == "in_flight"
    assert q.parent is not None and int(q.parent[0]) == 0
    assert q.n_layers < 63          # genuinely partial
    assert eng.metrics.snapshot()["counters"][
        "serve.deadline_exceeded"] == 1


def test_per_query_layer_budget_overrides_spec():
    eng = _engine(batch_slots=1)
    q = BfsQuery(uid=0, root=0, max_layers=1)
    eng.submit(q)
    eng.run_until_done()
    assert q.truncated and q.n_layers == 1
    assert q.error is None       # layer truncation is budget, not error


def test_global_budget_harvests_everything():
    eng = _engine(batch_slots=2)
    qs = [BfsQuery(uid=i, root=i) for i in range(6)]
    for q in qs:
        eng.submit(q)
    eng.run_until_done(budget_s=0.0)
    assert all(q.done for q in qs)
    assert len(eng.finished) == 6
    assert not eng.queue
    for q in qs:
        assert isinstance(q.error, DeadlineExceeded)
        assert q.error.where == "global"


# -- fault injection / recovery ---------------------------------------------
def test_injected_failures_retry_and_lose_nothing():
    reg = MetricsRegistry()
    inj = robust.ServeFaultInjector(fail_ticks=(0, 2, 5))
    eng = _engine(registry=reg, injector=inj)
    qs = [BfsQuery(uid=i, root=(i * 11) % V) for i in range(10)]
    for q in qs:
        eng.submit(q)
    eng.run_until_done()
    assert len(eng.finished) == 10
    assert {q.uid for q in eng.finished} == set(range(10))
    assert inj.faults_remaining == 0
    snap = reg.snapshot()["counters"]
    assert snap["serve.retries"] == 3
    for q in qs:
        assert not q.truncated and q.error is None
        assert validate(CSR, q.parent, q.root).ok


def test_poisoned_result_never_delivered():
    reg = MetricsRegistry()
    inj = robust.ServeFaultInjector(poison=((0, 0), (1, 2)))
    eng = _engine(registry=reg, injector=inj)
    qs = [BfsQuery(uid=i, root=i) for i in range(8)]
    for q in qs:
        eng.submit(q)
    eng.run_until_done()
    assert len(eng.finished) == 8
    snap = reg.snapshot()["counters"]
    assert snap["serve.poisoned"] == 2
    assert snap["serve.requeued"] == 2
    for q in qs:
        assert validate(CSR, q.parent, q.root).ok
    poisoned = [q for q in qs if q.retries > 0]
    assert len(poisoned) == 2


def test_retry_exhaustion_requeues_then_raises_typed():
    # a listed tick fires only once (retries then succeed), so retry
    # exhaustion needs an injector that fails tick 0 unconditionally
    class AlwaysFail(robust.ServeFaultInjector):
        def check_tick(self, tick):
            if tick == 0:
                raise InjectedFault("tick 0 always fails")
    eng = _engine(injector=AlwaysFail(), max_tick_retries=2)
    qs = [BfsQuery(uid=i, root=i) for i in range(4)]
    for q in qs:
        eng.submit(q)
    with pytest.raises(TickRetriesExhausted) as ei:
        eng.step()
    assert isinstance(ei.value, RuntimeError)
    assert isinstance(ei.value.__cause__, InjectedFault)
    # nothing lost: the in-flight queries went back to the queue...
    assert len(eng.queue) == 4
    assert all(q.retries == 1 for q in qs)
    # ...and a later drain (tick 0 is past) delivers all of them
    eng.run_until_done()
    assert {q.uid for q in eng.finished} == {0, 1, 2, 3}
    for q in qs:
        assert validate(CSR, q.parent, q.root).ok


def test_slow_tick_trips_deadline():
    inj = robust.ServeFaultInjector(slow_ticks=(0,), slow_s=0.05)
    eng = _engine(batch_slots=1, graph=_path_csr(64),
                  spec=bfs.TraversalSpec(max_layers=200),
                  injector=inj)
    q = BfsQuery(uid=0, root=0, deadline_s=0.02)
    eng.submit(q)
    eng.run_until_done()
    assert q.done and q.truncated
    assert isinstance(q.error, DeadlineExceeded)
    assert q.error.where == "in_flight"


def test_nonconvergence_report_carries_slot_state():
    eng = _engine(batch_slots=2)
    eng.submit(BfsQuery(uid=0, root=0, deadline_s=120.0))
    eng.submit(BfsQuery(uid=1, root=1))
    with pytest.raises(RuntimeError) as ei:
        eng.run_until_done(max_ticks=1)
    msg = str(ei.value)
    assert "deadline_remaining_s" in msg
    assert "retries" in msg
    assert "circuit=" in msg


def test_vmem_fallback_degrade_is_observable():
    """The packed->dense planner fallback is no longer silent: it
    counts ``serve.degrade.vmem_fallback`` and lands in the degrade
    log with the budget that failed.  ``eval_shape`` exercises the
    real trace-time decision without allocating the giant arrays."""
    import jax.numpy as jnp

    from repro.core import bitmap as bm
    from repro.core import engine as core_engine
    from repro.obs.metrics import (clear_degrade_log, degrade_log,
                                   get_registry)
    clear_degrade_log()
    reg = get_registry()
    before = reg.counter("serve.degrade.vmem_fallback").value
    v_pad = 131072
    n_batch = 128   # 128 x 128Ki x 4B = 64 MiB >> the 12 MiB budget
    words = jax.ShapeDtypeStruct(
        (n_batch, v_pad // bm.BITS_PER_WORD), jnp.uint32)
    colstarts = jax.ShapeDtypeStruct((v_pad + 1,), jnp.int32)
    jax.eval_shape(
        lambda cs, aw: core_engine.plan_active_tiles_batched(
            cs, aw, v_pad, tile=1024,
            n_blocks=8, packed=True),
        colstarts, words)
    assert reg.counter("serve.degrade.vmem_fallback").value \
        == before + 1
    events = [e for e in degrade_log() if e.site == "vmem_fallback"]
    assert events, "no DegradeEvent recorded"
    assert "VMEM budget" in events[-1].reason
    assert "dense" in events[-1].fallback
    clear_degrade_log()


def test_finished_queries_are_exactly_once():
    """No duplicate delivery under mixed injection."""
    inj = robust.ServeFaultInjector(fail_ticks=(1,), poison=((0, 1),))
    eng = _engine(injector=inj)
    for i in range(12):
        eng.submit(BfsQuery(uid=i, root=(i * 5) % V))
    eng.run_until_done()
    uids = [q.uid for q in eng.finished]
    assert sorted(uids) == list(range(12))
    assert len(set(uids)) == 12
