"""Per-kernel interpret-mode validation against the pure-jnp oracles.

Each Pallas kernel is swept over shapes and compared bit-exactly with
its ref.py oracle (the tile-sequential racy contract), plus property
tests of the invariants that BFS correctness actually relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import bitmap as bm
from repro.kernels import ops, ref
from repro.kernels.frontier_expand import frontier_expand
from repro.kernels.restoration import restoration
from repro.kernels.bitmap_kernels import popcount


def random_case(seed, n_slots, v_pad, frontier_density=0.1):
    rng = np.random.default_rng(seed)
    n_vertices = v_pad - 128
    nbr = rng.integers(0, n_vertices, n_slots).astype(np.int32)
    cand = rng.integers(0, n_vertices, n_slots).astype(np.int32)
    valid = (rng.random(n_slots) < 0.9).astype(np.int32)
    w = v_pad // 32
    frontier = rng.integers(0, 2**32, w, dtype=np.uint32)
    visited = (rng.integers(0, 2**32, w, dtype=np.uint32)
               & rng.integers(0, 2**32, w, dtype=np.uint32))
    out0 = np.zeros(w, np.uint32)
    p0 = np.full(v_pad, n_vertices, np.int32)
    return (jnp.asarray(nbr), jnp.asarray(cand), jnp.asarray(valid),
            jnp.asarray(frontier), jnp.asarray(visited),
            jnp.asarray(out0), jnp.asarray(p0), n_vertices)


@pytest.mark.parametrize("n_slots,tile", [(1024, 256), (2048, 1024),
                                          (4096, 512), (512, 512)])
@pytest.mark.parametrize("check_frontier", [False, True])
def test_expand_matches_oracle(n_slots, tile, check_frontier):
    nbr, cand, valid, frontier, visited, out0, p0, nv = random_case(
        n_slots * 7 + tile, n_slots, v_pad=2048)
    out_k, p_k = frontier_expand(
        nbr, cand, valid, frontier, visited, out0, p0, n_vertices=nv,
        tile=tile, check_frontier=check_frontier, interpret=True)
    out_r, p_r = ref.frontier_expand_ref(
        nbr, cand, valid, frontier, visited, out0, p0, n_vertices=nv,
        tile=tile, check_frontier=check_frontier)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


def test_expand_invariants():
    """The guarantees restoration relies on, regardless of races:
    1. every lane that passes the mask writes P (never lost);
    2. P entries are u - |V| (negative) for discovered, untouched else;
    3. out bits ⊆ {masked candidates}; every touched word has ≥1 bit.
    """
    nbr, cand, valid, frontier, visited, out0, p0, nv = random_case(
        3, 4096, v_pad=1024)
    out_k, p_k = frontier_expand(
        nbr, cand, valid, frontier, visited, out0, p0, n_vertices=nv,
        tile=512, interpret=True)
    p_np = np.asarray(p_k)
    changed = p_np != np.asarray(p0)
    assert (p_np[changed] < 0).all()
    parents = p_np[changed] + nv
    assert ((parents >= 0) & (parents < nv)).all()
    # every set bit corresponds to a vertex with a written P
    out_dense = np.asarray(bm.unpack_bool(out_k))
    assert (~out_dense | changed[:len(out_dense)]).all()


def test_expand_vmem_budget_guard():
    big_p = jnp.zeros((8 * 1024 * 1024,), jnp.int32)  # 32 MiB P
    w = big_p.shape[0] // 32
    z32 = jnp.zeros((1024,), jnp.int32)
    with pytest.raises(ValueError, match="VMEM"):
        ops.expand(z32, z32, z32, jnp.zeros((w,), jnp.uint32),
                   jnp.zeros((w,), jnp.uint32), jnp.zeros((w,), jnp.uint32),
                   big_p, n_vertices=big_p.shape[0] - 128)


def test_ops_expand_pads_stream():
    nbr, cand, valid, frontier, visited, out0, p0, nv = random_case(
        11, 1000, v_pad=1024)  # 1000 not a tile multiple
    out_k, p_k = ops.expand(nbr, cand, valid, frontier, visited, out0,
                            p0, n_vertices=nv, tile=512, interpret=True)
    pad = jnp.zeros((24,), jnp.int32)
    out_r, p_r = ref.frontier_expand_ref(
        jnp.concatenate([nbr, pad]), jnp.concatenate([cand, pad]),
        jnp.concatenate([valid, pad]), frontier, visited, out0, p0,
        n_vertices=nv, tile=512, check_frontier=False)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))


@pytest.mark.parametrize("v_pad,tile", [(1024, 256), (4096, 4096),
                                        (8192, 2048), (2048, 32)])
def test_restoration_matches_oracle(v_pad, tile):
    rng = np.random.default_rng(v_pad + tile)
    nv = v_pad - 128
    p = np.full(v_pad, nv, np.int32)
    marked = rng.random(v_pad) < 0.2
    parents = rng.integers(0, nv, v_pad)
    p[marked] = parents[marked] - nv
    p = jnp.asarray(p)
    f_k, d_k = restoration(p, n_vertices=nv, tile=tile, interpret=True)
    f_r, d_r = ref.restoration_ref(p, n_vertices=nv)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))


def test_restoration_fixes_exactly_marked():
    nv = 896
    p = jnp.asarray([-nv, 5, -1, nv, 0] + [nv] * 1019, jnp.int32)
    f, d = ops.restore(p, n_vertices=nv, interpret=True)
    f = np.asarray(f)
    assert f[0] == 0          # parent 0 (was -nv)
    assert f[1] == 5          # untouched
    assert f[2] == nv - 1     # parent nv-1 (was -1)
    dense = np.asarray(bm.unpack_bool(d))
    assert dense[0] and dense[2] and not dense[1] and not dense[3]


@pytest.mark.parametrize("n_words", [128, 4096, 5000])
def test_popcount_matches_oracle(n_words):
    rng = np.random.default_rng(n_words)
    words = jnp.asarray(rng.integers(0, 2**32, n_words, dtype=np.uint32))
    got = int(popcount(words, interpret=True))
    want = int(ref.popcount_ref(words))
    assert got == want


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=64))
def test_property_popcount(seed, n_words):
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 2**32, n_words, dtype=np.uint32)
    got = int(popcount(jnp.asarray(arr), interpret=True))
    assert got == sum(int(x).bit_count() for x in arr)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_property_expand_plus_restore_is_exact_discovery(seed):
    """THE paper invariant: racy expand + restoration == exact set of
    newly-discoverable candidates, for any interleaving."""
    rng = np.random.default_rng(seed)
    nbr, cand, valid, frontier, visited, out0, p0, nv = random_case(
        seed, 2048, v_pad=1024)
    out_k, p_k = frontier_expand(
        nbr, cand, valid, frontier, visited, out0, p0, n_vertices=nv,
        tile=256, interpret=True)
    p_f, delta = ref.restoration_ref(p_k, n_vertices=nv)
    out_final = np.asarray(out_k | delta)

    # expected discoveries: valid lanes whose cand bit unset in visited
    vis_dense = np.asarray(bm.unpack_bool(visited))
    cand_np, valid_np = np.asarray(cand), np.asarray(valid).astype(bool)
    expect = sorted({int(v) for v, ok in zip(cand_np, valid_np)
                     if ok and not vis_dense[v]})
    got = sorted(np.nonzero(np.asarray(bm.unpack_bool(
        jnp.asarray(out_final))))[0].tolist())
    assert got == expect
    # and every discovered vertex has a valid, in-range parent
    p_np = np.asarray(p_f)
    for v in got:
        assert 0 <= p_np[v] < nv
