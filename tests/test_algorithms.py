"""Oracle tests for the semiring algorithm portfolio (ISSUE 10).

Every portfolio algorithm (sssp / cc / ksource_bfs) must match a
serial numpy oracle — Dijkstra over the synthetic hash weights,
union-find connected components, per-source BFS depths — on all four
graph families from `test_formats`, over both streamed layouts
(csr / sell), both frontier representations (packed / unpacked), and
both entry shapes (single root / root batch).  Plus: BFS itself is
bit-identical whether it runs through the classic engine or as the
(select2nd, min) instance of the semiring machinery, the plan cache
keeps one trace per (geometry, spec), the serve tier answers
shortest-path / component / k-source queries, and invalid
spec/format combinations fail with typed errors.
"""
import heapq

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.bfs as bfs
from repro.algorithms.semiring import (INT_INF, SEMIRING_ALGORITHMS,
                                       edge_weight, edge_weight_np)
from repro.api.plan import clear_cache, plan
from repro.api.spec import TraversalSpec
from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_serial import bfs_serial
from repro.core.rmat import EdgeList
from repro.formats import registry
from repro.serve.graph_engine import GraphEngine

ALGORITHMS = SEMIRING_ALGORITHMS
FORMATS = ("csr", "sell")
#: SSSP walks one delta bucket per driver iteration, so the path
#: graph needs ~max-dist/delta iterations — far past the BFS-diameter
#: default of 64; the while_loop exits early so the ceiling is free
MAX_LAYERS = 512


def _csr_from_pairs(pairs, n):
    src = jnp.asarray([a for a, b in pairs] + [b for a, b in pairs],
                      jnp.int32)
    dst = jnp.asarray([b for a, b in pairs] + [a for a, b in pairs],
                      jnp.int32)
    return csr_mod.from_edges(EdgeList(src, dst, n))


GRAPHS = {
    "rmat9": lambda: csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=9, edgefactor=16)),
    "star": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 128)], 128),
    "path": lambda: _csr_from_pairs(
        [(i, i + 1) for i in range(63)], 64),
    "disconnected": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 64)]
        + [(i, i + 1) for i in range(64, 127)], 128),
}
ROOTS = {"rmat9": 17, "star": 0, "path": 0, "disconnected": 0}
BATCH_ROOTS = {"rmat9": (17, 5, 100), "star": (0, 1, 7),
               "path": (0, 13, 63), "disconnected": (0, 64, 100)}


@pytest.fixture(scope="module")
def graphs():
    return {k: v() for k, v in GRAPHS.items()}


@pytest.fixture(scope="module")
def formats(graphs):
    return {(gname, fname): registry.get(fname).from_graph(g)
            for gname, g in graphs.items() for fname in FORMATS}


# -- serial numpy oracles ------------------------------------------------

def _adjacency(csr):
    cs = np.asarray(csr.colstarts)
    rows = np.asarray(csr.rows[: csr.n_edges])
    return [rows[cs[u]:cs[u + 1]] for u in range(csr.n_vertices)]


def dijkstra_np(csr, root):
    """float32-accumulating Dijkstra over the synthetic hash weights
    — the same dtype and per-edge sum order as the device relax, so
    distances are comparable bit-for-bit."""
    adj = _adjacency(csr)
    dist = np.full(csr.n_vertices, np.inf, np.float32)
    dist[root] = np.float32(0)
    pq = [(0.0, int(root))]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for v in adj[u]:
            nd = np.float32(dist[u]
                            + edge_weight_np(np.int32(u), np.int32(v)))
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (float(nd), int(v)))
    return dist


def components_np(csr):
    """Union-find CC: every vertex -> smallest id in its component."""
    parent = np.arange(csr.n_vertices)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    adj = _adjacency(csr)
    for u in range(csr.n_vertices):
        for v in adj[u]:
            ru, rv = find(u), find(int(v))
            if ru != rv:
                parent[max(ru, rv)] = min(ru, rv)
    return np.asarray([find(x) for x in range(csr.n_vertices)])


def depths_np(csr, root):
    """Per-source BFS depths from the serial oracle (-1 unreached)."""
    _, depth = bfs_serial(csr.rows, csr.colstarts, csr.n_vertices,
                          root)
    return depth


def _spec(algorithm, packed):
    return TraversalSpec(algorithm=algorithm, policy="topdown",
                         packed=packed, max_layers=MAX_LAYERS)


def _check_sssp_tree(csr, dist, parent, root):
    """parent is a valid shortest-path tree over the reached set."""
    adj = _adjacency(csr)
    reached = np.isfinite(dist)
    assert parent[root] == root
    for v in np.nonzero(reached)[0]:
        if v == root:
            continue
        p = parent[v]
        assert 0 <= p < csr.n_vertices and reached[p]
        assert v in adj[p]
        w = edge_weight_np(np.int32(p), np.int32(v))
        assert dist[v] == np.float32(dist[p] + w)


# -- oracle equivalence: every algorithm x family x layout x packing -----

@pytest.mark.parametrize("packed", (True, False),
                         ids=("packed", "unpacked"))
@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_sssp_matches_dijkstra(graphs, formats, graph_name, fmt_name,
                               packed):
    g = graphs[graph_name]
    fmt = formats[(graph_name, fmt_name)]
    ct = plan(fmt, _spec("sssp", packed))
    root = ROOTS[graph_name]
    oracle = dijkstra_np(g, root)

    res = ct.run(root)
    dist = np.asarray(res.values)[: g.n_vertices]
    np.testing.assert_array_equal(dist, oracle)
    _check_sssp_tree(g, dist,
                     np.asarray(res.state.parent)[: g.n_vertices],
                     root)

    resb = ct.run_batched(np.asarray(BATCH_ROOTS[graph_name]))
    for i, r in enumerate(BATCH_ROOTS[graph_name]):
        np.testing.assert_array_equal(
            np.asarray(resb.values)[i, : g.n_vertices],
            dijkstra_np(g, r))


@pytest.mark.parametrize("packed", (True, False),
                         ids=("packed", "unpacked"))
@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_cc_matches_union_find(graphs, formats, graph_name, fmt_name,
                               packed):
    g = graphs[graph_name]
    fmt = formats[(graph_name, fmt_name)]
    ct = plan(fmt, _spec("cc", packed))
    oracle = components_np(g)

    # the root seeds nothing (every vertex starts in the frontier):
    # any root gives the same fixpoint, batching just repeats it
    res = ct.run(ROOTS[graph_name])
    np.testing.assert_array_equal(
        np.asarray(res.values)[: g.n_vertices], oracle)

    resb = ct.run_batched(np.asarray(BATCH_ROOTS[graph_name]))
    for i in range(len(BATCH_ROOTS[graph_name])):
        np.testing.assert_array_equal(
            np.asarray(resb.values)[i, : g.n_vertices], oracle)


@pytest.mark.parametrize("packed", (True, False),
                         ids=("packed", "unpacked"))
@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_ksource_matches_serial_depths(graphs, formats, graph_name,
                                       fmt_name, packed):
    g = graphs[graph_name]
    fmt = formats[(graph_name, fmt_name)]
    ct = plan(fmt, _spec("ksource_bfs", packed))
    root = ROOTS[graph_name]

    res = ct.run(root)
    got = np.asarray(res.values)[: g.n_vertices]
    np.testing.assert_array_equal(np.where(got >= INT_INF, -1, got),
                                  depths_np(g, root))

    # the k-source contract: ONE traversal, a (k, V) depth matrix
    roots = np.asarray(BATCH_ROOTS[graph_name])
    resb = ct.run_batched(roots)
    depths = np.asarray(resb.values)[:, : g.n_vertices]
    assert depths.shape == (len(roots), g.n_vertices)
    for i, r in enumerate(roots):
        np.testing.assert_array_equal(
            np.where(depths[i] >= INT_INF, -1, depths[i]),
            depths_np(g, int(r)))


# -- BFS unchanged: bit-parity regression --------------------------------

@pytest.mark.parametrize("fmt_name", FORMATS)
def test_bfs_bit_parity_with_ksource_instance(graphs, formats,
                                              fmt_name):
    """BFS as the (select2nd, min) semiring instance discovers the
    exact same reach set and depths as the classic engine — and the
    classic engine's own results still validate against the serial
    oracle (the default path is untouched by the refactor)."""
    g = graphs["rmat9"]
    fmt = formats[("rmat9", fmt_name)]
    root = ROOTS["rmat9"]
    eng_res = plan(fmt, TraversalSpec(policy="topdown")).run(root)
    parent = np.asarray(eng_res.state.parent)[: g.n_vertices]
    oracle_depth = depths_np(g, root)
    assert ((parent < g.n_vertices) == (oracle_depth >= 0)).all()

    sem = plan(fmt, _spec("ksource_bfs", True)).run(root)
    got = np.asarray(sem.values)[: g.n_vertices]
    np.testing.assert_array_equal(
        np.where(got >= INT_INF, -1, got), oracle_depth)
    # identical packed visited words: same reach set, bit for bit
    np.testing.assert_array_equal(np.asarray(sem.state.visited),
                                  np.asarray(eng_res.state.visited))


def test_edge_weight_jnp_numpy_parity():
    """The device and oracle weight functions are the same hash."""
    u = jnp.arange(512, dtype=jnp.int32)
    v = jnp.arange(512, dtype=jnp.int32)[::-1]
    dev = np.asarray(edge_weight(u, v))
    host = edge_weight_np(np.arange(512, dtype=np.int32),
                          np.arange(512, dtype=np.int32)[::-1])
    np.testing.assert_array_equal(dev, host)
    assert (dev >= 1.0).all() and (dev < 2.0).all()
    # symmetric: weight(u, v) == weight(v, u)
    np.testing.assert_array_equal(dev, np.asarray(edge_weight(v, u)))


# -- plan cache: <= 1 trace per (geometry, spec) -------------------------

def test_one_trace_per_geometry_and_spec(graphs):
    clear_cache()
    fmt = registry.get("csr").from_graph(graphs["rmat9"])
    spec = _spec("sssp", True)
    ct = plan(fmt, spec)
    for r in (17, 5, 100):
        ct.run(r)
    ct.run_batched(np.asarray([17, 5, 100]))
    ct2 = plan(fmt, spec)
    ct2.run(3)
    assert ct2.executable is ct.executable
    # one trace for the exact-width batch=1 shape, one for batch=3
    assert ct.executable.traces <= 2


# -- spec/format validation ----------------------------------------------

def test_semiring_values_accepted_and_resolved(graphs):
    fmt = registry.get("csr").from_graph(graphs["path"])
    for alg in ALGORITHMS:
        resolved = _spec(alg, True).resolve(fmt)
        assert resolved.algorithm == alg
        assert resolved.pipeline == "fused_gather"
        assert resolved.prefetch_depth == 0


def test_semiring_rejects_unsupported_combos(graphs):
    fmt = registry.get("csr").from_graph(graphs["path"])
    with pytest.raises(ValueError, match="unknown algorithm"):
        TraversalSpec(algorithm="bellman_ford").validate()
    with pytest.raises(ValueError, match="fused_gather"):
        TraversalSpec(algorithm="sssp",
                      pipeline="megakernel").validate()
    with pytest.raises(ValueError, match="fused_gather"):
        TraversalSpec(algorithm="cc",
                      pipeline="persistent").validate()
    with pytest.raises(ValueError, match="prefetch"):
        TraversalSpec(algorithm="sssp", prefetch_depth=2).validate()
    bmp = registry.get("bitmap").from_graph(graphs["path"])
    with pytest.raises(ValueError, match="supported_semirings"):
        _spec("sssp", True).validate(bmp)
    with pytest.raises(NotImplementedError, match="single-layer"):
        ct = plan(fmt, _spec("sssp", True))
        st = bfs.traverse(graphs["path"], 0).state
        ct.layer_step(st)


# -- serve tier: portfolio queries ---------------------------------------

def test_graph_engine_portfolio_queries(graphs):
    g = graphs["disconnected"]
    eng = GraphEngine(g, batch_slots=2)
    dist, parent = eng.shortest_paths(0)
    oracle = dijkstra_np(g, 0)
    np.testing.assert_array_equal(dist, oracle)
    assert (parent[np.isinf(oracle)] == -1).all()

    labels, n_comp = eng.components()
    np.testing.assert_array_equal(labels, components_np(g))
    assert n_comp == 2

    depths = eng.ksource_depths([0, 64])
    np.testing.assert_array_equal(depths[0], depths_np(g, 0))
    np.testing.assert_array_equal(depths[1], depths_np(g, 64))

    with pytest.raises(ValueError, match="shortest_paths"):
        GraphEngine(g, spec=TraversalSpec(algorithm="sssp"))


def test_trace_run_semiring_span(graphs):
    from repro.obs.trace import SEMIRING_SPAN, trace_run
    fmt = registry.get("csr").from_graph(graphs["star"])
    tr = trace_run(fmt, 0, spec=_spec("sssp", True))
    names = [s.name for s in tr.tracer.spans]
    assert SEMIRING_SPAN in names
    assert len(tr.stats) == len(tr.layer_seconds) >= 1
    assert sum(s.edges_examined for s in tr.stats) > 0
