"""ISSUE 3: fused in-kernel gather pipeline vs the materialized
edge-stream oracle.

Covers the acceptance matrix:

* fused-vs-materialized oracle equivalence for every graph family x
  direction policy x format, including batched multi-root;
* the adversarial frontier shapes of the gather path: zero-frontier
  layer (drained batch slot), single-hub frontier (star center),
  frontier == V (every vertex live at once);
* work-list/offset parity: `plan_active_tiles` against a numpy
  range-cover reference, and `rowsweep_stream` (the kernel's jnp
  oracle) against `edge_stream`'s apportioned candidate set;
* the apportionment hub-overflow clamp (`truncated_edges`);
* frontier-proportionality of the analytic counters (path graph
  layers cost ~1 tile; >= 5x bytes-moved win end to end).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmap as bm
from repro.core import csr as csr_mod
from repro.core import engine, rmat
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.rmat import EdgeList
from repro.core.validate import validate
from repro.formats.base import traversal_bytes
from repro.formats.csr_format import CsrFormat
from repro.formats.sell import SellFormat
from repro.kernels import ops

POLICIES = [
    engine.TopDown(),
    engine.ThresholdSimd(0),          # SIMD forced: every layer fused
    engine.PaperLiteralLayers((1, 2)),
    engine.BeamerHybrid(),
]


def _csr_from_pairs(pairs, n):
    src = jnp.asarray([a for a, b in pairs] + [b for a, b in pairs],
                      jnp.int32)
    dst = jnp.asarray([b for a, b in pairs] + [a for a, b in pairs],
                      jnp.int32)
    return csr_mod.from_edges(EdgeList(src, dst, n))


GRAPHS = {
    "rmat10": lambda: csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=10, edgefactor=16)),
    "star": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 128)], 128),
    "path": lambda: _csr_from_pairs(
        [(i, i + 1) for i in range(95)], 96),
    "disconnected": lambda: _csr_from_pairs(
        [(0, i) for i in range(1, 64)]
        + [(i, i + 1) for i in range(64, 127)], 128),
}
ROOTS = {"rmat10": 17, "star": 0, "path": 0, "disconnected": 0}


@pytest.fixture(scope="module")
def graphs():
    return {k: v() for k, v in GRAPHS.items()}


def check_oracle(csr, parent_g500, root):
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, parent_g500, root, reference_depth=ref_depth)
    assert res.ok, res


def _reached(res, n_vertices):
    return np.asarray(res.state.parent)[..., :n_vertices] < n_vertices


# ---------------------------------------------------------------------------
# Oracle equivalence: fused vs materialized, every family x policy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", POLICIES,
                         ids=lambda p: type(p).__name__)
@pytest.mark.parametrize("graph_name", list(GRAPHS))
def test_fused_matches_materialized(graphs, graph_name, policy):
    g = graphs[graph_name]
    root = ROOTS[graph_name]
    fused = engine.traverse(g, root, policy=policy, max_layers=128,
                            pipeline="fused_gather")
    mat = engine.traverse(g, root, policy=policy, max_layers=128,
                          pipeline="materialized")
    np.testing.assert_array_equal(_reached(fused, g.n_vertices),
                                  _reached(mat, g.n_vertices))
    assert int(fused.state.layer) == int(mat.state.layer)
    check_oracle(g, np.asarray(parents_graph500(fused.state,
                                                g.n_vertices)), root)


@pytest.mark.parametrize("fmt_name", ["csr", "sell", "bitmap"])
@pytest.mark.parametrize("policy", POLICIES[:2],
                         ids=lambda p: type(p).__name__)
def test_every_format_fused_oracle(graphs, fmt_name, policy):
    from repro.formats import build
    g = graphs["rmat10"]
    fmt = build(g, fmt_name)
    res = engine.traverse(fmt, 17, policy=policy,
                          pipeline="fused_gather")
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)), 17)


@pytest.mark.parametrize("fmt_name", ["csr", "sell"])
def test_batched_multiroot_fused_matches_materialized(graphs, fmt_name):
    from repro.formats import build
    g = graphs["disconnected"]
    fmt = build(g, fmt_name)
    # both components + an isolated-ish tail: slot 64's search dies at
    # a different layer than slot 0's, exercising n_active == 0 rows
    roots = [0, 64, 1, 127]
    fused = engine.traverse(fmt, roots, policy=engine.ThresholdSimd(0),
                            pipeline="fused_gather")
    mat = engine.traverse(fmt, roots, policy=engine.ThresholdSimd(0),
                          pipeline="materialized")
    np.testing.assert_array_equal(_reached(fused, g.n_vertices),
                                  _reached(mat, g.n_vertices))
    for b, root in enumerate(roots):
        st = engine.BfsState(fused.state.frontier[b],
                             fused.state.visited[b],
                             fused.state.parent[b], fused.state.layer)
        check_oracle(g, np.asarray(parents_graph500(st, g.n_vertices)),
                     root)


# ---------------------------------------------------------------------------
# Adversarial frontier shapes at the kernel/planner level
# ---------------------------------------------------------------------------

def _fused_one_layer(g, frontier, visited, parent, bottom_up=False):
    fmt = CsrFormat.from_csr(g)
    tile = fmt.resolve_tile(None)
    steps = fmt.make_steps(algorithm="simd", tile=tile,
                           pipeline="fused_gather")
    mode = engine.MODE_BOTTOMUP if bottom_up else engine.MODE_SIMD
    out, vis, par, aux = steps[mode](frontier[None], visited[None],
                                     parent[None])
    return out[0], vis[0], par[0], aux


def test_zero_frontier_layer_is_noop(graphs):
    """An empty frontier plans zero active tiles and changes nothing."""
    g = graphs["rmat10"]
    v_pad = g.n_vertices_padded
    frontier = bm.zeros(v_pad)
    visited = csr_mod.init_visited(g)
    parent = jnp.full((v_pad,), g.n_vertices, jnp.int32)
    out, vis, par, aux = _fused_one_layer(g, frontier, visited, parent)
    assert int(aux.tiles) == 0
    assert not np.asarray(out).any()
    np.testing.assert_array_equal(np.asarray(vis), np.asarray(visited))
    np.testing.assert_array_equal(np.asarray(par), np.asarray(parent))


def test_single_hub_frontier_discovers_all_leaves(graphs):
    """Star center: one frontier vertex owns every edge — the layer
    must discover all leaves and cost only the hub's blocks."""
    g = graphs["star"]
    v_pad = g.n_vertices_padded
    frontier = bm.set_bits_exact(bm.zeros(v_pad),
                                 jnp.asarray([0], jnp.int32))
    visited = bm.set_bits_exact(csr_mod.init_visited(g),
                                jnp.asarray([0], jnp.int32))
    parent = jnp.full((v_pad,), g.n_vertices, jnp.int32).at[0].set(0)
    out, vis, par, aux = _fused_one_layer(g, frontier, visited, parent)
    discovered = np.asarray(bm.unpack_bool(out))[:g.n_vertices]
    assert discovered[1:].all() and not discovered[0]
    # the hub's adjacency is contiguous: its block span bounds tiles
    fmt = CsrFormat.from_csr(g)
    tile = fmt.resolve_tile(None)
    assert int(aux.tiles) <= -(-int(g.out_degree(0)) // tile) + 1


def test_full_frontier_layer(graphs):
    """frontier == V: every block is active, every unvisited neighbor
    of anyone is discovered (here: none — all visited)."""
    g = graphs["rmat10"]
    v_pad = g.n_vertices_padded
    all_v = jnp.arange(g.n_vertices, dtype=jnp.int32)
    frontier = bm.set_bits_exact(bm.zeros(v_pad), all_v)
    visited = bm.set_bits_exact(csr_mod.init_visited(g), all_v)
    parent = jnp.full((v_pad,), g.n_vertices, jnp.int32)
    out, vis, par, aux = _fused_one_layer(g, frontier, visited, parent)
    assert not np.asarray(out).any()      # nothing left to discover
    # every non-empty adjacency block is scheduled
    fmt = CsrFormat.from_csr(g)
    tile = fmt.resolve_tile(None)
    n_blocks = -(-g.n_edges_padded // tile)
    wl, na = engine.plan_active_tiles(g.colstarts, frontier,
                                      g.n_vertices, tile, n_blocks)
    assert int(na) == -(-g.n_edges // tile)


# ---------------------------------------------------------------------------
# Work-list / offset parity against numpy references
# ---------------------------------------------------------------------------

def test_plan_active_tiles_matches_numpy_reference(graphs):
    g = graphs["rmat10"]
    tile = 128
    n_blocks = -(-g.n_edges_padded // tile)
    rng = np.random.default_rng(0)
    members = rng.choice(g.n_vertices, size=37, replace=False)
    frontier = bm.set_bits_exact(bm.zeros(g.n_vertices_padded),
                                 jnp.asarray(members, jnp.int32))
    wl, na = engine.plan_active_tiles(g.colstarts, frontier,
                                      g.n_vertices, tile, n_blocks)
    cs = np.asarray(g.colstarts)
    want = set()
    for u in members:
        if cs[u + 1] > cs[u]:
            want.update(range(cs[u] // tile,
                              (cs[u + 1] - 1) // tile + 1))
    assert int(na) == len(want)
    np.testing.assert_array_equal(np.sort(np.asarray(wl)[:int(na)]),
                                  np.sort(np.fromiter(want, np.int64)))
    if len(want):  # clamped tail repeats the last active block
        assert (np.asarray(wl)[int(na):] ==
                np.asarray(wl)[int(na) - 1]).all()


def test_rowsweep_stream_matches_edge_stream_candidates(graphs):
    """The fused gather's jnp oracle delivers exactly the apportioned
    stream's (u -> v) candidate multiset, reordered."""
    g = graphs["rmat10"]
    rng = np.random.default_rng(1)
    members = rng.choice(g.n_vertices, size=29, replace=False)
    frontier = bm.set_bits_exact(bm.zeros(g.n_vertices_padded),
                                 jnp.asarray(members, jnp.int32))
    u1, v1, valid1, trunc = engine.edge_stream(
        g.colstarts, g.rows, frontier, g.n_vertices_padded,
        g.n_vertices, g.n_edges_padded)
    u2, v2, valid2 = engine.rowsweep_stream(g.colstarts, g.rows,
                                            frontier, g.n_vertices)
    assert int(trunc) == 0
    pairs1 = sorted(zip(np.asarray(u1)[np.asarray(valid1)].tolist(),
                        np.asarray(v1)[np.asarray(valid1)].tolist()))
    pairs2 = sorted(zip(np.asarray(u2)[np.asarray(valid2)].tolist(),
                        np.asarray(v2)[np.asarray(valid2)].tolist()))
    assert pairs1 == pairs2


def test_gather_kernel_matches_rowsweep_oracle(graphs):
    """In-kernel gather (binary-searched owners, block schedule) ==
    the jnp rowsweep + shared expand body, exactly."""
    g = graphs["rmat10"]
    v_pad = g.n_vertices_padded
    tile = 128
    rows_t = jnp.concatenate(
        [g.rows, jnp.full(((-g.n_edges_padded) % tile,), g.n_vertices,
                          jnp.int32)]) \
        if g.n_edges_padded % tile else g.rows
    n_blocks = rows_t.shape[0] // tile
    rng = np.random.default_rng(2)
    members = rng.choice(g.n_vertices, size=61, replace=False)
    frontier = bm.set_bits_exact(bm.zeros(v_pad),
                                 jnp.asarray(members, jnp.int32))
    visited = bm.set_bits_exact(csr_mod.init_visited(g),
                                jnp.asarray(members, jnp.int32))
    parent = jnp.full((v_pad,), g.n_vertices, jnp.int32)
    wl, na = engine.plan_active_tiles(g.colstarts, frontier,
                                      g.n_vertices, tile, n_blocks)
    out_k, p_k = ops.gather_expand(
        wl, na, rows_t, g.colstarts, frontier, visited,
        bm.zeros(v_pad), parent, n_vertices=g.n_vertices, tile=tile)
    u, v, valid = engine.rowsweep_stream(g.colstarts, g.rows, frontier,
                                         g.n_vertices)
    out_r, vis_r, p_r = engine.expand_candidates(
        u, v, valid, frontier, visited, parent, g.n_vertices, "simd")
    # the jnp body applies restoration; apply it to the kernel's racy
    # output to compare final states
    p_fixed, delta = ops.restore(p_k, n_vertices=g.n_vertices)
    np.testing.assert_array_equal(np.asarray(out_k | delta),
                                  np.asarray(out_r))
    # parents: the discovered SET must agree exactly; the winning
    # parent of a multiply-discovered vertex is a benign race (tile
    # order vs scatter order), so check validity instead of identity
    pk, pr = np.asarray(p_fixed), np.asarray(p_r)
    np.testing.assert_array_equal(pk < g.n_vertices, pr < g.n_vertices)
    rows_np, cs = np.asarray(g.rows), np.asarray(g.colstarts)
    in_front = np.zeros(g.n_vertices_padded, bool)
    in_front[members] = True
    for vtx in np.nonzero((pk < g.n_vertices) & (pk >= 0))[0]:
        par = pk[vtx]
        if vtx in members:
            continue                      # pre-set, not this layer
        assert in_front[par]
        assert vtx in rows_np[cs[par]:cs[par + 1]]


# ---------------------------------------------------------------------------
# Hub-overflow truncation clamp
# ---------------------------------------------------------------------------

def test_apportion_hub_overflow_truncates_deterministically(graphs):
    g = graphs["star"]            # hub 0 has degree 127
    hub_deg = int(g.out_degree(0))
    n_slots = 64                  # smaller than the hub's adjacency
    flist = jnp.asarray([0] + [g.n_vertices] * 7, jnp.int32)
    u, v, valid, trunc = engine.apportion(g.colstarts, g.rows, flist,
                                          g.n_vertices, n_slots)
    assert int(trunc) == hub_deg - n_slots
    assert int(np.asarray(valid).sum()) == n_slots
    # deterministic clamp: the kept prefix is exactly the hub's first
    # n_slots neighbors, twice in a row
    np.testing.assert_array_equal(np.asarray(u), np.zeros(n_slots))
    np.testing.assert_array_equal(
        np.asarray(v), np.asarray(g.rows)[:n_slots])
    u2, v2, valid2, trunc2 = engine.apportion(
        g.colstarts, g.rows, flist, g.n_vertices, n_slots)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v2))
    assert int(trunc2) == int(trunc)


def test_no_truncation_at_full_width(graphs):
    g = graphs["rmat10"]
    res = engine.traverse(g, 17, policy=engine.TopDown())
    assert all(s.truncated_edges == 0 for s in engine.layer_stats(res))


# ---------------------------------------------------------------------------
# Frontier-proportional accounting (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_path_graph_layers_cost_one_tile():
    from benchmarks.bfs_layers import build_path_graph
    g = build_path_graph(1024)
    res = engine.traverse(g, 0, policy=engine.ThresholdSimd(0),
                          tile=128, max_layers=1100,
                          pipeline="fused_gather")
    stats = engine.layer_stats(res)
    assert len(stats) == 1024
    # one chain vertex per layer: its <=2 edges span at most 2 blocks
    assert max(s.active_tiles for s in stats) <= 2
    assert sum(s.active_tiles for s in stats) <= 2 * len(stats)


def test_high_diameter_bytes_drop_5x():
    """The hard acceptance number: analytic bytes-moved for a s>=10
    path traversal drops >= 5x fused vs materialized."""
    from benchmarks.bfs_layers import path_probe
    probe = path_probe(quiet=True)
    assert probe["ratio"] >= 5.0, probe


def test_fused_tiles_track_frontier_edges(graphs):
    """Within one traversal, layers examining fewer edges schedule
    fewer tiles (monotone up to block granularity)."""
    g = graphs["rmat10"]
    fmt = CsrFormat.from_csr(g)
    tile = fmt.resolve_tile(None)
    res = engine.traverse(g, 17, policy=engine.ThresholdSimd(0),
                          pipeline="fused_gather")
    stats = engine.layer_stats(res)
    n_blocks = -(-g.n_edges_padded // tile)
    for s in stats:
        assert s.active_tiles <= n_blocks
        # a vertex's adjacency range spans ceil(deg/tile) blocks plus
        # at most one straddle, so the schedule is bounded by the
        # layer's edges/tile plus two blocks per frontier vertex
        bound = min(n_blocks,
                    2 * s.frontier_vertices
                    + -(-s.edges_examined // tile))
        assert s.active_tiles <= bound


def test_sell_active_slabs_subset_of_full_sweep(graphs):
    g = graphs["rmat10"]
    fmt = SellFormat.from_csr(g)
    tile = fmt.resolve_tile(None)
    n_steps = -(-fmt.n_slabs // tile)
    res = engine.traverse(fmt, 17, policy=engine.ThresholdSimd(0),
                          pipeline="fused_gather")
    stats = engine.layer_stats(res)
    assert all(s.active_tiles <= n_steps for s in stats)
    assert stats[0].active_tiles < n_steps   # root layer is thin


def test_traversal_bytes_accounting(graphs):
    g = graphs["path"]
    fmt = CsrFormat.from_csr(g)
    tile = fmt.resolve_tile(None)
    res = engine.traverse(g, 0, policy=engine.ThresholdSimd(0),
                          max_layers=128)
    stats = engine.layer_stats(res)
    fused = traversal_bytes(fmt, stats, tile=tile,
                            pipeline="fused_gather")
    mat = traversal_bytes(fmt, stats, tile=tile,
                          pipeline="materialized")
    assert mat == fmt.layer_bytes() * len(stats)
    assert fused < mat
