"""Tests for the Graph500 64-root TEPS harness (§5.3)."""
import jax
import pytest

from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_parallel import run_bfs
from repro.core.stats import run_harness


@pytest.fixture(scope="module")
def g10():
    return csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(1), scale=10, edgefactor=16))


def test_harness_runs_and_validates(g10):
    res = run_harness(
        g10, lambda c, r: run_bfs(c, r, algorithm="simd"),
        jax.random.PRNGKey(0), n_roots=8, validate_runs=True)
    assert len(res.runs) == 8
    assert all(r.valid for r in res.runs)
    assert res.hmean_teps > 0
    assert res.max_teps >= res.hmean_teps
    assert "hmean_teps" in res.summary()


def test_hmean_is_harmonic(g10):
    res = run_harness(g10, lambda c, r: run_bfs(c, r),
                      jax.random.PRNGKey(2), n_roots=4)
    ts = [r.teps for r in res.runs if r.teps > 0]
    assert abs(res.hmean_teps - len(ts) / sum(1 / t for t in ts)) < 1e-6
