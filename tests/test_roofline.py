"""HLO analyzer: trip-count loops, dot flops, collective wire bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_analyze import (Analyzer, analyze,
                                        parse_computations, shape_bytes)
from repro.roofline.analysis import Roofline, model_flops_for


def compile_hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 512), jnp.float32)
    hlo = compile_hlo(lambda x, y: x @ y, a, b)
    c = analyze(hlo)
    assert c.flops == 2 * 128 * 256 * 512


def test_scan_multiplies_by_trip_count():
    a = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(h, _):
            return h @ a, None
        h, _ = jax.lax.scan(body, x, None, length=17)
        return h

    hlo = compile_hlo(f, jnp.ones((64, 64)))
    c = analyze(hlo)
    assert c.flops == 17 * 2 * 64 * 64 * 64, c.flops
    assert c.unresolved_whiles == 0


def test_nested_scan_trips_compound():
    a = jnp.zeros((32, 32), jnp.float32)

    def f(x):
        def inner(h, _):
            return h @ a, None

        def outer(h, _):
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=5)
        return h

    hlo = compile_hlo(f, jnp.ones((32, 32)))
    c = analyze(hlo)
    assert c.flops == 5 * 3 * 2 * 32 ** 3, c.flops


def test_bytes_reasonable_for_elementwise():
    x = jnp.ones((1024, 1024), jnp.float32)  # 4 MB
    hlo = compile_hlo(lambda x: x * 2 + 1, x)
    c = analyze(hlo)
    # read 4 MB + write 4 MB, fused: allow up to 3x for convert noise
    assert 8e6 <= c.bytes < 2.5e7, c.bytes


def test_shape_bytes_tuple():
    assert shape_bytes("(f32[10,10], bf16[4])") == 400 + 8
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("f32[]") == 4  # scalar


SYNTH = """
HloModule synth

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups=[8,16]<=[128], to_apply=%sum
  %ag = f32[2048]{0} all-gather(%ar), replica_groups={{0,1},{2,3}}, dimensions={0}
  ROOT %cp = f32[1024]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""


def test_synthetic_collectives():
    c = analyze(SYNTH, default_group=128)
    assert c.coll_ops == {"all-reduce": 1, "all-gather": 1,
                          "collective-permute": 1}
    ar_wire = 4096 * 2 * 15 / 16          # ring, group 16
    ag_wire = 8192 * 1 / 2                # group 2 (explicit groups)
    cp_wire = 4096
    assert abs(c.wire_bytes - (ar_wire + ag_wire + cp_wire)) < 1e-6
    assert c.coll_payload == 4096 + 8192 + 4096


def test_end_to_end_flops_vs_analytic():
    """Tiny LM train step: analyzer flops within [1x, 3.5x] of 6ND
    (attention + remat overhead land above 1x; 3.5x is generous)."""
    from repro.configs import registry
    from repro.models import lm
    from repro.train.train_step import TrainConfig, make_train_step
    from repro.train import optimizer as opt
    from repro.models.config import param_count

    cfg = registry.get("phi3", reduced=True).with_(
        dtype="float32", n_layers=2, vocab_size=512)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, t = 2, 64
    batch = {"tokens": jnp.ones((b, t), jnp.int32),
             "labels": jnp.ones((b, t), jnp.int32)}
    step = make_train_step(cfg, TrainConfig())
    hlo = jax.jit(step).lower(params, opt.init(params),
                              batch).compile().as_text()
    c = analyze(hlo)
    n_embed = cfg.vocab_size * cfg.d_model * 2
    expect = model_flops_for("train", param_count(cfg), b * t, n_embed)
    assert expect <= c.flops <= 3.5 * expect, (c.flops, expect)


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, bytes_accessed=819e9 * 2,
                 wire_bytes=50e9 * 0.5, n_chips=1,
                 model_flops=100e12)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 2.0) < 1e-9
    assert abs(r.t_collective - 0.5) < 1e-9
    assert r.bottleneck == "memory"
    assert abs(r.mfu_bound - 100e12 / (197e12 * 2.0)) < 1e-9
    assert abs(r.useful_flops_ratio - 100 / 197) < 1e-3
