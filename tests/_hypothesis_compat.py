"""``hypothesis`` with a tiny deterministic fallback sampler.

The property tests only use ``@settings(max_examples=..., deadline=None)``,
``@given(...)`` and the ``st.integers`` / ``st.lists`` strategies.  When
the real ``hypothesis`` package is installed (see requirements-dev.txt)
it is re-exported unchanged; otherwise this module provides a minimal
drop-in that draws ``max_examples`` pseudo-random cases from a fixed
per-test seed — no shrinking, but the same invariants get exercised and
failures are reproducible.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    class _Integers:
        def __init__(self, min_value, max_value):
            self.min_value, self.max_value = min_value, max_value

        def sample(self, rng):
            return rng.randint(self.min_value, self.max_value)

    class _Lists:
        def __init__(self, elements, min_size, max_size):
            self.elements = elements
            self.min_size, self.max_size = min_size, max_size

        def sample(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            return [self.elements.sample(rng) for _ in range(n)]

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Lists(elements, min_size, max_size)

    st = _Strategies()

    def given(*strats):
        def deco(fn):
            # NB: deliberately no functools.wraps — pytest must see a
            # zero-argument signature, not the strategy parameters
            # (it would try to resolve them as fixtures).
            def wrapper():
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(getattr(wrapper, "_max_examples", 20)):
                    drawn = [s.sample(rng) for s in strats]
                    fn(*drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
