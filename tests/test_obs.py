"""Unit tests for the obs subsystem (PR 7): span tracer, metrics
registry, instrumented trace_run, and the cost-drift model probe."""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.bfs as bfs
from repro.core import csr as csr_mod
from repro.core import rmat
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       SpanTracer, drift_rows, get_registry,
                       measure_drift, trace_run)
from repro.obs.cost_drift import analytic_layer_bytes
from repro.obs.trace import (LAYER_SPAN, STEP_SPAN, TRAVERSAL_SPAN,
                             xla_profiler)


@pytest.fixture(scope="module")
def g8():
    return csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(7), scale=8, edgefactor=8))


# -- SpanTracer -----------------------------------------------------------

def test_span_nesting_and_order():
    tr = SpanTracer()
    with tr.span("outer", kind="o") as o:
        with tr.span("inner"):
            pass
        o.args["amended"] = 1
    assert len(tr) == 2
    inner, outer = tr.spans            # closed innermost-first
    assert inner.name == "inner" and outer.name == "outer"
    # containment: inner lives inside outer's [ts, ts+dur] window
    assert outer.ts_us <= inner.ts_us
    assert inner.ts_us + inner.dur_us <= outer.ts_us + outer.dur_us + 1
    assert outer.args == {"kind": "o", "amended": 1}


def test_chrome_export_parses(tmp_path):
    tr = SpanTracer()
    with tr.span("a"):
        pass
    path = tr.export(str(tmp_path / "t.json"))
    doc = json.loads(open(path).read())
    assert doc["displayTimeUnit"] == "ms"
    meta, ev = doc["traceEvents"]
    assert meta["ph"] == "M" and meta["args"]["name"] == "repro.bfs"
    assert ev == {"name": "a", "cat": "bfs", "ph": "X",
                  "ts": ev["ts"], "dur": ev["dur"],
                  "pid": meta["pid"], "tid": 1, "args": {}}


def test_device_sync_modes():
    x = jnp.ones(4)
    SpanTracer(sync=True).device_sync(x)      # blocks, no error
    SpanTracer(sync=False).device_sync(x)     # no-op


def test_xla_profiler_noop_without_logdir():
    with xla_profiler(None) as ld:
        assert ld is None


# -- metrics --------------------------------------------------------------

def test_counter_monotonic():
    c = Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_updown():
    g = Gauge("g")
    g.set(5)
    g.dec(2)
    g.inc(0.5)
    assert g.value == 3.5


def test_histogram_exact_and_quantiles():
    h = Histogram("h")
    assert math.isnan(h.percentile(0.5))
    for v in [5, 1, 3, 2, 4]:
        h.observe(v)
    assert (h.count, h.sum, h.min, h.max) == (5, 15.0, 1.0, 5.0)
    assert h.percentile(0.5) == 3.0          # nearest-rank median
    assert h.percentile(0.99) == 5.0
    s = h.summary()
    assert s["count"] == 5 and s["p50"] == 3.0 and s["p99"] == 5.0


def test_histogram_reservoir_slides_but_count_exact():
    h = Histogram("h", reservoir=4)
    for v in range(10):
        h.observe(v)
    assert h.count == 10 and h.min == 0.0 and h.max == 9.0
    assert h.percentile(0.5) >= 6            # window holds 6..9 only


def test_histogram_timer():
    h = Histogram("h")
    with h.time():
        pass
    assert h.count == 1 and h.sum >= 0


def test_registry_get_or_create_and_conflict():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    assert "x" in reg and "y" not in reg
    reg.clear()
    assert "x" not in reg


def test_snapshot_roundtrip_and_prometheus():
    reg = MetricsRegistry()
    reg.counter("a.b").inc(2)
    reg.gauge("c-d").set(1.5)
    reg.histogram("lat").observe(0.25)
    snap = reg.snapshot()
    assert snap == json.loads(json.dumps(snap))
    assert snap["counters"]["a.b"] == 2.0
    assert snap["histograms"]["lat"]["p50"] == 0.25
    prom = reg.to_prometheus()
    assert "# TYPE a_b counter" in prom and "a_b 2" in prom
    assert "c_d 1.5" in prom
    assert 'lat{quantile="0.5"} 0.25' in prom
    assert "lat_count 1" in prom


def test_empty_histogram_snapshot_is_json_safe():
    reg = MetricsRegistry()
    reg.histogram("never")
    snap = reg.snapshot()                    # inf min/max must not leak
    assert snap["histograms"]["never"]["min"] is None
    assert snap["histograms"]["never"]["p99"] is None


def test_default_registry_is_shared():
    assert get_registry() is get_registry()


# -- trace_run ------------------------------------------------------------

def test_trace_run_matches_fused_engine(g8):
    from repro.core.validate import validate

    ct = bfs.plan(g8)
    tr = trace_run(g8, 3)
    ref = ct.run(3)
    assert int(tr.depths) == int(ref.depths)
    # parent ties may break differently between the fused program and
    # the layer tick; both must be valid BFS trees over the same set
    assert np.array_equal(np.asarray(tr.state.visited),
                          np.asarray(ref.state.visited))
    p = bfs.parents_graph500(tr.state, g8.n_vertices)
    assert validate(g8, p, 3).ok
    fused = ct.stats(ref)
    assert len(tr.stats) == len(fused)
    for a, b in zip(tr.stats, fused):
        assert (a.frontier_vertices, a.edges_examined, a.discovered) \
            == (b.frontier_vertices, b.edges_examined, b.discovered)


def test_trace_run_span_contract(g8):
    tr = trace_run(g8, [0, 5])
    names = [s.name for s in tr.tracer.spans]
    assert names.count(TRAVERSAL_SPAN) == 1
    assert names.count(LAYER_SPAN) == len(tr.stats)
    assert names.count(STEP_SPAN) == len(tr.stats)
    assert len(tr.layer_seconds) == len(tr.stats)
    assert all(s >= 0 for s in tr.layer_seconds)
    assert tr.depths.shape == (2,)
    top = [s for s in tr.tracer.spans if s.name == TRAVERSAL_SPAN][0]
    assert top.args["n_roots"] == 2
    assert top.args["n_layers"] == len(tr.stats)


def test_trace_run_reuses_plan_and_tracer(g8):
    ct = bfs.plan(g8)
    tracer = SpanTracer()
    tr = ct.trace_run(0, tracer=tracer)
    assert tr.tracer is tracer and len(tracer) > 0


# -- cost drift -----------------------------------------------------------

def test_analytic_layer_bytes_positive(g8):
    from repro.formats import build
    fmt = build(g8, "csr")
    full = analytic_layer_bytes(fmt, pipeline="materialized", tile=None)
    fused = analytic_layer_bytes(fmt, pipeline="fused_gather", tile=256)
    assert full > 0 and fused > 0


def test_measure_drift_and_rows(g8):
    (d,) = measure_drift(g8, pipelines=("fused_gather",))
    assert d.format == "csr" and d.pipeline == "fused_gather"
    assert d.analytic_bytes > 0 and d.compiled_bytes > 0
    assert d.ratio == d.compiled_bytes / d.analytic_bytes
    assert d.hlo_bytes > 0 and d.hlo_ratio > 0
    rows = drift_rows([d])
    assert list(rows) == ["obs.cost_drift.csr.fused_gather"]
    row = rows["obs.cost_drift.csr.fused_gather"]
    assert row["ratio"] == pytest.approx(d.ratio)
    assert row["analytic_bytes"] == d.analytic_bytes
