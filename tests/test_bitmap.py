"""Unit + property tests for the bitmap substrate (§3.3.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import bitmap as bm


def test_num_words():
    assert bm.num_words(1) == 1
    assert bm.num_words(32) == 1
    assert bm.num_words(33) == 2
    assert bm.num_words(1_048_576) == 32_768  # paper's §3.3.1 example


def test_paper_compression_example():
    # §3.3.1: 1,048,576 vertices -> 131,072 bytes as a bitmap
    assert bm.num_words(1_048_576) * 4 == 131_072


def test_word_and_bit():
    w, b = bm.word_and_bit(jnp.asarray([0, 31, 32, 28, 30, 95]))
    np.testing.assert_array_equal(np.asarray(w), [0, 0, 1, 0, 0, 2])
    np.testing.assert_array_equal(np.asarray(b), [0, 31, 0, 28, 30, 31])


def test_fig5_example():
    """Paper Fig. 5: vertices 28 and 30 land in word 0."""
    bitmap = bm.set_bits_exact(bm.zeros(128), jnp.asarray([28, 30]))
    assert int(bitmap[0]) == (1 << 28) | (1 << 30)
    assert int(bm.popcount(bitmap)) == 2


def test_set_test_roundtrip():
    vs = jnp.asarray([0, 5, 9, 63, 64, 127])
    bitmap = bm.set_bits_exact(bm.zeros(128), vs)
    assert bool(bm.test_bits(bitmap, vs).all())
    others = jnp.asarray([1, 4, 62, 65, 126])
    assert not bool(bm.test_bits(bitmap, others).any())


def test_set_bits_exact_handles_duplicates():
    vs = jnp.asarray([5, 5, 5, 9])
    bitmap = bm.set_bits_exact(bm.zeros(32), vs)
    assert int(bitmap[0]) == (1 << 5) | (1 << 9)


def test_set_bits_racy_same_word_race():
    """Fig. 6: two lanes updating word 0 -> one bit may be lost."""
    vs = jnp.asarray([5, 9])  # same word
    bitmap = bm.set_bits_racy(bm.zeros(32), vs)
    val = int(bitmap[0])
    # exactly the corrupted-word model: at least one bit lands,
    # and nothing outside the two bits is set
    assert val != 0
    assert val | ((1 << 5) | (1 << 9)) == (1 << 5) | (1 << 9)


def test_set_bits_racy_distinct_words_exact():
    vs = jnp.asarray([5, 37, 69])  # all different words
    bitmap = bm.set_bits_racy(bm.zeros(128), vs)
    assert bool(bm.test_bits(bitmap, vs).all())
    assert int(bm.popcount(bitmap)) == 3


def test_valid_mask_drops_lanes():
    vs = jnp.asarray([3, 7, 11])
    valid = jnp.asarray([True, False, True])
    bitmap = bm.set_bits_exact(bm.zeros(32), vs, valid)
    assert int(bitmap[0]) == (1 << 3) | (1 << 11)
    # racy variant: use distinct words so no race masks the check
    vs2 = jnp.asarray([3, 39, 75])
    bitmap_r = bm.set_bits_racy(bm.zeros(128), vs2, valid)
    assert int(bitmap_r[0]) == (1 << 3)
    assert int(bitmap_r[1]) == 0          # masked lane dropped
    assert int(bitmap_r[2]) == (1 << 11)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.random(256) < 0.3)
    assert bool((bm.unpack_bool(bm.pack_bool(dense)) == dense).all())


def test_compact():
    vs = jnp.asarray([3, 64, 100])
    bitmap = bm.set_bits_exact(bm.zeros(128), vs)
    out = bm.compact(bitmap, size=8, fill_value=128)
    np.testing.assert_array_equal(np.asarray(out),
                                  [3, 64, 100, 128, 128, 128, 128, 128])


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=511), min_size=0,
                max_size=64))
def test_property_exact_set_matches_python_set(vertices):
    """set_bits_exact == the mathematical set union, always."""
    bitmap = bm.set_bits_exact(bm.zeros(512),
                               jnp.asarray(vertices, jnp.int32)
                               if vertices else jnp.zeros((0,), jnp.int32))
    want = set(vertices)
    got = {i for i in range(512)
           if bool(bm.test_bits(bitmap, jnp.asarray([i]))[0])}
    assert got == want
    assert int(bm.popcount(bitmap)) == len(want)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=511), min_size=1,
                max_size=64))
def test_property_racy_is_subset_superset_bounds(vertices):
    """Racy scatter: result ⊆ requested set, ≥1 bit per touched word."""
    vs = jnp.asarray(vertices, jnp.int32)
    bitmap = bm.set_bits_racy(bm.zeros(512), vs)
    want = set(vertices)
    got = {i for i in range(512)
           if bool(bm.test_bits(bitmap, jnp.asarray([i]))[0])}
    assert got <= want                        # never invents bits
    touched_words = {v // 32 for v in want}
    got_words = {v // 32 for v in got}
    assert got_words == touched_words        # every word got >=1 lane


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=500))
def test_property_compact_inverts_set(n):
    rng = np.random.default_rng(n)
    vs = np.unique(rng.integers(0, 512, size=n)).astype(np.int32)
    bitmap = bm.set_bits_exact(bm.zeros(512), jnp.asarray(vs))
    out = np.asarray(bm.compact(bitmap, size=512, fill_value=512))
    np.testing.assert_array_equal(out[:len(vs)], np.sort(vs))
    assert (out[len(vs):] == 512).all()
