"""Public-API snapshot (ISSUE 5 CI gate).

``repro.bfs.__all__`` and the `TraversalSpec` field set ARE the
public contract — accidental additions, removals or renames must fail
CI, not ship silently.  Deliberate surface changes update the frozen
snapshots below (and the README migration table) in the same PR.
"""
import dataclasses

import repro.bfs as bfs

# frozen snapshot: the repro.bfs surface
EXPECTED_ALL = (
    "BeamerHybrid",
    "BfsState",
    "CompiledTraversal",
    "EngineResult",
    "LayerStats",
    "POLICIES",
    "PaperLiteralLayers",
    "SpanTracer",
    "ThresholdSimd",
    "TopDown",
    "TraceRun",
    "TraversalSpec",
    "clear_plan_cache",
    "direction_log",
    "layer_stats",
    "parents_graph500",
    "plan",
    "plan_cache_info",
    "trace_run",
    "traverse",
)

# frozen snapshot: the one declarative config object's fields, in
# declaration order (order matters: it is the positional-construction
# and to_dict contract)
EXPECTED_SPEC_FIELDS = (
    "policy",
    "algorithm",
    "pipeline",
    "packed",
    "tile",
    "prefetch_depth",
    "max_layers",
    "merge",
)


def test_bfs_all_is_frozen():
    assert tuple(sorted(bfs.__all__)) == EXPECTED_ALL, (
        "repro.bfs.__all__ changed; if deliberate, update "
        "tests/test_api_surface.py and the README migration table")


def test_bfs_all_names_resolve():
    for name in bfs.__all__:
        assert getattr(bfs, name, None) is not None, name


def test_traversal_spec_fields_are_frozen():
    fields = tuple(f.name for f in
                   dataclasses.fields(bfs.TraversalSpec))
    assert fields == EXPECTED_SPEC_FIELDS, (
        "TraversalSpec fields changed; if deliberate, update "
        "tests/test_api_surface.py, TraversalSpec.field_names "
        "consumers, and the README migration table")
    assert bfs.TraversalSpec.field_names() == EXPECTED_SPEC_FIELDS


def test_every_spec_field_defaults_to_auto():
    spec = bfs.TraversalSpec()
    assert all(getattr(spec, f) == "auto" for f in EXPECTED_SPEC_FIELDS)


def test_policy_registry_is_frozen():
    assert tuple(sorted(bfs.POLICIES)) == (
        "beamer", "paper_layers", "threshold_simd", "topdown")
