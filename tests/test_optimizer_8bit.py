"""int8 block-quantized AdamW: accuracy + structure tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train import optimizer as opt


def test_quantize_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 256)),
                    jnp.float32)
    qs = opt._quantize(x)
    assert qs["q"].dtype == jnp.int8
    assert qs["s"].shape == (4, 2)
    back = opt._dequantize(qs, x.shape)
    assert float(jnp.abs(back - x).max()) < float(jnp.abs(x).max()) / 100


def test_quantize_nonblock_fallback():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(7,)),
                    jnp.float32)
    qs = opt._quantize(x)
    back = opt._dequantize(qs, x.shape)
    assert float(jnp.abs(back - x).max()) < float(jnp.abs(x).max()) / 50


def test_8bit_tracks_fp32_adamw():
    """Quadratic optimization: int8 state tracks fp32 trajectories."""
    acfg = opt.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                           total_steps=100)
    target = jnp.asarray(np.random.default_rng(2).normal(size=(2, 128)),
                         jnp.float32)
    p32 = {"x": jnp.zeros((2, 128))}
    p8 = {"x": jnp.zeros((2, 128))}
    s32 = opt.init(p32)
    s8 = opt.init_8bit(p8)
    loss = lambda p: jnp.sum((p["x"] - target) ** 2)
    for _ in range(60):
        g32 = jax.grad(loss)(p32)
        p32, s32, _ = opt.update(acfg, p32, g32, s32)
        g8 = jax.grad(loss)(p8)
        p8, s8, _ = opt.update_8bit(acfg, p8, g8, s8)
    l32, l8 = float(loss(p32)), float(loss(p8))
    assert l8 < 0.15 * float(jnp.sum(target ** 2)), l8  # converging
    assert l8 < max(4 * l32, 1.0), (l8, l32)            # tracks fp32


def test_8bit_state_is_small():
    p = {"w": jnp.zeros((256, 512), jnp.float32)}
    s8 = opt.init_8bit(p)
    q_bytes = s8["m"]["w"]["q"].size  # int8
    s_bytes = s8["m"]["w"]["s"].size * 4
    assert q_bytes + s_bytes < 0.3 * p["w"].size * 4
