"""Distributed BFS: semantics on a 1-device mesh in-process, true
multi-device semantics in a subprocess with 8 forced host devices
(keeping this process at 1 device, as the dry-run isolation requires).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import csr as csr_mod
from repro.core import rmat
from repro.core.bfs_distributed import (partition_csr, partition_sizes,
                                        run_bfs_distributed)
from repro.core.bfs_serial import bfs_serial
from repro.core.validate import validate


@pytest.fixture(scope="module")
def g10():
    return csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(2), scale=10, edgefactor=16))


def test_partition_covers_all_edges(g10):
    rows_sh, colstarts_sh = partition_csr(g10, 4)
    rows_sh, colstarts_sh = np.asarray(rows_sh), np.asarray(colstarts_sh)
    total = sum(int(colstarts_sh[d, -1]) for d in range(4))
    assert total == g10.n_edges
    # every device's real edges match the global CSR slice
    v_loc = colstarts_sh.shape[1] - 1
    cs = np.asarray(g10.colstarts)
    rows = np.asarray(g10.rows)
    for d in range(4):
        lo, hi = d * v_loc, min((d + 1) * v_loc, g10.n_vertices)
        if lo >= g10.n_vertices:
            continue
        want = rows[cs[lo]:cs[hi]]
        np.testing.assert_array_equal(rows_sh[d, :len(want)], want)


def test_partition_capacity_is_measured_max(g10):
    rows_sh, colstarts_sh = partition_csr(g10, 8)
    colstarts_sh = np.asarray(colstarts_sh)
    real_max = max(int(colstarts_sh[d, -1]) for d in range(8))
    e_loc = rows_sh.shape[1]
    assert e_loc >= real_max and e_loc - real_max < 128
    # padding slots carry the sentinel
    for d in range(8):
        n = int(colstarts_sh[d, -1])
        assert (np.asarray(rows_sh[d, n:]) == g10.n_vertices).all()


def test_partition_sizes_aligned():
    v_loc, e_loc = partition_sizes(1 << 20, 2 * 16 << 20, 256)
    assert v_loc % 128 == 0 and e_loc % 128 == 0
    assert v_loc * 256 >= 1 << 20


def test_distributed_single_device_matches_oracle(g10):
    mesh = jax.make_mesh((1,), ("x",))
    parent, layers = run_bfs_distributed(g10, 11, mesh)
    p = np.asarray(parent)
    p = np.where(p >= g10.n_vertices, -1, p)
    _, ref_depth = bfs_serial(np.asarray(g10.rows),
                              np.asarray(g10.colstarts),
                              g10.n_vertices, 11)
    res = validate(g10, p, 11, reference_depth=ref_depth)
    assert res.ok, res
    assert int(layers) == int(ref_depth.max()) + 1


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from repro.core import csr as csr_mod, rmat
    from repro.core.bfs_distributed import run_bfs_distributed
    from repro.core.bfs_serial import bfs_serial
    from repro.core.validate import validate

    assert len(jax.devices()) == 8
    g = csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(2), scale=10, edgefactor=16))
    for mesh_shape, names in [((8,), ("x",)), ((2, 4), ("a", "b"))]:
        mesh = jax.make_mesh(mesh_shape, names)
        parent, layers = run_bfs_distributed(g, 11, mesh)
        p = np.asarray(parent)
        p = np.where(p >= g.n_vertices, -1, p)
        _, ref = bfs_serial(np.asarray(g.rows), np.asarray(g.colstarts),
                            g.n_vertices, 11)
        res = validate(g, p, 11, reference_depth=ref)
        assert res.ok, (mesh_shape, res)
    print("MULTIDEV_OK")
""")


def test_distributed_eight_devices_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "MULTIDEV_OK" in out.stdout, out.stderr[-3000:]


def test_distributed_deterministic_tree(g10):
    """min-parent merge => identical tree across runs (unlike 1-chip)."""
    mesh = jax.make_mesh((1,), ("x",))
    p1, _ = run_bfs_distributed(g10, 7, mesh)
    p2, _ = run_bfs_distributed(g10, 7, mesh)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
