"""TraversalSpec + plan/compile/run API tests (ISSUE 5).

Covers: plan-cache identity and the ≤1-trace-per-(geometry, resolved
spec) guarantee, spec round-trips, deterministic ``"auto"``
resolution (incl. the tile-default-drift regression: plan and the
legacy entries must pick the SAME tile), the single validation home,
legacy shims routing through the plan cache, the distributed spec
path, and the serve engine's deque under a many-query load.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.bfs as bfs
import repro.api.plan as api_plan
from repro.core import csr as csr_mod
from repro.core import engine, rmat
from repro.core.bfs_parallel import parents_graph500
from repro.core.bfs_serial import bfs_serial
from repro.core.validate import validate
from repro.formats import registry
from repro.formats.csr_format import CsrFormat


@pytest.fixture(scope="module")
def g():
    return csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(3), scale=9, edgefactor=8))


@pytest.fixture(scope="module")
def g10():
    return csr_mod.from_edges(
        rmat.generate(jax.random.PRNGKey(5), scale=10, edgefactor=8))


def check_oracle(csr, parent_g500, root):
    _, ref_depth = bfs_serial(np.asarray(csr.rows),
                              np.asarray(csr.colstarts),
                              csr.n_vertices, root)
    res = validate(csr, parent_g500, root, reference_depth=ref_depth)
    assert res.ok, res


# ---------------------------------------------------------------------------
# plan -> run correctness
# ---------------------------------------------------------------------------

def test_plan_run_matches_oracle(g):
    ct = bfs.plan(g)
    res = ct.run(17)
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)), 17)
    assert ct.resolved.is_resolved
    assert ct.stats(res)[0].frontier_vertices == 1


def test_plan_run_batched(g):
    roots = [3, 7, 17, 100]
    res = bfs.plan(g).run_batched(roots)
    assert res.state.parent.shape[0] == len(roots)
    for b, root in enumerate(roots):
        st = engine.BfsState(res.state.frontier[b], res.state.visited[b],
                             res.state.parent[b], res.state.layer)
        check_oracle(g, np.asarray(parents_graph500(st, g.n_vertices)),
                     root)


@pytest.mark.parametrize("fmt_name", ["csr", "sell", "bitmap"])
def test_plan_every_format(g, fmt_name):
    fmt = registry.get(fmt_name).from_graph(g)
    res = bfs.plan(fmt, bfs.TraversalSpec(policy="threshold_simd")).run(17)
    check_oracle(g, np.asarray(parents_graph500(res.state,
                                                g.n_vertices)), 17)


def test_plan_batch_width_pads_to_one_trace(g):
    # the executable cache is process-global: drop hits from earlier
    # test modules so the traces counter starts at zero here
    api_plan.clear_cache()
    ct = bfs.plan(g, bfs.TraversalSpec(policy="topdown"), batch=4)
    r1 = ct.run_batched([3, 7])           # padded to 4
    r2 = ct.run_batched([3, 7, 17, 100])  # exactly 4
    assert ct.traces == 1
    assert r1.state.parent.shape[0] == 2
    np.testing.assert_array_equal(np.asarray(r1.state.parent),
                                  np.asarray(r2.state.parent[:2]))
    # the fixed width is a contract, not a hint
    with pytest.raises(ValueError, match="exceeds"):
        ct.run_batched([1, 2, 3, 4, 5])
    with pytest.raises(ValueError, match="at least one root"):
        ct.run_batched([])


# ---------------------------------------------------------------------------
# Plan cache: identity, trace counts, misses
# ---------------------------------------------------------------------------

def test_plan_cache_one_trace_across_many_runs(g):
    api_plan.clear_cache()
    spec = bfs.TraversalSpec(policy="topdown")
    ct = bfs.plan(g, spec)
    for root in range(10):
        ct.run(root)
    assert ct.traces == 1, "re-running one plan must not re-trace"
    # re-planning the same geometry+spec reuses the executable…
    ct2 = bfs.plan(g, spec)
    assert ct2.executable is ct.executable
    ct2.run(11)
    assert ct.traces == 1
    info = api_plan.cache_info()
    assert info["size"] == 1 and info["hits"] == 1


def test_plan_cache_misses_on_spec_and_geometry(g, g10):
    api_plan.clear_cache()
    a = bfs.plan(g, bfs.TraversalSpec(policy="topdown"))
    b = bfs.plan(g, bfs.TraversalSpec(policy="topdown",
                                      pipeline="materialized"))
    c = bfs.plan(g10, bfs.TraversalSpec(policy="topdown"))
    assert a.executable is not b.executable
    assert a.executable is not c.executable
    assert api_plan.cache_info()["size"] == 3


def test_legacy_shims_share_the_plan_cache(g):
    """traverse/traverse_arrays/traverse_format with equal knobs land
    on ONE cached executable — including the same resolved tile (the
    ISSUE 5 tile-default-drift regression: traverse_format used to
    default tile=1, traverse_arrays 1024)."""
    api_plan.clear_cache()
    fmt = CsrFormat.from_csr(g)
    spec = bfs.TraversalSpec(policy="topdown")
    ct = bfs.plan(fmt, spec)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        r1 = engine.traverse(g, 17)
        r2 = engine.traverse_format(fmt, jnp.asarray([17], jnp.int32))
        r3 = engine.traverse_arrays(g.colstarts, g.rows,
                                    jnp.asarray([17], jnp.int32),
                                    n_vertices=g.n_vertices)
    info = api_plan.cache_info()
    assert info["size"] == 1, (
        f"legacy defaults drifted from plan(): {info}")
    assert ct.resolved.tile == fmt.resolve_tile(None)
    np.testing.assert_array_equal(np.asarray(r1.state.parent),
                                  np.asarray(r2.state.parent[0]))
    np.testing.assert_array_equal(np.asarray(r1.state.parent),
                                  np.asarray(r3.state.parent[0]))


def test_loose_knob_form_warns(g):
    with pytest.warns(DeprecationWarning, match="loose-knob"):
        engine.traverse(g, 17, policy=engine.TopDown())
    with pytest.raises(ValueError, match="not both"):
        engine.traverse(g, 17, tile=256,
                        spec=bfs.TraversalSpec(policy="topdown"))


# ---------------------------------------------------------------------------
# Spec: round-trip, determinism, validation
# ---------------------------------------------------------------------------

def test_spec_round_trips_through_dicts(g):
    import json
    for spec in (bfs.TraversalSpec(),
                 bfs.TraversalSpec(policy="beamer", tile=512),
                 bfs.TraversalSpec(
                     policy=engine.PaperLiteralLayers((1, 2)),
                     pipeline="materialized", packed=False,
                     prefetch_depth=2, max_layers=96, merge="owner"),
                 bfs.plan(g).resolved):
        wire = json.loads(json.dumps(spec.to_dict()))
        assert bfs.TraversalSpec.from_dict(wire) == spec


def test_auto_resolution_is_deterministic(g):
    a = bfs.TraversalSpec().resolve(g)
    b = bfs.TraversalSpec().resolve(g)
    assert a == b and a.is_resolved
    # the tile auto is the committed-BENCH-backed format rule
    assert a.tile == CsrFormat.from_csr(g).resolve_tile(None)
    # every field is concrete
    assert all(v != "auto" for v in a.to_dict().values())


def test_spec_validation_rejects_bad_values(g):
    with pytest.raises(ValueError, match="pipeline"):
        bfs.TraversalSpec(pipeline="bogus").validate()
    with pytest.raises(ValueError, match="algorithm"):
        bfs.TraversalSpec(algorithm="scalarish").validate()
    with pytest.raises(ValueError, match="merge"):
        bfs.TraversalSpec(merge="gossip").validate()
    with pytest.raises(ValueError, match="policy"):
        bfs.TraversalSpec(policy="dfs").validate()
    with pytest.raises(ValueError, match="tile"):
        bfs.TraversalSpec(tile=0).validate()
    with pytest.raises(ValueError, match="prefetch_depth"):
        bfs.TraversalSpec(prefetch_depth=-1).validate()
    with pytest.raises(ValueError, match="max_layers"):
        bfs.TraversalSpec(max_layers=0).validate()
    with pytest.raises(ValueError, match="unknown TraversalSpec"):
        bfs.TraversalSpec.from_dict({"tiles": 4})


def test_prefetch_on_bitmap_rejected_in_one_place(g):
    fmt = registry.get("bitmap").from_graph(g)
    spec = bfs.TraversalSpec(prefetch_depth=2)
    with pytest.raises(ValueError, match="bitmap"):
        spec.resolve(fmt)
    with pytest.raises(ValueError, match="bitmap"):
        bfs.plan(fmt, spec)
    # …and the same spec is fine on a streamed layout
    bfs.plan(CsrFormat.from_csr(g), spec).run(17)


def test_policy_string_names_resolve(g):
    for name, cls in bfs.POLICIES.items():
        r = bfs.TraversalSpec(policy=name).resolve(g)
        assert isinstance(r.policy, cls)


def test_make_steps_requires_resolved_spec(g):
    fmt = CsrFormat.from_csr(g)
    with pytest.raises(ValueError, match="resolve"):
        fmt.make_steps(bfs.TraversalSpec())      # 'auto' fields left
    fmt.make_steps(bfs.TraversalSpec().resolve(fmt))   # fine


def test_merge_flavour_shares_single_chip_executable(g):
    """merge is mesh-only: specs differing only in merge must share
    one single-chip trace."""
    api_plan.clear_cache()
    a = bfs.plan(g, bfs.TraversalSpec(policy="topdown",
                                      merge="allreduce"))
    b = bfs.plan(g, bfs.TraversalSpec(policy="topdown", merge="owner"))
    assert a.executable is b.executable
    assert api_plan.cache_info()["size"] == 1


def test_mesh_bound_plan_rejects_single_chip_surfaces(g):
    mesh = jax.make_mesh((1,), ("x",))
    ct = bfs.plan(g, mesh=mesh)
    with pytest.raises(NotImplementedError):
        ct.run_batched([3, 7])
    with pytest.raises(NotImplementedError):
        ct.lower()
    # fields the fixed per-chip program cannot honor are flagged…
    with pytest.warns(UserWarning, match="ignored"):
        bfs.plan(g, bfs.TraversalSpec(pipeline="materialized"),
                 mesh=mesh)
    # …but a fully-resolved spec passes silently (its concrete fields
    # are resolution artifacts, not user intent)
    resolved = bfs.plan(g).resolved
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        bfs.plan(g, resolved, mesh=mesh)


# ---------------------------------------------------------------------------
# layer_step + serve engine (deque under many-query load)
# ---------------------------------------------------------------------------

def test_compiled_layer_step_advances_one_layer(g):
    """Ticking layer_step to exhaustion yields a valid tree with the
    same reached set as the whole-search run (parent identities may
    differ: the tick is the SIMD step, the TopDown run the scalar
    one)."""
    ct = bfs.plan(g, bfs.TraversalSpec(policy="topdown"))
    full = ct.run(17)
    f, v, p = engine._init_batched(jnp.asarray([17], jnp.int32),
                                   g.n_vertices, g.n_vertices_padded)
    st = engine.BfsState(f, v, p, jnp.int32(0))
    for _ in range(int(full.state.layer)):
        st = ct.layer_step(st)
    assert int(st.layer) == int(full.state.layer)
    got = np.asarray(st.parent[0][:g.n_vertices])
    ref = np.asarray(full.state.parent[:g.n_vertices])
    np.testing.assert_array_equal(got < g.n_vertices,
                                  ref < g.n_vertices)
    check_oracle(g, np.where(got >= g.n_vertices, -1, got), 17)


def test_serve_engine_spec_and_deque_many_queries(g):
    from repro.serve.graph_engine import BfsQuery, GraphEngine
    eng = GraphEngine(g, batch_slots=4, spec=bfs.TraversalSpec())
    # the tick is policy-free: an explicitly-set policy is flagged,
    # the neutral topdown (name or object) is not
    with pytest.warns(UserWarning, match="policy-free"):
        GraphEngine(g, batch_slots=2,
                    spec=bfs.TraversalSpec(policy="beamer"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        GraphEngine(g, batch_slots=2,
                    spec=bfs.TraversalSpec(policy="topdown"))
    n = 40                         # >> slots: continuous refill churn
    for uid in range(n):
        eng.submit(BfsQuery(uid=uid, root=uid % 64))
    eng.run_until_done()
    assert len(eng.finished) == n
    assert not eng.queue
    by_uid = {q.uid: q for q in eng.finished}
    assert set(by_uid) == set(range(n))
    # same root => same tree, regardless of slot/tick interleaving
    ref = {}
    for uid, q in by_uid.items():
        r = uid % 64
        if r in ref:
            np.testing.assert_array_equal(q.parent, ref[r])
        else:
            ref[r] = q.parent
    check_oracle(g, by_uid[3].parent, 3)
    # the engine stores ONE CompiledTraversal, not loose attributes
    assert eng.compiled.resolved is eng.resolved
    assert eng.algorithm == "simd" and eng.max_layers == 64


def test_distributed_spec_path_matches_legacy(g):
    from repro.core.bfs_distributed import run_bfs_distributed
    mesh = jax.make_mesh((1,), ("x",))
    p_spec, l_spec = run_bfs_distributed(
        g, 11, mesh, spec=bfs.TraversalSpec())
    p_leg, l_leg = run_bfs_distributed(g, 11, mesh, merge="packed")
    np.testing.assert_array_equal(np.asarray(p_spec), np.asarray(p_leg))
    assert int(l_spec) == int(l_leg)
    with pytest.raises(ValueError, match="not both"):
        run_bfs_distributed(g, 11, mesh, merge="owner",
                            spec=bfs.TraversalSpec())
    # fields the fixed per-chip program cannot honor are flagged
    with pytest.warns(UserWarning, match="ignored"):
        run_bfs_distributed(g, 11, mesh,
                            spec=bfs.TraversalSpec(packed=False))


def test_plan_mesh_binding_routes_distributed(g):
    mesh = jax.make_mesh((1,), ("x",))
    ct = bfs.plan(g, mesh=mesh)
    assert ct.executable is None and ct.traces == 0
    parent, layers = ct.run(11)
    assert ct._partition is not None
    p2, _ = ct.run(11)            # partition reused, same result
    np.testing.assert_array_equal(np.asarray(parent), np.asarray(p2))
    p = np.asarray(parent)
    # the distributed tree resolves parents by min (deterministic), the
    # single-chip engine by racy scatter — compare the reached set and
    # validate the tree, not parent identities
    ref = bfs.plan(g, bfs.TraversalSpec(policy="topdown")).run(11)
    ref_p = np.asarray(ref.state.parent[:g.n_vertices])
    np.testing.assert_array_equal(p < g.n_vertices,
                                  ref_p < g.n_vertices)
    check_oracle(g, np.where(p >= g.n_vertices, -1, p), 11)
